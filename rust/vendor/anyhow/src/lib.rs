//! Minimal in-tree stand-in for the `anyhow` crate (this build box is
//! offline — same policy as `fat::util`'s json/cli/bench shims).
//!
//! Implements exactly the API subset the `fat` crate uses: [`Result`],
//! [`Error`], [`anyhow!`], [`bail!`], [`ensure!`] and the [`Context`]
//! extension trait. Error values are formatted messages with a flat
//! `context: cause` chain; backtraces and downcasting are not provided.
//! Swapping in the real crate is a one-line change in `rust/Cargo.toml`.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A formatted, context-chained error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prefix the error with higher-level context.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` —
// exactly like the real anyhow — so this blanket conversion cannot
// overlap the reflexive `From<Error> for Error` that `?` relies on.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Attach context to a fallible value (`Result` or `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn question_mark_passes_through_own_error() {
        fn inner() -> Result<()> {
            bail!("boom {}", 1)
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let v = 7;
        let e = anyhow!("inline {v}");
        assert_eq!(e.to_string(), "inline 7");
        let e = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn ensure_and_context() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "need positive, got {x}");
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "need positive, got -1");
        let r: Result<()> = io_fail().with_context(|| format!("loading {}", "cfg"));
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("loading cfg: "), "{msg}");
        let o: Option<i32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}
