//! `fat` — the FAT quantization pipeline launcher, on the staged
//! `QuantSession` → `Int8Engine` API.
//!
//! Runs with or without AOT artifacts: backend resolution picks the
//! native FP32 executor when `artifacts/` is absent, so a bare
//! `cargo run --release -- --epochs 1` executes the full calibrate →
//! fine-tune → export → int8 pipeline on a builtin model.
//!
//! Usage:
//!   fat [pipeline] [--config run.toml] [--model M] [--mode MODE]
//!                [--calibrator C] [--epochs N] [--max-steps N]
//!                [--val N] [--dws]
//!   fat info [--fatm PATH]
//!   fat quantize --model mnas_mini_10 --mode asym_vector [--dws]
//!                [--calibrator max|p9999|kl] [--val N]
//!   fat eval-int8 --model mnas_mini_10 --mode sym_vector [--val N]
//!                 [--threads N]
//!   fat serve-bench [--model tiny_cnn] [--clients 1,4,16,64]
//!                 [--requests N] [--max-batch N] [--max-wait-us N]
//!                 [--threads N] [--json PATH]
//!                 [--transport thread|socket|both]
//!   fat export [--models M1,M2] [--out DIR] [--mode MODE]
//!                 [--calibrator C] [--calib N]
//!                 [--isa scalar|sse2|avx2|avx512vnni]
//!                 [--tune off|capped|full]
//!   fat perf-gate --baseline F --current F [--max-regress-pct F]
//!                 [--inject-slowdown-pct F]
//!   fat perf-report --json F
//!   fat serve [--models M1,M2|path.fatm|artifact-dir]
//!                 [--addr 127.0.0.1:8080] [--mode MODE]
//!                 [--threads N] [--max-batch N] [--max-wait-us N]
//!                 [--max-conns N] [--max-inflight N] [--drain-secs N]
//!                 [--reload-secs N]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::evaluate::int8_accuracy;
use fat::coordinator::PipelineConfig;
use fat::int8::serve::EngineOptions;
use fat::model::ModelStore;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

const USAGE: &str = "\
fat — FAT: fast adjustable threshold quantization

Commands (default: pipeline):
  pipeline                     full FAT pipeline (calibrate→finetune→int8)
    [--config F] [--model M] [--mode MODE] [--calibrator C] [--epochs N]
    [--max-steps N] [--val N] [--lr F] [--dws]
  info                         list models + FP accuracies; with
    --fatm PATH, inspect a compiled artifact instead (header, etag,
    packing ISA, tuned per-layer GEMM blocking table)
  quantize                     calibration-only quantization + accuracy
    --model M --mode MODE --calib N --val N [--dws] [--calibrator C]
  eval-int8                    int8 engine vs fake-quant agreement
    --model M --mode MODE [--val N] [--threads N]
  serve-bench                  concurrent-client serving throughput:
    micro-batched vs unbatched engine, p50/p95/p99 latency, bit-exact
    check vs the reference interpreter, BENCH_serve.json log
    [--model M] [--clients 1,4,16,64] [--requests N] [--max-batch N]
    [--max-wait-us N] [--threads N] [--json PATH]
    [--transport thread|socket|both]  (socket drives a live loopback
    server over HTTP; both also prints loopback-vs-inprocess speedups)
  export                       compile models to .fatm artifacts:
    calibrate + quantize once, write the compiled plan + prepacked
    panels to <out>/<model>.fatm for zero-copy mmap serving cold-start
    [--models M1,M2] [--out DIR (default <artifacts>/compiled)]
    [--mode MODE] [--calibrator C] [--calib N]
    [--isa scalar|sse2|avx2|avx512vnni]
    [--tune off|capped|full (default full: autotune GEMM blockings per
    layer shape and persist the table in the .fatm)]
  perf-gate                    perf-trajectory regression gate: compare
    a fresh BENCH_*.json against a committed baseline snapshot, exit 1
    when any metric regresses past the threshold
    --baseline F --current F [--max-regress-pct F (default 15)]
    [--inject-slowdown-pct F (CI negative self-test)]
  perf-report                  render a BENCH_*.json as a markdown table
    --json F
  serve                        socket server over the int8 engine:
    HTTP/1.1 + binary frame protocol on one port, multi-model routing,
    admission control, /stats + /models, graceful drain on
    SIGINT/SIGTERM. --models items may be builtin/artifact model names
    (calibrate + export in-process), paths to compiled .fatm files
    (zero-copy mmap load), or directories of .fatm artifacts (load all;
    with --reload-secs N, hot-reload entries whose content etag changed —
    inotify-triggered on Linux with an N-second rescan heartbeat, pure
    N-second polling elsewhere)
    [--models M1,M2|path.fatm|dir] [--addr 127.0.0.1:8080] [--mode MODE]
    [--threads N] [--max-batch N] [--max-wait-us N] [--max-conns N]
    [--max-inflight N] [--read-timeout-ms N] [--drain-secs N]
    [--reload-secs N]

Modes: sym_scalar | sym_vector | asym_scalar | asym_vector
  Suffixes (native backend): _pow2 snaps every scale to a power of two
  and exports shift-only requant tables; _w4 trains against the int4
  weight grid and exports nibble-packed panels. Compose in any order:
  sym_vector_pow2_w4
Calibrators: max (default) | p99 | p999 | p9999 | kl
Global: --artifacts DIR (default ./artifacts or $FAT_ARTIFACTS)
        FAT_BACKEND=auto|native|artifact (float-stage backend)
        FAT_MMAP=off (read .fatm artifacts onto the heap instead of mmap)
        FAT_ISA=scalar|sse2|avx2|avx512vnni (cap the kernel ISA; clamped
        to what the host supports)
        FAT_TUNE=off|capped|full (autotune GEMM blockings when building
        models in-process; default off — `fat export` tunes regardless)
        FAT_FUSED=off (force the staged im2col conv path even on layers
        whose fused implicit-GEMM bit is set; default on)

Without an artifacts/ directory everything runs on the native FP32
backend over the builtin model zoo (deterministic untrained weights):
the pipeline mechanics are identical, only the accuracy ladder needs
the pretrained artifact models.
";

fn main() -> Result<()> {
    let args = Args::parse(&["dws", "help"]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let rt = Arc::new(Runtime::cpu()?);
    let reg = Arc::new(Registry::new(rt));

    // `fat --epochs 1` (no subcommand) runs the full pipeline.
    match args.subcommand.as_deref().unwrap_or("pipeline") {
        "info" => {
            if let Some(p) = args.get("fatm") {
                cmd_info_fatm(p)?;
                return Ok(());
            }
            let listed = if artifacts.join("models").exists() {
                let names = ModelStore::list(&artifacts)?;
                for name in &names {
                    let store = ModelStore::open(&artifacts, name)?;
                    let sites = store.sites()?;
                    println!(
                        "{name}: {} quant sites, FP pretrain acc {:.2}% \
                         (artifacts)",
                        sites.sites.len(),
                        sites.val_acc_fp_pretrain * 100.0
                    );
                }
                names
            } else {
                vec![]
            };
            for name in fat::model::builtin::names() {
                if listed.iter().any(|l| l == name) {
                    continue;
                }
                let (g, sites, _) = fat::model::builtin::load(name)?;
                println!(
                    "{name}: {} quant sites, {} nodes (builtin, native \
                     backend, untrained)",
                    sites.sites.len(),
                    g.nodes.len()
                );
            }
        }
        "quantize" => {
            let model = args.get_or("model", "mobilenet_v2_mini");
            let spec = QuantSpec::parse(
                args.get_or("mode", "sym_scalar"),
                args.get_or("calibrator", "max"),
            )?;
            let calib = args.usize_or("calib", 100);
            let val = args.usize_or("val", 0);
            // scope the session so mutating stage transitions below hold
            // the only reference to the model state (no copy-on-write)
            let mut cal = QuantSession::open(reg, &artifacts, model)?
                .calibrate(CalibOpts::images(calib))?;
            if args.flag("dws") {
                cal = cal.dws_rescale()?;
                for r in cal.rescale_reports() {
                    println!(
                        "  dws {}→{}: spread {:.1}→{:.1} ({} locked/{})",
                        r.dw, r.conv, r.spread_before, r.spread_after,
                        r.locked, r.channels
                    );
                }
            }
            let fp = cal.fp_accuracy(val)?;
            let q = cal.identity(&spec)?.quant_accuracy(val)?;
            println!(
                "{model} [{}/{}] no-finetune: FP {:.2}%  quant {:.2}%  (drop {:.2})",
                spec.mode().name(),
                spec.calibrator.name(),
                fp * 100.0,
                q * 100.0,
                (fp - q) * 100.0
            );
        }
        "pipeline" => {
            let mut cfg = match args.get("config") {
                Some(p) => PipelineConfig::load(p)?,
                None => PipelineConfig::default(),
            };
            if let Some(m) = args.get("model") {
                cfg.model = m.to_string();
            }
            if let Some(m) = args.get("mode") {
                cfg.mode = m.to_string();
            }
            if let Some(c) = args.get("calibrator") {
                cfg.calibrator = c.to_string();
            }
            if let Some(e) = args.get("epochs") {
                cfg.epochs = e.parse()?;
            }
            if let Some(s) = args.get("max-steps") {
                cfg.max_steps = s.parse()?;
            }
            if let Some(v) = args.get("val") {
                cfg.val_images = v.parse()?;
            }
            if let Some(lr) = args.get("lr") {
                cfg.lr = lr.parse()?;
            }
            cfg.dws_rescale |= args.flag("dws");
            run_pipeline(&reg, &artifacts, &cfg)?;
        }
        "eval-int8" => {
            let model = args.get_or("model", "mnas_mini_10");
            let spec = QuantSpec::parse(
                args.get_or("mode", "sym_vector"),
                args.get_or("calibrator", "max"),
            )?;
            let val = args.usize_or("val", 500);
            let opts = match args.get("threads") {
                Some(t) => EngineOptions::threads(t.parse()?),
                None => EngineOptions::default(),
            };
            let th = QuantSession::open(reg, &artifacts, model)?
                .calibrate(CalibOpts::images(100))?
                .identity(&spec)?;
            let fake = th.quant_accuracy(val)?;
            let engine = th.serve(opts)?;
            let t0 = std::time::Instant::now();
            let engine_acc = int8_accuracy(&engine, val)?;
            let dt = t0.elapsed();
            println!(
                "{model} [{}]: fake-quant {:.2}%  int8-engine {:.2}%  \
                 ({} int8 param bytes, {} worker(s), {:.1} img/s)",
                spec.mode().name(),
                fake * 100.0,
                engine_acc * 100.0,
                engine.param_bytes(),
                engine.threads(),
                val as f64 / dt.as_secs_f64()
            );
        }
        "serve-bench" => {
            let model = args.get_or("model", "tiny_cnn");
            let clients: Vec<usize> = args
                .get_or("clients", "1,4,16,64")
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|&c| c >= 1)
                .collect();
            anyhow::ensure!(
                !clients.is_empty(),
                "serve-bench: --clients must list positive counts"
            );
            let requests = args.usize_or("requests", 256);
            let max_batch = args.usize_or("max-batch", 16).max(2);
            let max_wait_us = args.usize_or("max-wait-us", 200) as u64;
            let threads = match args.get("threads") {
                Some(t) => Some(t.parse()?),
                None => None,
            };
            let transport = args.get_or("transport", "thread");
            anyhow::ensure!(
                matches!(transport, "thread" | "socket" | "both"),
                "serve-bench: --transport must be thread, socket or both"
            );
            serve_bench(
                &reg, &artifacts, model, &clients, requests, max_batch,
                max_wait_us, threads, args.get("json"), transport,
            )?;
        }
        "export" => {
            cmd_export(&reg, &artifacts, &args)?;
        }
        "perf-gate" => {
            cmd_perf_gate(&args)?;
        }
        "perf-report" => {
            let path = args.get("json").ok_or_else(|| {
                anyhow::anyhow!("perf-report: --json PATH is required")
            })?;
            let doc = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            print!("{}", fat::util::gate::markdown_table(&doc)?);
        }
        "serve" => {
            cmd_serve(&reg, &artifacts, &args)?;
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Deterministic synthetic client image: every client hammers its own
/// fixed pixels, so each response has one precomputable oracle row.
fn synth_image(per_img: usize, client: usize) -> Vec<u8> {
    (0..per_img)
        .map(|i| ((i * 31 + client * 97 + 13) % 256) as u8)
        .collect()
}

/// Drive batched-vs-unbatched serving with N concurrent closed-loop
/// clients; print throughput + latency percentiles, verify every
/// response bit-exactly against `run_quant_ref`, and write the
/// machine-readable `BENCH_serve.json`. `transport` picks in-process
/// engine handles (`thread`), a live loopback HTTP server (`socket`),
/// or `both` — one driver and one oracle either way
/// (`int8::serve::drive_with`).
#[allow(clippy::too_many_arguments)]
fn serve_bench(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    model: &str,
    clients: &[usize],
    requests: usize,
    max_batch: usize,
    max_wait_us: u64,
    threads: Option<usize>,
    json: Option<&str>,
    transport: &str,
) -> Result<()> {
    use fat::int8::serve::{drive_clients, drive_with};
    use fat::int8::{BatchOptions, Int8Engine, QTensor};
    use fat::net::{HttpClient, ModelRegistry, Server, ServerOptions};
    use fat::util::bench::{percentiles, report_speedup, BenchLog};

    let do_thread = transport != "socket";
    let do_socket = transport != "thread";

    let th = QuantSession::open(reg.clone(), artifacts, model)?
        .calibrate(CalibOpts::images(16))?
        .identity(&QuantSpec::default())?;
    let qm = th.export()?;
    let sh = qm
        .graph
        .nodes
        .iter()
        .find(|n| n.op == fat::model::Op::Input)
        .and_then(|n| n.input_shape.clone())
        .ok_or_else(|| anyhow::anyhow!("{model}: no shaped input node"))?;
    let per_img: usize = sh.iter().product();

    let base = match threads {
        Some(t) => EngineOptions::threads(t),
        None => EngineOptions::default(),
    };
    let unbatched = Int8Engine::new(qm.clone(), base);
    let batched = Int8Engine::new(
        qm.clone(),
        base.with_batch(BatchOptions { max_batch, max_wait_us }),
    );
    println!(
        "serve-bench: {model} [{} worker(s)] micro-batch \
         max_batch={max_batch} max_wait_us={max_wait_us}",
        unbatched.threads()
    );

    // Per-client deterministic images and their oracle logits from the
    // scalar/serial reference interpreter (the engine's bit-exactness
    // anchor).
    let max_clients = clients.iter().copied().max().unwrap_or(1);
    let images: Vec<Vec<u8>> =
        (0..max_clients).map(|c| synth_image(per_img, c)).collect();
    let mut oracle: Vec<Vec<f32>> = Vec::with_capacity(max_clients);
    for px in &images {
        let x: Vec<f32> = px.iter().map(|&p| p as f32 / 255.0).collect();
        let q = QTensor::quantize(
            vec![1, sh[0], sh[1], sh[2]],
            &x,
            qm.input_qp,
        );
        oracle.push(qm.run_quant_ref(q)?.dequantize());
    }

    // Socket transport: both engines behind one live loopback server,
    // routed by model name, driven over keep-alive HTTP.
    let server = if do_socket {
        let registry = ModelRegistry::new();
        registry.insert("unbatched", unbatched.clone());
        registry.insert("batched", batched.clone());
        let srv =
            Server::bind("127.0.0.1:0", registry, ServerOptions::default())?;
        println!("serve-bench: loopback server on {}", srv.local_addr());
        Some(srv)
    } else {
        None
    };
    let sock_addr = server.as_ref().map(|s| s.local_addr());

    let mut log = BenchLog::default();
    for &c in clients {
        let per_client = (requests / c).max(1);
        let stats0 = batched.batcher_stats().unwrap_or((0, 0, 0));
        let mut thread_secs = [0.0f64; 2];
        let mut socket_secs = [0.0f64; 2];
        for (mode_i, (name, engine)) in
            [("unbatched", &unbatched), ("batched", &batched)]
                .into_iter()
                .enumerate()
        {
            if do_thread {
                let rep = drive_clients(
                    engine,
                    c,
                    per_client,
                    |i| images[i].clone(),
                    |i| Some(oracle[i].clone()),
                )?;
                let mut lat = rep.latencies_secs.clone();
                let p = percentiles(&mut lat);
                let rps = rep.requests as f64 / rep.wall_secs.max(1e-12);
                println!(
                    "BENCH serve_{name}_c{c} rps={rps:.1} p50_ms={:.3} \
                     p95_ms={:.3} p99_ms={:.3} requests={}",
                    p.p50 * 1e3,
                    p.p95 * 1e3,
                    p.p99 * 1e3,
                    rep.requests
                );
                log.add_latency(
                    "serve",
                    name,
                    c,
                    engine.threads(),
                    rep.requests,
                    rep.wall_secs,
                    p,
                );
                thread_secs[mode_i] = rep.wall_secs / rep.requests as f64;
            }
            if let Some(addr) = sock_addr {
                let rep = drive_with(
                    |_| HttpClient::connect(addr, name),
                    c,
                    per_client,
                    |i| images[i].clone(),
                    |i| Some(oracle[i].clone()),
                )?;
                let mut lat = rep.latencies_secs.clone();
                let p = percentiles(&mut lat);
                let rps = rep.requests as f64 / rep.wall_secs.max(1e-12);
                println!(
                    "BENCH serve_socket_{name}_c{c} rps={rps:.1} \
                     p50_ms={:.3} p95_ms={:.3} p99_ms={:.3} requests={}",
                    p.p50 * 1e3,
                    p.p95 * 1e3,
                    p.p99 * 1e3,
                    rep.requests
                );
                log.add_latency(
                    "serve_socket",
                    name,
                    c,
                    engine.threads(),
                    rep.requests,
                    rep.wall_secs,
                    p,
                );
                socket_secs[mode_i] = rep.wall_secs / rep.requests as f64;
            }
        }
        if do_thread {
            report_speedup(
                &format!("serve_batched_vs_unbatched_c{c}"),
                thread_secs[0],
                thread_secs[1],
            );
        }
        if do_thread && do_socket {
            // How much the network hop costs at this concurrency: the
            // loopback (base) vs in-process (variant) batched engine.
            report_speedup(
                &format!("serve_loopback_vs_inprocess_c{c}"),
                socket_secs[1],
                thread_secs[1],
            );
        }
        // Per-client-count occupancy (stats delta over this config's
        // batched run only) — the number the EXPERIMENTS.md PR-5 table
        // records per row.
        if let Some((req, bat, rows)) = batched.batcher_stats() {
            let (dreq, dbat, drows) =
                (req - stats0.0, bat - stats0.1, rows - stats0.2);
            println!(
                "batcher c{c}: {dreq} requests -> {dbat} batches ({drows} \
                 rows, mean occupancy {:.2})",
                drows as f64 / dbat.max(1) as f64
            );
        }
    }
    if let Some(srv) = &server {
        srv.drain(std::time::Duration::from_secs(5));
        let st = srv.stats();
        println!(
            "loopback server: {} conns, {} admitted, {} rejected",
            st.accepted_conns, st.admitted, st.rejected
        );
    }
    println!("bit-exact: every response matched run_quant_ref");
    let path = json
        .map(str::to_string)
        .or_else(|| std::env::var("FAT_BENCH_JSON").ok())
        .unwrap_or_else(|| "BENCH_serve.json".to_string());
    if let Err(e) = log.write(&path) {
        println!("BENCH log write failed ({path}): {e}");
    }
    Ok(())
}

/// The `fat export` subcommand: compile each requested model (calibrate
/// → quantize → `build_qmodel`) and save the result as a `.fatm`
/// artifact, so a later `fat serve --models <dir>` cold-starts by
/// zero-copy mmap instead of redoing any of that work.
fn cmd_export(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    args: &Args,
) -> Result<()> {
    use fat::int8::Isa;
    use fat::model::store::{compiled_dir, fatm_path};

    let models: Vec<String> = args
        .get("models")
        .or_else(|| args.get("model"))
        .unwrap_or("tiny_cnn")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(
        !models.is_empty(),
        "export: --models must list at least one model"
    );
    let out = args
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| compiled_dir(artifacts));
    let spec = QuantSpec::parse(
        args.get_or("mode", "sym_vector"),
        args.get_or("calibrator", "max"),
    )?;
    let calib = args.usize_or("calib", 16);
    let isa = match args.get("isa") {
        Some(s) => Isa::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "export: --isa must be scalar|sse2|avx2|avx512vnni, got {s}"
            )
        })?,
        None => Isa::detect(),
    };
    // Export tunes by default (the whole point of persisting the table
    // in the artifact); --tune off skips it, --tune capped bounds it.
    // When FAT_TUNE is set, build_qmodel already tuned inside export()
    // per that policy, so don't tune a second time here.
    let tune_opts = match args.get("tune") {
        Some("off") => None,
        Some("capped") => Some(fat::int8::tune::TuneOptions::capped()),
        Some("full") => Some(fat::int8::tune::TuneOptions::full()),
        Some(other) => anyhow::bail!(
            "export: --tune must be off|capped|full, got {other}"
        ),
        None if std::env::var("FAT_TUNE").is_ok() => None,
        None => Some(fat::int8::tune::TuneOptions::full()),
    }
    .map(|mut t| {
        // time the schedule on the ISA the panels target, as far as
        // this host can actually execute it
        t.isa = isa.min(Isa::detect());
        t
    });
    for name in &models {
        let t0 = std::time::Instant::now();
        let mut qm = QuantSession::open(reg.clone(), artifacts, name)?
            .calibrate(CalibOpts::images(calib))?
            .identity(&spec)?
            .export()?;
        if let Some(topts) = &tune_opts {
            let tr = fat::int8::tune::tune_model(&mut qm, topts);
            println!(
                "tuned {name}: {}/{} layers off-default ({} shapes timed, \
                 {} repacked, est {:.2}x GEMM, {:.2}s)",
                tr.tuned,
                tr.layers,
                tr.shapes,
                tr.repacked,
                tr.speedup(),
                tr.wall_secs
            );
        }
        let build_secs = t0.elapsed().as_secs_f64();
        let path = fatm_path(&out, name);
        let t1 = std::time::Instant::now();
        let etag = fat::artifact::save(&qm, &path, isa)?;
        let size = std::fs::metadata(&path)?.len();
        println!(
            "exported {name} [{}] -> {} ({size} bytes, {etag}, \
             panels packed for {}; build {build_secs:.2}s, \
             write {:.3}s)",
            spec.mode().name(),
            path.display(),
            isa.name(),
            t1.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

/// `fat info --fatm PATH`: inspect a compiled artifact — header facts,
/// packing ISA and the tuned per-layer GEMM blocking table the loader
/// will serve with on this host.
fn cmd_info_fatm(path: &str) -> Result<()> {
    let (qm, rep) =
        fat::artifact::load(path, fat::artifact::LoadOptions::default())?;
    println!(
        "{path}: {} bytes, {}, {}",
        rep.bytes,
        rep.etag,
        if rep.mapped { "mmapped" } else { "heap" }
    );
    println!(
        "  graph {} ({} nodes), {} int8 param bytes",
        if qm.graph.name.is_empty() { "<unnamed>" } else { &qm.graph.name },
        qm.graph.nodes.len(),
        qm.param_bytes
    );
    println!(
        "  packed for {}{}",
        rep.file_isa.name(),
        if rep.repacked {
            format!(", repacked for {}", rep.host_isa.name())
        } else {
            String::new()
        }
    );
    println!("  GEMM blocking table (kc/nr/mr/grain):");
    for (bk, layers) in qm.blocking_summary() {
        let tag = if bk == fat::int8::Blocking::default() {
            "default"
        } else {
            "tuned"
        };
        println!("    {}: {layers} layer(s) ({tag})", bk.label());
    }
    let (shift, mul, int4, int8) = qm.epilogue_summary();
    println!(
        "  requant epilogue: {shift} shift-only layer(s), {mul} \
         multiplier layer(s)"
    );
    println!(
        "  weight panels: {int4} int4 layer(s), {int8} int8 layer(s)"
    );
    let (fused, staged) = qm.fused_summary();
    println!(
        "  conv path: {fused} fused layer(s), {staged} staged layer(s)"
    );
    // Peak scratch of one forward pass: run the plan once on a zero
    // input so the staged scratch and arena report real high-water
    // marks (fused layers leave patches/acc at zero).
    if let Some(shape) = input_shape(&qm.graph) {
        let mut st = fat::int8::ExecState::with_threads(1);
        let zeros = vec![0.0f32; shape.iter().product()];
        let q = fat::int8::QTensor::quantize(shape, &zeros, qm.input_qp);
        if qm.run_quant_state(q, &mut st).is_ok() {
            let sc = st.scratch_stats();
            println!(
                "  peak scratch (1 worker): {} patch bytes, {} acc \
                 bytes, {} arena bytes",
                sc.patches_bytes, sc.acc_bytes, sc.arena_bytes
            );
        }
    }
    Ok(())
}

/// Input-node shape of a graph (batch 1), for the scratch probe above.
fn input_shape(g: &fat::model::GraphDef) -> Option<Vec<usize>> {
    g.nodes
        .iter()
        .find(|n| n.op == fat::model::Op::Input)
        .and_then(|n| n.input_shape.clone())
}

/// `fat perf-gate`: compare a fresh bench log against its committed
/// baseline and exit non-zero on regression (`util::gate`).
fn cmd_perf_gate(args: &Args) -> Result<()> {
    use fat::util::gate::{check, GateOptions};

    let baseline = args.get("baseline").ok_or_else(|| {
        anyhow::anyhow!("perf-gate: --baseline PATH is required")
    })?;
    let current = args.get("current").ok_or_else(|| {
        anyhow::anyhow!("perf-gate: --current PATH is required")
    })?;
    let mut opts = GateOptions::default();
    if let Some(v) = args.get("max-regress-pct") {
        opts.max_regress_pct = v.parse()?;
    }
    if let Some(v) = args.get("inject-slowdown-pct") {
        opts.inject_slowdown_pct = v.parse()?;
    }
    let b = std::fs::read_to_string(baseline)
        .map_err(|e| anyhow::anyhow!("reading {baseline}: {e}"))?;
    let c = std::fs::read_to_string(current)
        .map_err(|e| anyhow::anyhow!("reading {current}: {e}"))?;
    let rep = check(&b, &c, &opts)?;
    print!("{}", rep.render());
    if !rep.pass() {
        std::process::exit(1);
    }
    Ok(())
}

/// The `fat serve` subcommand: register every requested model in one
/// [`fat::net::ModelRegistry`] — builtin/artifact names calibrate +
/// export in-process, `.fatm` paths and artifact directories load
/// zero-copy — bind the socket front-end and run until SIGINT/SIGTERM
/// asks for a drain, optionally rescanning artifact directories for
/// etag-changed files every `--reload-secs`.
fn cmd_serve(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    args: &Args,
) -> Result<()> {
    use fat::int8::BatchOptions;
    use fat::net::{signal, ModelRegistry, Server, ServerOptions};
    use std::time::Duration;

    let models: Vec<String> = args
        .get("models")
        .or_else(|| args.get("model"))
        .unwrap_or("tiny_cnn")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(
        !models.is_empty(),
        "serve: --models must list at least one model"
    );
    let addr = args.get_or("addr", "127.0.0.1:8080");
    let spec = QuantSpec::parse(
        args.get_or("mode", "sym_vector"),
        args.get_or("calibrator", "max"),
    )?;
    // Serving defaults to micro-batching on: concurrent socket clients
    // are exactly the traffic it coalesces. `--max-batch 1` turns it off.
    let max_batch = args.usize_or("max-batch", 16);
    let max_wait_us = args.usize_or("max-wait-us", 200) as u64;
    let mut opts = match args.get("threads") {
        Some(t) => EngineOptions::threads(t.parse()?),
        None => EngineOptions::default(),
    };
    if max_batch >= 2 {
        opts = opts.with_batch(BatchOptions { max_batch, max_wait_us });
    }
    let server_opts = ServerOptions {
        max_conns: args.usize_or("max-conns", 256),
        max_inflight: args.usize_or("max-inflight", 128),
        read_timeout: Duration::from_millis(
            args.usize_or("read-timeout-ms", 5_000) as u64,
        ),
        write_timeout: Duration::from_millis(
            args.usize_or("write-timeout-ms", 5_000) as u64,
        ),
        ..ServerOptions::default()
    };

    let registry = ModelRegistry::new();
    let mut watch_dirs: Vec<std::path::PathBuf> = Vec::new();
    for name in &models {
        let path = std::path::Path::new(name);
        if name.ends_with(".fatm") {
            let (reg_name, rep) = registry.load_artifact(path, opts)?;
            println!(
                "model {reg_name} [.fatm {}]: {} bytes {}, \
                 packed for {}{}",
                rep.etag,
                rep.bytes,
                if rep.mapped { "mmapped" } else { "heap" },
                rep.file_isa.name(),
                if rep.repacked {
                    format!(" (repacked for {})", rep.host_isa.name())
                } else {
                    String::new()
                }
            );
        } else if path.is_dir() {
            let sr = registry.sync_dir(path, opts)?;
            println!(
                "artifact dir {}: loaded {:?} ({} unchanged)",
                path.display(),
                sr.loaded,
                sr.unchanged
            );
            watch_dirs.push(path.to_path_buf());
        } else {
            let engine = QuantSession::open(reg.clone(), artifacts, name)?
                .calibrate(CalibOpts::images(16))?
                .identity(&spec)?
                .serve(opts)?;
            println!(
                "model {name} [{}]: {} int8 param bytes, {} worker(s)",
                spec.mode().name(),
                engine.param_bytes(),
                engine.threads()
            );
            registry.insert(name, engine);
        }
    }
    anyhow::ensure!(
        !registry.is_empty(),
        "serve: no models registered (empty artifact dir?)"
    );
    let server = Server::bind(addr, registry.clone(), server_opts)?;
    let local = server.local_addr();
    println!("fat serve: http://{local} (HTTP/1.1 + 0xFA frame protocol)");
    println!("  curl http://{local}/healthz");
    println!("  curl http://{local}/stats");
    println!("  curl http://{local}/models");
    // `models` items can be dirs/paths; quote a name that actually
    // resolved (the ensure above guarantees at least one).
    println!(
        "  head -c {{input_bytes}} /dev/urandom | curl -s --data-binary @- \
         http://{local}/v1/models/{}/infer",
        registry.names()[0]
    );
    signal::install_drain_handler();
    let reload_secs = args.usize_or("reload-secs", 0) as u64;
    // Kernel change notification where available: a landed/removed
    // `.fatm` triggers a rescan within ~100 ms, and the `--reload-secs`
    // timer stays on as the heartbeat (sole driver in poll fallback).
    let mut watcher = (reload_secs > 0 && !watch_dirs.is_empty())
        .then(|| fat::net::DirWatcher::new(&watch_dirs));
    if let Some(w) = &watcher {
        println!(
            "hot reload: {}, rescan heartbeat every {reload_secs}s",
            w.describe()
        );
    }
    println!("serving; SIGINT/SIGTERM drains");
    let mut last_sync = std::time::Instant::now();
    while !signal::drain_requested() {
        std::thread::sleep(Duration::from_millis(100));
        let kicked = watcher.as_mut().is_some_and(|w| w.pending());
        if reload_secs > 0
            && !watch_dirs.is_empty()
            && (kicked
                || last_sync.elapsed() >= Duration::from_secs(reload_secs))
        {
            for d in &watch_dirs {
                match registry.sync_dir(d, opts) {
                    Ok(sr) if !sr.loaded.is_empty() || !sr.removed.is_empty() => {
                        println!(
                            "reload {}: loaded {:?}, removed {:?}",
                            d.display(),
                            sr.loaded,
                            sr.removed
                        );
                    }
                    Ok(_) => {}
                    Err(e) => eprintln!("reload {}: {e:#}", d.display()),
                }
            }
            last_sync = std::time::Instant::now();
        }
    }
    let grace = Duration::from_secs(args.usize_or("drain-secs", 5) as u64);
    println!("drain requested; grace {}s", grace.as_secs());
    server.drain(grace);
    println!("{}", server.stats_json());
    Ok(())
}

fn run_pipeline(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    cfg: &PipelineConfig,
) -> Result<()> {
    let spec = cfg.quant_spec()?;
    println!(
        "== FAT pipeline: {} [{}] calibrator={} ==",
        cfg.model,
        cfg.mode,
        spec.calibrator.name()
    );
    // scope the session so a later dws_rescale holds the only reference
    // to the model state (no copy-on-write)
    let t0 = std::time::Instant::now();
    let session = QuantSession::open(reg.clone(), artifacts, &cfg.model)?;
    println!("backend: {}", session.core().backend_name());
    let mut cal = session.calibrate(CalibOpts::images(cfg.calib_images))?;
    drop(session);
    println!(
        "calibrated on {} images ({} batches) in {:.1}s",
        cfg.calib_images,
        cal.stats().batches,
        t0.elapsed().as_secs_f64()
    );

    if cfg.dws_rescale {
        cal = cal.dws_rescale()?;
        for r in cal.rescale_reports() {
            println!(
                "  dws {}→{}: threshold spread {:.1}→{:.1} ({} locked / {})",
                r.dw, r.conv, r.spread_before, r.spread_after, r.locked,
                r.channels
            );
        }
    }

    let fp = cal.fp_accuracy(cfg.val_images)?;
    let q0 = cal.identity(&spec)?.quant_accuracy(cfg.val_images)?;
    println!(
        "FP acc {:.2}%   quant (no finetune) {:.2}%",
        fp * 100.0,
        q0 * 100.0
    );

    let t1 = std::time::Instant::now();
    let th = cal.finetune(&spec, &cfg.finetune_opts(false), |step, loss, lr| {
        if step % 10 == 0 {
            println!("  step {step}: rmse {loss:.4} lr {lr:.4}");
        }
    })?;
    let losses = th.losses();
    println!(
        "fine-tuned {} steps in {:.1}s (rmse {:.4} → {:.4})",
        losses.len(),
        t1.elapsed().as_secs_f64(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );

    let q1 = th.quant_accuracy(cfg.val_images)?;
    let engine = th.serve(EngineOptions::default())?;
    let int8_acc = int8_accuracy(&engine, cfg.val_images.clamp(100, 500))?;
    println!("quant (FAT)     {:.2}%", q1 * 100.0);
    println!(
        "int8 engine     {:.2}%  ({} param bytes)",
        int8_acc * 100.0,
        engine.param_bytes()
    );
    println!(
        "ladder: FP {:.2} → no-ft {:.2} → FAT {:.2} (drop {:.2}%)",
        fp * 100.0,
        q0 * 100.0,
        q1 * 100.0,
        (fp - q1) * 100.0
    );
    Ok(())
}
