//! `fat` — the FAT quantization pipeline launcher.
//!
//! Usage:
//!   fat info
//!   fat quantize --model mnas_mini_10 --mode asym_vector [--dws] [--val N]
//!   fat pipeline [--config run.toml] [--model M] [--mode MODE]
//!                [--epochs N] [--max-steps N] [--val N] [--dws]
//!   fat eval-int8 --model mnas_mini_10 --mode sym_vector [--val N]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::evaluate::int8_accuracy;
use fat::coordinator::{Pipeline, PipelineConfig};
use fat::model::ModelStore;
use fat::quant::export::QuantMode;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

const USAGE: &str = "\
fat — FAT: fast adjustable threshold quantization

Commands:
  info                         list models + FP accuracies
  quantize                     calibration-only quantization + accuracy
    --model M --mode MODE --calib N --val N [--dws]
  pipeline                     full FAT pipeline (calibrate→finetune→int8)
    [--config F] [--model M] [--mode MODE] [--epochs N]
    [--max-steps N] [--val N] [--lr F] [--dws]
  eval-int8                    int8 engine vs fake-quant agreement
    --model M --mode MODE [--val N]

Global: --artifacts DIR (default ./artifacts or $FAT_ARTIFACTS)
";

fn main() -> Result<()> {
    let args = Args::parse(&["dws", "help"]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    if args.flag("help") || args.subcommand.is_none() {
        print!("{USAGE}");
        return Ok(());
    }
    let rt = Arc::new(Runtime::cpu()?);
    let reg = Arc::new(Registry::new(rt));

    match args.subcommand.as_deref().unwrap() {
        "info" => {
            for name in ModelStore::list(&artifacts)? {
                let store = ModelStore::open(&artifacts, &name)?;
                let sites = store.sites()?;
                println!(
                    "{name}: {} quant sites, FP pretrain acc {:.2}%",
                    sites.sites.len(),
                    sites.val_acc_fp_pretrain * 100.0
                );
            }
        }
        "quantize" => {
            let model = args.get_or("model", "mobilenet_v2_mini");
            let mode = QuantMode::parse(args.get_or("mode", "sym_scalar"))?;
            let calib = args.usize_or("calib", 100);
            let val = args.usize_or("val", 0);
            let mut p = Pipeline::new(reg, &artifacts, model)?;
            let stats = p.calibrate(calib)?;
            if args.flag("dws") {
                for r in p.dws_rescale(&stats)? {
                    println!(
                        "  dws {}→{}: spread {:.1}→{:.1} ({} locked/{})",
                        r.dw, r.conv, r.spread_before, r.spread_after,
                        r.locked, r.channels
                    );
                }
            }
            let fp = p.fp_accuracy(val)?;
            let tr = p.identity_trainables(mode)?;
            let q = p.quant_accuracy(mode, &stats, &tr, val)?;
            println!(
                "{model} [{}] no-finetune: FP {:.2}%  quant {:.2}%  (drop {:.2})",
                mode.name(),
                fp * 100.0,
                q * 100.0,
                (fp - q) * 100.0
            );
        }
        "pipeline" => {
            let mut cfg = match args.get("config") {
                Some(p) => PipelineConfig::load(p)?,
                None => PipelineConfig::default(),
            };
            if let Some(m) = args.get("model") {
                cfg.model = m.to_string();
            }
            if let Some(m) = args.get("mode") {
                cfg.mode = m.to_string();
            }
            if let Some(e) = args.get("epochs") {
                cfg.epochs = e.parse()?;
            }
            if let Some(s) = args.get("max-steps") {
                cfg.max_steps = s.parse()?;
            }
            if let Some(v) = args.get("val") {
                cfg.val_images = v.parse()?;
            }
            if let Some(lr) = args.get("lr") {
                cfg.lr = lr.parse()?;
            }
            cfg.dws_rescale |= args.flag("dws");
            run_pipeline(&reg, &artifacts, &cfg)?;
        }
        "eval-int8" => {
            let model = args.get_or("model", "mnas_mini_10");
            let mode = QuantMode::parse(args.get_or("mode", "sym_vector"))?;
            let val = args.usize_or("val", 500);
            let p = Pipeline::new(reg, &artifacts, model)?;
            let stats = p.calibrate(100)?;
            let trained = p.identity_trained(mode);
            let qm = p.export_int8(mode, &stats, &trained)?;
            let tr = p.identity_trainables(mode)?;
            let fake = p.quant_accuracy(mode, &stats, &tr, val)?;
            let t0 = std::time::Instant::now();
            let engine_acc = int8_accuracy(&qm, val)?;
            let dt = t0.elapsed();
            println!(
                "{model} [{}]: fake-quant {:.2}%  int8-engine {:.2}%  \
                 ({} int8 param bytes, {:.1} img/s)",
                mode.name(),
                fake * 100.0,
                engine_acc * 100.0,
                qm.param_bytes,
                val as f64 / dt.as_secs_f64()
            );
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn run_pipeline(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    cfg: &PipelineConfig,
) -> Result<()> {
    let mode = QuantMode::parse(&cfg.mode)?;
    println!("== FAT pipeline: {} [{}] ==", cfg.model, cfg.mode);
    let mut p = Pipeline::new(reg.clone(), artifacts, &cfg.model)?;

    let t0 = std::time::Instant::now();
    let stats = p.calibrate(cfg.calib_images)?;
    println!(
        "calibrated on {} images ({} batches) in {:.1}s",
        cfg.calib_images,
        stats.batches,
        t0.elapsed().as_secs_f64()
    );

    if cfg.dws_rescale {
        for r in p.dws_rescale(&stats)? {
            println!(
                "  dws {}→{}: threshold spread {:.1}→{:.1} ({} locked / {})",
                r.dw, r.conv, r.spread_before, r.spread_after, r.locked,
                r.channels
            );
        }
    }

    let fp = p.fp_accuracy(cfg.val_images)?;
    let tr0 = p.identity_trainables(mode)?;
    let q0 = p.quant_accuracy(mode, &stats, &tr0, cfg.val_images)?;
    println!(
        "FP acc {:.2}%   quant (no finetune) {:.2}%",
        fp * 100.0,
        q0 * 100.0
    );

    let t1 = std::time::Instant::now();
    let (tr, losses) = p.finetune(mode, &stats, cfg, |step, loss, lr| {
        if step % 10 == 0 {
            println!("  step {step}: rmse {loss:.4} lr {lr:.4}");
        }
    })?;
    println!(
        "fine-tuned {} steps in {:.1}s (rmse {:.4} → {:.4})",
        losses.len(),
        t1.elapsed().as_secs_f64(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );

    let q1 = p.quant_accuracy(mode, &stats, &tr, cfg.val_images)?;
    let trained = p.trained_of_map(mode, &tr)?;
    let qm = p.export_int8(mode, &stats, &trained)?;
    let int8_acc = int8_accuracy(&qm, cfg.val_images.clamp(100, 500))?;
    println!("quant (FAT)     {:.2}%", q1 * 100.0);
    println!(
        "int8 engine     {:.2}%  ({} param bytes)",
        int8_acc * 100.0,
        qm.param_bytes
    );
    println!(
        "ladder: FP {:.2} → no-ft {:.2} → FAT {:.2} (drop {:.2}%)",
        fp * 100.0,
        q0 * 100.0,
        q1 * 100.0,
        (fp - q1) * 100.0
    );
    Ok(())
}
