//! `fat` — the FAT quantization pipeline launcher, on the staged
//! `QuantSession` → `Int8Engine` API.
//!
//! Runs with or without AOT artifacts: backend resolution picks the
//! native FP32 executor when `artifacts/` is absent, so a bare
//! `cargo run --release -- --epochs 1` executes the full calibrate →
//! fine-tune → export → int8 pipeline on a builtin model.
//!
//! Usage:
//!   fat [pipeline] [--config run.toml] [--model M] [--mode MODE]
//!                [--calibrator C] [--epochs N] [--max-steps N]
//!                [--val N] [--dws]
//!   fat info
//!   fat quantize --model mnas_mini_10 --mode asym_vector [--dws]
//!                [--calibrator max|p9999|kl] [--val N]
//!   fat eval-int8 --model mnas_mini_10 --mode sym_vector [--val N]
//!                 [--threads N]
//!   fat serve-bench [--model tiny_cnn] [--clients 1,4,16,64]
//!                 [--requests N] [--max-batch N] [--max-wait-us N]
//!                 [--threads N] [--json PATH]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::evaluate::int8_accuracy;
use fat::coordinator::PipelineConfig;
use fat::int8::serve::EngineOptions;
use fat::model::ModelStore;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

const USAGE: &str = "\
fat — FAT: fast adjustable threshold quantization

Commands (default: pipeline):
  pipeline                     full FAT pipeline (calibrate→finetune→int8)
    [--config F] [--model M] [--mode MODE] [--calibrator C] [--epochs N]
    [--max-steps N] [--val N] [--lr F] [--dws]
  info                         list models + FP accuracies
  quantize                     calibration-only quantization + accuracy
    --model M --mode MODE --calib N --val N [--dws] [--calibrator C]
  eval-int8                    int8 engine vs fake-quant agreement
    --model M --mode MODE [--val N] [--threads N]
  serve-bench                  concurrent-client serving throughput:
    micro-batched vs unbatched engine, p50/p95/p99 latency, bit-exact
    check vs the reference interpreter, BENCH_serve.json log
    [--model M] [--clients 1,4,16,64] [--requests N] [--max-batch N]
    [--max-wait-us N] [--threads N] [--json PATH]

Modes: sym_scalar | sym_vector | asym_scalar | asym_vector
Calibrators: max (default) | p99 | p999 | p9999 | kl
Global: --artifacts DIR (default ./artifacts or $FAT_ARTIFACTS)
        FAT_BACKEND=auto|native|artifact (float-stage backend)

Without an artifacts/ directory everything runs on the native FP32
backend over the builtin model zoo (deterministic untrained weights):
the pipeline mechanics are identical, only the accuracy ladder needs
the pretrained artifact models.
";

fn main() -> Result<()> {
    let args = Args::parse(&["dws", "help"]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let rt = Arc::new(Runtime::cpu()?);
    let reg = Arc::new(Registry::new(rt));

    // `fat --epochs 1` (no subcommand) runs the full pipeline.
    match args.subcommand.as_deref().unwrap_or("pipeline") {
        "info" => {
            let listed = if artifacts.join("models").exists() {
                let names = ModelStore::list(&artifacts)?;
                for name in &names {
                    let store = ModelStore::open(&artifacts, name)?;
                    let sites = store.sites()?;
                    println!(
                        "{name}: {} quant sites, FP pretrain acc {:.2}% \
                         (artifacts)",
                        sites.sites.len(),
                        sites.val_acc_fp_pretrain * 100.0
                    );
                }
                names
            } else {
                vec![]
            };
            for name in fat::model::builtin::names() {
                if listed.iter().any(|l| l == name) {
                    continue;
                }
                let (g, sites, _) = fat::model::builtin::load(name)?;
                println!(
                    "{name}: {} quant sites, {} nodes (builtin, native \
                     backend, untrained)",
                    sites.sites.len(),
                    g.nodes.len()
                );
            }
        }
        "quantize" => {
            let model = args.get_or("model", "mobilenet_v2_mini");
            let spec = QuantSpec::parse(
                args.get_or("mode", "sym_scalar"),
                args.get_or("calibrator", "max"),
            )?;
            let calib = args.usize_or("calib", 100);
            let val = args.usize_or("val", 0);
            // scope the session so mutating stage transitions below hold
            // the only reference to the model state (no copy-on-write)
            let mut cal = QuantSession::open(reg, &artifacts, model)?
                .calibrate(CalibOpts::images(calib))?;
            if args.flag("dws") {
                cal = cal.dws_rescale()?;
                for r in cal.rescale_reports() {
                    println!(
                        "  dws {}→{}: spread {:.1}→{:.1} ({} locked/{})",
                        r.dw, r.conv, r.spread_before, r.spread_after,
                        r.locked, r.channels
                    );
                }
            }
            let fp = cal.fp_accuracy(val)?;
            let q = cal.identity(&spec)?.quant_accuracy(val)?;
            println!(
                "{model} [{}/{}] no-finetune: FP {:.2}%  quant {:.2}%  (drop {:.2})",
                spec.mode().name(),
                spec.calibrator.name(),
                fp * 100.0,
                q * 100.0,
                (fp - q) * 100.0
            );
        }
        "pipeline" => {
            let mut cfg = match args.get("config") {
                Some(p) => PipelineConfig::load(p)?,
                None => PipelineConfig::default(),
            };
            if let Some(m) = args.get("model") {
                cfg.model = m.to_string();
            }
            if let Some(m) = args.get("mode") {
                cfg.mode = m.to_string();
            }
            if let Some(c) = args.get("calibrator") {
                cfg.calibrator = c.to_string();
            }
            if let Some(e) = args.get("epochs") {
                cfg.epochs = e.parse()?;
            }
            if let Some(s) = args.get("max-steps") {
                cfg.max_steps = s.parse()?;
            }
            if let Some(v) = args.get("val") {
                cfg.val_images = v.parse()?;
            }
            if let Some(lr) = args.get("lr") {
                cfg.lr = lr.parse()?;
            }
            cfg.dws_rescale |= args.flag("dws");
            run_pipeline(&reg, &artifacts, &cfg)?;
        }
        "eval-int8" => {
            let model = args.get_or("model", "mnas_mini_10");
            let spec = QuantSpec::parse(
                args.get_or("mode", "sym_vector"),
                args.get_or("calibrator", "max"),
            )?;
            let val = args.usize_or("val", 500);
            let opts = match args.get("threads") {
                Some(t) => EngineOptions::threads(t.parse()?),
                None => EngineOptions::default(),
            };
            let th = QuantSession::open(reg, &artifacts, model)?
                .calibrate(CalibOpts::images(100))?
                .identity(&spec)?;
            let fake = th.quant_accuracy(val)?;
            let engine = th.serve(opts)?;
            let t0 = std::time::Instant::now();
            let engine_acc = int8_accuracy(&engine, val)?;
            let dt = t0.elapsed();
            println!(
                "{model} [{}]: fake-quant {:.2}%  int8-engine {:.2}%  \
                 ({} int8 param bytes, {} worker(s), {:.1} img/s)",
                spec.mode().name(),
                fake * 100.0,
                engine_acc * 100.0,
                engine.param_bytes(),
                engine.threads(),
                val as f64 / dt.as_secs_f64()
            );
        }
        "serve-bench" => {
            let model = args.get_or("model", "tiny_cnn");
            let clients: Vec<usize> = args
                .get_or("clients", "1,4,16,64")
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|&c| c >= 1)
                .collect();
            anyhow::ensure!(
                !clients.is_empty(),
                "serve-bench: --clients must list positive counts"
            );
            let requests = args.usize_or("requests", 256);
            let max_batch = args.usize_or("max-batch", 16).max(2);
            let max_wait_us = args.usize_or("max-wait-us", 200) as u64;
            let threads = match args.get("threads") {
                Some(t) => Some(t.parse()?),
                None => None,
            };
            serve_bench(
                &reg, &artifacts, model, &clients, requests, max_batch,
                max_wait_us, threads, args.get("json"),
            )?;
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Deterministic synthetic client image: every client hammers its own
/// fixed pixels, so each response has one precomputable oracle row.
fn synth_image(per_img: usize, client: usize) -> Vec<u8> {
    (0..per_img)
        .map(|i| ((i * 31 + client * 97 + 13) % 256) as u8)
        .collect()
}

/// Drive batched-vs-unbatched serving with N concurrent closed-loop
/// clients; print throughput + latency percentiles, verify every
/// response bit-exactly against `run_quant_ref`, and write the
/// machine-readable `BENCH_serve.json`.
#[allow(clippy::too_many_arguments)]
fn serve_bench(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    model: &str,
    clients: &[usize],
    requests: usize,
    max_batch: usize,
    max_wait_us: u64,
    threads: Option<usize>,
    json: Option<&str>,
) -> Result<()> {
    use fat::int8::serve::drive_clients;
    use fat::int8::{BatchOptions, Int8Engine, QTensor};
    use fat::util::bench::{percentiles, report_speedup, BenchLog};

    let th = QuantSession::open(reg.clone(), artifacts, model)?
        .calibrate(CalibOpts::images(16))?
        .identity(&QuantSpec::default())?;
    let qm = th.export()?;
    let sh = qm
        .graph
        .nodes
        .iter()
        .find(|n| n.op == fat::model::Op::Input)
        .and_then(|n| n.input_shape.clone())
        .ok_or_else(|| anyhow::anyhow!("{model}: no shaped input node"))?;
    let per_img: usize = sh.iter().product();

    let base = match threads {
        Some(t) => EngineOptions::threads(t),
        None => EngineOptions::default(),
    };
    let unbatched = Int8Engine::new(qm.clone(), base);
    let batched = Int8Engine::new(
        qm.clone(),
        base.with_batch(BatchOptions { max_batch, max_wait_us }),
    );
    println!(
        "serve-bench: {model} [{} worker(s)] micro-batch \
         max_batch={max_batch} max_wait_us={max_wait_us}",
        unbatched.threads()
    );

    // Per-client deterministic images and their oracle logits from the
    // scalar/serial reference interpreter (the engine's bit-exactness
    // anchor).
    let max_clients = clients.iter().copied().max().unwrap_or(1);
    let images: Vec<Vec<u8>> =
        (0..max_clients).map(|c| synth_image(per_img, c)).collect();
    let mut oracle: Vec<Vec<f32>> = Vec::with_capacity(max_clients);
    for px in &images {
        let x: Vec<f32> = px.iter().map(|&p| p as f32 / 255.0).collect();
        let q = QTensor::quantize(
            vec![1, sh[0], sh[1], sh[2]],
            &x,
            qm.input_qp,
        );
        oracle.push(qm.run_quant_ref(q)?.dequantize());
    }

    let mut log = BenchLog::default();
    for &c in clients {
        let per_client = (requests / c).max(1);
        let stats0 = batched.batcher_stats().unwrap_or((0, 0, 0));
        let mut secs_per_req = [0.0f64; 2];
        for (mode_i, (name, engine)) in
            [("unbatched", &unbatched), ("batched", &batched)]
                .into_iter()
                .enumerate()
        {
            let rep = drive_clients(
                engine,
                c,
                per_client,
                |i| images[i].clone(),
                |i| Some(oracle[i].clone()),
            )?;
            let mut lat = rep.latencies_secs.clone();
            let p = percentiles(&mut lat);
            let rps = rep.requests as f64 / rep.wall_secs.max(1e-12);
            println!(
                "BENCH serve_{name}_c{c} rps={rps:.1} p50_ms={:.3} \
                 p95_ms={:.3} p99_ms={:.3} requests={}",
                p.p50 * 1e3,
                p.p95 * 1e3,
                p.p99 * 1e3,
                rep.requests
            );
            log.add_latency(
                "serve",
                name,
                c,
                engine.threads(),
                rep.requests,
                rep.wall_secs,
                p,
            );
            secs_per_req[mode_i] = rep.wall_secs / rep.requests as f64;
        }
        report_speedup(
            &format!("serve_batched_vs_unbatched_c{c}"),
            secs_per_req[0],
            secs_per_req[1],
        );
        // Per-client-count occupancy (stats delta over this config's
        // batched run only) — the number the EXPERIMENTS.md PR-5 table
        // records per row.
        if let Some((req, bat, rows)) = batched.batcher_stats() {
            let (dreq, dbat, drows) =
                (req - stats0.0, bat - stats0.1, rows - stats0.2);
            println!(
                "batcher c{c}: {dreq} requests -> {dbat} batches ({drows} \
                 rows, mean occupancy {:.2})",
                drows as f64 / dbat.max(1) as f64
            );
        }
    }
    println!("bit-exact: every response matched run_quant_ref");
    let path = json
        .map(str::to_string)
        .or_else(|| std::env::var("FAT_BENCH_JSON").ok())
        .unwrap_or_else(|| "BENCH_serve.json".to_string());
    if let Err(e) = log.write(&path) {
        println!("BENCH log write failed ({path}): {e}");
    }
    Ok(())
}

fn run_pipeline(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    cfg: &PipelineConfig,
) -> Result<()> {
    let spec = cfg.quant_spec()?;
    println!(
        "== FAT pipeline: {} [{}] calibrator={} ==",
        cfg.model,
        cfg.mode,
        spec.calibrator.name()
    );
    // scope the session so a later dws_rescale holds the only reference
    // to the model state (no copy-on-write)
    let t0 = std::time::Instant::now();
    let session = QuantSession::open(reg.clone(), artifacts, &cfg.model)?;
    println!("backend: {}", session.core().backend_name());
    let mut cal = session.calibrate(CalibOpts::images(cfg.calib_images))?;
    drop(session);
    println!(
        "calibrated on {} images ({} batches) in {:.1}s",
        cfg.calib_images,
        cal.stats().batches,
        t0.elapsed().as_secs_f64()
    );

    if cfg.dws_rescale {
        cal = cal.dws_rescale()?;
        for r in cal.rescale_reports() {
            println!(
                "  dws {}→{}: threshold spread {:.1}→{:.1} ({} locked / {})",
                r.dw, r.conv, r.spread_before, r.spread_after, r.locked,
                r.channels
            );
        }
    }

    let fp = cal.fp_accuracy(cfg.val_images)?;
    let q0 = cal.identity(&spec)?.quant_accuracy(cfg.val_images)?;
    println!(
        "FP acc {:.2}%   quant (no finetune) {:.2}%",
        fp * 100.0,
        q0 * 100.0
    );

    let t1 = std::time::Instant::now();
    let th = cal.finetune(&spec, &cfg.finetune_opts(false), |step, loss, lr| {
        if step % 10 == 0 {
            println!("  step {step}: rmse {loss:.4} lr {lr:.4}");
        }
    })?;
    let losses = th.losses();
    println!(
        "fine-tuned {} steps in {:.1}s (rmse {:.4} → {:.4})",
        losses.len(),
        t1.elapsed().as_secs_f64(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );

    let q1 = th.quant_accuracy(cfg.val_images)?;
    let engine = th.serve(EngineOptions::default())?;
    let int8_acc = int8_accuracy(&engine, cfg.val_images.clamp(100, 500))?;
    println!("quant (FAT)     {:.2}%", q1 * 100.0);
    println!(
        "int8 engine     {:.2}%  ({} param bytes)",
        int8_acc * 100.0,
        engine.param_bytes()
    );
    println!(
        "ladder: FP {:.2} → no-ft {:.2} → FAT {:.2} (drop {:.2}%)",
        fp * 100.0,
        q0 * 100.0,
        q1 * 100.0,
        (fp - q1) * 100.0
    );
    Ok(())
}
