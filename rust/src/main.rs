//! `fat` — the FAT quantization pipeline launcher, on the staged
//! `QuantSession` → `Int8Engine` API.
//!
//! Runs with or without AOT artifacts: backend resolution picks the
//! native FP32 executor when `artifacts/` is absent, so a bare
//! `cargo run --release -- --epochs 1` executes the full calibrate →
//! fine-tune → export → int8 pipeline on a builtin model.
//!
//! Usage:
//!   fat [pipeline] [--config run.toml] [--model M] [--mode MODE]
//!                [--calibrator C] [--epochs N] [--max-steps N]
//!                [--val N] [--dws]
//!   fat info
//!   fat quantize --model mnas_mini_10 --mode asym_vector [--dws]
//!                [--calibrator max|p9999|kl] [--val N]
//!   fat eval-int8 --model mnas_mini_10 --mode sym_vector [--val N]
//!                 [--threads N]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::evaluate::int8_accuracy;
use fat::coordinator::PipelineConfig;
use fat::int8::serve::EngineOptions;
use fat::model::ModelStore;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

const USAGE: &str = "\
fat — FAT: fast adjustable threshold quantization

Commands (default: pipeline):
  pipeline                     full FAT pipeline (calibrate→finetune→int8)
    [--config F] [--model M] [--mode MODE] [--calibrator C] [--epochs N]
    [--max-steps N] [--val N] [--lr F] [--dws]
  info                         list models + FP accuracies
  quantize                     calibration-only quantization + accuracy
    --model M --mode MODE --calib N --val N [--dws] [--calibrator C]
  eval-int8                    int8 engine vs fake-quant agreement
    --model M --mode MODE [--val N] [--threads N]

Modes: sym_scalar | sym_vector | asym_scalar | asym_vector
Calibrators: max (default) | p99 | p999 | p9999 | kl
Global: --artifacts DIR (default ./artifacts or $FAT_ARTIFACTS)
        FAT_BACKEND=auto|native|artifact (float-stage backend)

Without an artifacts/ directory everything runs on the native FP32
backend over the builtin model zoo (deterministic untrained weights):
the pipeline mechanics are identical, only the accuracy ladder needs
the pretrained artifact models.
";

fn main() -> Result<()> {
    let args = Args::parse(&["dws", "help"]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let rt = Arc::new(Runtime::cpu()?);
    let reg = Arc::new(Registry::new(rt));

    // `fat --epochs 1` (no subcommand) runs the full pipeline.
    match args.subcommand.as_deref().unwrap_or("pipeline") {
        "info" => {
            let listed = if artifacts.join("models").exists() {
                let names = ModelStore::list(&artifacts)?;
                for name in &names {
                    let store = ModelStore::open(&artifacts, name)?;
                    let sites = store.sites()?;
                    println!(
                        "{name}: {} quant sites, FP pretrain acc {:.2}% \
                         (artifacts)",
                        sites.sites.len(),
                        sites.val_acc_fp_pretrain * 100.0
                    );
                }
                names
            } else {
                vec![]
            };
            for name in fat::model::builtin::names() {
                if listed.iter().any(|l| l == name) {
                    continue;
                }
                let (g, sites, _) = fat::model::builtin::load(name)?;
                println!(
                    "{name}: {} quant sites, {} nodes (builtin, native \
                     backend, untrained)",
                    sites.sites.len(),
                    g.nodes.len()
                );
            }
        }
        "quantize" => {
            let model = args.get_or("model", "mobilenet_v2_mini");
            let spec = QuantSpec::parse(
                args.get_or("mode", "sym_scalar"),
                args.get_or("calibrator", "max"),
            )?;
            let calib = args.usize_or("calib", 100);
            let val = args.usize_or("val", 0);
            // scope the session so mutating stage transitions below hold
            // the only reference to the model state (no copy-on-write)
            let mut cal = QuantSession::open(reg, &artifacts, model)?
                .calibrate(CalibOpts::images(calib))?;
            if args.flag("dws") {
                cal = cal.dws_rescale()?;
                for r in cal.rescale_reports() {
                    println!(
                        "  dws {}→{}: spread {:.1}→{:.1} ({} locked/{})",
                        r.dw, r.conv, r.spread_before, r.spread_after,
                        r.locked, r.channels
                    );
                }
            }
            let fp = cal.fp_accuracy(val)?;
            let q = cal.identity(&spec)?.quant_accuracy(val)?;
            println!(
                "{model} [{}/{}] no-finetune: FP {:.2}%  quant {:.2}%  (drop {:.2})",
                spec.mode().name(),
                spec.calibrator.name(),
                fp * 100.0,
                q * 100.0,
                (fp - q) * 100.0
            );
        }
        "pipeline" => {
            let mut cfg = match args.get("config") {
                Some(p) => PipelineConfig::load(p)?,
                None => PipelineConfig::default(),
            };
            if let Some(m) = args.get("model") {
                cfg.model = m.to_string();
            }
            if let Some(m) = args.get("mode") {
                cfg.mode = m.to_string();
            }
            if let Some(c) = args.get("calibrator") {
                cfg.calibrator = c.to_string();
            }
            if let Some(e) = args.get("epochs") {
                cfg.epochs = e.parse()?;
            }
            if let Some(s) = args.get("max-steps") {
                cfg.max_steps = s.parse()?;
            }
            if let Some(v) = args.get("val") {
                cfg.val_images = v.parse()?;
            }
            if let Some(lr) = args.get("lr") {
                cfg.lr = lr.parse()?;
            }
            cfg.dws_rescale |= args.flag("dws");
            run_pipeline(&reg, &artifacts, &cfg)?;
        }
        "eval-int8" => {
            let model = args.get_or("model", "mnas_mini_10");
            let spec = QuantSpec::parse(
                args.get_or("mode", "sym_vector"),
                args.get_or("calibrator", "max"),
            )?;
            let val = args.usize_or("val", 500);
            let opts = match args.get("threads") {
                Some(t) => EngineOptions::threads(t.parse()?),
                None => EngineOptions::default(),
            };
            let th = QuantSession::open(reg, &artifacts, model)?
                .calibrate(CalibOpts::images(100))?
                .identity(&spec)?;
            let fake = th.quant_accuracy(val)?;
            let engine = th.serve(opts)?;
            let t0 = std::time::Instant::now();
            let engine_acc = int8_accuracy(&engine, val)?;
            let dt = t0.elapsed();
            println!(
                "{model} [{}]: fake-quant {:.2}%  int8-engine {:.2}%  \
                 ({} int8 param bytes, {} worker(s), {:.1} img/s)",
                spec.mode().name(),
                fake * 100.0,
                engine_acc * 100.0,
                engine.param_bytes(),
                engine.threads(),
                val as f64 / dt.as_secs_f64()
            );
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn run_pipeline(
    reg: &Arc<Registry>,
    artifacts: &std::path::Path,
    cfg: &PipelineConfig,
) -> Result<()> {
    let spec = cfg.quant_spec()?;
    println!(
        "== FAT pipeline: {} [{}] calibrator={} ==",
        cfg.model,
        cfg.mode,
        spec.calibrator.name()
    );
    // scope the session so a later dws_rescale holds the only reference
    // to the model state (no copy-on-write)
    let t0 = std::time::Instant::now();
    let session = QuantSession::open(reg.clone(), artifacts, &cfg.model)?;
    println!("backend: {}", session.core().backend_name());
    let mut cal = session.calibrate(CalibOpts::images(cfg.calib_images))?;
    drop(session);
    println!(
        "calibrated on {} images ({} batches) in {:.1}s",
        cfg.calib_images,
        cal.stats().batches,
        t0.elapsed().as_secs_f64()
    );

    if cfg.dws_rescale {
        cal = cal.dws_rescale()?;
        for r in cal.rescale_reports() {
            println!(
                "  dws {}→{}: threshold spread {:.1}→{:.1} ({} locked / {})",
                r.dw, r.conv, r.spread_before, r.spread_after, r.locked,
                r.channels
            );
        }
    }

    let fp = cal.fp_accuracy(cfg.val_images)?;
    let q0 = cal.identity(&spec)?.quant_accuracy(cfg.val_images)?;
    println!(
        "FP acc {:.2}%   quant (no finetune) {:.2}%",
        fp * 100.0,
        q0 * 100.0
    );

    let t1 = std::time::Instant::now();
    let th = cal.finetune(&spec, &cfg.finetune_opts(false), |step, loss, lr| {
        if step % 10 == 0 {
            println!("  step {step}: rmse {loss:.4} lr {lr:.4}");
        }
    })?;
    let losses = th.losses();
    println!(
        "fine-tuned {} steps in {:.1}s (rmse {:.4} → {:.4})",
        losses.len(),
        t1.elapsed().as_secs_f64(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );

    let q1 = th.quant_accuracy(cfg.val_images)?;
    let engine = th.serve(EngineOptions::default())?;
    let int8_acc = int8_accuracy(&engine, cfg.val_images.clamp(100, 500))?;
    println!("quant (FAT)     {:.2}%", q1 * 100.0);
    println!(
        "int8 engine     {:.2}%  ({} param bytes)",
        int8_acc * 100.0,
        engine.param_bytes()
    );
    println!(
        "ladder: FP {:.2} → no-ft {:.2} → FAT {:.2} (drop {:.2}%)",
        fp * 100.0,
        q0 * 100.0,
        q1 * 100.0,
        (fp - q1) * 100.0
    );
    Ok(())
}
