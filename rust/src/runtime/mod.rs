//! PJRT runtime: loads AOT HLO-text artifacts, compiles them on the CPU
//! PJRT client, and marshals host tensors in manifest order.
//!
//! Python is never involved: the HLO text in `artifacts/` is the entire
//! interchange (see /opt/xla-example/README.md for why text, not proto).
//!
//! The PJRT backing (the external `xla` crate) is gated behind the
//! `pjrt` cargo feature so the crate builds on boxes without the PJRT
//! C library. Without the feature, [`Runtime`] and [`Artifact`] are
//! API-identical stubs: constructing the runtime succeeds, and only
//! executing an AOT artifact errors — which nothing reaches by default,
//! because backend resolution (`quant::backend::resolve`) routes every
//! float-side stage to the native FP32 executor (`crate::fp`) whenever
//! PJRT or the artifacts are absent.

#[cfg(feature = "pjrt")]
pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod registry;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use artifact::Artifact;
#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use registry::Registry;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Artifact, Runtime};

/// Whether this build can execute AOT PJRT artifacts (the `pjrt` cargo
/// feature). Backend resolution and artifact-gated tests consult this
/// instead of probing `Runtime::cpu()`, which always succeeds now.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}
