//! PJRT runtime: loads AOT HLO-text artifacts, compiles them on the CPU
//! PJRT client, and marshals host tensors in manifest order.
//!
//! Python is never involved: the HLO text in `artifacts/` is the entire
//! interchange (see /opt/xla-example/README.md for why text, not proto).
//!
//! The PJRT backing (the external `xla` crate) is gated behind the
//! `pjrt` cargo feature so the crate builds on boxes without the PJRT
//! C library. Without the feature, [`Runtime`] and [`Artifact`] are
//! API-identical stubs that report a clear error at runtime; everything
//! artifact-free (the int8 engine, quant math, data substrate) is
//! unaffected.

#[cfg(feature = "pjrt")]
pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod registry;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use artifact::Artifact;
#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use registry::Registry;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Artifact, Runtime};
