//! PJRT runtime: loads AOT HLO-text artifacts, compiles them on the CPU
//! PJRT client, and marshals host tensors in manifest order.
//!
//! Python is never involved: the HLO text in `artifacts/` is the entire
//! interchange (see /opt/xla-example/README.md for why text, not proto).

pub mod artifact;
pub mod client;
pub mod registry;

pub use artifact::Artifact;
pub use client::Runtime;
pub use registry::Registry;
