//! One compiled AOT artifact: HLO text + manifest + PJRT executable.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::{ArtifactManifest, IoSpec};
use crate::tensor::{Data, DType, Tensor};

use super::client::Runtime;

/// A compiled executable with its marshalling manifest.
pub struct Artifact {
    pub manifest: ArtifactManifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Load `<prefix>.hlo.txt` + `<prefix>.manifest.json` and compile.
    pub fn load<P: AsRef<Path>>(rt: &Runtime, prefix: P) -> Result<Self> {
        let prefix = prefix.as_ref();
        let hlo = prefix.with_extension("hlo.txt");
        let man = prefix.with_extension("manifest.json");
        let manifest = ArtifactManifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .map_err(|e| anyhow::anyhow!("parsing {hlo:?}: {e}"))
            .context("HLO text load")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = rt
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {hlo:?}: {e}"))?;
        Ok(Artifact { manifest, exe })
    }

    /// Upload a host tensor as a device buffer (for arguments reused
    /// across many calls, e.g. the frozen weights).
    pub fn upload(&self, rt: &Runtime, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let ty = to_elem_ty(t.dtype());
        rt.client
            .buffer_from_host_raw_bytes(ty, t.raw_bytes(), &t.shape, None)
            .map_err(|e| anyhow::anyhow!("upload: {e}"))
    }

    /// Validate + upload all inputs in manifest order.
    pub fn upload_inputs(
        &self,
        rt: &Runtime,
        inputs: &[Tensor],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.check_inputs(inputs)?;
        inputs.iter().map(|t| self.upload(rt, t)).collect()
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.manifest.name,
            self.manifest.inputs.len(),
            inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&self.manifest.inputs) {
            anyhow::ensure!(
                t.shape == spec.shape && t.dtype() == spec.dtype()?,
                "{}: input {} expects {:?}/{}, got {:?}/{:?}",
                self.manifest.name,
                spec.name,
                spec.shape,
                spec.dtype,
                t.shape,
                t.dtype()
            );
        }
        Ok(())
    }

    /// Execute with host tensors (uploads everything each call).
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let ty = to_elem_ty(t.dtype());
                xla::Literal::create_from_shape_and_untyped_data(
                    ty,
                    &t.shape,
                    t.raw_bytes(),
                )
                .map_err(|e| anyhow::anyhow!("literal: {e}"))
            })
            .collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.manifest.name))?;
        self.unpack(&out[0][0])
    }

    /// Execute with pre-uploaded device buffers (hot path).
    pub fn execute_buffers(
        &self,
        bufs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let out = self
            .exe
            .execute_b(bufs)
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e}", self.manifest.name))?;
        self.unpack(&out[0][0])
    }

    fn unpack(&self, buf: &xla::PjRtBuffer) -> Result<Vec<Tensor>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        // artifacts are lowered with return_tuple=True
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e}"))?;
        anyhow::ensure!(
            parts.len() == self.manifest.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.manifest.name,
            self.manifest.outputs.len(),
            parts.len()
        );
        parts
            .into_iter()
            .zip(&self.manifest.outputs)
            .map(|(l, spec)| literal_to_tensor(&l, spec))
            .collect()
    }
}

fn to_elem_ty(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I8 => xla::ElementType::S8,
        DType::I32 => xla::ElementType::S32,
        DType::U8 => xla::ElementType::U8,
    }
}


fn literal_to_tensor(l: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
    let data = match spec.dtype()? {
        DType::F32 => Data::F32(
            l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?,
        ),
        DType::I32 => Data::I32(
            l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?,
        ),
        DType::U8 => Data::U8(
            l.to_vec::<u8>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?,
        ),
        DType::I8 => {
            let v =
                l.to_vec::<u8>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
            Data::I8(v.into_iter().map(|b| b as i8).collect())
        }
    };
    Ok(Tensor { shape: spec.shape.clone(), data })
}
