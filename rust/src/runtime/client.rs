//! PJRT client wrapper.

use anyhow::Result;

/// Shared PJRT CPU client. Cheap to clone (the underlying client is
/// reference-counted by the xla crate).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}
