//! No-PJRT stand-ins for the `client::Runtime` / `artifact::Artifact`
//! pair (compiled when the `pjrt` feature is off). They keep the same
//! API surface so every binary, bench and test builds unchanged.
//!
//! Since the native FP32 backend landed (DESIGN.md §7), a build without
//! `pjrt` is **not** degraded: calibration, fine-tuning, evaluation and
//! export all run natively (`quant::backend::resolve` picks
//! `NativeExec` automatically). Constructing the stub [`Runtime`]
//! therefore succeeds — only executing a loaded AOT [`Artifact`]
//! reports an error, and nothing reaches that call unless the backend
//! was explicitly forced to the artifact path.

use std::path::Path;

use anyhow::Result;

use crate::model::ArtifactManifest;
use crate::tensor::Tensor;

const NO_PJRT: &str = "fat was built without the `pjrt` feature, so AOT \
PJRT artifacts cannot execute. This does not block the pipeline: the \
native backend (the default when artifacts are absent — see DESIGN.md \
§7) runs calibrate → fine-tune → export → int8 serving in pure Rust. \
To execute the AOT artifacts instead, add the `xla` crate (PJRT CPU \
bindings) to rust/Cargo.toml [dependencies] (e.g. a vendored checkout: \
xla = { path = \"vendor/xla\" }), build with `--features pjrt`, and run \
`make artifacts`.";

/// Stub PJRT client. Construction succeeds (the registry and session
/// plumbing are backend-agnostic); only artifact execution errors.
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime)
    }

    pub fn platform(&self) -> String {
        "none (built without `pjrt`; native backend available)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Stub compiled artifact: carries the manifest, errors on execution.
pub struct Artifact {
    pub manifest: ArtifactManifest,
}

impl Artifact {
    /// Load `<prefix>.manifest.json`; compilation is unavailable, so any
    /// later [`Artifact::execute`] fails with a clear message.
    pub fn load<P: AsRef<Path>>(_rt: &Runtime, prefix: P) -> Result<Self> {
        let man = prefix.as_ref().with_extension("manifest.json");
        Ok(Artifact { manifest: ArtifactManifest::load(&man)? })
    }

    pub fn execute(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        anyhow::bail!("{}: {NO_PJRT}", self.manifest.name)
    }
}
