//! No-PJRT stand-ins for the `client::Runtime` / `artifact::Artifact`
//! pair (compiled when the `pjrt` feature is off). They keep the same
//! API surface so every binary, bench and test
//! builds unchanged; constructing the runtime reports a clear error, and
//! artifact-gated code paths (which check for `artifacts/` first) skip
//! exactly as they do before `make artifacts`.

use std::path::Path;

use anyhow::Result;

use crate::model::ArtifactManifest;
use crate::tensor::Tensor;

const NO_PJRT: &str = "fat was built without the `pjrt` feature: the PJRT \
runtime (and the AOT artifact paths) are unavailable. To enable it, add \
the `xla` crate (PJRT CPU bindings) to rust/Cargo.toml [dependencies] \
(e.g. a vendored checkout: xla = { path = \"vendor/xla\" }) and build \
with `--features pjrt`; the int8 engine, quantization math and data \
substrate work without it.";

/// Stub PJRT client.
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn platform(&self) -> String {
        "none (built without `pjrt`)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Stub compiled artifact: carries the manifest, errors on execution.
pub struct Artifact {
    pub manifest: ArtifactManifest,
}

impl Artifact {
    /// Load `<prefix>.manifest.json`; compilation is unavailable, so any
    /// later [`Artifact::execute`] fails with a clear message.
    pub fn load<P: AsRef<Path>>(_rt: &Runtime, prefix: P) -> Result<Self> {
        let man = prefix.as_ref().with_extension("manifest.json");
        Ok(Artifact { manifest: ArtifactManifest::load(&man)? })
    }

    pub fn execute(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        anyhow::bail!("{}: {NO_PJRT}", self.manifest.name)
    }
}
