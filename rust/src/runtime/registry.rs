//! Lazily-compiled artifact cache: each HLO module is compiled at most
//! once per process, keyed by path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::{Artifact, Runtime};

/// Thread-safe artifact registry.
pub struct Registry {
    rt: Arc<Runtime>,
    cache: Mutex<HashMap<PathBuf, Arc<Artifact>>>,
}

impl Registry {
    pub fn new(rt: Arc<Runtime>) -> Self {
        Registry { rt, cache: Mutex::new(HashMap::new()) }
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Get (compiling on first use) the artifact at `prefix`.
    pub fn get<P: AsRef<Path>>(&self, prefix: P) -> Result<Arc<Artifact>> {
        let key = prefix.as_ref().to_path_buf();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(a) = cache.get(&key) {
                return Ok(a.clone());
            }
        }
        // compile outside the lock (can take seconds)
        let art = Arc::new(Artifact::load(&self.rt, &key)?);
        self.cache
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| art.clone());
        Ok(art)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
