//! Quantized-model executor. `quant::export::build_qmodel` compiles the
//! folded graph into an [`ExecPlan`] once (topological schedule, dense
//! parameter table, liveness-based buffer slots — see `int8::plan`);
//! this module executes that plan with integer-only kernels, an i8
//! buffer arena and two axes of parallelism: independent images of a
//! batch are sharded across workers in [`QModel::run_batch`], and
//! single-image runs shard GEMM/depthwise rows inside the kernels. The
//! worker count defaults to `$FAT_THREADS` (see `util::threads`); every
//! thread count is bit-exact with the sequential reference interpreter
//! [`QModel::run_quant_ref`].

use anyhow::Result;

use crate::model::{GraphDef, Op};
use crate::quant::scale::QParams;
use crate::tensor::Tensor;
use crate::util::threads::fat_threads;

use super::ops::{self, OpCtx};
use super::plan::{Arena, ExecPlan};
use super::qtensor::QTensor;

/// Parameters of one conv-like quantized layer. Weight bytes live in
/// [`crate::artifact::I8Slab`]s: owned when built by
/// `quant::export::build_qmodel`, windows into a shared read-only
/// mapping when loaded zero-copy from a `.fatm` artifact
/// (`crate::artifact`).
#[derive(Debug, Clone)]
pub struct QLayer {
    /// conv: (k*k*cin, cout) row-major; dwconv: (k,k,ch); dense: (cin, cout)
    pub w_q: crate::artifact::I8Slab,
    pub w_sums: Vec<i32>,
    pub bias_q: Vec<i32>,
    /// Per output channel (m0, shift): s_in * s_w[c] / s_out.
    pub requant: Vec<(i32, i32)>,
    /// Per-channel **rounding-shift** requant table — present iff the
    /// exporter ran in power-of-two mode and every multiplier collapsed
    /// to an exact `2^-shift[c]` (`quant::scale::shift_table`). When
    /// set, the kernels take the shift-only epilogue
    /// (`ops::requant_store_shift`) and `requant` is carried only for
    /// diagnostics/serialization cross-checks. Note the two epilogues
    /// round differently (the multiplier path rounds twice), so this is
    /// a distinct numeric mode, not a fast path.
    pub requant_shift: Option<Vec<i32>>,
    pub out_qp: QParams,
    pub clamp: (i32, i32),
    /// Per-channel weight scales (len 1 in scalar mode).
    pub w_scales: Vec<f32>,
    /// Conv/dense weights prepacked at plan-build time for the SIMD
    /// microkernels (`int8::kernels`); `None` for depthwise layers and
    /// ad-hoc hand-built layers (those run the unpacked kernel).
    pub packed: Option<super::kernels::PackedWeights>,
    /// GEMM loop schedule for this layer — [`Default::default`] unless
    /// the autotuner (`int8::tune`) picked a better one; persisted in
    /// the `.fatm` PLAN section (v2) and validated on load. Its `nr`
    /// always matches the strip width `packed` was packed with.
    pub blocking: super::kernels::Blocking,
    /// Execute this layer on the fused implicit-GEMM path
    /// (`ops::conv2d_fused`, DESIGN.md §14): A micro-panels assembled on
    /// the fly from the NHWC input and requant applied in the
    /// register-tile epilogue — no patch matrix, no i32 buffer.
    /// Tuner-assigned (`int8::tune`), persisted in the `.fatm` PLAN
    /// section (v4); only meaningful with `packed`. The `FAT_FUSED` env
    /// gate can veto it process-wide at run time.
    pub fused: bool,
}

#[derive(Debug, Clone)]
pub struct AddParams {
    pub ma: (i32, i32),
    pub mb: (i32, i32),
    pub out_qp: QParams,
    pub clamp: (i32, i32),
}

#[derive(Debug, Clone)]
pub struct GapParams {
    pub m: (i32, i32),
    pub out_qp: QParams,
}

#[derive(Debug, Clone)]
pub enum QNode {
    Layer(QLayer),
    Add(AddParams),
    Gap(GapParams),
    /// relu/relu6 whose clamp was fused into the producer.
    Passthrough,
}

/// Batch-shard geometry shared by [`QModel::run_batch_with`] and the
/// serving handle (`int8::serve`): `(shards, kernel_threads, rows)` —
/// worker count clamped to the batch, leftover capacity row-sharding
/// the kernels inside each worker, and images per shard. Keeping this
/// in one place is what makes the pooled serving path bit-exact with
/// the bare engine by construction.
pub(crate) fn shard_geometry(
    threads: usize,
    batch: usize,
) -> (usize, usize, usize) {
    let t = threads.max(1);
    let shards = t.min(batch.max(1));
    (shards, t.div_ceil(shards), batch.div_ceil(shards))
}

/// Peak scratch footprint of one execution state, in bytes: the staged
/// conv path's im2col patch matrix and i32 accumulator high-water
/// marks ([`OpCtx::scratch_bytes`]) plus the activation [`Arena`]'s
/// pooled-capacity high-water mark. Vec capacities only grow, so these
/// are true peaks over the state's lifetime. Fused layers bypass the
/// first two entirely — `/stats` and `fat info --fatm` surface this so
/// the fused path's memory win is observable, not just timed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchStats {
    pub patches_bytes: usize,
    pub acc_bytes: usize,
    pub arena_bytes: usize,
}

impl ScratchStats {
    /// Element-wise max — aggregates peaks across pooled states.
    pub fn max(self, o: ScratchStats) -> ScratchStats {
        ScratchStats {
            patches_bytes: self.patches_bytes.max(o.patches_bytes),
            acc_bytes: self.acc_bytes.max(o.acc_bytes),
            arena_bytes: self.arena_bytes.max(o.arena_bytes),
        }
    }
}

/// Reusable per-worker execution state: the plan's slot table, the
/// activation-buffer [`Arena`] and the kernels' im2col/accumulator
/// scratch ([`OpCtx`]). One state serves one inference at a time;
/// keeping it alive across [`QModel::run_quant_state`] calls removes
/// the per-call allocations. [`crate::int8::serve::Int8Engine`] pools
/// these per worker.
#[derive(Default)]
pub struct ExecState {
    slots: Vec<Option<QTensor>>,
    arena: Arena,
    ctx: OpCtx,
}

impl ExecState {
    /// Empty state with a kernel worker count.
    pub fn with_threads(threads: usize) -> Self {
        ExecState {
            slots: Vec::new(),
            arena: Arena::default(),
            ctx: OpCtx::with_threads(threads),
        }
    }

    /// Empty state with an explicit worker count **and** kernel ISA —
    /// the in-process ISA-sweep path (artifact round-trip tests, A/B
    /// runs). [`Isa::detect`](super::kernels::Isa::detect) caches the
    /// process-wide level once, so sweeping ISAs requires pinning it
    /// per state rather than mutating the environment.
    pub fn with_threads_isa(
        threads: usize,
        isa: super::kernels::Isa,
    ) -> Self {
        let mut st = Self::with_threads(threads);
        st.ctx.isa = isa;
        st
    }

    /// Change the kernel worker count for subsequent runs.
    pub fn set_threads(&mut self, threads: usize) {
        self.ctx.threads = threads.max(1);
    }

    /// Kernel worker count used by runs through this state.
    pub fn threads(&self) -> usize {
        self.ctx.threads
    }

    /// Hand a dead i8 buffer (e.g. a consumed output) back to the arena.
    pub fn recycle(&mut self, buf: Vec<i8>) {
        self.arena.put(buf);
    }

    /// Borrow a recycled (empty, capacity-retaining) buffer from this
    /// state's arena — the serving handle quantizes request pixels into
    /// it and feeds the result back via [`QModel::run_quant_state`], so
    /// the unbatched single-image path allocates nothing at steady
    /// state either.
    pub fn take_buffer(&mut self) -> Vec<i8> {
        self.arena.take()
    }

    /// Number of pooled arena buffers (diagnostics).
    pub fn pooled_buffers(&self) -> usize {
        self.arena.pooled()
    }

    /// Peak scratch/arena footprint of this state ([`ScratchStats`]).
    pub fn scratch_stats(&self) -> ScratchStats {
        let (patches_bytes, acc_bytes) = self.ctx.scratch_bytes();
        ScratchStats {
            patches_bytes,
            acc_bytes,
            arena_bytes: self.arena.hi_bytes(),
        }
    }
}

/// A fully-quantized model, ready for integer-only inference.
#[derive(Debug, Clone)]
pub struct QModel {
    pub graph: GraphDef,
    /// Precompiled schedule + parameters (built once at export).
    pub plan: ExecPlan,
    pub input_qp: QParams,
    /// total int8 parameter bytes (for the size report)
    pub param_bytes: usize,
}

impl QModel {
    /// Quantized parameters of a compute node, if it has any.
    pub fn node(&self, id: &str) -> Option<&QNode> {
        self.plan.node(id)
    }

    /// Distinct GEMM blockings in use and how many layers carry each —
    /// surfaced by `/stats` and `fat info`. A freshly built (untuned)
    /// model reports a single [`Blocking::default`] entry.
    ///
    /// [`Blocking::default`]: super::kernels::Blocking::default
    pub fn blocking_summary(&self) -> Vec<(super::kernels::Blocking, usize)> {
        let mut out: Vec<(super::kernels::Blocking, usize)> = Vec::new();
        for p in &self.plan.params {
            if let QNode::Layer(l) = p {
                match out.iter_mut().find(|(b, _)| *b == l.blocking) {
                    Some((_, c)) => *c += 1,
                    None => out.push((l.blocking, 1)),
                }
            }
        }
        out
    }

    /// Per-layer census of the requant epilogue and packed-weight width:
    /// `(shift_layers, mul_layers, int4_layers, int8_layers)` —
    /// surfaced by `/stats` and `fat info --fatm` so a pow2/int4 export
    /// is visible end to end. Unpacked layers (depthwise) count as
    /// int8: their weights are stored at a byte per lane.
    pub fn epilogue_summary(&self) -> (usize, usize, usize, usize) {
        let (mut sh, mut mu, mut b4, mut b8) = (0usize, 0usize, 0usize, 0usize);
        for p in &self.plan.params {
            if let QNode::Layer(l) = p {
                if l.requant_shift.is_some() {
                    sh += 1;
                } else {
                    mu += 1;
                }
                match l.packed.as_ref().map(|pw| pw.bits()) {
                    Some(4) => b4 += 1,
                    _ => b8 += 1,
                }
            }
        }
        (sh, mu, b4, b8)
    }

    /// Per-layer census of the conv/dense execution path:
    /// `(fused_layers, staged_layers)` — surfaced by `/stats` and
    /// `fat info --fatm`. Counts the plan's fused bits (what the tuner
    /// chose and the artifact persists); the run-time `FAT_FUSED` gate
    /// can still veto them process-wide. Unpacked layers (depthwise,
    /// ad-hoc) always count as staged.
    pub fn fused_summary(&self) -> (usize, usize) {
        let (mut fu, mut st) = (0usize, 0usize);
        for p in &self.plan.params {
            if let QNode::Layer(l) = p {
                if l.fused && l.packed.is_some() {
                    fu += 1;
                } else {
                    st += 1;
                }
            }
        }
        (fu, st)
    }

    /// Run a float NHWC batch through the integer engine; returns f32
    /// logits (dequantized from the final site). Uses `$FAT_THREADS`
    /// workers (batch-sharded across independent images).
    pub fn run_batch(&self, x: &Tensor) -> Result<Tensor> {
        self.run_batch_with(x, fat_threads())
    }

    /// [`QModel::run_batch`] with an explicit worker count.
    pub fn run_batch_with(&self, x: &Tensor, threads: usize) -> Result<Tensor> {
        let q = QTensor::quantize(x.shape.clone(), x.as_f32()?, self.input_qp);
        let batch = q.shape[0];
        let per_img: usize = q.shape[1..].iter().product();
        let (shards, kernel_threads, rows) = shard_geometry(threads, batch);
        let logits = if shards <= 1 || per_img == 0 {
            self.run_quant_with(q, threads.max(1))?
        } else {
            self.run_sharded(q, shards, kernel_threads, rows)?
        };
        let n = logits.shape[0];
        let c = logits.shape[1];
        Ok(Tensor::f32(vec![n, c], logits.dequantize()))
    }

    /// Split the batch into `shards` contiguous image groups and run them
    /// on pool workers with fresh per-worker states. Images are
    /// independent through every kernel, so the concatenated logits are
    /// bit-exact with the unsharded run.
    fn run_sharded(
        &self,
        q: QTensor,
        shards: usize,
        kernel_threads: usize,
        rows: usize,
    ) -> Result<QTensor> {
        let mut states: Vec<ExecState> = (0..shards)
            .map(|_| ExecState::with_threads(kernel_threads))
            .collect();
        self.run_sharded_states(q, rows, &mut states)
    }

    /// Shared sharded executor: split the batch into `rows`-image chunks,
    /// run chunk *i* on `states[i]`, and stitch the logits in order
    /// (chunk count never exceeds the shard count the rows were derived
    /// from, so `states` is always long enough). Consumed output buffers
    /// are recycled into their worker's arena. Both [`QModel::run_batch_with`]
    /// (fresh states) and the pooled `int8::serve::Int8Engine` call this,
    /// so their outputs are identical by construction.
    pub(crate) fn run_sharded_states(
        &self,
        q: QTensor,
        rows: usize,
        states: &mut [ExecState],
    ) -> Result<QTensor> {
        self.run_rows_sharded(&q.data, &q.shape, q.qp, rows, states)
    }

    /// Row-writable sharded input path: the batch input is a borrowed,
    /// already-quantized `(n, per_img)` i8 slab (assembled in place by
    /// the micro-batcher, or the data of an owned [`QTensor`] via
    /// [`QModel::run_sharded_states`]). Per-shard chunk copies come out
    /// of each worker state's arena ([`Arena::take_filled`]), so the
    /// steady-state sharded path performs no input allocation, and the
    /// caller keeps ownership of the assembled rows for reuse.
    pub(crate) fn run_rows_sharded(
        &self,
        rows: &[i8],
        shape: &[usize],
        in_qp: QParams,
        rows_per_shard: usize,
        states: &mut [ExecState],
    ) -> Result<QTensor> {
        let per_img: usize = shape[1..].iter().product();
        debug_assert!(rows_per_shard * per_img > 0, "degenerate shard geometry");
        let chunks = shape[0].div_ceil(rows_per_shard.max(1));
        debug_assert!(
            chunks <= states.len(),
            "fewer worker states than chunks"
        );
        // Pair each chunk's result cell with its worker state so the
        // pool shards can borrow both mutably through one slab each.
        let mut cells: Vec<(Option<Result<QTensor>>, &mut ExecState)> =
            states.iter_mut().take(chunks).map(|st| (None, st)).collect();
        crate::util::threads::pool().run_chunks(&mut cells, 1, |i, cell| {
            let (res, st) = &mut cell[0];
            let start = i * rows_per_shard * per_img;
            let end = (start + rows_per_shard * per_img).min(rows.len());
            let chunk = &rows[start..end];
            let mut sub_shape = shape.to_vec();
            sub_shape[0] = chunk.len() / per_img;
            let sub = QTensor {
                shape: sub_shape,
                data: st.arena.take_filled(chunk),
                qp: in_qp,
            };
            *res = Some(self.run_quant_state(sub, st));
        });
        let mut data = Vec::new();
        let mut classes = 0usize;
        let mut total = 0usize;
        let mut qp = in_qp;
        let mut first_err = None;
        for (part, st) in cells.iter_mut() {
            match part.take().expect("pool shard ran") {
                Ok(t) => {
                    classes = t.shape[1];
                    qp = t.qp;
                    total += t.shape[0];
                    data.extend_from_slice(&t.data);
                    st.recycle(t.data);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(QTensor { shape: vec![total, classes], data, qp })
    }

    /// Integer-only path: quantized input to quantized logits, with
    /// `$FAT_THREADS` workers row-sharding the kernels.
    pub fn run_quant(&self, input: QTensor) -> Result<QTensor> {
        self.run_quant_with(input, fat_threads())
    }

    /// [`QModel::run_quant_state`] with a fresh throwaway [`ExecState`].
    /// Serving callers should prefer [`crate::int8::serve::Int8Engine`],
    /// which pools states across calls instead of re-allocating them.
    pub fn run_quant_with(
        &self,
        input: QTensor,
        threads: usize,
    ) -> Result<QTensor> {
        let mut state = ExecState::with_threads(threads);
        self.run_quant_state(input, &mut state)
    }

    /// Execute the precompiled plan using caller-owned, reusable state.
    /// Activation buffers recycle through the state's [`Arena`], and
    /// im2col/accumulator scratch is reused across nodes *and across
    /// calls* — repeated inference through one state performs no
    /// steady-state allocation beyond the output tensor. Bit-exact with
    /// a fresh state for any state history (buffers are fully
    /// overwritten before use).
    pub fn run_quant_state(
        &self,
        input: QTensor,
        state: &mut ExecState,
    ) -> Result<QTensor> {
        let plan = &self.plan;
        // Drop stale values (possible after an earlier mid-plan error)
        // and fit the slot table to this model's plan.
        for s in state.slots.iter_mut() {
            if let Some(dead) = s.take() {
                state.arena.put(dead.data);
            }
        }
        state.slots.resize_with(plan.num_slots, || None);
        state.slots[plan.input_slot] = Some(input);
        let steps = &plan.steps;
        let mut si = 0usize;
        while si < steps.len() {
            let step = &steps[si];
            // Fused conv → add chain (DESIGN.md §14): when this conv runs
            // the fused epilogue and the next step is a residual add
            // whose liveness proves it is the sole consumer of the conv
            // output (the add frees the conv's dst slot), the add's
            // rescale runs inside the conv's register-tile epilogue and
            // the intermediate conv activation is never materialized.
            if step.op == Op::Conv {
                if let (Some(nx), QNode::Layer(l)) =
                    (steps.get(si + 1), &plan.params[step.param])
                {
                    if let QNode::Add(p) = &plan.params[nx.param] {
                        let conv_is_a = nx.a == step.dst;
                        let conv_is_b = nx.b == Some(step.dst);
                        if ops::takes_fused_path(l)
                            && (conv_is_a ^ conv_is_b)
                            && nx.frees.contains(&step.dst)
                        {
                            let other =
                                if conv_is_a { nx.b.unwrap() } else { nx.a };
                            let out_buf = state.arena.take();
                            let out = {
                                let a = state.slots[step.a]
                                    .as_ref()
                                    .ok_or_else(|| {
                                        anyhow::anyhow!(
                                            "{}: input slot {} empty",
                                            step.id,
                                            step.a
                                        )
                                    })?;
                                let b = state.slots[other]
                                    .as_ref()
                                    .ok_or_else(|| {
                                        anyhow::anyhow!(
                                            "{}: input slot {other} empty",
                                            nx.id
                                        )
                                    })?;
                                ops::conv2d_fused(
                                    a,
                                    l,
                                    step.k,
                                    step.stride,
                                    step.cout,
                                    &mut state.ctx,
                                    out_buf,
                                    Some(ops::ConvResidual {
                                        b,
                                        params: p,
                                        conv_is_a,
                                    }),
                                )
                            };
                            // both steps' frees; the conv dst was never
                            // materialized, so its take() is a no-op
                            for &f in step.frees.iter().chain(&nx.frees) {
                                if let Some(dead) = state.slots[f].take() {
                                    state.arena.put(dead.data);
                                }
                            }
                            state.slots[nx.dst] = Some(out);
                            si += 2;
                            continue;
                        }
                    }
                }
            }
            let out_buf = state.arena.take();
            let out = {
                let a = state.slots[step.a].as_ref().ok_or_else(|| {
                    anyhow::anyhow!("{}: input slot {} empty", step.id, step.a)
                })?;
                match &plan.params[step.param] {
                    QNode::Layer(l) => match step.op {
                        Op::Conv => ops::conv2d(
                            a, l, step.k, step.stride, step.cout,
                            &mut state.ctx, out_buf,
                        ),
                        Op::DwConv => ops::dwconv2d(
                            a, l, step.k, step.stride, &mut state.ctx,
                            out_buf,
                        ),
                        Op::Dense => {
                            ops::dense(a, l, step.cout, &mut state.ctx, out_buf)
                        }
                        op => anyhow::bail!(
                            "{}: op {op:?} scheduled with layer params",
                            step.id
                        ),
                    },
                    QNode::Add(p) => {
                        let bs = step.b.ok_or_else(|| {
                            anyhow::anyhow!("{}: add without 2nd input", step.id)
                        })?;
                        let b = state.slots[bs].as_ref().ok_or_else(|| {
                            anyhow::anyhow!("{}: input slot {bs} empty", step.id)
                        })?;
                        ops::add(a, b, p, out_buf)
                    }
                    QNode::Gap(p) => ops::gap(a, p, out_buf),
                    QNode::Passthrough => anyhow::bail!(
                        "{}: passthrough compiled as a step",
                        step.id
                    ),
                }
            };
            for &f in &step.frees {
                if let Some(dead) = state.slots[f].take() {
                    state.arena.put(dead.data);
                }
            }
            state.slots[step.dst] = Some(out);
            si += 1;
        }
        state.slots[plan.output_slot]
            .take()
            .ok_or_else(|| anyhow::anyhow!("plan produced no output"))
    }

    /// Row-writable single-state path: copy the assembled, already
    /// quantized batch rows into a state-arena buffer and run the plan.
    /// The caller keeps ownership of `rows` (the micro-batcher recycles
    /// its assembly buffer), and the input copy comes out of the
    /// state's arena, so repeated calls through one state stay
    /// allocation-free — the input take balances the output recycle.
    pub(crate) fn run_quant_rows_state(
        &self,
        rows: &[i8],
        shape: Vec<usize>,
        in_qp: QParams,
        state: &mut ExecState,
    ) -> Result<QTensor> {
        let data = state.arena.take_filled(rows);
        self.run_quant_state(QTensor { shape, data, qp: in_qp }, state)
    }

    /// Reference interpreter: the pre-plan sequential `BTreeMap` walk
    /// with per-node allocations, kept as the bit-exactness oracle for
    /// the planned/parallel engine (see `rust/tests/engine_equiv.rs`).
    /// Pinned to the scalar single-threaded kernels so the oracle is
    /// independent of the pool and the SIMD dispatch under test.
    pub fn run_quant_ref(&self, input: QTensor) -> Result<QTensor> {
        use std::collections::BTreeMap;
        let mut vals: BTreeMap<&str, QTensor> = BTreeMap::new();
        let mut last = "input";
        let mut ctx = OpCtx {
            isa: super::kernels::Isa::Scalar,
            ..Default::default()
        };
        for n in &self.graph.nodes {
            if n.op == Op::Input {
                vals.insert(n.id.as_str(), input.clone());
                last = n.id.as_str();
                continue;
            }
            let out = {
                let a = &vals[self.graph.node(&n.inputs[0])?.id.as_str()];
                match (&n.op, self.node(&n.id)) {
                    (Op::Conv, Some(QNode::Layer(l))) => ops::conv2d(
                        a, l, n.k, n.stride, n.cout, &mut ctx, Vec::new(),
                    ),
                    (Op::DwConv, Some(QNode::Layer(l))) => ops::dwconv2d(
                        a, l, n.k, n.stride, &mut ctx, Vec::new(),
                    ),
                    (Op::Dense, Some(QNode::Layer(l))) => {
                        ops::dense(a, l, n.cout, &mut ctx, Vec::new())
                    }
                    (Op::Add, Some(QNode::Add(p))) => {
                        let b =
                            &vals[self.graph.node(&n.inputs[1])?.id.as_str()];
                        ops::add(a, b, p, Vec::new())
                    }
                    (Op::Gap, Some(QNode::Gap(p))) => {
                        ops::gap(a, p, Vec::new())
                    }
                    (Op::Relu | Op::Relu6, _) => a.clone(),
                    (op, entry) => anyhow::bail!(
                        "node {} ({op:?}): missing/invalid qparams ({})",
                        n.id,
                        entry.is_some()
                    ),
                }
            };
            vals.insert(n.id.as_str(), out);
            last = n.id.as_str();
        }
        vals.remove(last)
            .ok_or_else(|| anyhow::anyhow!("empty graph"))
    }

    /// Classification accuracy over (x, labels).
    pub fn accuracy(&self, x: &Tensor, labels: &[i32]) -> Result<f64> {
        let logits = self.run_batch(x)?;
        let n = logits.shape[0];
        let c = logits.shape[1];
        let d = logits.as_f32()?;
        let mut correct = 0usize;
        for i in 0..n {
            let row = &d[i * c..(i + 1) * c];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if arg as i32 == labels[i] {
                correct += 1;
            }
        }
        Ok(correct as f64 / n as f64)
    }
}
