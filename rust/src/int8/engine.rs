//! Quantized-graph executor: walks the folded GraphDef with integer-only
//! kernels. Built by `quant::export::build_qmodel`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::{GraphDef, Op};
use crate::quant::scale::QParams;
use crate::tensor::Tensor;

use super::ops;
use super::qtensor::QTensor;

/// Parameters of one conv-like quantized layer.
#[derive(Debug, Clone)]
pub struct QLayer {
    /// conv: (k*k*cin, cout) row-major; dwconv: (k,k,ch); dense: (cin, cout)
    pub w_q: Vec<i8>,
    pub w_sums: Vec<i32>,
    pub bias_q: Vec<i32>,
    /// Per output channel (m0, shift): s_in * s_w[c] / s_out.
    pub requant: Vec<(i32, i32)>,
    pub out_qp: QParams,
    pub clamp: (i32, i32),
    /// Per-channel weight scales (len 1 in scalar mode).
    pub w_scales: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct AddParams {
    pub ma: (i32, i32),
    pub mb: (i32, i32),
    pub out_qp: QParams,
    pub clamp: (i32, i32),
}

#[derive(Debug, Clone)]
pub struct GapParams {
    pub m: (i32, i32),
    pub out_qp: QParams,
}

#[derive(Debug, Clone)]
pub enum QNode {
    Layer(QLayer),
    Add(AddParams),
    Gap(GapParams),
    /// relu/relu6 whose clamp was fused into the producer.
    Passthrough,
}

/// A fully-quantized model, ready for integer-only inference.
#[derive(Debug, Clone)]
pub struct QModel {
    pub graph: GraphDef,
    pub nodes: BTreeMap<String, QNode>,
    pub input_qp: QParams,
    /// total int8 parameter bytes (for the size report)
    pub param_bytes: usize,
}

impl QModel {
    /// Run a float NHWC batch through the integer engine; returns f32
    /// logits (dequantized from the final site).
    pub fn run_batch(&self, x: &Tensor) -> Result<Tensor> {
        let q = QTensor::quantize(
            x.shape.clone(),
            x.as_f32()?,
            self.input_qp,
        );
        let logits = self.run_quant(q)?;
        let n = logits.shape[0];
        let c = logits.shape[1];
        Ok(Tensor::f32(vec![n, c], logits.dequantize()))
    }

    /// Integer-only path: quantized input to quantized logits.
    pub fn run_quant(&self, input: QTensor) -> Result<QTensor> {
        let mut vals: BTreeMap<&str, QTensor> = BTreeMap::new();
        let mut last = "input";
        for n in &self.graph.nodes {
            if n.op == Op::Input {
                vals.insert(n.id.as_str(), input.clone());
                continue;
            }
            let a = &vals[self.graph.node(&n.inputs[0])?.id.as_str()];
            let out = match (&n.op, self.nodes.get(&n.id)) {
                (Op::Conv, Some(QNode::Layer(l))) => ops::conv2d(
                    a, &l.w_q, &l.w_sums, &l.bias_q, &l.requant, l.out_qp,
                    l.clamp, n.k, n.stride, n.cout,
                ),
                (Op::DwConv, Some(QNode::Layer(l))) => ops::dwconv2d(
                    a, &l.w_q, &l.bias_q, &l.requant, l.out_qp, l.clamp,
                    n.k, n.stride,
                ),
                (Op::Dense, Some(QNode::Layer(l))) => ops::dense(
                    a, &l.w_q, &l.w_sums, &l.bias_q, &l.requant, l.out_qp,
                    l.clamp, n.cout,
                ),
                (Op::Add, Some(QNode::Add(p))) => {
                    let b = &vals[self.graph.node(&n.inputs[1])?.id.as_str()];
                    ops::add(a, b, p.ma, p.mb, p.out_qp, p.clamp)
                }
                (Op::Gap, Some(QNode::Gap(p))) => ops::gap(a, p.m, p.out_qp),
                (Op::Relu | Op::Relu6, _) => a.clone(),
                (op, entry) => anyhow::bail!(
                    "node {} ({op:?}): missing/invalid qparams ({})",
                    n.id,
                    entry.is_some()
                ),
            };
            vals.insert(n.id.as_str(), out);
            last = n.id.as_str();
        }
        Ok(vals.remove(last).unwrap())
    }

    /// Classification accuracy over (x, labels).
    pub fn accuracy(&self, x: &Tensor, labels: &[i32]) -> Result<f64> {
        let logits = self.run_batch(x)?;
        let n = logits.shape[0];
        let c = logits.shape[1];
        let d = logits.as_f32()?;
        let mut correct = 0usize;
        for i in 0..n {
            let row = &d[i * c..(i + 1) * c];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if arg as i32 == labels[i] {
                correct += 1;
            }
        }
        Ok(correct as f64 / n as f64)
    }
}
