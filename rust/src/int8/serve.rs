//! Serving handle for the int8 engine — the one blessed entry point for
//! inference traffic (DESIGN.md §6, §9).
//!
//! [`Int8Engine`] wraps a compiled [`QModel`] (weights + execution plan)
//! behind a cheaply clonable `Arc` handle, so one exported model can be
//! shared across request threads without copying parameters. Worker
//! count and the micro-batching knobs are explicit [`EngineOptions`];
//! every call runs on pooled per-worker [`ExecState`]s drawn from a
//! sharded, lock-light state pool whose resting size is capped at the
//! configured worker count. With [`EngineOptions::batch`] set,
//! concurrent `infer` / `infer_batch` calls coalesce into micro-batches
//! (`int8::batcher`): requests quantize straight into a shared,
//! arena-owned batch row buffer — no per-request `QTensor` allocation,
//! no concat copy — and demux their own logits rows after one sharded
//! plan execution. All entry points, batched or not, are bit-exact with
//! the bare [`QModel::run_batch_with`] path and with `run_quant_ref`
//! for every thread count, batch schedule and pool history (see
//! `rust/tests/session_equiv.rs` and `rust/tests/serve_stress.rs`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::model::Op;
use crate::quant::scale::QParams;
use crate::tensor::Tensor;
use crate::util::threads::fat_threads;

use super::batcher::{BatchOptions, BatchOutput, Batcher, BatcherStats};
use super::engine::{shard_geometry, ExecState, QModel};
use super::qtensor::{quantize_f32_into, quantize_u8_into, to_i8_domain, QTensor};

/// Engine construction options.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineOptions {
    /// Worker count for batch sharding and kernel row sharding —
    /// the top of the precedence chain documented in `util::threads`:
    /// `EngineOptions.threads` > `$FAT_THREADS` (read once per process)
    /// > machine parallelism. Shards execute on the persistent worker
    /// pool, so any count here is a scheduling degree, not a thread
    /// spawn count.
    pub threads: Option<usize>,
    /// Dynamic micro-batching knobs (`int8::batcher`). `None` — the
    /// default — disables the batcher entirely and preserves the
    /// pre-batching serving behavior unchanged.
    pub batch: Option<BatchOptions>,
}

impl EngineOptions {
    /// Pin the worker count explicitly.
    pub fn threads(threads: usize) -> Self {
        EngineOptions { threads: Some(threads), ..Default::default() }
    }

    /// Default worker count with micro-batching at the default knobs.
    pub fn batched() -> Self {
        EngineOptions { batch: Some(BatchOptions::default()), ..Default::default() }
    }

    /// Builder: set the micro-batching knobs.
    pub fn with_batch(mut self, batch: BatchOptions) -> Self {
        self.batch = Some(batch);
        self
    }

    /// Builder: pin the worker count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }
}

/// Input-node facts resolved once at [`Int8Engine::new`] instead of
/// being re-derived from a graph scan on every `infer` call: the HWC
/// shape, its element count, and the input quantization parameters
/// already shifted into the i8 domain.
struct InputMeta {
    shape: Vec<usize>,
    per_img: usize,
    qp: QParams,
}

/// Sharded, lock-light pool of resting [`ExecState`]s. Checkout scans
/// the stripes with `try_lock` (round-robin start) so concurrent
/// requests rarely contend on one mutex; checkout also normalizes the
/// state's kernel thread count, so a state can never carry a stale
/// count from its previous call. Check-in enforces a per-stripe cap
/// whose sum is exactly the engine's configured worker count — the
/// largest number of states one call can use — so a burst of concurrent
/// requests cannot grow the resting pool without bound: excess states
/// are simply dropped.
struct StatePool {
    stripes: Vec<Mutex<Vec<ExecState>>>,
    caps: Vec<usize>,
    next: AtomicUsize,
}

impl StatePool {
    fn new(threads: usize) -> Self {
        let n = threads.clamp(1, 8);
        // Distribute the total cap (= threads) exactly across stripes.
        let caps: Vec<usize> =
            (0..n).map(|i| threads / n + usize::from(i < threads % n)).collect();
        StatePool {
            stripes: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            caps,
            next: AtomicUsize::new(0),
        }
    }

    fn take(&self, threads: usize) -> ExecState {
        let n = self.stripes.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            if let Ok(mut stripe) = self.stripes[(start + i) % n].try_lock() {
                if let Some(mut st) = stripe.pop() {
                    st.set_threads(threads);
                    return st;
                }
            }
        }
        ExecState::with_threads(threads)
    }

    fn put(&self, st: ExecState) {
        let n = self.stripes.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            let idx = (start + i) % n;
            if let Ok(mut stripe) = self.stripes[idx].try_lock() {
                if stripe.len() < self.caps[idx] {
                    stripe.push(st);
                    return;
                }
                // at cap: keep scanning — a warm state is worth keeping
                // while any stripe is under its cap
            }
        }
        // Every stripe contended or full: block on the home stripe,
        // still capped — a genuinely full pool drops the state.
        let idx = start % n;
        let mut stripe = self.stripes[idx].lock().unwrap();
        if stripe.len() < self.caps[idx] {
            stripe.push(st);
        }
    }

    fn resting(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Element-wise max of the resting states' scratch peaks — the
    /// worst per-worker footprint the pool has seen. In-flight states
    /// are invisible until checked back in; resting peaks are the
    /// steady-state answer `/stats` wants.
    fn scratch(&self) -> super::engine::ScratchStats {
        let mut agg = super::engine::ScratchStats::default();
        for s in &self.stripes {
            for st in s.lock().unwrap().iter() {
                agg = agg.max(st.scratch_stats());
            }
        }
        agg
    }
}

struct EngineInner {
    model: QModel,
    threads: usize,
    /// Input facts resolved once at construction (`None` only for a
    /// model whose graph lacks a shaped input node; `infer` then
    /// errors, exactly like the old per-call scan did).
    meta: Option<InputMeta>,
    /// Reusable per-worker execution states (sharded, capped).
    pool: StatePool,
    /// Micro-batch collector; present iff `EngineOptions::batch` asked
    /// for batching and the model has usable input metadata.
    batcher: Option<Batcher>,
    /// Inference calls currently executing (gauge, all entry points).
    in_flight: AtomicU64,
    /// Inference calls ever started (cumulative, all entry points).
    requests: AtomicU64,
}

/// RAII decrement for the engine's `in_flight` gauge — error returns
/// and batch-execution panics still restore the gauge.
struct Gauge<'a>(&'a AtomicU64);

impl Drop for Gauge<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Point-in-time engine counters for `/stats`-style introspection
/// (`crate::net::server` serializes one per registered model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    /// Configured worker count.
    pub threads: usize,
    /// Kernel ISA the engine executes with ([`Isa::detect`] name).
    ///
    /// [`Isa::detect`]: super::kernels::Isa::detect
    pub isa: &'static str,
    /// Execution states resting in the pool right now.
    pub pooled_states: usize,
    /// Inference calls currently executing.
    pub in_flight: u64,
    /// Inference calls ever started.
    pub requests: u64,
    /// Peak per-worker scratch/arena bytes across pooled states
    /// ([`super::engine::ScratchStats`]) — shows the fused path's
    /// staged-scratch bypass as zeros.
    pub scratch: super::engine::ScratchStats,
    /// Micro-batcher counters, when batching is enabled.
    pub batcher: Option<BatcherStats>,
}

/// A cheap-to-clone serving handle over a compiled quantized model.
///
/// Cloning shares the model, the state pool and the micro-batcher
/// (`Arc` internally), so a server can hand one engine to many request
/// workers. Produced by [`crate::quant::session::Thresholded::serve`];
/// [`Int8Engine::infer`] and [`Int8Engine::infer_batch`] are the
/// supported inference paths.
#[derive(Clone)]
pub struct Int8Engine {
    inner: Arc<EngineInner>,
}

impl Int8Engine {
    /// Wrap a compiled model. `opts.threads` pins the worker count
    /// (unset, it follows `$FAT_THREADS` / machine parallelism);
    /// `opts.batch` enables the micro-batching scheduler.
    pub fn new(model: QModel, opts: EngineOptions) -> Self {
        let threads = opts.threads.unwrap_or_else(fat_threads).max(1);
        let meta = model
            .graph
            .nodes
            .iter()
            .find(|n| n.op == Op::Input)
            .and_then(|n| n.input_shape.clone())
            .filter(|sh| sh.len() == 3 && sh.iter().product::<usize>() > 0)
            .map(|sh| InputMeta {
                per_img: sh.iter().product(),
                shape: sh,
                qp: to_i8_domain(model.input_qp),
            });
        let batcher = match (&meta, opts.batch) {
            (Some(m), Some(b)) if b.max_batch >= 2 => {
                Some(Batcher::new(m.per_img, b))
            }
            _ => None,
        };
        Int8Engine {
            inner: Arc::new(EngineInner {
                model,
                threads,
                meta,
                pool: StatePool::new(threads),
                batcher,
                in_flight: AtomicU64::new(0),
                requests: AtomicU64::new(0),
            }),
        }
    }

    /// Count one inference call: bump the cumulative counter and hold
    /// the `in_flight` gauge for the caller's scope.
    fn track(&self) -> Gauge<'_> {
        self.inner.requests.fetch_add(1, Ordering::Relaxed);
        self.inner.in_flight.fetch_add(1, Ordering::Relaxed);
        Gauge(&self.inner.in_flight)
    }

    /// The wrapped quantized model.
    pub fn model(&self) -> &QModel {
        &self.inner.model
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Total int8 parameter bytes of the served model.
    pub fn param_bytes(&self) -> usize {
        self.inner.model.param_bytes
    }

    /// Execution states currently resting in the pool (diagnostics).
    pub fn pooled_states(&self) -> usize {
        self.inner.pool.resting()
    }

    /// Micro-batcher counters `(requests, batches, rows)` when batching
    /// is enabled (diagnostics; mean occupancy is `rows / batches`).
    pub fn batcher_stats(&self) -> Option<(u64, u64, u64)> {
        self.inner.batcher.as_ref().map(|b| b.stats())
    }

    /// Point-in-time counter snapshot across the engine's moving parts
    /// — worker count, pooled states, the request gauge/total, and the
    /// micro-batcher's counters when batching is enabled.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            threads: self.inner.threads,
            isa: super::kernels::Isa::detect().name(),
            pooled_states: self.inner.pool.resting(),
            in_flight: self.inner.in_flight.load(Ordering::Relaxed),
            requests: self.inner.requests.load(Ordering::Relaxed),
            scratch: self.inner.pool.scratch(),
            batcher: self.inner.batcher.as_ref().map(|b| b.snapshot()),
        }
    }

    fn take_state(&self, threads: usize) -> ExecState {
        self.inner.pool.take(threads)
    }

    fn put_state(&self, st: ExecState) {
        self.inner.pool.put(st);
    }

    /// Classify one raw image: `pixels` is HWC u8 data matching the
    /// model's input shape, mapped to floats in `[0, 1]` (`p / 255`).
    /// Returns the logits row. With batching enabled, concurrent calls
    /// coalesce into one plan execution (bit-exact either way).
    pub fn infer(&self, pixels: &[u8]) -> Result<Vec<f32>> {
        let _g = self.track();
        let meta = self.meta()?;
        anyhow::ensure!(
            pixels.len() == meta.per_img,
            "infer: expected {} bytes for input shape {:?}, got {}",
            meta.per_img,
            meta.shape,
            pixels.len()
        );
        let qp = meta.qp;
        if let Some(b) = &self.inner.batcher {
            return b.submit(
                1,
                |rows| quantize_u8_into(pixels, qp, rows),
                |rows, n| self.exec_rows(rows, n),
            );
        }
        // Unbatched: quantize into a state-arena row and run directly —
        // no intermediate f32 tensor, no fresh input allocation.
        let mut st = self.take_state(self.inner.threads);
        let mut data = st.take_buffer();
        quantize_u8_into(pixels, qp, &mut data);
        let shape = vec![1, meta.shape[0], meta.shape[1], meta.shape[2]];
        let q = QTensor { shape, data, qp };
        match self.inner.model.run_quant_state(q, &mut st) {
            Ok(out) => {
                let logits = out.dequantize();
                st.recycle(out.data);
                self.put_state(st);
                Ok(logits)
            }
            Err(e) => {
                self.put_state(st);
                Err(e)
            }
        }
    }

    /// Run a float NHWC batch; returns f32 logits `(n, classes)`.
    /// Batch-shards across the configured worker count; with batching
    /// enabled, input-shaped batches up to `max_batch` rows coalesce
    /// with concurrent traffic.
    pub fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        if let (Some(b), Some(meta)) =
            (&self.inner.batcher, self.inner.meta.as_ref())
        {
            let opts = b.options();
            let joins = x.shape.len() == 4
                && x.shape[1..] == meta.shape[..]
                && x.shape[0] >= 1
                && x.shape[0] <= opts.max_batch;
            if joins {
                let _g = self.track();
                let n = x.shape[0];
                let xs = x.as_f32()?;
                let qp = meta.qp;
                let logits = b.submit(
                    n,
                    |rows| quantize_f32_into(xs, qp, rows),
                    |rows, m| self.exec_rows(rows, m),
                )?;
                let classes = logits.len() / n;
                return Ok(Tensor::f32(vec![n, classes], logits));
            }
        }
        self.infer_batch_with(x, self.inner.threads)
    }

    /// [`Int8Engine::infer_batch`] with an explicit worker count (thread
    /// sweeps); still uses the shared state pool, but always bypasses
    /// the micro-batcher — an explicit count pins this call's schedule.
    pub fn infer_batch_with(&self, x: &Tensor, threads: usize) -> Result<Tensor> {
        let _g = self.track();
        let model = &self.inner.model;
        let q = QTensor::quantize(x.shape.clone(), x.as_f32()?, model.input_qp);
        let batch = q.shape[0];
        let per_img: usize = q.shape[1..].iter().product();
        // Shard geometry comes from the same helper as
        // QModel::run_batch_with, so the pooled path is bit-exact with
        // the bare engine by construction.
        let (shards, kernel_threads, rows) = shard_geometry(threads, batch);
        if shards <= 1 || per_img == 0 {
            let mut st = self.take_state(threads.max(1));
            let out = match model.run_quant_state(q, &mut st) {
                Ok(out) => out,
                Err(e) => {
                    self.put_state(st);
                    return Err(e);
                }
            };
            let (n, c) = (out.shape[0], out.shape[1]);
            let logits = out.dequantize();
            st.recycle(out.data);
            self.put_state(st);
            return Ok(Tensor::f32(vec![n, c], logits));
        }

        let mut states: Vec<ExecState> =
            (0..shards).map(|_| self.take_state(kernel_threads)).collect();
        let result = model.run_sharded_states(q, rows, &mut states);
        for st in states {
            self.put_state(st);
        }
        let logits = result?;
        let (n, c) = (logits.shape[0], logits.shape[1]);
        Ok(Tensor::f32(vec![n, c], logits.dequantize()))
    }

    fn meta(&self) -> Result<&InputMeta> {
        self.inner.meta.as_ref().ok_or_else(|| {
            anyhow::anyhow!("model has no shaped input node")
        })
    }

    /// Execute one sealed micro-batch of `n` already-quantized rows
    /// through exactly the shard geometry the unbatched path uses, on
    /// pooled states — bit-exact with `n` separate requests because
    /// images are independent through every kernel (DESIGN.md §8.3).
    fn exec_rows(&self, rows: Vec<i8>, n: usize) -> Result<BatchOutput> {
        let meta = self.meta()?;
        let model = &self.inner.model;
        let threads = self.inner.threads;
        let shape = vec![n, meta.shape[0], meta.shape[1], meta.shape[2]];
        let (shards, kernel_threads, per_shard) = shard_geometry(threads, n);
        if shards <= 1 {
            let mut st = self.take_state(threads);
            let res =
                model.run_quant_rows_state(&rows, shape, meta.qp, &mut st);
            let out = match res {
                Ok(out) => out,
                Err(e) => {
                    self.put_state(st);
                    return Err(e);
                }
            };
            let classes = out.shape[1];
            let logits = out.dequantize();
            st.recycle(out.data);
            self.put_state(st);
            return Ok(BatchOutput { logits, classes, reclaimed: Some(rows) });
        }
        let mut states: Vec<ExecState> =
            (0..shards).map(|_| self.take_state(kernel_threads)).collect();
        let result = model.run_rows_sharded(
            &rows,
            &shape,
            meta.qp,
            per_shard,
            &mut states,
        );
        for st in states {
            self.put_state(st);
        }
        let out = result?;
        let classes = out.shape[1];
        Ok(BatchOutput {
            logits: out.dequantize(),
            classes,
            reclaimed: Some(rows),
        })
    }
}

/// What [`drive_with`] measured: wall time for the whole run and the
/// per-request latencies (unsorted; feed to `util::bench::percentiles`).
pub struct DriveReport {
    pub wall_secs: f64,
    pub latencies_secs: Vec<f64>,
    pub requests: usize,
}

/// One synthetic client's view of the serving stack: a single-image
/// classify call, whatever the transport. [`Int8Engine`] implements it
/// directly (thread mode); `crate::net::client` implements it over live
/// sockets (HTTP and frame protocols), so the benchmark driver and its
/// bit-exactness oracle are shared by every transport.
pub trait InferClient {
    /// Classify one HWC u8 image; returns the logits row.
    fn infer_one(&mut self, pixels: &[u8]) -> Result<Vec<f32>>;
}

impl InferClient for Int8Engine {
    fn infer_one(&mut self, pixels: &[u8]) -> Result<Vec<f32>> {
        self.infer(pixels)
    }
}

impl<T: InferClient + ?Sized> InferClient for Box<T> {
    fn infer_one(&mut self, pixels: &[u8]) -> Result<Vec<f32>> {
        (**self).infer_one(pixels)
    }
}

/// Closed-loop synthetic client driver shared by the `serve-bench` CLI
/// subcommand (thread and socket transports) and
/// `benches/bench_serve.rs`: spawns `clients` OS threads, each calling
/// `connect(client)` for its own transport handle and then issuing
/// `per_client` single-image [`InferClient::infer_one`] calls with its
/// own deterministic image (`image(client)`), timing every request.
/// When `expected(client)` returns a logits row, every response is
/// checked against it **bit-exactly** — neither the batched scheduler
/// nor a network hop may change a single byte.
pub fn drive_with<C, M, I, E>(
    connect: M,
    clients: usize,
    per_client: usize,
    image: I,
    expected: E,
) -> Result<DriveReport>
where
    C: InferClient + Send,
    M: Fn(usize) -> Result<C> + Sync,
    I: Fn(usize) -> Vec<u8> + Sync,
    E: Fn(usize) -> Option<Vec<f32>> + Sync,
{
    let connect = &connect;
    let image = &image;
    let expected = &expected;
    let t0 = std::time::Instant::now();
    let mut results: Vec<Result<Vec<f64>>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..clients {
            handles.push(s.spawn(move || -> Result<Vec<f64>> {
                let mut conn = connect(c)?;
                let px = image(c);
                let want = expected(c);
                let mut lats = Vec::with_capacity(per_client);
                for r in 0..per_client {
                    let t = std::time::Instant::now();
                    let got = conn.infer_one(&px)?;
                    lats.push(t.elapsed().as_secs_f64());
                    if let Some(w) = &want {
                        anyhow::ensure!(
                            w.len() == got.len(),
                            "client {c} request {r}: {} logits, want {}",
                            got.len(),
                            w.len()
                        );
                        for (i, (a, b)) in
                            w.iter().zip(got.iter()).enumerate()
                        {
                            anyhow::ensure!(
                                a.to_bits() == b.to_bits(),
                                "client {c} request {r} logit {i}: \
                                 {b} != expected {a} (not bit-exact)"
                            );
                        }
                    }
                }
                Ok(lats)
            }));
        }
        for h in handles {
            results.push(h.join().expect("client thread panicked"));
        }
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let mut latencies_secs = Vec::with_capacity(clients * per_client);
    for r in results {
        latencies_secs.extend(r?);
    }
    Ok(DriveReport {
        wall_secs,
        requests: clients * per_client,
        latencies_secs,
    })
}

/// [`drive_with`] in thread mode: every client is a clone of the same
/// in-process engine handle.
pub fn drive_clients<I, E>(
    engine: &Int8Engine,
    clients: usize,
    per_client: usize,
    image: I,
    expected: E,
) -> Result<DriveReport>
where
    I: Fn(usize) -> Vec<u8> + Sync,
    E: Fn(usize) -> Option<Vec<f32>> + Sync,
{
    drive_with(|_| Ok(engine.clone()), clients, per_client, image, expected)
}
