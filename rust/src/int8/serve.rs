//! Serving handle for the int8 engine — the one blessed entry point for
//! inference traffic (DESIGN.md §6).
//!
//! [`Int8Engine`] wraps a compiled [`QModel`] (weights + execution plan)
//! behind a cheaply clonable `Arc` handle, so one exported model can be
//! shared across request threads without copying parameters. Worker
//! count is an explicit [`EngineOptions`] knob (the `$FAT_THREADS`
//! environment default still applies when unset), and every call runs
//! on pooled per-worker [`ExecState`]s: slot tables, activation arenas
//! and im2col/accumulator scratch persist across calls instead of being
//! re-allocated per batch. All entry points are bit-exact with the bare
//! [`QModel::run_batch_with`] path for every thread count and any pool
//! history (see `rust/tests/session_equiv.rs`).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::model::Op;
use crate::tensor::Tensor;
use crate::util::threads::fat_threads;

use super::engine::{shard_geometry, ExecState, QModel};
use super::qtensor::QTensor;

/// Engine construction options.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineOptions {
    /// Worker count for batch sharding and kernel row sharding —
    /// the top of the precedence chain documented in `util::threads`:
    /// `EngineOptions.threads` > `$FAT_THREADS` (read once per process)
    /// > machine parallelism. Shards execute on the persistent worker
    /// pool, so any count here is a scheduling degree, not a thread
    /// spawn count.
    pub threads: Option<usize>,
}

impl EngineOptions {
    /// Pin the worker count explicitly.
    pub fn threads(threads: usize) -> Self {
        EngineOptions { threads: Some(threads) }
    }
}

struct EngineInner {
    model: QModel,
    threads: usize,
    /// Reusable per-worker execution states; grows up to the shard
    /// count actually used and is then recycled call after call.
    pool: Mutex<Vec<ExecState>>,
}

/// A cheap-to-clone serving handle over a compiled quantized model.
///
/// Cloning shares the model and the state pool (`Arc` internally), so a
/// server can hand one engine to many request workers. Produced by
/// [`crate::quant::session::Thresholded::serve`]; [`Int8Engine::infer`]
/// and [`Int8Engine::infer_batch`] are the supported inference paths.
#[derive(Clone)]
pub struct Int8Engine {
    inner: Arc<EngineInner>,
}

impl Int8Engine {
    /// Wrap a compiled model. `opts.threads` pins the worker count;
    /// unset, it follows `$FAT_THREADS` / machine parallelism.
    pub fn new(model: QModel, opts: EngineOptions) -> Self {
        let threads = opts.threads.unwrap_or_else(fat_threads).max(1);
        Int8Engine {
            inner: Arc::new(EngineInner {
                model,
                threads,
                pool: Mutex::new(Vec::new()),
            }),
        }
    }

    /// The wrapped quantized model.
    pub fn model(&self) -> &QModel {
        &self.inner.model
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Total int8 parameter bytes of the served model.
    pub fn param_bytes(&self) -> usize {
        self.inner.model.param_bytes
    }

    /// Execution states currently resting in the pool (diagnostics).
    pub fn pooled_states(&self) -> usize {
        self.inner.pool.lock().unwrap().len()
    }

    fn take_state(&self, threads: usize) -> ExecState {
        let mut st =
            self.inner.pool.lock().unwrap().pop().unwrap_or_default();
        st.set_threads(threads);
        st
    }

    fn put_state(&self, st: ExecState) {
        self.inner.pool.lock().unwrap().push(st);
    }

    /// Classify one raw image: `pixels` is HWC u8 data matching the
    /// model's input shape, mapped to floats in `[0, 1]` (`p / 255`).
    /// Returns the logits row.
    pub fn infer(&self, pixels: &[u8]) -> Result<Vec<f32>> {
        let sh = self
            .inner
            .model
            .graph
            .nodes
            .iter()
            .find(|n| n.op == Op::Input)
            .ok_or_else(|| anyhow::anyhow!("model has no input node"))?
            .input_shape
            .clone()
            .ok_or_else(|| anyhow::anyhow!("model input has no shape"))?;
        let want: usize = sh.iter().product();
        anyhow::ensure!(
            pixels.len() == want && sh.len() == 3,
            "infer: expected {want} bytes for input shape {sh:?}, got {}",
            pixels.len()
        );
        let x: Vec<f32> = pixels.iter().map(|&p| p as f32 / 255.0).collect();
        let t = Tensor::f32(vec![1, sh[0], sh[1], sh[2]], x);
        Ok(self.infer_batch(&t)?.as_f32()?.to_vec())
    }

    /// Run a float NHWC batch; returns f32 logits `(n, classes)`.
    /// Batch-shards across the configured worker count.
    pub fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        self.infer_batch_with(x, self.inner.threads)
    }

    /// [`Int8Engine::infer_batch`] with an explicit worker count (thread
    /// sweeps); still uses the shared state pool.
    pub fn infer_batch_with(&self, x: &Tensor, threads: usize) -> Result<Tensor> {
        let model = &self.inner.model;
        let q = QTensor::quantize(x.shape.clone(), x.as_f32()?, model.input_qp);
        let batch = q.shape[0];
        let per_img: usize = q.shape[1..].iter().product();
        // Shard geometry comes from the same helper as
        // QModel::run_batch_with, so the pooled path is bit-exact with
        // the bare engine by construction.
        let (shards, kernel_threads, rows) = shard_geometry(threads, batch);
        if shards <= 1 || per_img == 0 {
            let mut st = self.take_state(threads.max(1));
            let out = match model.run_quant_state(q, &mut st) {
                Ok(out) => out,
                Err(e) => {
                    self.put_state(st);
                    return Err(e);
                }
            };
            let (n, c) = (out.shape[0], out.shape[1]);
            let logits = out.dequantize();
            st.recycle(out.data);
            self.put_state(st);
            return Ok(Tensor::f32(vec![n, c], logits));
        }

        let mut states: Vec<ExecState> =
            (0..shards).map(|_| self.take_state(kernel_threads)).collect();
        let result = model.run_sharded_states(q, rows, &mut states);
        for st in states {
            self.put_state(st);
        }
        let logits = result?;
        let (n, c) = (logits.shape[0], logits.shape[1]);
        Ok(Tensor::f32(vec![n, c], logits.dequantize()))
    }

}
