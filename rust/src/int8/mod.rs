//! Integer-only int8 inference engine — the mobile-deployment simulator
//! (DESIGN.md §2 methodology, §5 architecture). Consumes the quantized
//! model exported by `quant::export` and executes it with int8 storage,
//! int32 accumulators and fixed-point requantization, exactly as the
//! paper's target devices (and TFLite) do.
//!
//! Execution is plan-driven: `quant::export::build_qmodel` compiles a
//! [`plan::ExecPlan`] once (topological schedule, dense indices,
//! liveness-based buffer reuse, weights prepacked for the SIMD
//! microkernels) and [`engine::QModel`] runs it with cache-blocked
//! int8 GEMM microkernels ([`kernels`]: SSE2/AVX2/AVX-512-VNNI with a
//! bit-exact scalar fallback, DESIGN.md §8) and `FAT_THREADS`-way
//! parallelism on the persistent worker pool — batch-sharded across
//! images, row-sharded inside kernels. Per-layer loop schedules come
//! from the [`tune`] autotuner (DESIGN.md §12) and persist in `.fatm`
//! artifacts.
//!
//! Serving traffic should go through [`serve::Int8Engine`] — an
//! `Arc`-clone handle with pooled per-worker execution state — rather
//! than calling the bare [`engine::QModel`] run methods. With
//! [`serve::EngineOptions::batch`] set, the engine coalesces concurrent
//! requests into micro-batches ([`batcher`], DESIGN.md §9) so traffic
//! keeps the worker pool saturated with one well-sharded plan run
//! instead of many contending batch-1 runs.

pub mod batcher;
pub mod engine;
pub mod gemm;
pub mod im2col;
pub mod kernels;
pub mod ops;
pub mod plan;
pub mod qtensor;
pub mod serve;
pub mod tune;

pub use batcher::BatchOptions;
pub use engine::{ExecState, QLayer, QModel};
pub use kernels::{Blocking, Isa, PackedWeights};
pub use plan::ExecPlan;
pub use qtensor::QTensor;
pub use serve::{EngineOptions, Int8Engine};
