//! Integer-only int8 inference engine — the mobile-deployment simulator
//! (DESIGN.md §2). Consumes the quantized model exported by
//! `quant::export` and executes it with int8 storage, int32 accumulators
//! and fixed-point requantization, exactly as the paper's target devices
//! (and TFLite) do.

pub mod engine;
pub mod gemm;
pub mod im2col;
pub mod ops;
pub mod qtensor;

pub use engine::{QLayer, QModel};
pub use qtensor::QTensor;
