//! int8 × int8 → int32 GEMM with zero-point handling.
//!
//! `acc[m,n] = Σ_k (a[m,k] - a_zp) * b[k,n]` computed as
//! `Σ a*b - a_zp * colsum(b)` (gemmlowp trick: weights are symmetric,
//! b_zp = 0). This is the hot path of the deployment simulator; see
//! EXPERIMENTS.md §Perf for the blocking/iteration log.

/// Precomputed column sums of the weight matrix (for the zero-point term).
pub fn col_sums(b: &[i8], k: usize, n: usize) -> Vec<i32> {
    let mut s = vec![0i32; n];
    for ki in 0..k {
        let row = &b[ki * n..(ki + 1) * n];
        for (ni, &v) in row.iter().enumerate() {
            s[ni] += v as i32;
        }
    }
    s
}

/// Dense GEMM: a (m,k) row-major i8, b (k,n) row-major i8, out (m,n) i32.
pub fn gemm_i8(
    a: &[i8],
    a_zp: i32,
    b: &[i8],
    bsums: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    // i16-friendly blocked kernel: accumulate in i32, iterate k-inner.
    for mi in 0..m {
        let arow = &a[mi * k..(mi + 1) * k];
        let orow = &mut out[mi * n..(mi + 1) * n];
        orow.fill(0);
        for (ki, &av) in arow.iter().enumerate() {
            let av = av as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[ki * n..(ki + 1) * n];
            for (ni, &bv) in brow.iter().enumerate() {
                orow[ni] += av * bv as i32;
            }
        }
        if a_zp != 0 {
            for (ni, o) in orow.iter_mut().enumerate() {
                *o -= a_zp * bsums[ni];
            }
        }
    }
}

/// Reference (naive) GEMM for property tests.
pub fn gemm_ref(
    a: &[i8],
    a_zp: i32,
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0i32;
            for ki in 0..k {
                acc += (a[mi * k + ki] as i32 - a_zp) * b[ki * n + ni] as i32;
            }
            out[mi * n + ni] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_i8(n: usize, seed: u64) -> Vec<i8> {
        (0..n)
            .map(|i| {
                (crate::data::prng::hash_u64(seed, i as u64, 0, 0, 0, 0)
                    % 255) as i64 as i8
            })
            .collect()
    }

    #[test]
    fn matches_reference() {
        for &(m, k, n, zp) in
            &[(1, 1, 1, 0), (3, 5, 7, -3), (8, 16, 4, 12), (17, 9, 33, -128)]
        {
            let a = rand_i8(m * k, 1);
            let b = rand_i8(k * n, 2);
            let sums = col_sums(&b, k, n);
            let mut out = vec![0i32; m * n];
            gemm_i8(&a, zp, &b, &sums, m, k, n, &mut out);
            assert_eq!(out, gemm_ref(&a, zp, &b, m, k, n), "({m},{k},{n})");
        }
    }

    #[test]
    fn col_sums_correct() {
        let b = vec![1i8, 2, 3, 4, 5, 6]; // (3,2)
        assert_eq!(col_sums(&b, 3, 2), vec![9, 12]);
    }

    #[test]
    fn accumulates_beyond_i16() {
        let a = vec![127i8; 512];
        let b = vec![127i8; 512];
        let sums = col_sums(&b, 512, 1);
        let mut out = vec![0i32; 1];
        gemm_i8(&a, 0, &b, &sums, 1, 512, 1, &mut out);
        assert_eq!(out[0], 127 * 127 * 512);
    }
}
