//! int8 × int8 → int32 GEMM with zero-point handling.
//!
//! `acc[m,n] = Σ_k (a[m,k] - a_zp) * b[k,n]` computed as
//! `Σ a*b - a_zp * colsum(b)` (gemmlowp trick: weights are symmetric,
//! b_zp = 0). This is the hot path of the deployment simulator; see
//! EXPERIMENTS.md §Perf for the blocking/iteration log.
//!
//! The kernel is cache-blocked: `k` is split into [`KC`]-row panels and
//! `n` into [`NR`]-column strips so one `(KC, NR)` panel of `b` (~8 KiB)
//! stays L1-resident while every row of `a` streams over it, and each
//! `(MR, NR)` micro-tile accumulates into a stack-resident i32 block so
//! a loaded `b` row is reused across [`MR`] rows of `a`. Multi-threading
//! is row-sharded in [`gemm_i8_parallel`] over the persistent worker
//! pool (`util::threads::pool`): workers own disjoint row slabs of
//! `out`, so no synchronisation is needed and — i32 addition being
//! associative — every blocking and thread count is bit-exact with
//! [`gemm_ref`].
//!
//! This unpacked-`b` kernel serves ad-hoc weights (tests, hand-built
//! layers). Exported models prepack their weights at plan-build time and
//! run the SIMD microkernels in `int8::kernels` instead — same blocking
//! constants, same results.

use super::kernels::{KC, MR, NR};

/// Precomputed column sums of the weight matrix (for the zero-point term).
pub fn col_sums(b: &[i8], k: usize, n: usize) -> Vec<i32> {
    let mut s = vec![0i32; n];
    for ki in 0..k {
        let row = &b[ki * n..(ki + 1) * n];
        for (ni, &v) in row.iter().enumerate() {
            s[ni] += v as i32;
        }
    }
    s
}

/// Dense GEMM: a (m,k) row-major i8, b (k,n) row-major i8, out (m,n) i32.
/// Cache-blocked single-threaded kernel; see the module docs.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8(
    a: &[i8],
    a_zp: i32,
    b: &[i8],
    bsums: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0);
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        for n0 in (0..n).step_by(NR) {
            let nr = NR.min(n - n0);
            let mut m0 = 0;
            while m0 < m {
                let mr = MR.min(m - m0);
                // (MR, NR) i32 accumulator block on the stack.
                let mut acc = [[0i32; NR]; MR];
                for ki in 0..kc {
                    let brow =
                        &b[(k0 + ki) * n + n0..(k0 + ki) * n + n0 + nr];
                    for (r, arow) in acc.iter_mut().take(mr).enumerate() {
                        // No zero-skip: the branch defeats
                        // auto-vectorization and costs more than the
                        // multiplies it saves (EXPERIMENTS.md §Perf).
                        let av = a[(m0 + r) * k + k0 + ki] as i32;
                        for (j, &bv) in brow.iter().enumerate() {
                            arow[j] += av * bv as i32;
                        }
                    }
                }
                for (r, arow) in acc.iter().take(mr).enumerate() {
                    let o0 = (m0 + r) * n + n0;
                    let orow = &mut out[o0..o0 + nr];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o += arow[j];
                    }
                }
                m0 += MR;
            }
        }
    }
    if a_zp != 0 {
        for mi in 0..m {
            let orow = &mut out[mi * n..(mi + 1) * n];
            for (ni, o) in orow.iter_mut().enumerate() {
                *o -= a_zp * bsums[ni];
            }
        }
    }
}

/// Row-sharded parallel GEMM over the persistent worker pool: each
/// shard owns a disjoint slab of `out` rows. Bit-exact with [`gemm_i8`]
/// for every thread count (workers never share accumulators).
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_parallel(
    a: &[i8],
    a_zp: i32,
    b: &[i8],
    bsums: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
    threads: usize,
) {
    let t = threads.max(1).min(m.max(1));
    if t <= 1 || n == 0 {
        return gemm_i8(a, a_zp, b, bsums, m, k, n, out);
    }
    let rows = m.div_ceil(t);
    crate::util::threads::pool().run_chunks(out, rows * n, |i, out_slab| {
        let mc = out_slab.len() / n;
        let a_slab = &a[i * rows * k..i * rows * k + mc * k];
        gemm_i8(a_slab, a_zp, b, bsums, mc, k, n, out_slab);
    });
}

/// Reference (naive) GEMM for property tests.
pub fn gemm_ref(
    a: &[i8],
    a_zp: i32,
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0i32;
            for ki in 0..k {
                acc += (a[mi * k + ki] as i32 - a_zp) * b[ki * n + ni] as i32;
            }
            out[mi * n + ni] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_i8(n: usize, seed: u64) -> Vec<i8> {
        (0..n)
            .map(|i| {
                (crate::data::prng::hash_u64(seed, i as u64, 0, 0, 0, 0)
                    % 255) as i64 as i8
            })
            .collect()
    }

    // Blocking-edge shapes shared with the packed-kernel proptests.
    use crate::util::prop::SHAPES;

    #[test]
    fn matches_reference() {
        for &(m, k, n, zp) in SHAPES {
            let a = rand_i8(m * k, 1);
            let b = rand_i8(k * n, 2);
            let sums = col_sums(&b, k, n);
            let mut out = vec![0i32; m * n];
            gemm_i8(&a, zp, &b, &sums, m, k, n, &mut out);
            assert_eq!(out, gemm_ref(&a, zp, &b, m, k, n), "({m},{k},{n})");
        }
    }

    #[test]
    fn parallel_matches_reference_across_thread_counts() {
        for &(m, k, n, zp) in SHAPES {
            let a = rand_i8(m * k, 3);
            let b = rand_i8(k * n, 4);
            let sums = col_sums(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for threads in [1usize, 2, 3, 8, 64] {
                let mut out = vec![0i32; m * n];
                gemm_i8_parallel(
                    &a, zp, &b, &sums, m, k, n, &mut out, threads,
                );
                assert_eq!(out, want, "({m},{k},{n}) t={threads}");
            }
        }
    }

    #[test]
    fn col_sums_correct() {
        let b = vec![1i8, 2, 3, 4, 5, 6]; // (3,2)
        assert_eq!(col_sums(&b, 3, 2), vec![9, 12]);
    }

    #[test]
    fn accumulates_beyond_i16() {
        let a = vec![127i8; 512];
        let b = vec![127i8; 512];
        let sums = col_sums(&b, 512, 1);
        let mut out = vec![0i32; 1];
        gemm_i8(&a, 0, &b, &sums, 1, 512, 1, &mut out);
        assert_eq!(out[0], 127 * 127 * 512);
    }

    #[test]
    fn stale_output_is_overwritten() {
        // the planned engine recycles buffers; the kernel must not
        // accumulate into stale contents
        let (m, k, n) = (3, 4, 5);
        let a = rand_i8(m * k, 9);
        let b = rand_i8(k * n, 10);
        let sums = col_sums(&b, k, n);
        let mut out = vec![i32::MAX; m * n];
        gemm_i8(&a, 2, &b, &sums, m, k, n, &mut out);
        assert_eq!(out, gemm_ref(&a, 2, &b, m, k, n));
    }
}
