//! NHWC im2col for SAME-padded k×k convolutions over i8 activations.
//! Out-of-image taps are filled with the input zero-point (= real 0.0).
//!
//! Two consumers share the index math here: the staged conv path
//! materializes the whole patch matrix via [`im2col_into`], and the
//! fused implicit-GEMM path (`kernels::gemm_fused`) assembles a few
//! rows at a time through [`PatchGeom::fill_rows`] so the matrix never
//! exists. Both produce byte-identical rows by construction —
//! `im2col_into` is implemented on top of `fill_rows`.

/// Geometry of the implicit im2col view of one SAME-padded conv input:
/// the `(n·oh·ow, k·k·c)` patch matrix [`im2col_into`] would produce,
/// addressable one row range at a time without materializing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PatchGeom {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub k: usize,
    pub stride: usize,
    pub oh: usize,
    pub ow: usize,
    pub pad_top: usize,
    pub pad_left: usize,
    /// Input zero-point — the value of out-of-image taps.
    pub zp: i8,
}

impl PatchGeom {
    /// Resolve the SAME-padding geometry (matches XLA:
    /// `pad_total = (o-1)*s + k - in`, split top/left-biased).
    pub fn new(
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        k: usize,
        stride: usize,
        zp: i8,
    ) -> PatchGeom {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let pad_top = (((oh - 1) * stride + k).saturating_sub(h)) / 2;
        let pad_left = (((ow - 1) * stride + k).saturating_sub(w)) / 2;
        PatchGeom { n, h, w, c, k, stride, oh, ow, pad_top, pad_left, zp }
    }

    /// Rows of the virtual patch matrix (= output pixels, `n·oh·ow`).
    pub fn rows(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// Columns of the virtual patch matrix (= `k·k·c`).
    pub fn cols(&self) -> usize {
        self.k * self.k * self.c
    }

    /// Assemble rows `[row0, row0 + mr)` of the virtual patch matrix
    /// into the first `mr * cols()` bytes of `dst` (row-major): fill
    /// each row with the zero-point, then copy the contiguous in-bounds
    /// `kx` span of every in-bounds kernel row straight from the input
    /// image (consecutive `kx` taps are consecutive input pixels, so
    /// one `copy_from_slice` covers the whole span). Byte-identical to
    /// the same rows of [`im2col_into`]'s output.
    pub fn fill_rows(&self, x: &[i8], row0: usize, mr: usize, dst: &mut [i8]) {
        let (k, c, stride) = (self.k, self.c, self.stride);
        let cols = self.cols();
        debug_assert!(row0 + mr <= self.rows());
        for (r, drow) in
            dst[..mr * cols].chunks_exact_mut(cols).enumerate()
        {
            let row = row0 + r;
            let ni = row / (self.oh * self.ow);
            let oy = (row / self.ow) % self.oh;
            let ox = row % self.ow;
            drow.fill(self.zp);
            let x0 = ox * stride;
            // in-bounds kx span: 0 <= x0 + kx - pad_left < w
            let kx_lo = self.pad_left.saturating_sub(x0).min(k);
            let kx_hi = (self.w + self.pad_left).saturating_sub(x0).min(k);
            if kx_lo >= kx_hi {
                continue; // every tap of every kernel row is padding
            }
            let ix0 = x0 + kx_lo - self.pad_left;
            let span = (kx_hi - kx_lo) * c;
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - self.pad_top as isize;
                if iy < 0 || iy >= self.h as isize {
                    continue;
                }
                let src = ((ni * self.h + iy as usize) * self.w + ix0) * c;
                let d0 = (ky * k + kx_lo) * c;
                drow[d0..d0 + span]
                    .copy_from_slice(&x[src..src + span]);
            }
        }
    }
}

/// im2col: input (n, h, w, c) i8 → patches ((n*oh*ow), (k*k*c)) i8.
/// Returns (patches, oh, ow).
#[allow(clippy::too_many_arguments)]
pub fn im2col_i8(
    x: &[i8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    zp: i8,
) -> (Vec<i8>, usize, usize) {
    let mut out = Vec::new();
    let (oh, ow) = im2col_into(x, n, h, w, c, k, stride, zp, &mut out);
    (out, oh, ow)
}

/// [`im2col_i8`] into a caller-provided buffer (cleared and refilled) so
/// the engine can reuse one patch buffer across nodes. Returns (oh, ow).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &[i8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    zp: i8,
    out: &mut Vec<i8>,
) -> (usize, usize) {
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    // 1×1 window: SAME padding is zero ((oh-1)*stride + 1 <= h) and every
    // patch is one in-bounds pixel, so the whole output is a pure copy —
    // skip the zero-point prefill of the full buffer. This is the hot
    // shape of the pointwise-conv-heavy mobilenet/mnas builtins.
    if k == 1 {
        out.clear();
        out.reserve(n * oh * ow * c);
        if stride == 1 {
            out.extend_from_slice(x);
        } else {
            for ni in 0..n {
                for oy in 0..oh {
                    let iy = oy * stride;
                    for ox in 0..ow {
                        let src = ((ni * h + iy) * w + ox * stride) * c;
                        out.extend_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
        return (oh, ow);
    }
    let g = PatchGeom::new(n, h, w, c, k, stride, zp);
    debug_assert_eq!((g.oh, g.ow), (oh, ow));
    out.clear();
    out.resize(g.rows() * g.cols(), zp);
    g.fill_rows(x, 0, g.rows(), out);
    (oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1() {
        let x: Vec<i8> = (0..2 * 2 * 3).map(|i| i as i8).collect();
        let (p, oh, ow) = im2col_i8(&x, 1, 2, 2, 3, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(p, x);
    }

    #[test]
    fn same_padding_3x3() {
        // 1x1 image, 3x3 kernel: the single patch has 8 padded taps.
        let x = vec![5i8, 6];
        let (p, oh, ow) = im2col_i8(&x, 1, 1, 1, 2, 3, 1, -7);
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(p.len(), 9 * 2);
        // centre tap is the real pixel
        assert_eq!(&p[4 * 2..4 * 2 + 2], &[5, 6]);
        assert_eq!(p.iter().filter(|&&v| v == -7).count(), 16);
    }

    #[test]
    fn stride_two_output_shape() {
        let x = vec![1i8; 4 * 4];
        let (p, oh, ow) = im2col_i8(&x, 1, 4, 4, 1, 3, 2, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(p.len(), 4 * 9);
    }

    #[test]
    fn into_reuses_stale_buffers_correctly() {
        let x: Vec<i8> = (0..4 * 4).map(|i| i as i8).collect();
        let (want, oh, ow) = im2col_i8(&x, 1, 4, 4, 1, 3, 2, -9);
        let mut buf = vec![42i8; 7]; // stale, wrong-sized scratch
        let (oh2, ow2) = im2col_into(&x, 1, 4, 4, 1, 3, 2, -9, &mut buf);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(want, buf);
    }

    #[test]
    fn strided_1x1_copies_subsampled_pixels() {
        // 4x4, 2 channels, stride 2: the copy fast path must pick pixels
        // (0,0), (0,2), (2,0), (2,2) with no zero-point fill anywhere.
        let x: Vec<i8> = (0..4 * 4 * 2).map(|i| i as i8).collect();
        let (p, oh, ow) = im2col_i8(&x, 1, 4, 4, 2, 1, 2, -9);
        assert_eq!((oh, ow), (2, 2));
        let mut want = Vec::new();
        for &(r, c0) in &[(0usize, 0usize), (0, 2), (2, 0), (2, 2)] {
            let s = (r * 4 + c0) * 2;
            want.extend_from_slice(&x[s..s + 2]);
        }
        assert_eq!(p, want);
        assert!(!p.contains(&-9));
    }

    #[test]
    fn fast_path_reuses_stale_buffer() {
        let x: Vec<i8> = (0..3 * 3).map(|i| i as i8).collect();
        let mut buf = vec![111i8; 50]; // stale, oversized
        let (oh, ow) = im2col_into(&x, 1, 3, 3, 1, 1, 1, -5, &mut buf);
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(buf, x);
    }

    #[test]
    fn fill_rows_matches_full_im2col_windows() {
        // every (shape, stride, row window) of the implicit view must
        // be byte-identical to the materialized patch matrix
        for &(n, h, w, c, k, stride) in &[
            (2usize, 5usize, 4usize, 3usize, 3usize, 1usize),
            (2, 5, 4, 3, 3, 2),
            (1, 1, 1, 2, 3, 1), // all-padding borders (1×1 image)
            (1, 4, 4, 1, 5, 2), // window wider than the image
        ] {
            let x: Vec<i8> =
                (0..n * h * w * c).map(|i| (i as i8).wrapping_mul(7)).collect();
            let (full, oh, ow) = im2col_i8(&x, n, h, w, c, k, stride, -9);
            let g = PatchGeom::new(n, h, w, c, k, stride, -9);
            assert_eq!((g.oh, g.ow), (oh, ow));
            let cols = g.cols();
            for row0 in 0..g.rows() {
                let mr_max = 3usize.min(g.rows() - row0);
                for mr in 1..=mr_max {
                    let mut dst = vec![55i8; mr * cols + 2]; // stale + slack
                    g.fill_rows(&x, row0, mr, &mut dst);
                    assert_eq!(
                        &dst[..mr * cols],
                        &full[row0 * cols..(row0 + mr) * cols],
                        "k{k} s{stride} row0 {row0} mr {mr}"
                    );
                    assert_eq!(&dst[mr * cols..], &[55, 55]); // slack untouched
                }
            }
        }
    }

    #[test]
    fn batch_independent() {
        let x0 = vec![1i8; 9];
        let x1 = vec![2i8; 9];
        let mut x = x0.clone();
        x.extend(&x1);
        let (p, _, _) = im2col_i8(&x, 2, 3, 3, 1, 1, 1, 0);
        assert_eq!(&p[..9], &x0[..]);
        assert_eq!(&p[9..], &x1[..]);
    }
}
