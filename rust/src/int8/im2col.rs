//! NHWC im2col for SAME-padded k×k convolutions over i8 activations.
//! Out-of-image taps are filled with the input zero-point (= real 0.0).

/// im2col: input (n, h, w, c) i8 → patches ((n*oh*ow), (k*k*c)) i8.
/// Returns (patches, oh, ow).
#[allow(clippy::too_many_arguments)]
pub fn im2col_i8(
    x: &[i8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    zp: i8,
) -> (Vec<i8>, usize, usize) {
    let mut out = Vec::new();
    let (oh, ow) = im2col_into(x, n, h, w, c, k, stride, zp, &mut out);
    (out, oh, ow)
}

/// [`im2col_i8`] into a caller-provided buffer (cleared and refilled) so
/// the engine can reuse one patch buffer across nodes. Returns (oh, ow).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &[i8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    zp: i8,
    out: &mut Vec<i8>,
) -> (usize, usize) {
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    // 1×1 window: SAME padding is zero ((oh-1)*stride + 1 <= h) and every
    // patch is one in-bounds pixel, so the whole output is a pure copy —
    // skip the zero-point prefill of the full buffer. This is the hot
    // shape of the pointwise-conv-heavy mobilenet/mnas builtins.
    if k == 1 {
        out.clear();
        out.reserve(n * oh * ow * c);
        if stride == 1 {
            out.extend_from_slice(x);
        } else {
            for ni in 0..n {
                for oy in 0..oh {
                    let iy = oy * stride;
                    for ox in 0..ow {
                        let src = ((ni * h + iy) * w + ox * stride) * c;
                        out.extend_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
        return (oh, ow);
    }
    // SAME padding (matches XLA): pad_total = (o-1)*s + k - h
    let pad_top = (((oh - 1) * stride + k).saturating_sub(h)) / 2;
    let pad_left = (((ow - 1) * stride + k).saturating_sub(w)) / 2;
    let cols = k * k * c;
    out.clear();
    out.resize(n * oh * ow * cols, zp);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst0 = ((ni * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix =
                            (ox * stride + kx) as isize - pad_left as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src =
                            ((ni * h + iy as usize) * w + ix as usize) * c;
                        let dst = dst0 + (ky * k + kx) * c;
                        out[dst..dst + c]
                            .copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    (oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1() {
        let x: Vec<i8> = (0..2 * 2 * 3).map(|i| i as i8).collect();
        let (p, oh, ow) = im2col_i8(&x, 1, 2, 2, 3, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(p, x);
    }

    #[test]
    fn same_padding_3x3() {
        // 1x1 image, 3x3 kernel: the single patch has 8 padded taps.
        let x = vec![5i8, 6];
        let (p, oh, ow) = im2col_i8(&x, 1, 1, 1, 2, 3, 1, -7);
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(p.len(), 9 * 2);
        // centre tap is the real pixel
        assert_eq!(&p[4 * 2..4 * 2 + 2], &[5, 6]);
        assert_eq!(p.iter().filter(|&&v| v == -7).count(), 16);
    }

    #[test]
    fn stride_two_output_shape() {
        let x = vec![1i8; 4 * 4];
        let (p, oh, ow) = im2col_i8(&x, 1, 4, 4, 1, 3, 2, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(p.len(), 4 * 9);
    }

    #[test]
    fn into_reuses_stale_buffers_correctly() {
        let x: Vec<i8> = (0..4 * 4).map(|i| i as i8).collect();
        let (want, oh, ow) = im2col_i8(&x, 1, 4, 4, 1, 3, 2, -9);
        let mut buf = vec![42i8; 7]; // stale, wrong-sized scratch
        let (oh2, ow2) = im2col_into(&x, 1, 4, 4, 1, 3, 2, -9, &mut buf);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(want, buf);
    }

    #[test]
    fn strided_1x1_copies_subsampled_pixels() {
        // 4x4, 2 channels, stride 2: the copy fast path must pick pixels
        // (0,0), (0,2), (2,0), (2,2) with no zero-point fill anywhere.
        let x: Vec<i8> = (0..4 * 4 * 2).map(|i| i as i8).collect();
        let (p, oh, ow) = im2col_i8(&x, 1, 4, 4, 2, 1, 2, -9);
        assert_eq!((oh, ow), (2, 2));
        let mut want = Vec::new();
        for &(r, c0) in &[(0usize, 0usize), (0, 2), (2, 0), (2, 2)] {
            let s = (r * 4 + c0) * 2;
            want.extend_from_slice(&x[s..s + 2]);
        }
        assert_eq!(p, want);
        assert!(!p.contains(&-9));
    }

    #[test]
    fn fast_path_reuses_stale_buffer() {
        let x: Vec<i8> = (0..3 * 3).map(|i| i as i8).collect();
        let mut buf = vec![111i8; 50]; // stale, oversized
        let (oh, ow) = im2col_into(&x, 1, 3, 3, 1, 1, 1, -5, &mut buf);
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(buf, x);
    }

    #[test]
    fn batch_independent() {
        let x0 = vec![1i8; 9];
        let x1 = vec![2i8; 9];
        let mut x = x0.clone();
        x.extend(&x1);
        let (p, _, _) = im2col_i8(&x, 2, 3, 3, 1, 1, 1, 0);
        assert_eq!(&p[..9], &x0[..]);
        assert_eq!(&p[9..], &x1[..]);
    }
}
