//! SIMD int8 microkernels over prepacked weight panels (DESIGN.md §8,
//! §12).
//!
//! The conv/dense hot loop is `i8 × i8 → i32`: widen both operands to
//! i16, multiply-accumulate pairs into i32 lanes (`pmaddwd` — the
//! gemmlowp/oneDNN lineage), with an SSE2 baseline, an AVX2 path picked
//! once per process by [`Isa::detect`], an AVX-512/VNNI path
//! (`vpdpwssd`, behind the default-off `avx512` cargo feature), and a
//! portable scalar fallback that reads the **same packed layout** so
//! every path is bit-exact.
//!
//! ## Packed layout
//!
//! [`PackedWeights::pack_with`] reorders the row-major `(k, n)` weight
//! matrix into `nr`-column strips of k-**pair**-interleaved rows (the
//! shape `pmaddwd`/`vpdpwssd` consume directly):
//!
//! ```text
//! strip ns (columns n0 = ns·nr .. n0+nr, zero-padded past n):
//!   pair p (rows 2p, 2p+1; row k zero-padded when k is odd):
//!     b[2p][n0], b[2p+1][n0], b[2p][n0+1], b[2p+1][n0+1], …  (2·nr i8)
//! ```
//!
//! The strip width `nr` and the loop blockings around it are no longer
//! compile-time constants: each layer carries a [`Blocking`] chosen by
//! the autotuner (`crate::int8::tune`, persisted in `.fatm` PLAN v2) or
//! the [`Blocking::default`] that reproduces the historical
//! `KC=128/NR=64/MR=4` schedule. One `kc`-row panel of a strip is
//! `kc × nr` i8 (≈ 8 KiB at the defaults, L1-resident), and a 16-byte
//! load inside a pair yields 8 interleaved columns — the exact operand
//! layout of a widening multiply-add, with no shuffles on the hot path.
//!
//! ## int4 panels (`bits = 4`)
//!
//! When every weight fits `[-8, 7]` the panel can be packed at four
//! bits per weight ([`PackedWeights::pack_bits`]): one byte per
//! (pair, column) — row `2p` in the low nibble, row `2p+1` in the high
//! nibble — so a strip shrinks to `pk/2 × nr` bytes, **half** the int8
//! footprint, and one `kc`-panel holds twice the k-depth in the same
//! L1 bytes:
//!
//! ```text
//! strip ns, pair p:  lo(b[2p][n0]) | hi(b[2p+1][n0]), …   (nr bytes)
//! ```
//!
//! The micro-tiles widen nibbles in-register (mask, `xor 8`, `sub 8` —
//! a branch-free 4-bit sign extension) and interleave lo/hi back into
//! the exact pair-interleaved i8 stream the `pmaddwd` paths consume,
//! so the multiply-accumulate structure (and therefore bit-exactness
//! vs `gemm_ref`) is shared with the int8 path, not re-argued.
//!
//! ## Fused implicit-GEMM (DESIGN.md §14)
//!
//! [`gemm_fused`] drives the same micro-tiles without ever
//! materializing the im2col patch matrix or the i32 accumulator
//! buffer: the A micro-panel is assembled per `mr`-row tile straight
//! from the NHWC input ([`FusedA::Implicit`], or aliased for dense /
//! 1×1-stride-1 shapes via [`FusedA::Direct`]), one accumulator tile
//! persists across all k-panels of a strip, and a register-tile
//! epilogue ([`FusedEpilogue`]) requantizes it directly to i8 — with
//! an optional fused residual add ([`FusedResidual`]) for
//! `conv → add` chains.
//!
//! ## Bit-exactness
//!
//! Products of i8 (and of `(x - zp) · w` in the depthwise tap, with
//! `|x - zp| ≤ 255`, `|w| ≤ 128`, so `|prod| ≤ 32640 < 2^15`) fit i16
//! exactly; every accumulation is i32, and i32 addition is associative
//! and commutative, so any vector width, blocking, shard count and ISA
//! produces identical bytes. `gemm_ref` stays the oracle
//! (`rust/tests/proptests.rs`, `kernels::tests`).

use std::sync::OnceLock;

use crate::artifact::I8Slab;

/// Default rows of `a` per micro-tile (register-block height).
pub const MR: usize = 4;
/// Maximum columns of `b` per strip; also the default strip width.
pub const NR: usize = 64;
/// Default depth of one cache panel of `b` (`KC * NR` i8 ≈ 8 KiB).
pub const KC: usize = 128;
/// Maximum micro-tile height any [`Blocking`] may request (the
/// accumulator block is statically sized `MR_MAX × NR`).
pub const MR_MAX: usize = 8;

/// One GEMM loop schedule: panel depth, strip width, micro-tile height
/// and the parallel shard grain. Chosen per layer by the autotuner
/// (`crate::int8::tune`), persisted in the `.fatm` PLAN section (v2),
/// and validated on load before it reaches the unchecked inner loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Blocking {
    /// Rows of `b` per cache panel (must be even: the layout pairs rows).
    pub kc: usize,
    /// Columns per packed strip (multiple of 16, ≤ [`NR`]); must match
    /// the `nr` the panel was packed with.
    pub nr: usize,
    /// Rows of `a` per micro-tile (1 ..= [`MR_MAX`]).
    pub mr: usize,
    /// Row-shard granularity for [`gemm_packed_parallel`]: shards are
    /// rounded up to a multiple of this many rows.
    pub grain: usize,
}

impl Default for Blocking {
    /// The historical hard-coded schedule (`KC=128/NR=64/MR=4`,
    /// ungrained sharding) — what PLAN v1 artifacts implicitly used.
    fn default() -> Blocking {
        Blocking { kc: KC, nr: NR, mr: MR, grain: 1 }
    }
}

impl Blocking {
    /// Reject geometries the unchecked micro-tile loops cannot take:
    /// this is the loader's safety gate for hostile `.fatm` tables.
    pub fn validate(self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.kc >= 2 && self.kc <= 8192 && self.kc % 2 == 0,
            "blocking kc={} (want even, 2..=8192)",
            self.kc
        );
        anyhow::ensure!(
            self.nr >= 16 && self.nr <= NR && self.nr % 16 == 0,
            "blocking nr={} (want multiple of 16, 16..={NR})",
            self.nr
        );
        anyhow::ensure!(
            self.mr >= 1 && self.mr <= MR_MAX,
            "blocking mr={} (want 1..={MR_MAX})",
            self.mr
        );
        anyhow::ensure!(
            self.grain >= 1 && self.grain <= 4096,
            "blocking grain={} (want 1..=4096)",
            self.grain
        );
        Ok(())
    }

    /// Compact `kc/nr/mr/grain` form for logs, `/stats` and `fat info`.
    pub fn label(self) -> String {
        format!("{}/{}/{}/{}", self.kc, self.nr, self.mr, self.grain)
    }
}

/// Instruction-set level for the int8 microkernels. Ordered: a request
/// above the hardware clamps down ([`Isa::detect`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Portable scalar loop over the packed layout (any arch).
    Scalar,
    /// x86_64 baseline: 128-bit `pmaddwd` path.
    Sse2,
    /// 256-bit `vpmaddwd` path, runtime-detected.
    Avx2,
    /// 512-bit `vpdpwssd` (AVX-512 VNNI) path. The variant always
    /// exists (so `FAT_ISA=avx512vnni` parses everywhere), but it is
    /// only *selectable* when the crate is built with the `avx512`
    /// feature **and** the CPU reports avx512f/bw/vnni — otherwise
    /// [`Isa::detect`] clamps down and the dispatch falls back to
    /// scalar, which is bit-exact anyway.
    Avx512Vnni,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Avx512Vnni => "avx512vnni",
        }
    }

    /// Inverse of [`Isa::name`] for CLI/env values
    /// (`scalar|sse2|avx2|avx512vnni`).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim() {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            "avx512vnni" => Some(Isa::Avx512Vnni),
            _ => None,
        }
    }

    /// Best ISA the hardware (and build) supports.
    fn best() -> Isa {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        {
            if std::arch::is_x86_feature_detected!("avx512vnni")
                && std::arch::is_x86_feature_detected!("avx512bw")
                && std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx2")
            {
                return Isa::Avx512Vnni;
            }
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Isa::Avx2
            } else {
                Isa::Sse2
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Isa::Scalar
        }
    }

    /// The process-wide kernel ISA, detected **once** (`OnceLock`) when
    /// the first plan is built or executed.
    /// `FAT_ISA=scalar|sse2|avx2|avx512vnni` pins a lower level for A/B
    /// runs; asking above the hardware clamps down to the best
    /// supported level. Any other value aborts with an error naming the
    /// accepted set — an explicit pin the user typo'd must not silently
    /// turn into "fastest", that would invert A/B runs. Tests sweep
    /// explicitly via [`Isa::available`] instead of mutating the
    /// environment.
    pub fn detect() -> Isa {
        static CACHE: OnceLock<Isa> = OnceLock::new();
        *CACHE.get_or_init(|| {
            let best = Isa::best();
            let req = match std::env::var("FAT_ISA").ok().as_deref() {
                Some(other) => match Isa::parse(other) {
                    Some(r) => Some(r),
                    None => panic!(
                        "FAT_ISA: unknown value {other:?} \
                         (accepted: scalar, sse2, avx2, avx512vnni)"
                    ),
                },
                None => None,
            };
            req.map_or(best, |r| r.min(best))
        })
    }

    /// Every ISA runnable on this machine, weakest first (test sweeps).
    pub fn available() -> Vec<Isa> {
        let best = Isa::best();
        [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Avx512Vnni]
            .into_iter()
            .filter(|i| *i <= best)
            .collect()
    }
}

/// Weight matrix prepacked at `build_qmodel` plan time into the strip /
/// pair-interleaved layout the microkernels consume (module docs). Built
/// once per exported model and stored on the plan's dense parameter
/// table (`QLayer::packed`). The panel bytes live in an [`I8Slab`]:
/// owned when packed in-process, a borrowed window into a shared
/// read-only mapping when loaded zero-copy from a `.fatm` artifact
/// (`crate::artifact`) — the packed layout is ISA-independent, so a
/// panel packed on one machine is valid on any other.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    data: I8Slab,
    /// Logical row count of the source `(k, n)` matrix.
    pub k: usize,
    /// Logical column count of the source `(k, n)` matrix.
    pub n: usize,
    /// Rows per strip after padding `k` up to a pair boundary.
    pk: usize,
    /// Number of `nr`-column strips (`n` padded up).
    strips: usize,
    /// Strip width the panel was packed with (a [`Blocking::nr`]).
    nr: usize,
    /// Bits per packed weight: 8 (one byte per lane) or 4 (two weights
    /// per byte, nibble-packed — module docs).
    bits: usize,
}

/// Whether every weight fits the int4 nibble range `[-8, 7]` — the
/// precondition for [`PackedWeights::pack_bits`] at `bits = 4`. True
/// by construction for models quantized with 4-bit weights
/// (`|q| ≤ 7`); checked by the tuner before it tries an int4 repack of
/// an 8-bit table.
pub fn fits_int4(b: &[i8]) -> bool {
    b.iter().all(|&v| (-8..=7).contains(&(v as i32)))
}

impl PackedWeights {
    /// Pack with the default strip width ([`NR`]) at 8 bits.
    pub fn pack(b: &[i8], k: usize, n: usize) -> PackedWeights {
        PackedWeights::pack_bits(b, k, n, NR, 8)
    }

    /// Pack into `nrw`-column strips at 8 bits.
    pub fn pack_with(
        b: &[i8],
        k: usize,
        n: usize,
        nrw: usize,
    ) -> PackedWeights {
        PackedWeights::pack_bits(b, k, n, nrw, 8)
    }

    /// Pack a row-major `(k, n)` i8 matrix into `nrw`-column strips at
    /// `bits` ∈ {8, 4} per weight. Padding lanes (columns ≥ n, the row
    /// `k` of an odd-`k` pair) are zero, so they contribute nothing to
    /// any accumulator. `bits = 4` requires every value in `[-8, 7]`
    /// ([`fits_int4`]) and stores row `2p` in the low nibble, row
    /// `2p+1` in the high nibble of one byte per column.
    pub fn pack_bits(
        b: &[i8],
        k: usize,
        n: usize,
        nrw: usize,
        bits: usize,
    ) -> PackedWeights {
        assert_eq!(b.len(), k * n, "pack: bad weight shape ({k},{n})");
        assert!(
            nrw >= 16 && nrw <= NR && nrw % 16 == 0,
            "pack: bad strip width {nrw}"
        );
        assert!(bits == 8 || bits == 4, "pack: bad bits {bits}");
        let strips = n.div_ceil(nrw);
        let pk = k + (k & 1);
        if bits == 4 {
            assert!(fits_int4(b), "pack: int4 weight out of [-8, 7]");
            let mut data = vec![0i8; strips * (pk / 2) * nrw];
            for ns in 0..strips {
                let n0 = ns * nrw;
                let nc = nrw.min(n - n0);
                let sbase = ns * (pk / 2) * nrw;
                for ki in 0..k {
                    let hi = ki & 1;
                    let pair = ki / 2;
                    let src = &b[ki * n + n0..ki * n + n0 + nc];
                    for (j, &v) in src.iter().enumerate() {
                        let cell = &mut data[sbase + pair * nrw + j];
                        let nib = v as u8 & 0x0F;
                        let cur = *cell as u8;
                        *cell = (cur | if hi == 1 { nib << 4 } else { nib })
                            as i8;
                    }
                }
            }
            return PackedWeights {
                data: data.into(),
                k,
                n,
                pk,
                strips,
                nr: nrw,
                bits,
            };
        }
        let mut data = vec![0i8; strips * pk * nrw];
        for ns in 0..strips {
            let n0 = ns * nrw;
            let nc = nrw.min(n - n0);
            let sbase = ns * pk * nrw;
            for ki in 0..k {
                let lane = ki & 1;
                let pair = ki / 2;
                let src = &b[ki * n + n0..ki * n + n0 + nc];
                for (j, &v) in src.iter().enumerate() {
                    data[sbase + (pair * nrw + j) * 2 + lane] = v;
                }
            }
        }
        PackedWeights { data: data.into(), k, n, pk, strips, nr: nrw, bits }
    }

    /// Rehydrate from already-packed 8-bit panel bytes (back-compat
    /// entry point; see [`PackedWeights::from_packed_bits`]).
    pub fn from_packed(
        data: I8Slab,
        k: usize,
        n: usize,
        nrw: usize,
    ) -> anyhow::Result<PackedWeights> {
        PackedWeights::from_packed_bits(data, k, n, nrw, 8)
    }

    /// Rehydrate from already-packed panel bytes (the `.fatm` zero-copy
    /// load path). `data` must be exactly the output of
    /// [`PackedWeights::pack_bits`] for a `(k, n)` matrix at strip
    /// width `nrw` and `bits` per weight; only the geometry is
    /// checkable here — byte-level validity is the artifact digest's
    /// job.
    pub fn from_packed_bits(
        data: I8Slab,
        k: usize,
        n: usize,
        nrw: usize,
        bits: usize,
    ) -> anyhow::Result<PackedWeights> {
        anyhow::ensure!(
            nrw >= 16 && nrw <= NR && nrw % 16 == 0,
            "packed panel for ({k},{n}): bad strip width {nrw}"
        );
        anyhow::ensure!(
            bits == 8 || bits == 4,
            "packed panel for ({k},{n}): bad bits {bits} (want 8 or 4)"
        );
        let strips = n.div_ceil(nrw);
        let pk = k + (k & 1);
        let rows = if bits == 4 { pk / 2 } else { pk };
        let want = strips
            .checked_mul(rows)
            .and_then(|v| v.checked_mul(nrw))
            .ok_or_else(|| {
                anyhow::anyhow!("packed shape ({k},{n}) overflows")
            })?;
        anyhow::ensure!(
            data.len() == want,
            "packed panel for ({k},{n}) nr={nrw} bits={bits}: {} bytes, \
             want {want}",
            data.len()
        );
        Ok(PackedWeights { data, k, n, pk, strips, nr: nrw, bits })
    }

    /// Packed size in bytes (padding included) — for size reports.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// The raw packed panel bytes (artifact serialization).
    pub fn raw_data(&self) -> &[i8] {
        &self.data
    }

    /// Whether the panel bytes borrow a mapped artifact (vs owned heap).
    pub fn is_mapped(&self) -> bool {
        self.data.is_mapped()
    }

    /// Strip width the panel was packed with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// Bits per packed weight (8 or 4).
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Bytes per strip (layout-dependent: int4 strips are half size).
    #[inline]
    fn strip_bytes(&self) -> usize {
        if self.bits == 4 {
            self.pk / 2 * self.nr
        } else {
            self.pk * self.nr
        }
    }

    #[inline]
    fn strip(&self, ns: usize) -> &[i8] {
        let sb = self.strip_bytes();
        &self.data[ns * sb..(ns + 1) * sb]
    }
}

/// Packed-panel GEMM: `out[mi, ni] = Σ_k (a[mi,k] - a_zp) · b[k,ni]`,
/// single-threaded, with the a_zp term applied via the precomputed
/// column sums exactly like `gemm::gemm_i8`. Loop blockings come from
/// `bk` (the strip width is fixed by how `pw` was packed); every
/// [`Blocking`] × [`Isa`] is bit-exact with `gemm_ref`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed(
    a: &[i8],
    a_zp: i32,
    pw: &PackedWeights,
    bsums: &[i32],
    m: usize,
    out: &mut [i32],
    isa: Isa,
    bk: Blocking,
) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(bk.nr, pw.nr, "blocking/panel strip width mismatch");
    out.fill(0);
    if m == 0 || n == 0 {
        return;
    }
    // Defensive clamps: `Blocking::validate` runs on every artifact
    // load, but the unchecked inner loops must stay in bounds even if
    // an unvalidated value slips through some other path.
    let kc = (bk.kc.max(2) & !1).min(8192);
    let mr_b = bk.mr.clamp(1, MR_MAX);
    let nrw = pw.nr;
    let pairs_total = pw.pk / 2;
    for ns in 0..pw.strips {
        let n0 = ns * nrw;
        let nc = nrw.min(n - n0);
        let strip = pw.strip(ns);
        let mut p0 = 0usize;
        while p0 < pairs_total {
            // One kc-row cache panel = kc/2 interleaved pairs.
            let pc = (kc / 2).min(pairs_total - p0);
            let mut m0 = 0usize;
            while m0 < m {
                let mr = mr_b.min(m - m0);
                let mut acc = [[0i32; NR]; MR_MAX];
                microtile_dispatch(
                    a, m0, k, strip, p0, pc, mr, nrw, &mut acc, isa, pw.bits,
                );
                for (r, arow) in acc.iter().take(mr).enumerate() {
                    let o0 = (m0 + r) * n + n0;
                    let orow = &mut out[o0..o0 + nc];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o += arow[j];
                    }
                }
                m0 += mr_b;
            }
            p0 += pc;
        }
    }
    if a_zp != 0 {
        for mi in 0..m {
            let orow = &mut out[mi * n..(mi + 1) * n];
            for (ni, o) in orow.iter_mut().enumerate() {
                *o -= a_zp * bsums[ni];
            }
        }
    }
}

/// Row-sharded [`gemm_packed`] over the persistent worker pool
/// (`util::threads::pool`), shard sizes rounded up to `bk.grain` rows.
/// Workers own disjoint `out` slabs, so every thread count is
/// bit-exact.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_parallel(
    a: &[i8],
    a_zp: i32,
    pw: &PackedWeights,
    bsums: &[i32],
    m: usize,
    out: &mut [i32],
    threads: usize,
    isa: Isa,
    bk: Blocking,
) {
    let (k, n) = (pw.k, pw.n);
    let t = threads.max(1).min(m.max(1));
    if t <= 1 || n == 0 {
        return gemm_packed(a, a_zp, pw, bsums, m, out, isa, bk);
    }
    let g = bk.grain.clamp(1, 4096);
    let rows = m.div_ceil(t).div_ceil(g) * g;
    crate::util::threads::pool().run_chunks(out, rows * n, |i, out_slab| {
        let mc = out_slab.len() / n;
        let a_slab = &a[i * rows * k..i * rows * k + mc * k];
        gemm_packed(a_slab, a_zp, pw, bsums, mc, out_slab, isa, bk);
    });
}

/// Route one micro-tile to the ISA / bit-width kernel. Shared by the
/// staged [`gemm_packed`] and the fused [`gemm_fused`] drivers, so the
/// fused path's inner loops are *the same code* as the staged path's —
/// bit-exactness is inherited, not re-argued per driver.
#[allow(clippy::too_many_arguments)]
#[inline]
fn microtile_dispatch(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
    isa: Isa,
    bits: usize,
) {
    if bits == 4 {
        match isa {
            // The nibble decode has no 512-bit variant; the
            // VNNI detection gate guarantees AVX2 is there.
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 | Isa::Avx512Vnni => unsafe {
                microtile_avx2_i4(a, m0, k, strip, p0, pc, mr, nr, acc)
            },
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => unsafe {
                microtile_sse2_i4(a, m0, k, strip, p0, pc, mr, nr, acc)
            },
            _ => microtile_scalar_i4(a, m0, k, strip, p0, pc, mr, nr, acc),
        }
    } else {
        match isa {
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512Vnni => unsafe {
                microtile_avx512vnni(a, m0, k, strip, p0, pc, mr, nr, acc)
            },
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe {
                microtile_avx2(a, m0, k, strip, p0, pc, mr, nr, acc)
            },
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => unsafe {
                microtile_sse2(a, m0, k, strip, p0, pc, mr, nr, acc)
            },
            _ => microtile_scalar(a, m0, k, strip, p0, pc, mr, nr, acc),
        }
    }
}

/// The A operand of the fused implicit-GEMM driver ([`gemm_fused`]).
pub enum FusedA<'a> {
    /// Already row-major `(rows, k)` over the **global** row space:
    /// dense layers, and 1×1 stride-1 convs aliasing the input
    /// activation slab directly (the virtual patch matrix of a
    /// stride-1 pointwise conv *is* the input — zero copies).
    Direct(&'a [i8]),
    /// SAME-padded k×k conv input addressed through its implicit
    /// im2col view: micro-panel rows are assembled on demand via
    /// [`PatchGeom::fill_rows`] and the patch matrix never exists.
    ///
    /// [`PatchGeom::fill_rows`]: super::im2col::PatchGeom::fill_rows
    Implicit {
        /// The NHWC input activation slab.
        x: &'a [i8],
        /// Its padded-patch geometry (`cols()` must equal the panel's
        /// `k`).
        geom: super::im2col::PatchGeom,
    },
}

/// Second operand and rescale parameters of a fused residual add —
/// numerically identical to running `ops::add` on the conv output as
/// operand *a* and [`FusedResidual::b`] as operand *b*.
pub struct FusedResidual<'a> {
    /// The other add operand, `(rows, n)` row-major over the **global**
    /// row space (indexed by absolute output row, so row-sharded calls
    /// read the right slice).
    pub b: &'a [i8],
    /// Zero-point of the conv output (the add's *a*-operand domain).
    pub a_zp: i32,
    /// Zero-point of `b`.
    pub b_zp: i32,
    /// `(multiplier, shift)` rescaling the conv operand into the add's
    /// fixed-point domain.
    pub ma: (i32, i32),
    /// `(multiplier, shift)` rescaling `b` likewise.
    pub mb: (i32, i32),
    /// The add's output zero-point.
    pub out_zp: i32,
    /// The add's output clamp.
    pub clamp: (i32, i32),
}

/// Register-tile epilogue parameters for [`gemm_fused`]: everything
/// needed to take an i32 accumulator tile to clamped i8 without a
/// round-trip through a full accumulator buffer — the zero-point
/// correction (`- a_zp · bsums[c]`), the bias add, one of the two
/// requant forms, the output zero-point + clamp, and optionally a fused
/// residual add.
pub struct FusedEpilogue<'a> {
    /// A-operand (activation) zero-point.
    pub a_zp: i32,
    /// Weight column sums (the gemmlowp zero-point term).
    pub bsums: &'a [i32],
    /// Per-channel bias, already in the accumulator domain.
    pub bias: &'a [i32],
    /// Per-channel fixed-point `(multiplier, shift)` table — used when
    /// `shift` is `None` (mirrors `ops::requant_store`).
    pub requant: &'a [(i32, i32)],
    /// Per-channel rounding-shift table for pow2 exports (mirrors
    /// `ops::requant_store_shift`); takes precedence over `requant`.
    pub shift: Option<&'a [i32]>,
    /// Output zero-point.
    pub out_zp: i32,
    /// Output clamp.
    pub clamp: (i32, i32),
    /// `conv → add` chain fusion: requantize, then rescale into the
    /// add's output domain against [`FusedResidual::b`] — the
    /// intermediate conv activation never exists.
    pub residual: Option<FusedResidual<'a>>,
}

/// Fused implicit-GEMM conv/dense driver: one pass from the input
/// activation to clamped i8 output. Per `mr`-row tile the A micro-panel
/// is assembled on the fly (or aliased — [`FusedA::Direct`]), every
/// `kc`-pair panel of one strip accumulates into a single
/// stack-resident i32 tile, and [`fused_epilogue_tile`] requantizes
/// that tile straight into `out` — neither the patch matrix nor the
/// i32 accumulator buffer is ever materialized. Computes the virtual
/// output rows `[row0, row0 + m)`; `out` is that shard's `(m, n)` i8
/// slab.
///
/// Bit-exactness vs the staged path: the micro-tiles are the *same
/// functions* [`gemm_packed`] dispatches to (an `mr × k` row panel with
/// row stride `k` is indistinguishable from an `mr`-row window of the
/// full patch matrix, and [`PatchGeom::fill_rows`] produces
/// byte-identical rows to `im2col_into`); the per-strip accumulation
/// only regroups associative i32 adds; and the epilogue applies the
/// identical scalar formulas as `ops::requant_store` /
/// `ops::requant_store_shift` / `ops::add`. So fused output equals
/// staged output byte for byte on every ISA, blocking and thread
/// count.
///
/// [`PatchGeom::fill_rows`]: super::im2col::PatchGeom::fill_rows
#[allow(clippy::too_many_arguments)]
pub fn gemm_fused(
    a: &FusedA,
    row0: usize,
    m: usize,
    pw: &PackedWeights,
    ep: &FusedEpilogue,
    out: &mut [i8],
    isa: Isa,
    bk: Blocking,
) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(bk.nr, pw.nr, "blocking/panel strip width mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if let FusedA::Implicit { geom, .. } = a {
        debug_assert_eq!(geom.cols(), k, "patch geometry/panel mismatch");
        debug_assert!(row0 + m <= geom.rows());
    }
    // Defensive clamps, mirroring `gemm_packed`.
    let kc = (bk.kc.max(2) & !1).min(8192);
    let mr_b = bk.mr.clamp(1, MR_MAX);
    let nrw = pw.nr;
    let pairs_total = pw.pk / 2;
    // Row-panel scratch for the implicit view: `mr_b` (≤ MR_MAX)
    // virtual patch rows, filled once per m-tile and reused across
    // every strip and k-panel — L1/L2-resident for any realistic
    // `k·k·c`, and a vanishing fraction of the full patch matrix.
    let mut panel: Vec<i8> = match a {
        FusedA::Direct(_) => Vec::new(),
        FusedA::Implicit { .. } => vec![0i8; mr_b * k],
    };
    let mut m0 = 0usize;
    while m0 < m {
        let mr = mr_b.min(m - m0);
        let (aref, arow0): (&[i8], usize) = match a {
            FusedA::Direct(d) => {
                debug_assert!((row0 + m0 + mr) * k <= d.len());
                (d, row0 + m0)
            }
            FusedA::Implicit { x, geom } => {
                geom.fill_rows(x, row0 + m0, mr, &mut panel);
                (panel.as_slice(), 0)
            }
        };
        for ns in 0..pw.strips {
            let n0 = ns * nrw;
            let nc = nrw.min(n - n0);
            let strip = pw.strip(ns);
            // One accumulator tile persists across *all* k-panels of
            // this strip (the micro-tiles load-accumulate-store), so
            // the epilogue runs exactly once per (m-tile, strip).
            let mut acc = [[0i32; NR]; MR_MAX];
            let mut p0 = 0usize;
            while p0 < pairs_total {
                let pc = (kc / 2).min(pairs_total - p0);
                microtile_dispatch(
                    aref, arow0, k, strip, p0, pc, mr, nrw, &mut acc, isa,
                    pw.bits,
                );
                p0 += pc;
            }
            fused_epilogue_tile(&acc, ep, row0 + m0, m0, mr, n0, nc, n, out);
        }
        m0 += mr_b;
    }
}

/// Requantize one `(mr, nc)` accumulator tile into `out` rows while it
/// is still cache-hot — the scalar formulas of `ops::requant_store`
/// (multiplier), `ops::requant_store_shift` (pow2 rounding shift) and
/// `ops::add` (fused residual), verbatim. The epilogue is `O(mr·nc)`
/// against the tile's `O(mr·nc·k)` multiply work, so this scalar loop
/// costs ~`1/k` of the kernel and vectorizing it would not move the
/// total.
///
/// `grow0` is the tile's absolute output row (for indexing the
/// residual's global `b` slab); `m0` its row offset within `out`.
#[allow(clippy::too_many_arguments)]
fn fused_epilogue_tile(
    acc: &[[i32; NR]; MR_MAX],
    ep: &FusedEpilogue,
    grow0: usize,
    m0: usize,
    mr: usize,
    n0: usize,
    nc: usize,
    n: usize,
    out: &mut [i8],
) {
    use crate::quant::scale::{apply_multiplier, rounding_rshift};
    for (r, arow) in acc.iter().take(mr).enumerate() {
        let o0 = (m0 + r) * n + n0;
        let orow = &mut out[o0..o0 + nc];
        for (j, o) in orow.iter_mut().enumerate() {
            let c = n0 + j;
            let mut v = arow[j];
            if ep.a_zp != 0 {
                v -= ep.a_zp * ep.bsums[c];
            }
            v += ep.bias[c];
            let q = match ep.shift {
                Some(sh) => rounding_rshift(v, sh[c]),
                None => {
                    let (mq, s) = ep.requant[c];
                    apply_multiplier(v, mq, s)
                }
            } + ep.out_zp;
            let q = q.clamp(ep.clamp.0, ep.clamp.1);
            *o = match &ep.residual {
                None => q as i8,
                Some(res) => {
                    let qb = res.b[(grow0 + r) * n + c] as i32;
                    let va = apply_multiplier(
                        (q - res.a_zp) << 20,
                        res.ma.0,
                        res.ma.1,
                    );
                    let vb = apply_multiplier(
                        (qb - res.b_zp) << 20,
                        res.mb.0,
                        res.mb.1,
                    );
                    let y = rounding_rshift(va + vb, 20) + res.out_zp;
                    y.clamp(res.clamp.0, res.clamp.1) as i8
                }
            };
        }
    }
}

/// Row-sharded [`gemm_fused`] over the persistent worker pool, shard
/// sizes rounded up to `bk.grain` rows exactly like
/// [`gemm_packed_parallel`]. Workers own disjoint `out` row slabs and
/// each computes its rows identically to the serial driver, so every
/// thread count is bit-exact.
#[allow(clippy::too_many_arguments)]
pub fn gemm_fused_parallel(
    a: &FusedA,
    m: usize,
    pw: &PackedWeights,
    ep: &FusedEpilogue,
    out: &mut [i8],
    threads: usize,
    isa: Isa,
    bk: Blocking,
) {
    let n = pw.n;
    debug_assert_eq!(out.len(), m * n);
    let t = threads.max(1).min(m.max(1));
    if t <= 1 || n == 0 {
        return gemm_fused(a, 0, m, pw, ep, out, isa, bk);
    }
    let g = bk.grain.clamp(1, 4096);
    let rows = m.div_ceil(t).div_ceil(g) * g;
    crate::util::threads::pool().run_chunks(out, rows * n, |i, out_slab| {
        let mc = out_slab.len() / n;
        gemm_fused(a, i * rows, mc, pw, ep, out_slab, isa, bk);
    });
}

/// Portable reference micro-tile over the packed layout: accumulate
/// `pc` row-pairs of one `nr`-wide strip into the first `(mr, nr)` of
/// the i32 accumulator block. The SIMD paths compute exactly this sum
/// (associative i32 adds).
#[allow(clippy::too_many_arguments)]
fn microtile_scalar(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    for p in p0..p0 + pc {
        let prow = &strip[p * 2 * nr..(p + 1) * 2 * nr];
        for (r, arow) in acc.iter_mut().take(mr).enumerate() {
            let ai = (m0 + r) * k + 2 * p;
            let a0 = a[ai] as i32;
            let a1 = if 2 * p + 1 < k { a[ai + 1] as i32 } else { 0 };
            for (j, av) in arow.iter_mut().take(nr).enumerate() {
                *av += a0 * prow[2 * j] as i32 + a1 * prow[2 * j + 1] as i32;
            }
        }
    }
}

/// Sign-extend a 4-bit two's-complement nibble (branch-free xor-sub:
/// `(v ^ 8) - 8` maps 0..=7 → 0..=7 and 8..=15 → -8..=-1).
#[inline]
fn nib_i32(v: u8) -> i32 {
    ((v & 0x0F) ^ 8) as i32 - 8
}

/// Portable reference micro-tile over the **int4** packed layout: each
/// strip byte holds the pair's two rows as nibbles; decode and run the
/// identical multiply-accumulate as [`microtile_scalar`].
#[allow(clippy::too_many_arguments)]
fn microtile_scalar_i4(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    for p in p0..p0 + pc {
        let prow = &strip[p * nr..(p + 1) * nr];
        for (r, arow) in acc.iter_mut().take(mr).enumerate() {
            let ai = (m0 + r) * k + 2 * p;
            let a0 = a[ai] as i32;
            let a1 = if 2 * p + 1 < k { a[ai + 1] as i32 } else { 0 };
            for (j, av) in arow.iter_mut().take(nr).enumerate() {
                let byte = prow[j] as u8;
                *av += a0 * nib_i32(byte) + a1 * nib_i32(byte >> 4);
            }
        }
    }
}

/// AVX2 **int4** micro-tile: per pair iteration, one 16-byte load
/// covers 16 columns; nibbles widen in-register (mask, `xor 0x08`,
/// `sub 0x08`) and `unpacklo/hi_epi8` re-interleaves lo/hi rows into
/// the same pair-interleaved i8 stream [`microtile_avx2`] eats, feeding
/// the unchanged sign-extend → `vpmaddwd` → `vpaddd` pipeline.
///
/// # Safety
/// Caller must ensure AVX2 is available and the slice geometry
/// invariants of [`gemm_packed`] (`nr % 16 == 0`, `nr ≤ NR`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_avx2_i4(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    use std::arch::x86_64::*;
    let groups = nr / 16;
    let mask = _mm_set1_epi8(0x0F);
    let eight = _mm_set1_epi8(0x08);
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        let mut accv = [_mm256_setzero_si256(); NR / 8];
        for (i, v) in accv.iter_mut().take(2 * groups).enumerate() {
            *v = _mm256_loadu_si256(
                arow_acc.as_ptr().add(i * 8) as *const __m256i
            );
        }
        for p in p0..p0 + pc {
            let a0 = *a.get_unchecked(abase + 2 * p) as i32;
            let a1 = if 2 * p + 1 < k {
                *a.get_unchecked(abase + 2 * p + 1) as i32
            } else {
                0
            };
            let av = _mm256_set1_epi32(pair_i32(a0, a1));
            let brow = strip.as_ptr().add(p * nr);
            for i in 0..groups {
                let b = _mm_loadu_si128(brow.add(i * 16) as *const __m128i);
                let bl = _mm_sub_epi8(
                    _mm_xor_si128(_mm_and_si128(b, mask), eight),
                    eight,
                );
                let bh = _mm_sub_epi8(
                    _mm_xor_si128(
                        _mm_and_si128(_mm_srli_epi16(b, 4), mask),
                        eight,
                    ),
                    eight,
                );
                // columns i·16 .. i·16+8 and i·16+8 .. i·16+16, each as
                // the pair-interleaved byte stream of the int8 layout
                let lo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(bl, bh));
                let hi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(bl, bh));
                let v0 = &mut accv[2 * i];
                *v0 = _mm256_add_epi32(*v0, _mm256_madd_epi16(av, lo));
                let v1 = &mut accv[2 * i + 1];
                *v1 = _mm256_add_epi32(*v1, _mm256_madd_epi16(av, hi));
            }
        }
        for (i, v) in accv.iter().take(2 * groups).enumerate() {
            _mm256_storeu_si256(
                arow_acc.as_mut_ptr().add(i * 8) as *mut __m256i,
                *v,
            );
        }
    }
}

/// SSE2 **int4** micro-tile: per pair iteration an 8-byte load covers
/// 8 columns; nibbles widen via the same xor-sub trick, interleave back
/// to the pair stream, then take the compare+unpack sign extension and
/// `pmaddwd` of [`microtile_sse2`].
///
/// # Safety
/// Caller must uphold the slice geometry invariants of [`gemm_packed`]
/// (`nr % 16 == 0`, `nr ≤ NR`). SSE2 is the x86_64 baseline.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_sse2_i4(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    use std::arch::x86_64::*;
    let zero = _mm_setzero_si128();
    let mask = _mm_set1_epi8(0x0F);
    let eight = _mm_set1_epi8(0x08);
    let groups = nr / 8;
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        let mut accv = [_mm_setzero_si128(); NR / 4];
        for (i, v) in accv.iter_mut().take(2 * groups).enumerate() {
            *v = _mm_loadu_si128(
                arow_acc.as_ptr().add(i * 4) as *const __m128i
            );
        }
        for p in p0..p0 + pc {
            let a0 = *a.get_unchecked(abase + 2 * p) as i32;
            let a1 = if 2 * p + 1 < k {
                *a.get_unchecked(abase + 2 * p + 1) as i32
            } else {
                0
            };
            let av = _mm_set1_epi32(pair_i32(a0, a1));
            let brow = strip.as_ptr().add(p * nr);
            for i in 0..groups {
                let b8 = _mm_loadl_epi64(brow.add(i * 8) as *const __m128i);
                let bl = _mm_sub_epi8(
                    _mm_xor_si128(_mm_and_si128(b8, mask), eight),
                    eight,
                );
                let bh = _mm_sub_epi8(
                    _mm_xor_si128(
                        _mm_and_si128(_mm_srli_epi16(b8, 4), mask),
                        eight,
                    ),
                    eight,
                );
                let inter = _mm_unpacklo_epi8(bl, bh);
                let sign = _mm_cmpgt_epi8(zero, inter);
                let b16lo = _mm_unpacklo_epi8(inter, sign);
                let b16hi = _mm_unpackhi_epi8(inter, sign);
                let v0 = &mut accv[2 * i];
                *v0 = _mm_add_epi32(*v0, _mm_madd_epi16(av, b16lo));
                let v1 = &mut accv[2 * i + 1];
                *v1 = _mm_add_epi32(*v1, _mm_madd_epi16(av, b16hi));
            }
        }
        for (i, v) in accv.iter().take(2 * groups).enumerate() {
            _mm_storeu_si128(
                arow_acc.as_mut_ptr().add(i * 4) as *mut __m128i,
                *v,
            );
        }
    }
}

/// Broadcastable i16 pair `[a0, a1]` as one i32 lane value.
#[cfg(target_arch = "x86_64")]
#[inline]
fn pair_i32(a0: i32, a1: i32) -> i32 {
    (((a1 as i16 as u16 as u32) << 16) | (a0 as i16 as u16 as u32)) as i32
}

/// AVX2 micro-tile: per a-row, `nr/8` 256-bit i32 accumulators cover
/// the strip; each pair iteration does one broadcast + (16-byte load →
/// sign-extend → `vpmaddwd` → `vpaddd`) per 8 columns.
///
/// # Safety
/// Caller must ensure AVX2 is available (guarded by [`Isa::detect`] /
/// [`Isa::available`]) and the slice geometry invariants of
/// [`gemm_packed`] (in particular `nr % 16 == 0`, `nr ≤ NR`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_avx2(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    use std::arch::x86_64::*;
    let groups = nr / 8;
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        let mut accv = [_mm256_setzero_si256(); NR / 8];
        for (i, v) in accv.iter_mut().take(groups).enumerate() {
            *v = _mm256_loadu_si256(
                arow_acc.as_ptr().add(i * 8) as *const __m256i
            );
        }
        for p in p0..p0 + pc {
            let a0 = *a.get_unchecked(abase + 2 * p) as i32;
            let a1 = if 2 * p + 1 < k {
                *a.get_unchecked(abase + 2 * p + 1) as i32
            } else {
                0
            };
            let av = _mm256_set1_epi32(pair_i32(a0, a1));
            let brow = strip.as_ptr().add(p * 2 * nr);
            for (i, v) in accv.iter_mut().take(groups).enumerate() {
                let b16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                    brow.add(i * 16) as *const __m128i,
                ));
                *v = _mm256_add_epi32(*v, _mm256_madd_epi16(av, b16));
            }
        }
        for (i, v) in accv.iter().take(groups).enumerate() {
            _mm256_storeu_si256(
                arow_acc.as_mut_ptr().add(i * 8) as *mut __m256i,
                *v,
            );
        }
    }
}

/// AVX-512 VNNI micro-tile: per a-row, `nr/16` 512-bit i32 accumulators
/// cover the strip; each pair iteration does one broadcast + (32-byte
/// load → sign-extend → fused `vpdpwssd`) per 16 columns. It consumes
/// the **same** pair-interleaved layout as the pmaddwd paths (the
/// `vpdpbusd` quad layout was rejected — see DESIGN.md §12), so
/// bit-exactness is inherited, not re-argued.
///
/// # Safety
/// Caller must ensure avx512f/bw/vnni are available (guarded by
/// [`Isa::detect`] / [`Isa::available`]) and the slice geometry
/// invariants of [`gemm_packed`] (`nr % 16 == 0`, `nr ≤ NR`).
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_avx512vnni(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    use std::arch::x86_64::*;
    let groups = nr / 16;
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        let mut accv = [_mm512_setzero_si512(); NR / 16];
        for (i, v) in accv.iter_mut().take(groups).enumerate() {
            *v = _mm512_loadu_si512(
                arow_acc.as_ptr().add(i * 16) as *const __m512i
            );
        }
        for p in p0..p0 + pc {
            let a0 = *a.get_unchecked(abase + 2 * p) as i32;
            let a1 = if 2 * p + 1 < k {
                *a.get_unchecked(abase + 2 * p + 1) as i32
            } else {
                0
            };
            let av = _mm512_set1_epi32(pair_i32(a0, a1));
            let brow = strip.as_ptr().add(p * 2 * nr);
            for (i, v) in accv.iter_mut().take(groups).enumerate() {
                let b16 = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
                    brow.add(i * 32) as *const __m256i,
                ));
                *v = _mm512_dpwssd_epi32(*v, av, b16);
            }
        }
        for (i, v) in accv.iter().take(groups).enumerate() {
            _mm512_storeu_si512(
                arow_acc.as_mut_ptr().add(i * 16) as *mut __m512i,
                *v,
            );
        }
    }
}

/// SSE2 micro-tile (x86_64 baseline — no runtime check needed): 128-bit
/// `pmaddwd` over 4-column groups, sign-extension via compare+unpack.
///
/// # Safety
/// Caller must uphold the slice geometry invariants of [`gemm_packed`]
/// (`nr % 16 == 0`, `nr ≤ NR`).
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_sse2(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
    acc: &mut [[i32; NR]; MR_MAX],
) {
    use std::arch::x86_64::*;
    let zero = _mm_setzero_si128();
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        for jv in 0..nr / 4 {
            let mut accv = _mm_loadu_si128(
                arow_acc.as_ptr().add(jv * 4) as *const __m128i
            );
            for p in p0..p0 + pc {
                let a0 = *a.get_unchecked(abase + 2 * p) as i32;
                let a1 = if 2 * p + 1 < k {
                    *a.get_unchecked(abase + 2 * p + 1) as i32
                } else {
                    0
                };
                let av = _mm_set1_epi32(pair_i32(a0, a1));
                let b8 = _mm_loadl_epi64(
                    strip.as_ptr().add((p * nr + jv * 4) * 2)
                        as *const __m128i,
                );
                let b16 = _mm_unpacklo_epi8(b8, _mm_cmpgt_epi8(zero, b8));
                accv = _mm_add_epi32(accv, _mm_madd_epi16(av, b16));
            }
            _mm_storeu_si128(
                arow_acc.as_mut_ptr().add(jv * 4) as *mut __m128i,
                accv,
            );
        }
    }
}

/// One depthwise-conv tap over all channels:
/// `acc[ci] += (x[ci] - zp) · w[ci]`. The i16 product is exact
/// (`|x - zp| ≤ 255`, `|w| ≤ 128` ⇒ `|prod| ≤ 32640 < 2^15`), so every
/// ISA is bit-exact.
pub fn dw_accum_tap(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32, isa: Isa) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), w.len());
    match isa {
        // The depthwise tap has no 512-bit variant (it is bandwidth-,
        // not ALU-bound); VNNI machines take the AVX2 tap, which their
        // detection gate guarantees is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 | Isa::Avx512Vnni => unsafe { dw_tap_avx2(acc, x, w, zp) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { dw_tap_sse2(acc, x, w, zp) },
        _ => dw_tap_scalar(acc, x, w, zp),
    }
}

fn dw_tap_scalar(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32) {
    for ((a, &xv), &wv) in acc.iter_mut().zip(x).zip(w) {
        *a += (xv as i32 - zp) * wv as i32;
    }
}

/// # Safety
/// Caller must ensure AVX2 is available and `acc`/`x`/`w` have equal
/// lengths (debug-asserted in [`dw_accum_tap`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dw_tap_avx2(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32) {
    use std::arch::x86_64::*;
    let c = acc.len();
    let zpv = _mm256_set1_epi16(zp as i16);
    let mut i = 0usize;
    while i + 16 <= c {
        let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(
            x.as_ptr().add(i) as *const __m128i
        ));
        let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(
            w.as_ptr().add(i) as *const __m128i
        ));
        let prod = _mm256_mullo_epi16(_mm256_sub_epi16(xv, zpv), wv);
        let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
        let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256(prod, 1));
        let ap = acc.as_mut_ptr().add(i) as *mut __m256i;
        _mm256_storeu_si256(ap, _mm256_add_epi32(_mm256_loadu_si256(ap), lo));
        let ap2 = acc.as_mut_ptr().add(i + 8) as *mut __m256i;
        _mm256_storeu_si256(
            ap2,
            _mm256_add_epi32(_mm256_loadu_si256(ap2), hi),
        );
        i += 16;
    }
    dw_tap_scalar(&mut acc[i..], &x[i..], &w[i..], zp);
}

/// # Safety
/// Caller must ensure `acc`/`x`/`w` have equal lengths (debug-asserted
/// in [`dw_accum_tap`]). SSE2 is the x86_64 baseline.
#[cfg(target_arch = "x86_64")]
unsafe fn dw_tap_sse2(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32) {
    use std::arch::x86_64::*;
    let c = acc.len();
    let zero = _mm_setzero_si128();
    let zpv = _mm_set1_epi16(zp as i16);
    let mut i = 0usize;
    while i + 8 <= c {
        let x8 = _mm_loadl_epi64(x.as_ptr().add(i) as *const __m128i);
        let x16 = _mm_unpacklo_epi8(x8, _mm_cmpgt_epi8(zero, x8));
        let w8 = _mm_loadl_epi64(w.as_ptr().add(i) as *const __m128i);
        let w16 = _mm_unpacklo_epi8(w8, _mm_cmpgt_epi8(zero, w8));
        let prod = _mm_mullo_epi16(_mm_sub_epi16(x16, zpv), w16);
        let sign = _mm_srai_epi16(prod, 15);
        let lo = _mm_unpacklo_epi16(prod, sign);
        let hi = _mm_unpackhi_epi16(prod, sign);
        let ap = acc.as_mut_ptr().add(i) as *mut __m128i;
        _mm_storeu_si128(ap, _mm_add_epi32(_mm_loadu_si128(ap), lo));
        let ap2 = acc.as_mut_ptr().add(i + 4) as *mut __m128i;
        _mm_storeu_si128(ap2, _mm_add_epi32(_mm_loadu_si128(ap2), hi));
        i += 8;
    }
    dw_tap_scalar(&mut acc[i..], &x[i..], &w[i..], zp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::int8::gemm::{col_sums, gemm_ref};
    use crate::util::prop;

    #[test]
    fn pack_layout_golden() {
        // (3, 2) matrix, k odd → one zero-padded pair row; n < NR → the
        // strip's tail columns are zero.
        let b = vec![1i8, 2, 3, 4, 5, 6];
        let pw = PackedWeights::pack(&b, 3, 2);
        assert_eq!((pw.k, pw.n, pw.pk, pw.strips, pw.nr), (3, 2, 4, 1, NR));
        assert_eq!(pw.bytes(), 4 * NR);
        let d = &pw.data;
        // pair 0 (rows 0, 1), columns 0 and 1
        assert_eq!(&d[0..4], &[1, 3, 2, 4]);
        // pair 1 (row 2 + zero pad)
        assert_eq!(&d[2 * NR..2 * NR + 4], &[5, 0, 6, 0]);
        // every other lane is padding
        let live = [0usize, 1, 2, 3, 2 * NR, 2 * NR + 1, 2 * NR + 2, 2 * NR + 3];
        for (i, &v) in d.iter().enumerate() {
            if !live.contains(&i) {
                assert_eq!(v, 0, "lane {i}");
            }
        }
    }

    #[test]
    fn pack_with_narrow_strip_golden() {
        // (2, 20) at nr=16 → two strips; column 16 starts strip 1.
        let mut b = vec![0i8; 2 * 20];
        b[16] = 9; // row 0, col 16
        b[20 + 16] = 7; // row 1, col 16
        let pw = PackedWeights::pack_with(&b, 2, 20, 16);
        assert_eq!((pw.pk, pw.strips, pw.nr), (2, 2, 16));
        assert_eq!(pw.bytes(), 2 * 2 * 16);
        // strip 1, pair 0, column offset 0: interleaved [row0, row1]
        assert_eq!(&pw.data[2 * 16..2 * 16 + 2], &[9, 7]);
    }

    #[test]
    fn blocking_validate_rejects_hostile_geometries() {
        assert!(Blocking::default().validate().is_ok());
        assert!(Blocking { kc: 2, nr: 16, mr: 1, grain: 1 }.validate().is_ok());
        assert!(
            Blocking { kc: 8192, nr: 48, mr: MR_MAX, grain: 4096 }
                .validate()
                .is_ok()
        );
        let bad = [
            Blocking { kc: 0, ..Blocking::default() },
            Blocking { kc: 3, ..Blocking::default() },
            Blocking { kc: 1 << 20, ..Blocking::default() },
            Blocking { nr: 0, ..Blocking::default() },
            Blocking { nr: 8, ..Blocking::default() },
            Blocking { nr: 63, ..Blocking::default() },
            Blocking { nr: NR + 16, ..Blocking::default() },
            Blocking { mr: 0, ..Blocking::default() },
            Blocking { mr: MR_MAX + 1, ..Blocking::default() },
            Blocking { grain: 0, ..Blocking::default() },
            Blocking { grain: 1 << 20, ..Blocking::default() },
        ];
        for bk in bad {
            assert!(bk.validate().is_err(), "{bk:?} should be rejected");
        }
    }

    #[test]
    fn packed_matches_reference_across_isas() {
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(21, m * k);
            let b = prop::i8s(22, k * n);
            let sums = col_sums(&b, k, n);
            let pw = PackedWeights::pack(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for isa in Isa::available() {
                let mut out = vec![i32::MIN; m * n];
                gemm_packed(
                    &a,
                    zp,
                    &pw,
                    &sums,
                    m,
                    &mut out,
                    isa,
                    Blocking::default(),
                );
                assert_eq!(out, want, "({m},{k},{n}) zp={zp} {}", isa.name());
            }
        }
    }

    #[test]
    fn packed_parallel_matches_reference_across_isa_and_threads() {
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(23, m * k);
            let b = prop::i8s(24, k * n);
            let sums = col_sums(&b, k, n);
            let pw = PackedWeights::pack(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for isa in Isa::available() {
                for threads in [1usize, 2, 8] {
                    let mut out = vec![0i32; m * n];
                    gemm_packed_parallel(
                        &a,
                        zp,
                        &pw,
                        &sums,
                        m,
                        &mut out,
                        threads,
                        isa,
                        Blocking::default(),
                    );
                    assert_eq!(
                        out,
                        want,
                        "({m},{k},{n}) t={threads} {}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn blocking_sweep_matches_reference_across_isas() {
        // Every candidate geometry the tuner may emit must be
        // bit-exact; strip widths below NR force a repack.
        let cands = [
            Blocking { kc: 2, nr: 16, mr: 1, grain: 1 },
            Blocking { kc: 64, nr: 32, mr: 2, grain: 4 },
            Blocking { kc: 128, nr: 48, mr: 3, grain: 2 },
            Blocking { kc: 256, nr: 64, mr: MR_MAX, grain: 8 },
            Blocking { kc: 8192, nr: 16, mr: 5, grain: 1 },
        ];
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(25, m * k);
            let b = prop::i8s(26, k * n);
            let sums = col_sums(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for bk in cands {
                bk.validate().unwrap();
                let pw = PackedWeights::pack_with(&b, k, n, bk.nr);
                for isa in Isa::available() {
                    for threads in [1usize, 3] {
                        let mut out = vec![i32::MIN; m * n];
                        gemm_packed_parallel(
                            &a, zp, &pw, &sums, m, &mut out, threads, isa, bk,
                        );
                        assert_eq!(
                            out,
                            want,
                            "({m},{k},{n}) {} t={threads} {}",
                            bk.label(),
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dw_tap_matches_scalar_across_isas_and_channel_remainders() {
        // channel counts straddling the 16/8-lane vector widths
        for &c in &[1usize, 3, 7, 8, 15, 16, 17, 31, 64, 67] {
            let x = prop::i8s(31, c);
            let w = prop::i8s(32, c);
            for &zp in &[0i32, -7, 127, -128] {
                let mut want = vec![3i32; c];
                dw_tap_scalar(&mut want, &x, &w, zp);
                for isa in Isa::available() {
                    let mut acc = vec![3i32; c];
                    dw_accum_tap(&mut acc, &x, &w, zp, isa);
                    assert_eq!(acc, want, "c={c} zp={zp} {}", isa.name());
                }
            }
        }
    }

    #[test]
    fn dw_tap_extreme_operands_stay_exact() {
        // the i16-product proof obligation: |x-zp|·|w| peaks at 32640
        let c = 40usize;
        let x = vec![127i8; c];
        let w = vec![-128i8; c];
        let mut want = vec![0i32; c];
        dw_tap_scalar(&mut want, &x, &w, -128);
        assert!(want.iter().all(|&v| v == (127 + 128) * -128));
        for isa in Isa::available() {
            let mut acc = vec![0i32; c];
            dw_accum_tap(&mut acc, &x, &w, -128, isa);
            assert_eq!(acc, want, "{}", isa.name());
        }
    }

    #[test]
    fn accumulates_beyond_i16_on_every_isa() {
        // 512 × 127·127 overflows i16 by far; i32 accumulation must hold.
        let a = vec![127i8; 512];
        let b = vec![127i8; 512];
        let pw = PackedWeights::pack(&b, 512, 1);
        let sums = col_sums(&b, 512, 1);
        for isa in Isa::available() {
            let mut out = vec![0i32; 1];
            gemm_packed(
                &a,
                0,
                &pw,
                &sums,
                1,
                &mut out,
                isa,
                Blocking::default(),
            );
            assert_eq!(out[0], 127 * 127 * 512, "{}", isa.name());
        }
    }

    #[test]
    fn from_packed_rehydrates_identically() {
        let b = prop::i8s(41, 24 * 70);
        for nrw in [16usize, 32, 48, 64] {
            let pw = PackedWeights::pack_with(&b, 24, 70, nrw);
            let re = PackedWeights::from_packed(
                pw.raw_data().to_vec().into(),
                24,
                70,
                nrw,
            )
            .unwrap();
            assert_eq!(re.raw_data(), pw.raw_data());
            assert_eq!(
                (re.k, re.n, re.pk, re.strips, re.nr),
                (pw.k, pw.n, pw.pk, pw.strips, pw.nr)
            );
        }
        // wrong byte count / strip width is rejected, not asserted
        assert!(
            PackedWeights::from_packed(vec![0i8; 7].into(), 24, 70, NR).is_err()
        );
        let pw = PackedWeights::pack(&b, 24, 70);
        assert!(PackedWeights::from_packed(
            pw.raw_data().to_vec().into(),
            24,
            70,
            32
        )
        .is_err());
        assert!(PackedWeights::from_packed(
            pw.raw_data().to_vec().into(),
            24,
            70,
            7
        )
        .is_err());
    }

    #[test]
    fn int4_pack_layout_golden() {
        // (3, 2), k odd → pair 1 is row 2 + zero pad; -8 exercises the
        // negative nibble boundary.
        let b = vec![1i8, 2, 3, 4, 5, -8];
        let pw = PackedWeights::pack_bits(&b, 3, 2, NR, 4);
        assert_eq!((pw.k, pw.n, pw.pk, pw.strips, pw.nr, pw.bits()),
                   (3, 2, 4, 1, NR, 4));
        // half the int8 footprint: (pk/2) rows of NR bytes
        assert_eq!(pw.bytes(), 2 * NR);
        let d = &pw.data;
        // pair 0: lo = row 0, hi = row 1 → 0x31, 0x42
        assert_eq!(&d[0..2], &[0x31, 0x42]);
        // pair 1: lo = row 2 (5 and -8 → nibble 0x8), hi = zero pad
        assert_eq!(&d[NR..NR + 2], &[0x05, 0x08]);
        for (i, &v) in d.iter().enumerate() {
            if ![0usize, 1, NR, NR + 1].contains(&i) {
                assert_eq!(v, 0, "lane {i}");
            }
        }
        // the decode helper inverts the nibble encode exactly
        for v in -8i32..=7 {
            assert_eq!(nib_i32(v as i8 as u8), v);
        }
    }

    #[test]
    fn fits_int4_tracks_nibble_range() {
        assert!(fits_int4(&[]));
        assert!(fits_int4(&[-8, -1, 0, 7]));
        assert!(!fits_int4(&[8]));
        assert!(!fits_int4(&[-9]));
        assert!(!fits_int4(&[0, 0, 127]));
    }

    #[test]
    fn int4_packed_matches_reference_across_isas_and_threads() {
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(51, m * k);
            let mut b: Vec<i8> =
                prop::i8s(52, k * n).iter().map(|&v| v % 8).collect();
            b[0] = -8; // boundary nibble
            let sums = col_sums(&b, k, n);
            let pw = PackedWeights::pack_bits(&b, k, n, NR, 4);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for isa in Isa::available() {
                for threads in [1usize, 2, 8] {
                    let mut out = vec![i32::MIN; m * n];
                    gemm_packed_parallel(
                        &a,
                        zp,
                        &pw,
                        &sums,
                        m,
                        &mut out,
                        threads,
                        isa,
                        Blocking::default(),
                    );
                    assert_eq!(
                        out,
                        want,
                        "int4 ({m},{k},{n}) zp={zp} t={threads} {}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn int4_blocking_sweep_matches_reference_across_isas() {
        let cands = [
            Blocking { kc: 2, nr: 16, mr: 1, grain: 1 },
            Blocking { kc: 64, nr: 32, mr: 2, grain: 4 },
            Blocking { kc: 128, nr: 48, mr: 3, grain: 2 },
            Blocking { kc: 256, nr: 64, mr: MR_MAX, grain: 8 },
        ];
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(53, m * k);
            let b: Vec<i8> =
                prop::i8s(54, k * n).iter().map(|&v| v % 8).collect();
            let sums = col_sums(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for bk in cands {
                let pw = PackedWeights::pack_bits(&b, k, n, bk.nr, 4);
                for isa in Isa::available() {
                    let mut out = vec![i32::MIN; m * n];
                    gemm_packed(&a, zp, &pw, &sums, m, &mut out, isa, bk);
                    assert_eq!(
                        out,
                        want,
                        "int4 ({m},{k},{n}) {} {}",
                        bk.label(),
                        isa.name()
                    );
                }
            }
        }
    }

    /// The staged store formulas (`ops::requant_store` /
    /// `ops::requant_store_shift`), inlined as the fused oracle.
    fn staged_epilogue(
        acc: &[i32],
        bias: &[i32],
        requant: &[(i32, i32)],
        shift: Option<&[i32]>,
        out_zp: i32,
        clamp: (i32, i32),
        n: usize,
    ) -> Vec<i8> {
        use crate::quant::scale::{apply_multiplier, rounding_rshift};
        acc.iter()
            .enumerate()
            .map(|(i, &a)| {
                let c = i % n;
                let v = a + bias[c];
                let q = match shift {
                    Some(sh) => rounding_rshift(v, sh[c]),
                    None => {
                        let (mq, s) = requant[c];
                        apply_multiplier(v, mq, s)
                    }
                } + out_zp;
                q.clamp(clamp.0, clamp.1) as i8
            })
            .collect()
    }

    #[test]
    fn fused_direct_matches_staged_epilogue_across_isas_and_threads() {
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(61, m * k);
            let b = prop::i8s(62, k * n);
            let sums = col_sums(&b, k, n);
            let pw = PackedWeights::pack(&b, k, n);
            let bias: Vec<i32> =
                (0..n).map(|c| (c as i32 % 19) - 9).collect();
            let requant: Vec<(i32, i32)> =
                (0..n).map(|c| (1 << 30, (c as i32 % 3) + 4)).collect();
            let shifts: Vec<i32> =
                (0..n).map(|c| (c as i32 % 5) + 3).collect();
            // staged oracle: full i32 GEMM, then the scalar store pass
            let mut acc = vec![0i32; m * n];
            gemm_packed(
                &a,
                zp,
                &pw,
                &sums,
                m,
                &mut acc,
                Isa::Scalar,
                Blocking::default(),
            );
            for use_shift in [false, true] {
                let sh = use_shift.then_some(shifts.as_slice());
                let want = staged_epilogue(
                    &acc, &bias, &requant, sh, -1, (-128, 127), n,
                );
                let ep = FusedEpilogue {
                    a_zp: zp,
                    bsums: &sums,
                    bias: &bias,
                    requant: &requant,
                    shift: sh,
                    out_zp: -1,
                    clamp: (-128, 127),
                    residual: None,
                };
                for isa in Isa::available() {
                    for threads in [1usize, 2, 8] {
                        let mut out = vec![77i8; m * n];
                        gemm_fused_parallel(
                            &FusedA::Direct(&a),
                            m,
                            &pw,
                            &ep,
                            &mut out,
                            threads,
                            isa,
                            Blocking::default(),
                        );
                        assert_eq!(
                            out,
                            want,
                            "({m},{k},{n}) zp={zp} shift={use_shift} \
                             t={threads} {}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_implicit_view_matches_direct_on_materialized_patches() {
        // The implicit im2col A operand must equal Direct fed the
        // materialized patch matrix — same panels, same epilogue.
        for &(nb, h, w, c, k, stride) in &[
            (1usize, 6usize, 6usize, 3usize, 3usize, 1usize),
            (2, 5, 4, 2, 3, 2),
            (1, 7, 7, 1, 5, 2),
        ] {
            let x = prop::i8s(63, nb * h * w * c);
            let g = crate::int8::im2col::PatchGeom::new(
                nb, h, w, c, k, stride, -3,
            );
            let (m, kk) = (g.rows(), g.cols());
            let (full, _, _) = crate::int8::im2col::im2col_i8(
                &x, nb, h, w, c, k, stride, -3,
            );
            let cout = 20usize;
            for bits in [8usize, 4] {
                let wts: Vec<i8> = if bits == 4 {
                    prop::i8s(64, kk * cout).iter().map(|&v| v % 8).collect()
                } else {
                    prop::i8s(64, kk * cout)
                };
                let sums = col_sums(&wts, kk, cout);
                let pw = PackedWeights::pack_bits(&wts, kk, cout, NR, bits);
                let bias: Vec<i32> =
                    (0..cout).map(|i| i as i32 * 3 - 5).collect();
                let requant: Vec<(i32, i32)> = vec![(1 << 30, 6); cout];
                let ep = FusedEpilogue {
                    a_zp: -3,
                    bsums: &sums,
                    bias: &bias,
                    requant: &requant,
                    shift: None,
                    out_zp: 2,
                    clamp: (-128, 127),
                    residual: None,
                };
                let mut want = vec![0i8; m * cout];
                gemm_fused(
                    &FusedA::Direct(&full),
                    0,
                    m,
                    &pw,
                    &ep,
                    &mut want,
                    Isa::Scalar,
                    Blocking::default(),
                );
                for isa in Isa::available() {
                    for threads in [1usize, 3] {
                        let mut out = vec![-9i8; m * cout];
                        gemm_fused_parallel(
                            &FusedA::Implicit { x: &x, geom: g },
                            m,
                            &pw,
                            &ep,
                            &mut out,
                            threads,
                            isa,
                            Blocking::default(),
                        );
                        assert_eq!(
                            out,
                            want,
                            "k{k} s{stride} bits{bits} t{threads} {}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_blocking_sweep_matches_default_schedule() {
        // Fused output must be schedule-independent, like the staged
        // GEMM: every tuner-reachable blocking gives identical bytes.
        let cands = [
            Blocking { kc: 2, nr: 16, mr: 1, grain: 1 },
            Blocking { kc: 64, nr: 32, mr: 2, grain: 4 },
            Blocking { kc: 256, nr: 64, mr: MR_MAX, grain: 8 },
        ];
        let (nb, h, w, c, k, stride) = (2usize, 6, 5, 3, 3, 1);
        let x = prop::i8s(71, nb * h * w * c);
        let g = crate::int8::im2col::PatchGeom::new(nb, h, w, c, k, stride, 4);
        let (m, kk, cout) = (g.rows(), g.cols(), 24usize);
        let wts = prop::i8s(72, kk * cout);
        let sums = col_sums(&wts, kk, cout);
        let bias: Vec<i32> = (0..cout).map(|i| 11 - i as i32).collect();
        let requant: Vec<(i32, i32)> = vec![((1 << 30) + 333, 5); cout];
        let ep = |sums: &[i32], bias: &[i32], rq: &[(i32, i32)]| FusedEpilogue {
            a_zp: 4,
            bsums: sums,
            bias,
            requant: rq,
            shift: None,
            out_zp: 0,
            clamp: (-128, 127),
            residual: None,
        };
        let pw0 = PackedWeights::pack(&wts, kk, cout);
        let mut want = vec![0i8; m * cout];
        gemm_fused(
            &FusedA::Implicit { x: &x, geom: g },
            0,
            m,
            &pw0,
            &ep(&sums, &bias, &requant),
            &mut want,
            Isa::Scalar,
            Blocking::default(),
        );
        for bk in cands {
            bk.validate().unwrap();
            let pw = PackedWeights::pack_with(&wts, kk, cout, bk.nr);
            for isa in Isa::available() {
                for threads in [1usize, 2] {
                    let mut out = vec![5i8; m * cout];
                    gemm_fused_parallel(
                        &FusedA::Implicit { x: &x, geom: g },
                        m,
                        &pw,
                        &ep(&sums, &bias, &requant),
                        &mut out,
                        threads,
                        isa,
                        bk,
                    );
                    assert_eq!(
                        out,
                        want,
                        "{} t={threads} {}",
                        bk.label(),
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn fused_residual_epilogue_matches_scalar_add_chain() {
        use crate::quant::scale::{apply_multiplier, rounding_rshift};
        let (m, k, n, zp) = (13usize, 18usize, 20usize, -4);
        let a = prop::i8s(65, m * k);
        let wts = prop::i8s(66, k * n);
        let sums = col_sums(&wts, k, n);
        let pw = PackedWeights::pack(&wts, k, n);
        let bias: Vec<i32> = (0..n).map(|i| i as i32 - 7).collect();
        let requant: Vec<(i32, i32)> = vec![(1 << 30, 5); n];
        let resid = prop::i8s(67, m * n);
        let (conv_zp, b_zp, add_zp) = (3, -2, 1);
        let (ma, mb) = ((1 << 30, 2), ((1 << 29) + 1234, 1));
        // oracle: plain fused conv, then the ops::add scalar formula
        let base = FusedEpilogue {
            a_zp: zp,
            bsums: &sums,
            bias: &bias,
            requant: &requant,
            shift: None,
            out_zp: conv_zp,
            clamp: (-100, 100),
            residual: None,
        };
        let mut conv = vec![0i8; m * n];
        gemm_fused(
            &FusedA::Direct(&a),
            0,
            m,
            &pw,
            &base,
            &mut conv,
            Isa::Scalar,
            Blocking::default(),
        );
        let want: Vec<i8> = conv
            .iter()
            .zip(&resid)
            .map(|(&qa, &qb)| {
                let va =
                    apply_multiplier(((qa as i32) - conv_zp) << 20, ma.0, ma.1);
                let vb =
                    apply_multiplier(((qb as i32) - b_zp) << 20, mb.0, mb.1);
                let v = rounding_rshift(va + vb, 20) + add_zp;
                v.clamp(-128, 127) as i8
            })
            .collect();
        let ep = FusedEpilogue {
            a_zp: zp,
            bsums: &sums,
            bias: &bias,
            requant: &requant,
            shift: None,
            out_zp: conv_zp,
            clamp: (-100, 100),
            residual: Some(FusedResidual {
                b: &resid,
                a_zp: conv_zp,
                b_zp,
                ma,
                mb,
                out_zp: add_zp,
                clamp: (-128, 127),
            }),
        };
        for isa in Isa::available() {
            for threads in [1usize, 2, 8] {
                let mut out = vec![99i8; m * n];
                gemm_fused_parallel(
                    &FusedA::Direct(&a),
                    m,
                    &pw,
                    &ep,
                    &mut out,
                    threads,
                    isa,
                    Blocking::default(),
                );
                assert_eq!(out, want, "t={threads} {}", isa.name());
            }
        }
    }

    #[test]
    fn int4_from_packed_validates_geometry() {
        let b: Vec<i8> =
            prop::i8s(55, 24 * 70).iter().map(|&v| v % 8).collect();
        for nrw in [16usize, 32, 64] {
            let pw = PackedWeights::pack_bits(&b, 24, 70, nrw, 4);
            let re = PackedWeights::from_packed_bits(
                pw.raw_data().to_vec().into(),
                24,
                70,
                nrw,
                4,
            )
            .unwrap();
            assert_eq!(re.raw_data(), pw.raw_data());
            assert_eq!(re.bits(), 4);
        }
        let pw = PackedWeights::pack_bits(&b, 24, 70, NR, 4);
        // int8-sized buffer under a bits=4 tag (and vice versa) is
        // rejected by length, as is a bogus bits value.
        let i8pw = PackedWeights::pack(&b, 24, 70);
        assert!(PackedWeights::from_packed_bits(
            i8pw.raw_data().to_vec().into(),
            24,
            70,
            NR,
            4
        )
        .is_err());
        assert!(PackedWeights::from_packed_bits(
            pw.raw_data().to_vec().into(),
            24,
            70,
            NR,
            8
        )
        .is_err());
        for bits in [0usize, 1, 2, 3, 5, 16] {
            assert!(PackedWeights::from_packed_bits(
                pw.raw_data().to_vec().into(),
                24,
                70,
                NR,
                bits
            )
            .is_err());
        }
    }

    #[test]
    #[should_panic(expected = "int4 weight out of")]
    fn int4_pack_rejects_out_of_range() {
        let b = vec![0i8, 8, 0, 0];
        PackedWeights::pack_bits(&b, 2, 2, 16, 4);
    }

    #[test]
    fn isa_parse_inverts_name() {
        for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Avx512Vnni] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse(" avx2 "), Some(Isa::Avx2));
        assert_eq!(Isa::parse("avx512vnni"), Some(Isa::Avx512Vnni));
        assert_eq!(Isa::parse("neon"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn isa_order_supports_clamping() {
        assert!(Isa::Scalar < Isa::Sse2 && Isa::Sse2 < Isa::Avx2);
        assert!(Isa::Avx2 < Isa::Avx512Vnni);
        assert_eq!(Isa::Avx2.min(Isa::Sse2), Isa::Sse2);
        // Requesting VNNI on a non-VNNI build/machine clamps down.
        assert_eq!(Isa::Avx512Vnni.min(Isa::Avx2), Isa::Avx2);
        let avail = Isa::available();
        assert!(avail.contains(&Isa::Scalar));
        // detect() clamps to best(), and available() lists every level
        // up to best(), so the detected ISA is always runnable.
        assert!(avail.contains(&Isa::detect()));
    }
}
