//! SIMD int8 microkernels over prepacked weight panels (DESIGN.md §8).
//!
//! The conv/dense hot loop is `i8 × i8 → i32`: widen both operands to
//! i16, multiply-accumulate pairs into i32 lanes (`pmaddwd` — the
//! gemmlowp/oneDNN lineage), with an SSE2 baseline, an AVX2 path picked
//! once per process by [`Isa::detect`], and a portable scalar fallback
//! that reads the **same packed layout** so every path is bit-exact.
//!
//! ## Packed layout
//!
//! [`PackedWeights::pack`] reorders the row-major `(k, n)` weight matrix
//! into `NR`-column strips of k-**pair**-interleaved rows (the shape
//! `pmaddwd` consumes directly):
//!
//! ```text
//! strip ns (columns n0 = ns·NR .. n0+NR, zero-padded past n):
//!   pair p (rows 2p, 2p+1; row k zero-padded when k is odd):
//!     b[2p][n0], b[2p+1][n0], b[2p][n0+1], b[2p+1][n0+1], …  (2·NR i8)
//! ```
//!
//! One `KC`-row panel of a strip is `KC × NR` i8 ≈ 8 KiB (L1-resident),
//! and a 16-byte load inside a pair yields 8 interleaved columns — the
//! exact operand layout of a widening multiply-add, with no shuffles on
//! the hot path.
//!
//! ## Bit-exactness
//!
//! Products of i8 (and of `(x - zp) · w` in the depthwise tap, with
//! `|x - zp| ≤ 255`, `|w| ≤ 128`, so `|prod| ≤ 32640 < 2^15`) fit i16
//! exactly; every accumulation is i32, and i32 addition is associative
//! and commutative, so any vector width, blocking, shard count and ISA
//! produces identical bytes. `gemm_ref` stays the oracle
//! (`rust/tests/proptests.rs`, `kernels::tests`).

use std::sync::OnceLock;

use crate::artifact::I8Slab;

/// Rows of `a` per micro-tile (register-block height).
pub const MR: usize = 4;
/// Columns of `b` per strip (register-block width).
pub const NR: usize = 64;
/// Depth of one cache panel of `b` (`KC * NR` i8 ≈ 8 KiB).
pub const KC: usize = 128;

/// Instruction-set level for the int8 microkernels. Ordered: a request
/// above the hardware clamps down ([`Isa::detect`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Portable scalar loop over the packed layout (any arch).
    Scalar,
    /// x86_64 baseline: 128-bit `pmaddwd` path.
    Sse2,
    /// 256-bit `vpmaddwd` path, runtime-detected.
    Avx2,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
        }
    }

    /// Inverse of [`Isa::name`] for CLI/env values (`scalar|sse2|avx2`).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim() {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            _ => None,
        }
    }

    /// Best ISA the hardware supports.
    fn best() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Isa::Avx2
            } else {
                Isa::Sse2
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Isa::Scalar
        }
    }

    /// The process-wide kernel ISA, detected **once** (`OnceLock`) when
    /// the first plan is built or executed. `FAT_ISA=scalar|sse2|avx2`
    /// pins a lower level for A/B runs; asking above the hardware clamps
    /// down to the best supported level. Tests sweep explicitly via
    /// [`Isa::available`] instead of mutating the environment.
    pub fn detect() -> Isa {
        static CACHE: OnceLock<Isa> = OnceLock::new();
        *CACHE.get_or_init(|| {
            let best = Isa::best();
            let req = match std::env::var("FAT_ISA").ok().as_deref() {
                Some(other) => match Isa::parse(other) {
                    Some(r) => Some(r),
                    None => {
                        // An explicit pin the user typo'd must not
                        // silently turn into "fastest": that would
                        // invert A/B runs.
                        eprintln!(
                            "FAT_ISA: unknown value {other:?} \
                             (want scalar|sse2|avx2); using detected {}",
                            best.name()
                        );
                        None
                    }
                },
                None => None,
            };
            req.map_or(best, |r| r.min(best))
        })
    }

    /// Every ISA runnable on this machine, weakest first (test sweeps).
    pub fn available() -> Vec<Isa> {
        match Isa::best() {
            Isa::Avx2 => vec![Isa::Scalar, Isa::Sse2, Isa::Avx2],
            Isa::Sse2 => vec![Isa::Scalar, Isa::Sse2],
            Isa::Scalar => vec![Isa::Scalar],
        }
    }
}

/// Weight matrix prepacked at `build_qmodel` plan time into the strip /
/// pair-interleaved layout the microkernels consume (module docs). Built
/// once per exported model and stored on the plan's dense parameter
/// table (`QLayer::packed`). The panel bytes live in an [`I8Slab`]:
/// owned when packed in-process, a borrowed window into a shared
/// read-only mapping when loaded zero-copy from a `.fatm` artifact
/// (`crate::artifact`) — the packed layout is ISA-independent, so a
/// panel packed on one machine is valid on any other.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    data: I8Slab,
    /// Logical row count of the source `(k, n)` matrix.
    pub k: usize,
    /// Logical column count of the source `(k, n)` matrix.
    pub n: usize,
    /// Rows per strip after padding `k` up to a pair boundary.
    pk: usize,
    /// Number of `NR`-column strips (`n` padded up).
    strips: usize,
}

impl PackedWeights {
    /// Pack a row-major `(k, n)` i8 matrix. Padding lanes (columns ≥ n,
    /// the row `k` of an odd-`k` pair) are zero, so they contribute
    /// nothing to any accumulator.
    pub fn pack(b: &[i8], k: usize, n: usize) -> PackedWeights {
        assert_eq!(b.len(), k * n, "pack: bad weight shape ({k},{n})");
        let strips = n.div_ceil(NR);
        let pk = k + (k & 1);
        let mut data = vec![0i8; strips * pk * NR];
        for ns in 0..strips {
            let n0 = ns * NR;
            let nr = NR.min(n - n0);
            let sbase = ns * pk * NR;
            for ki in 0..k {
                let lane = ki & 1;
                let pair = ki / 2;
                let src = &b[ki * n + n0..ki * n + n0 + nr];
                for (j, &v) in src.iter().enumerate() {
                    data[sbase + (pair * NR + j) * 2 + lane] = v;
                }
            }
        }
        PackedWeights { data: data.into(), k, n, pk, strips }
    }

    /// Rehydrate from already-packed panel bytes (the `.fatm` zero-copy
    /// load path). `data` must be exactly the output of
    /// [`PackedWeights::pack`] for a `(k, n)` matrix; only the length is
    /// checkable here — byte-level validity is the artifact digest's
    /// job.
    pub fn from_packed(
        data: I8Slab,
        k: usize,
        n: usize,
    ) -> anyhow::Result<PackedWeights> {
        let strips = n.div_ceil(NR);
        let pk = k + (k & 1);
        let want = strips
            .checked_mul(pk)
            .and_then(|v| v.checked_mul(NR))
            .ok_or_else(|| {
                anyhow::anyhow!("packed shape ({k},{n}) overflows")
            })?;
        anyhow::ensure!(
            data.len() == want,
            "packed panel for ({k},{n}): {} bytes, want {want}",
            data.len()
        );
        Ok(PackedWeights { data, k, n, pk, strips })
    }

    /// Packed size in bytes (padding included) — for size reports.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// The raw packed panel bytes (artifact serialization).
    pub fn raw_data(&self) -> &[i8] {
        &self.data
    }

    /// Whether the panel bytes borrow a mapped artifact (vs owned heap).
    pub fn is_mapped(&self) -> bool {
        self.data.is_mapped()
    }

    #[inline]
    fn strip(&self, ns: usize) -> &[i8] {
        &self.data[ns * self.pk * NR..(ns + 1) * self.pk * NR]
    }
}

/// Packed-panel GEMM: `out[mi, ni] = Σ_k (a[mi,k] - a_zp) · b[k,ni]`,
/// single-threaded, with the a_zp term applied via the precomputed
/// column sums exactly like `gemm::gemm_i8`. Bit-exact with `gemm_ref`
/// for every [`Isa`].
pub fn gemm_packed(
    a: &[i8],
    a_zp: i32,
    pw: &PackedWeights,
    bsums: &[i32],
    m: usize,
    out: &mut [i32],
    isa: Isa,
) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0);
    if m == 0 || n == 0 {
        return;
    }
    let pairs_total = pw.pk / 2;
    for ns in 0..pw.strips {
        let n0 = ns * NR;
        let nr = NR.min(n - n0);
        let strip = pw.strip(ns);
        let mut p0 = 0usize;
        while p0 < pairs_total {
            // One KC-row cache panel = KC/2 interleaved pairs.
            let pc = (KC / 2).min(pairs_total - p0);
            let mut m0 = 0usize;
            while m0 < m {
                let mr = MR.min(m - m0);
                let mut acc = [[0i32; NR]; MR];
                match isa {
                    #[cfg(target_arch = "x86_64")]
                    Isa::Avx2 => unsafe {
                        microtile_avx2(a, m0, k, strip, p0, pc, mr, &mut acc)
                    },
                    #[cfg(target_arch = "x86_64")]
                    Isa::Sse2 => unsafe {
                        microtile_sse2(a, m0, k, strip, p0, pc, mr, &mut acc)
                    },
                    _ => microtile_scalar(a, m0, k, strip, p0, pc, mr, &mut acc),
                }
                for (r, arow) in acc.iter().take(mr).enumerate() {
                    let o0 = (m0 + r) * n + n0;
                    let orow = &mut out[o0..o0 + nr];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o += arow[j];
                    }
                }
                m0 += MR;
            }
            p0 += pc;
        }
    }
    if a_zp != 0 {
        for mi in 0..m {
            let orow = &mut out[mi * n..(mi + 1) * n];
            for (ni, o) in orow.iter_mut().enumerate() {
                *o -= a_zp * bsums[ni];
            }
        }
    }
}

/// Row-sharded [`gemm_packed`] over the persistent worker pool
/// (`util::threads::pool`). Workers own disjoint `out` slabs, so every
/// thread count is bit-exact.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_parallel(
    a: &[i8],
    a_zp: i32,
    pw: &PackedWeights,
    bsums: &[i32],
    m: usize,
    out: &mut [i32],
    threads: usize,
    isa: Isa,
) {
    let (k, n) = (pw.k, pw.n);
    let t = threads.max(1).min(m.max(1));
    if t <= 1 || n == 0 {
        return gemm_packed(a, a_zp, pw, bsums, m, out, isa);
    }
    let rows = m.div_ceil(t);
    crate::util::threads::pool().run_chunks(out, rows * n, |i, out_slab| {
        let mc = out_slab.len() / n;
        let a_slab = &a[i * rows * k..i * rows * k + mc * k];
        gemm_packed(a_slab, a_zp, pw, bsums, mc, out_slab, isa);
    });
}

/// Portable reference micro-tile over the packed layout: accumulate
/// `pc` row-pairs of one strip into the `(mr, NR)` i32 block. The SIMD
/// paths compute exactly this sum (associative i32 adds).
#[allow(clippy::too_many_arguments)]
fn microtile_scalar(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    acc: &mut [[i32; NR]; MR],
) {
    for p in p0..p0 + pc {
        let prow = &strip[p * 2 * NR..(p + 1) * 2 * NR];
        for (r, arow) in acc.iter_mut().take(mr).enumerate() {
            let ai = (m0 + r) * k + 2 * p;
            let a0 = a[ai] as i32;
            let a1 = if 2 * p + 1 < k { a[ai + 1] as i32 } else { 0 };
            for (j, av) in arow.iter_mut().enumerate() {
                *av += a0 * prow[2 * j] as i32 + a1 * prow[2 * j + 1] as i32;
            }
        }
    }
}

/// Broadcastable i16 pair `[a0, a1]` as one i32 lane value.
#[cfg(target_arch = "x86_64")]
#[inline]
fn pair_i32(a0: i32, a1: i32) -> i32 {
    (((a1 as i16 as u16 as u32) << 16) | (a0 as i16 as u16 as u32)) as i32
}

/// AVX2 micro-tile: per a-row, 8 × 256-bit i32 accumulators cover the
/// NR=64 strip; each pair iteration does one broadcast + 4×(16-byte load
/// → sign-extend → `vpmaddwd` → `vpaddd`) per 16 columns.
///
/// # Safety
/// Caller must ensure AVX2 is available (guarded by [`Isa::detect`] /
/// [`Isa::available`]) and the slice geometry invariants of
/// [`gemm_packed`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_avx2(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    acc: &mut [[i32; NR]; MR],
) {
    use std::arch::x86_64::*;
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        let mut accv = [_mm256_setzero_si256(); NR / 8];
        for (i, v) in accv.iter_mut().enumerate() {
            *v = _mm256_loadu_si256(
                arow_acc.as_ptr().add(i * 8) as *const __m256i
            );
        }
        for p in p0..p0 + pc {
            let a0 = *a.get_unchecked(abase + 2 * p) as i32;
            let a1 = if 2 * p + 1 < k {
                *a.get_unchecked(abase + 2 * p + 1) as i32
            } else {
                0
            };
            let av = _mm256_set1_epi32(pair_i32(a0, a1));
            let brow = strip.as_ptr().add(p * 2 * NR);
            for (i, v) in accv.iter_mut().enumerate() {
                let b16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                    brow.add(i * 16) as *const __m128i,
                ));
                *v = _mm256_add_epi32(*v, _mm256_madd_epi16(av, b16));
            }
        }
        for (i, v) in accv.iter().enumerate() {
            _mm256_storeu_si256(
                arow_acc.as_mut_ptr().add(i * 8) as *mut __m256i,
                *v,
            );
        }
    }
}

/// SSE2 micro-tile (x86_64 baseline — no runtime check needed): 128-bit
/// `pmaddwd` over 4-column groups, sign-extension via compare+unpack.
///
/// # Safety
/// Caller must uphold the slice geometry invariants of [`gemm_packed`].
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn microtile_sse2(
    a: &[i8],
    m0: usize,
    k: usize,
    strip: &[i8],
    p0: usize,
    pc: usize,
    mr: usize,
    acc: &mut [[i32; NR]; MR],
) {
    use std::arch::x86_64::*;
    let zero = _mm_setzero_si128();
    for (r, arow_acc) in acc.iter_mut().take(mr).enumerate() {
        let abase = (m0 + r) * k;
        for jv in 0..NR / 4 {
            let mut accv = _mm_loadu_si128(
                arow_acc.as_ptr().add(jv * 4) as *const __m128i
            );
            for p in p0..p0 + pc {
                let a0 = *a.get_unchecked(abase + 2 * p) as i32;
                let a1 = if 2 * p + 1 < k {
                    *a.get_unchecked(abase + 2 * p + 1) as i32
                } else {
                    0
                };
                let av = _mm_set1_epi32(pair_i32(a0, a1));
                let b8 = _mm_loadl_epi64(
                    strip.as_ptr().add((p * NR + jv * 4) * 2)
                        as *const __m128i,
                );
                let b16 = _mm_unpacklo_epi8(b8, _mm_cmpgt_epi8(zero, b8));
                accv = _mm_add_epi32(accv, _mm_madd_epi16(av, b16));
            }
            _mm_storeu_si128(
                arow_acc.as_mut_ptr().add(jv * 4) as *mut __m128i,
                accv,
            );
        }
    }
}

/// One depthwise-conv tap over all channels:
/// `acc[ci] += (x[ci] - zp) · w[ci]`. The i16 product is exact
/// (`|x - zp| ≤ 255`, `|w| ≤ 128` ⇒ `|prod| ≤ 32640 < 2^15`), so every
/// ISA is bit-exact.
pub fn dw_accum_tap(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32, isa: Isa) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), w.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { dw_tap_avx2(acc, x, w, zp) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { dw_tap_sse2(acc, x, w, zp) },
        _ => dw_tap_scalar(acc, x, w, zp),
    }
}

fn dw_tap_scalar(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32) {
    for ((a, &xv), &wv) in acc.iter_mut().zip(x).zip(w) {
        *a += (xv as i32 - zp) * wv as i32;
    }
}

/// # Safety
/// Caller must ensure AVX2 is available and `acc`/`x`/`w` have equal
/// lengths (debug-asserted in [`dw_accum_tap`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dw_tap_avx2(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32) {
    use std::arch::x86_64::*;
    let c = acc.len();
    let zpv = _mm256_set1_epi16(zp as i16);
    let mut i = 0usize;
    while i + 16 <= c {
        let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(
            x.as_ptr().add(i) as *const __m128i
        ));
        let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(
            w.as_ptr().add(i) as *const __m128i
        ));
        let prod = _mm256_mullo_epi16(_mm256_sub_epi16(xv, zpv), wv);
        let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
        let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256(prod, 1));
        let ap = acc.as_mut_ptr().add(i) as *mut __m256i;
        _mm256_storeu_si256(ap, _mm256_add_epi32(_mm256_loadu_si256(ap), lo));
        let ap2 = acc.as_mut_ptr().add(i + 8) as *mut __m256i;
        _mm256_storeu_si256(
            ap2,
            _mm256_add_epi32(_mm256_loadu_si256(ap2), hi),
        );
        i += 16;
    }
    dw_tap_scalar(&mut acc[i..], &x[i..], &w[i..], zp);
}

/// # Safety
/// Caller must ensure `acc`/`x`/`w` have equal lengths (debug-asserted
/// in [`dw_accum_tap`]). SSE2 is the x86_64 baseline.
#[cfg(target_arch = "x86_64")]
unsafe fn dw_tap_sse2(acc: &mut [i32], x: &[i8], w: &[i8], zp: i32) {
    use std::arch::x86_64::*;
    let c = acc.len();
    let zero = _mm_setzero_si128();
    let zpv = _mm_set1_epi16(zp as i16);
    let mut i = 0usize;
    while i + 8 <= c {
        let x8 = _mm_loadl_epi64(x.as_ptr().add(i) as *const __m128i);
        let x16 = _mm_unpacklo_epi8(x8, _mm_cmpgt_epi8(zero, x8));
        let w8 = _mm_loadl_epi64(w.as_ptr().add(i) as *const __m128i);
        let w16 = _mm_unpacklo_epi8(w8, _mm_cmpgt_epi8(zero, w8));
        let prod = _mm_mullo_epi16(_mm_sub_epi16(x16, zpv), w16);
        let sign = _mm_srai_epi16(prod, 15);
        let lo = _mm_unpacklo_epi16(prod, sign);
        let hi = _mm_unpackhi_epi16(prod, sign);
        let ap = acc.as_mut_ptr().add(i) as *mut __m128i;
        _mm_storeu_si128(ap, _mm_add_epi32(_mm_loadu_si128(ap), lo));
        let ap2 = acc.as_mut_ptr().add(i + 4) as *mut __m128i;
        _mm_storeu_si128(ap2, _mm_add_epi32(_mm_loadu_si128(ap2), hi));
        i += 8;
    }
    dw_tap_scalar(&mut acc[i..], &x[i..], &w[i..], zp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::int8::gemm::{col_sums, gemm_ref};
    use crate::util::prop;

    #[test]
    fn pack_layout_golden() {
        // (3, 2) matrix, k odd → one zero-padded pair row; n < NR → the
        // strip's tail columns are zero.
        let b = vec![1i8, 2, 3, 4, 5, 6];
        let pw = PackedWeights::pack(&b, 3, 2);
        assert_eq!((pw.k, pw.n, pw.pk, pw.strips), (3, 2, 4, 1));
        assert_eq!(pw.bytes(), 4 * NR);
        let d = &pw.data;
        // pair 0 (rows 0, 1), columns 0 and 1
        assert_eq!(&d[0..4], &[1, 3, 2, 4]);
        // pair 1 (row 2 + zero pad)
        assert_eq!(&d[2 * NR..2 * NR + 4], &[5, 0, 6, 0]);
        // every other lane is padding
        let live = [0usize, 1, 2, 3, 2 * NR, 2 * NR + 1, 2 * NR + 2, 2 * NR + 3];
        for (i, &v) in d.iter().enumerate() {
            if !live.contains(&i) {
                assert_eq!(v, 0, "lane {i}");
            }
        }
    }

    #[test]
    fn packed_matches_reference_across_isas() {
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(21, m * k);
            let b = prop::i8s(22, k * n);
            let sums = col_sums(&b, k, n);
            let pw = PackedWeights::pack(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for isa in Isa::available() {
                let mut out = vec![i32::MIN; m * n];
                gemm_packed(&a, zp, &pw, &sums, m, &mut out, isa);
                assert_eq!(out, want, "({m},{k},{n}) zp={zp} {}", isa.name());
            }
        }
    }

    #[test]
    fn packed_parallel_matches_reference_across_isa_and_threads() {
        for &(m, k, n, zp) in prop::SHAPES {
            let a = prop::i8s(23, m * k);
            let b = prop::i8s(24, k * n);
            let sums = col_sums(&b, k, n);
            let pw = PackedWeights::pack(&b, k, n);
            let want = gemm_ref(&a, zp, &b, m, k, n);
            for isa in Isa::available() {
                for threads in [1usize, 2, 8] {
                    let mut out = vec![0i32; m * n];
                    gemm_packed_parallel(
                        &a, zp, &pw, &sums, m, &mut out, threads, isa,
                    );
                    assert_eq!(
                        out,
                        want,
                        "({m},{k},{n}) t={threads} {}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dw_tap_matches_scalar_across_isas_and_channel_remainders() {
        // channel counts straddling the 16/8-lane vector widths
        for &c in &[1usize, 3, 7, 8, 15, 16, 17, 31, 64, 67] {
            let x = prop::i8s(31, c);
            let w = prop::i8s(32, c);
            for &zp in &[0i32, -7, 127, -128] {
                let mut want = vec![3i32; c];
                dw_tap_scalar(&mut want, &x, &w, zp);
                for isa in Isa::available() {
                    let mut acc = vec![3i32; c];
                    dw_accum_tap(&mut acc, &x, &w, zp, isa);
                    assert_eq!(acc, want, "c={c} zp={zp} {}", isa.name());
                }
            }
        }
    }

    #[test]
    fn dw_tap_extreme_operands_stay_exact() {
        // the i16-product proof obligation: |x-zp|·|w| peaks at 32640
        let c = 40usize;
        let x = vec![127i8; c];
        let w = vec![-128i8; c];
        let mut want = vec![0i32; c];
        dw_tap_scalar(&mut want, &x, &w, -128);
        assert!(want.iter().all(|&v| v == (127 + 128) * -128));
        for isa in Isa::available() {
            let mut acc = vec![0i32; c];
            dw_accum_tap(&mut acc, &x, &w, -128, isa);
            assert_eq!(acc, want, "{}", isa.name());
        }
    }

    #[test]
    fn accumulates_beyond_i16_on_every_isa() {
        // 512 × 127·127 overflows i16 by far; i32 accumulation must hold.
        let a = vec![127i8; 512];
        let b = vec![127i8; 512];
        let pw = PackedWeights::pack(&b, 512, 1);
        let sums = col_sums(&b, 512, 1);
        for isa in Isa::available() {
            let mut out = vec![0i32; 1];
            gemm_packed(&a, 0, &pw, &sums, 1, &mut out, isa);
            assert_eq!(out[0], 127 * 127 * 512, "{}", isa.name());
        }
    }

    #[test]
    fn from_packed_rehydrates_identically() {
        let b = prop::i8s(41, 24 * 70);
        let pw = PackedWeights::pack(&b, 24, 70);
        let re =
            PackedWeights::from_packed(pw.raw_data().to_vec().into(), 24, 70)
                .unwrap();
        assert_eq!(re.raw_data(), pw.raw_data());
        assert_eq!((re.k, re.n, re.pk, re.strips), (pw.k, pw.n, pw.pk, pw.strips));
        // wrong byte count is rejected, not asserted
        assert!(PackedWeights::from_packed(vec![0i8; 7].into(), 24, 70).is_err());
    }

    #[test]
    fn isa_parse_inverts_name() {
        for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse(" avx2 "), Some(Isa::Avx2));
        assert_eq!(Isa::parse("neon"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn isa_order_supports_clamping() {
        assert!(Isa::Scalar < Isa::Sse2 && Isa::Sse2 < Isa::Avx2);
        assert_eq!(Isa::Avx2.min(Isa::Sse2), Isa::Sse2);
        let avail = Isa::available();
        assert!(avail.contains(&Isa::Scalar));
        // detect() clamps to best(), and available() lists every level
        // up to best(), so the detected ISA is always runnable.
        assert!(avail.contains(&Isa::detect()));
    }
}
