//! Quantized activation tensor: i8 storage + quantization parameters.
//!
//! Unsigned (u8) sites are stored shifted into the i8 domain
//! (`q_i8 = q_u8 - 128`, `zp_i8 = zp_u8 - 128`) so the whole engine runs
//! on one storage type.

use crate::quant::scale::QParams;

#[derive(Debug, Clone)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    pub qp: QParams,
}

/// Shift u8-domain params into the i8 domain (no-op for signed params).
pub fn to_i8_domain(qp: QParams) -> QParams {
    if qp.qmin == 0 && qp.qmax == 255 {
        QParams {
            scale: qp.scale,
            zero_point: qp.zero_point - 128,
            qmin: -128,
            qmax: 127,
        }
    } else {
        qp
    }
}

impl QTensor {
    /// Quantize a float tensor under (u8/i8-domain) params.
    pub fn quantize(shape: Vec<usize>, x: &[f32], qp: QParams) -> Self {
        let qp = to_i8_domain(qp);
        let data = x
            .iter()
            .map(|&v| {
                ((v / qp.scale).round_ties_even() as i32 + qp.zero_point)
                    .clamp(qp.qmin, qp.qmax) as i8
            })
            .collect();
        QTensor { shape, data, qp }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        self.data
            .iter()
            .map(|&q| self.qp.scale * (q as i32 - self.qp.zero_point) as f32)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsigned_params_shift_to_i8() {
        let qp = QParams::symmetric_unsigned(2.55);
        let s = to_i8_domain(qp);
        assert_eq!(s.zero_point, -128);
        assert_eq!(s.qmin, -128);
        assert_eq!(s.qmax, 127);
        assert_eq!(s.scale, qp.scale);
    }

    #[test]
    fn signed_params_unchanged() {
        let qp = QParams::symmetric_signed(1.0);
        assert_eq!(to_i8_domain(qp), qp);
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let qp = QParams::symmetric_unsigned(2.0);
        let x = vec![0.0, 0.5, 1.0, 2.0, 3.0];
        let q = QTensor::quantize(vec![5], &x, qp);
        let d = q.dequantize();
        for (a, b) in x.iter().zip(&d) {
            let want = a.min(2.0);
            assert!((want - b).abs() <= qp.scale, "{a} -> {b}");
        }
    }

    #[test]
    fn quantize_clips_negative_for_unsigned() {
        let qp = QParams::symmetric_unsigned(1.0);
        let q = QTensor::quantize(vec![1], &[-5.0], qp);
        assert_eq!(q.data[0], -128); // u8 0 shifted
        assert_eq!(q.dequantize()[0], 0.0);
    }
}
