//! Quantized activation tensor: i8 storage + quantization parameters.
//!
//! Unsigned (u8) sites are stored shifted into the i8 domain
//! (`q_i8 = q_u8 - 128`, `zp_i8 = zp_u8 - 128`) so the whole engine runs
//! on one storage type.

use crate::quant::scale::QParams;

#[derive(Debug, Clone)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    pub qp: QParams,
}

/// Shift u8-domain params into the i8 domain (no-op for signed params).
pub fn to_i8_domain(qp: QParams) -> QParams {
    if qp.qmin == 0 && qp.qmax == 255 {
        QParams {
            scale: qp.scale,
            zero_point: qp.zero_point - 128,
            qmin: -128,
            qmax: 127,
        }
    } else {
        qp
    }
}

/// Quantize a float row into `dst` (appending) under **already
/// i8-domain** params — the row-writable input path of the serving
/// stack: micro-batch requests quantize straight into a shared,
/// arena-owned batch row buffer instead of allocating a per-request
/// [`QTensor`]. Bit-exact with [`QTensor::quantize`] by construction
/// (that constructor calls this).
pub fn quantize_f32_into(x: &[f32], qp: QParams, dst: &mut Vec<i8>) {
    dst.reserve(x.len());
    for &v in x {
        dst.push(
            ((v / qp.scale).round_ties_even() as i32 + qp.zero_point)
                .clamp(qp.qmin, qp.qmax) as i8,
        );
    }
}

/// Quantize raw u8 pixels into `dst` (appending) under **already
/// i8-domain** params, using the serving handle's `p / 255` float
/// mapping. Bit-exact with mapping to f32 first and then calling
/// [`quantize_f32_into`] (it performs exactly those two steps per
/// element).
pub fn quantize_u8_into(pixels: &[u8], qp: QParams, dst: &mut Vec<i8>) {
    dst.reserve(pixels.len());
    for &p in pixels {
        let v = p as f32 / 255.0;
        dst.push(
            ((v / qp.scale).round_ties_even() as i32 + qp.zero_point)
                .clamp(qp.qmin, qp.qmax) as i8,
        );
    }
}

impl QTensor {
    /// Quantize a float tensor under (u8/i8-domain) params.
    pub fn quantize(shape: Vec<usize>, x: &[f32], qp: QParams) -> Self {
        let qp = to_i8_domain(qp);
        let mut data = Vec::with_capacity(x.len());
        quantize_f32_into(x, qp, &mut data);
        QTensor { shape, data, qp }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        self.data
            .iter()
            .map(|&q| self.qp.scale * (q as i32 - self.qp.zero_point) as f32)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsigned_params_shift_to_i8() {
        let qp = QParams::symmetric_unsigned(2.55);
        let s = to_i8_domain(qp);
        assert_eq!(s.zero_point, -128);
        assert_eq!(s.qmin, -128);
        assert_eq!(s.qmax, 127);
        assert_eq!(s.scale, qp.scale);
    }

    #[test]
    fn signed_params_unchanged() {
        let qp = QParams::symmetric_signed(1.0);
        assert_eq!(to_i8_domain(qp), qp);
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let qp = QParams::symmetric_unsigned(2.0);
        let x = vec![0.0, 0.5, 1.0, 2.0, 3.0];
        let q = QTensor::quantize(vec![5], &x, qp);
        let d = q.dequantize();
        for (a, b) in x.iter().zip(&d) {
            let want = a.min(2.0);
            assert!((want - b).abs() <= qp.scale, "{a} -> {b}");
        }
    }

    #[test]
    fn row_writers_match_quantize() {
        let qp = QParams::symmetric_unsigned(1.7);
        let pixels: Vec<u8> = (0..=255u16).map(|p| p as u8).collect();
        let x: Vec<f32> = pixels.iter().map(|&p| p as f32 / 255.0).collect();
        let want = QTensor::quantize(vec![pixels.len()], &x, qp);
        let qpi = to_i8_domain(qp);
        let mut via_f32 = Vec::new();
        quantize_f32_into(&x, qpi, &mut via_f32);
        assert_eq!(via_f32, want.data);
        let mut via_u8 = vec![7i8]; // appends after existing content
        quantize_u8_into(&pixels, qpi, &mut via_u8);
        assert_eq!(via_u8[0], 7);
        assert_eq!(&via_u8[1..], &want.data[..]);
    }

    #[test]
    fn quantize_clips_negative_for_unsigned() {
        let qp = QParams::symmetric_unsigned(1.0);
        let q = QTensor::quantize(vec![1], &[-5.0], qp);
        assert_eq!(q.data[0], -128); // u8 0 shifted
        assert_eq!(q.dequantize()[0], 0.0);
    }
}
