//! GEMM blocking autotuner (DESIGN.md §12).
//!
//! The packed-panel GEMM takes its loop schedule from a per-layer
//! [`Blocking`] instead of the historical compile-time
//! `KC/NR/MR` constants. This module picks that schedule: for every
//! distinct `(k, n)` weight shape in a [`QModel`] it times a small set
//! of candidate blockings on a synthetic activation batch and keeps the
//! fastest, repacking the weight panel when the winning strip width
//! differs. Because every candidate is bit-exact (kernels module docs),
//! tuning can never change results — only wall-clock — so the sweep
//! needs no accuracy re-validation.
//!
//! Two sweeps exist:
//! - **full** — `fat export` time: all `kc × nr × mr (× grain)`
//!   candidates. The winner is persisted in the `.fatm` PLAN section
//!   (v2), so cold starts inherit the table for free.
//! - **capped** — opt-in first-run fallback for models built in-process
//!   without an artifact (`FAT_TUNE=capped`): strip width stays at the
//!   packed default (no repack), fewer candidates, tight wall-clock
//!   budget.
//!
//! Timings use `std::time::Instant` minima over a few repetitions;
//! candidate order is deterministic and ties keep the earlier
//! (default-first) candidate, so a machine where nothing wins keeps
//! [`Blocking::default`].

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::engine::{QModel, QNode};
use super::kernels::{Blocking, Isa, PackedWeights};

/// Tuning configuration. Construct via [`TuneOptions::full`],
/// [`TuneOptions::capped`] or [`TuneOptions::from_env`].
#[derive(Debug, Clone)]
pub struct TuneOptions {
    /// Worker count the schedule is tuned for (the serving thread
    /// count). Grain candidates only matter when > 1.
    pub threads: usize,
    /// ISA the schedule is tuned for.
    pub isa: Isa,
    /// Synthetic activation rows per timing run (a mid-size conv
    /// im2col batch; 14×14 spatial = 196 is typical for the builtins).
    pub rows: usize,
    /// Timed repetitions per candidate (the minimum is kept).
    pub iters: usize,
    /// Wall-clock budget for the whole model sweep; once spent,
    /// remaining shapes keep their current blocking.
    pub budget: Duration,
    /// Whether to sweep non-default strip widths (forces a repack).
    pub sweep_nr: bool,
    /// Whether to sweep the panel bit width (int8 vs int4 nibble
    /// panels) for layers whose weights fit the int4 range. Like every
    /// other axis this is bit-exact — the int4 decode reproduces the
    /// identical i8 lanes — so only wall-clock moves.
    pub sweep_bits: bool,
    /// Whether to time the fused implicit-GEMM path (register-tile
    /// epilogue, `kernels::gemm_fused_parallel`) against the staged
    /// GEMM + requant pipeline and stamp the per-layer fused bit from
    /// the verdict. Bit-exact like every other axis.
    pub sweep_fused: bool,
}

impl TuneOptions {
    /// The `fat export` sweep: all candidates, generous budget.
    pub fn full() -> TuneOptions {
        TuneOptions {
            threads: crate::util::fat_threads(),
            isa: Isa::detect(),
            rows: 196,
            iters: 3,
            budget: Duration::from_millis(4000),
            sweep_nr: true,
            sweep_bits: true,
            sweep_fused: true,
        }
    }

    /// The first-run fallback: default strip width only (no repack),
    /// fewer candidates, tight budget.
    pub fn capped() -> TuneOptions {
        TuneOptions {
            threads: crate::util::fat_threads(),
            isa: Isa::detect(),
            rows: 64,
            iters: 2,
            budget: Duration::from_millis(300),
            sweep_nr: false,
            sweep_bits: false,
            sweep_fused: false,
        }
    }

    /// `FAT_TUNE=off|capped|full` (aliases: `0`≡`off`, `on`/`1`≡
    /// `capped`). `None` means tuning is off — the default, so tests
    /// and library consumers stay deterministic and fast. Unknown
    /// values are a hard configuration error (mirroring `FAT_ISA`):
    /// silently disabling tuning would hide the typo until a perf
    /// regression surfaced much later.
    pub fn from_env() -> Option<TuneOptions> {
        match std::env::var("FAT_TUNE").ok().as_deref().map(str::trim) {
            None | Some("") | Some("off") | Some("0") => None,
            Some("capped") | Some("on") | Some("1") => {
                Some(TuneOptions::capped())
            }
            Some("full") => Some(TuneOptions::full()),
            Some(other) => panic!(
                "FAT_TUNE: unknown value {other:?} \
                 (accepted: off, 0, capped, on, 1, full)"
            ),
        }
    }
}

/// The candidate schedules a sweep considers, default first (ties keep
/// it). `sweep_nr=false` restricts to the packed default strip width so
/// no repack is needed.
pub fn candidates(opts: &TuneOptions) -> Vec<Blocking> {
    let mut out = vec![Blocking::default()];
    let grains: &[usize] =
        if opts.threads > 1 { &[1, 4] } else { &[1] };
    let (kcs, nrs, mrs): (&[usize], &[usize], &[usize]) = if opts.sweep_nr {
        (&[64, 128, 256], &[32, 64], &[2, 4, 8])
    } else {
        (&[128, 256], &[64], &[4, 8])
    };
    for &kc in kcs {
        for &nr in nrs {
            for &mr in mrs {
                for &grain in grains {
                    let bk = Blocking { kc, nr, mr, grain };
                    debug_assert!(bk.validate().is_ok());
                    if !out.contains(&bk) {
                        out.push(bk);
                    }
                }
            }
        }
    }
    out
}

/// Result of tuning one `(k, n)` GEMM shape.
#[derive(Debug, Clone, Copy)]
pub struct TunedChoice {
    pub blocking: Blocking,
    /// Winning panel bit width (8, or 4 when the int4 sweep won).
    pub bits: usize,
    /// Fused-path verdict at the winning schedule: `Some(true)` when
    /// the fused implicit-GEMM beat the staged pipeline, `Some(false)`
    /// when staged won, `None` when the sweep was off or the deadline
    /// blew first (the layer keeps its current bit).
    pub fused: Option<bool>,
    /// Best observed time of the default schedule, seconds/run.
    pub default_secs: f64,
    /// Best observed time of the winning schedule, seconds/run.
    pub best_secs: f64,
}

/// [`tune_gemm_bits`] for an int8-packed layer.
pub fn tune_gemm(
    w: &[i8],
    k: usize,
    n: usize,
    opts: &TuneOptions,
    deadline: Option<Instant>,
) -> TunedChoice {
    tune_gemm_bits(w, k, n, 8, opts, deadline)
}

/// Time the candidate schedules for one `(k, n)` weight matrix on a
/// synthetic `(rows, k)` activation block and return the fastest.
/// Stops early (keeping the best so far) once `deadline` passes — the
/// default candidate (at the layer's current `bits`) is always timed
/// first, so a blown budget can only ever report the status quo. With
/// [`TuneOptions::sweep_bits`] set, each blocking is also timed against
/// the other panel width (int4 only when the weights fit `[-8, 7]`).
pub fn tune_gemm_bits(
    w: &[i8],
    k: usize,
    n: usize,
    bits: usize,
    opts: &TuneOptions,
    deadline: Option<Instant>,
) -> TunedChoice {
    debug_assert_eq!(w.len(), k * n);
    // bit widths to try, the layer's current width first (ties keep it)
    let mut widths = vec![bits];
    if opts.sweep_bits {
        if bits == 8 && super::kernels::fits_int4(w) {
            widths.push(4);
        } else if bits == 4 {
            widths.push(8);
        }
    }
    let m = opts.rows.max(1);
    let a = crate::util::prop::i8s(97, m * k);
    let bsums = crate::int8::gemm::col_sums(w, k, n);
    let mut out = vec![0i32; m * n];
    let mut packs: HashMap<(usize, usize), PackedWeights> = HashMap::new();
    let mut best: Option<(Blocking, usize, f64)> = None;
    let mut default_secs = f64::INFINITY;
    let mut ci = 0usize;
    'sweep: for bk in candidates(opts) {
        for &width in &widths {
            if ci > 0 && deadline.is_some_and(|d| Instant::now() >= d) {
                break 'sweep;
            }
            let pw = packs.entry((bk.nr, width)).or_insert_with(|| {
                PackedWeights::pack_bits(w, k, n, bk.nr, width)
            });
            let mut best_run = f64::INFINITY;
            for _ in 0..opts.iters.max(1) + 1 {
                let t0 = Instant::now();
                super::kernels::gemm_packed_parallel(
                    &a,
                    -3,
                    pw,
                    &bsums,
                    m,
                    &mut out,
                    opts.threads,
                    opts.isa,
                    bk,
                );
                let dt = t0.elapsed().as_secs_f64();
                // first rep is warmup for the cold panel/activation cache
                best_run = best_run.min(dt);
            }
            if ci == 0 {
                default_secs = best_run;
            }
            ci += 1;
            // strict `<`: ties keep the earlier (default-first) candidate
            let better = match best {
                None => true,
                Some((_, _, t)) => best_run < t,
            };
            if better {
                best = Some((bk, width, best_run));
            }
        }
    }
    let (blocking, bits, best_secs) =
        best.unwrap_or((Blocking::default(), bits, default_secs));
    // Fused-path verdict at the winning schedule: staged GEMM + requant
    // epilogue vs the one-pass fused kernel, same reps/warmup protocol.
    let mut fused = None;
    if opts.sweep_fused && !deadline.is_some_and(|d| Instant::now() >= d) {
        if let Some(pw) = packs.get(&(blocking.nr, bits)) {
            let bias = vec![0i32; n];
            let requant = vec![(1i32 << 30, 8i32); n];
            let ep = super::kernels::FusedEpilogue {
                a_zp: -3,
                bsums: &bsums,
                bias: &bias,
                requant: &requant,
                shift: None,
                out_zp: 0,
                clamp: (-127, 127),
                residual: None,
            };
            let mut out8 = vec![0i8; m * n];
            let (mut staged_t, mut fused_t) = (f64::INFINITY, f64::INFINITY);
            for _ in 0..opts.iters.max(1) + 1 {
                let t0 = Instant::now();
                super::kernels::gemm_packed_parallel(
                    &a,
                    -3,
                    pw,
                    &bsums,
                    m,
                    &mut out,
                    opts.threads,
                    opts.isa,
                    blocking,
                );
                // the staged path's third pass (the multiplier requant
                // epilogue is scalar, matching `ops::requant_store`)
                for (i, &v) in out.iter().enumerate() {
                    let c = i % n;
                    let (m0, s) = requant[c];
                    let q = crate::quant::scale::apply_multiplier(
                        v + bias[c],
                        m0,
                        s,
                    );
                    out8[i] = q.clamp(-127, 127) as i8;
                }
                staged_t = staged_t.min(t0.elapsed().as_secs_f64());
                let t1 = Instant::now();
                super::kernels::gemm_fused_parallel(
                    &super::kernels::FusedA::Direct(&a),
                    m,
                    pw,
                    &ep,
                    &mut out8,
                    opts.threads,
                    opts.isa,
                    blocking,
                );
                fused_t = fused_t.min(t1.elapsed().as_secs_f64());
            }
            fused = Some(fused_t < staged_t);
        }
    }
    TunedChoice { blocking, bits, fused, default_secs, best_secs }
}

/// Summary of a whole-model sweep, for CLI/log reporting.
#[derive(Debug, Clone, Copy, Default)]
pub struct TuneReport {
    /// GEMM-bearing layers visited.
    pub layers: usize,
    /// Distinct `(k, n)` shapes actually timed.
    pub shapes: usize,
    /// Layers whose blocking changed from the default.
    pub tuned: usize,
    /// Layers whose panel was repacked to a new strip width.
    pub repacked: usize,
    /// Layers left on the fused implicit-GEMM path after the sweep.
    pub fused: usize,
    /// Σ over shapes of the default schedule's time, seconds/run.
    pub default_secs: f64,
    /// Σ over shapes of the winning schedule's time, seconds/run.
    pub best_secs: f64,
    /// Wall-clock spent sweeping.
    pub wall_secs: f64,
}

impl TuneReport {
    /// `default/best` over the timed shapes (1.0 = nothing won).
    pub fn speedup(&self) -> f64 {
        if self.best_secs > 0.0 {
            self.default_secs / self.best_secs
        } else {
            1.0
        }
    }
}

/// Tune every packed layer of a model in place: choose a blocking per
/// distinct `(k, n)` shape (cached — builtin nets repeat shapes),
/// repack panels whose winning strip width differs, and stamp
/// `QLayer::blocking`. Results are unchanged by construction; only the
/// schedule moves.
pub fn tune_model(qm: &mut QModel, opts: &TuneOptions) -> TuneReport {
    let t0 = Instant::now();
    let deadline = t0 + opts.budget;
    let mut cache: HashMap<(usize, usize, usize), TunedChoice> = HashMap::new();
    let mut report = TuneReport::default();
    for p in &mut qm.plan.params {
        let QNode::Layer(l) = p else { continue };
        let Some(pw) = &l.packed else { continue };
        let (k, n, bits) = (pw.k, pw.n, pw.bits());
        report.layers += 1;
        let choice = match cache.get(&(k, n, bits)) {
            Some(c) => *c,
            None => {
                let c = tune_gemm_bits(&l.w_q, k, n, bits, opts, Some(deadline));
                report.shapes += 1;
                report.default_secs += c.default_secs;
                report.best_secs += c.best_secs;
                cache.insert((k, n, bits), c);
                c
            }
        };
        l.blocking = choice.blocking;
        if choice.blocking != Blocking::default() {
            report.tuned += 1;
        }
        if choice.blocking.nr != pw.nr() || choice.bits != pw.bits() {
            l.packed = Some(PackedWeights::pack_bits(
                &l.w_q,
                k,
                n,
                choice.blocking.nr,
                choice.bits,
            ));
            report.repacked += 1;
        }
        if let Some(f) = choice.fused {
            l.fused = f;
        }
        if l.fused {
            report.fused += 1;
        }
    }
    report.wall_secs = t0.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::int8::gemm::{col_sums, gemm_ref};
    use crate::util::prop;

    #[test]
    fn candidates_start_with_default_and_all_validate() {
        for opts in [TuneOptions::full(), TuneOptions::capped()] {
            let cands = candidates(&opts);
            assert_eq!(cands[0], Blocking::default());
            assert!(cands.len() > 1);
            for bk in &cands {
                bk.validate().unwrap();
                if !opts.sweep_nr {
                    assert_eq!(bk.nr, Blocking::default().nr);
                }
            }
            // no duplicates — each candidate is timed once
            for (i, a) in cands.iter().enumerate() {
                assert!(!cands[i + 1..].contains(a));
            }
        }
    }

    #[test]
    fn every_candidate_is_bit_exact_vs_reference() {
        let (m, k, n, zp) = (7, 34, 40, -3);
        let a = prop::i8s(51, m * k);
        let w = prop::i8s(52, k * n);
        let sums = col_sums(&w, k, n);
        let want = gemm_ref(&a, zp, &w, m, k, n);
        for bk in candidates(&TuneOptions::full()) {
            let pw = PackedWeights::pack_with(&w, k, n, bk.nr);
            for isa in Isa::available() {
                let mut out = vec![0i32; m * n];
                crate::int8::kernels::gemm_packed_parallel(
                    &a, zp, &pw, &sums, m, &mut out, 2, isa, bk,
                );
                assert_eq!(out, want, "{} {}", bk.label(), isa.name());
            }
        }
    }

    #[test]
    fn tune_gemm_returns_a_valid_choice_and_timings() {
        let (k, n) = (48, 24);
        let w = prop::i8s(53, k * n);
        let mut opts = TuneOptions::capped();
        opts.rows = 8;
        opts.iters = 1;
        let c = tune_gemm(&w, k, n, &opts, None);
        c.blocking.validate().unwrap();
        assert_eq!(c.blocking.nr, Blocking::default().nr); // capped: no repack
        assert!(c.default_secs.is_finite() && c.default_secs > 0.0);
        assert!(c.best_secs <= c.default_secs);
    }

    #[test]
    fn bits_sweep_is_gated_and_bit_exact() {
        let (k, n, m) = (48usize, 32usize, 5usize);
        let w: Vec<i8> =
            prop::i8s(55, k * n).into_iter().map(|v| v % 8).collect();
        assert!(crate::int8::kernels::fits_int4(&w));
        let mut opts = TuneOptions::full();
        opts.rows = 8;
        opts.iters = 1;
        opts.threads = 1;
        let c = tune_gemm_bits(&w, k, n, 8, &opts, None);
        c.blocking.validate().unwrap();
        assert!(c.bits == 8 || c.bits == 4, "bits {}", c.bits);
        // whichever width won, the panel it implies is bit-exact
        let a = prop::i8s(56, m * k);
        let sums = col_sums(&w, k, n);
        let want = gemm_ref(&a, -3, &w, m, k, n);
        let pw = PackedWeights::pack_bits(&w, k, n, c.blocking.nr, c.bits);
        let mut out = vec![0i32; m * n];
        crate::int8::kernels::gemm_packed_parallel(
            &a, -3, &pw, &sums, m, &mut out, 2, Isa::detect(), c.blocking,
        );
        assert_eq!(out, want);
        // out-of-range weights never report an int4 win
        let w8 = prop::i8s(57, k * n);
        assert!(!crate::int8::kernels::fits_int4(&w8));
        let c8 = tune_gemm_bits(&w8, k, n, 8, &opts, None);
        assert_eq!(c8.bits, 8);
        // and an int4 layer keeps a valid width with the sweep off
        let mut capped = TuneOptions::capped();
        capped.rows = 4;
        capped.iters = 1;
        let c4 = tune_gemm_bits(&w, k, n, 4, &capped, None);
        assert_eq!(c4.bits, 4); // sweep_bits=false: width is pinned
    }

    #[test]
    fn blown_deadline_keeps_the_default() {
        let (k, n) = (32, 16);
        let w = prop::i8s(54, k * n);
        let mut opts = TuneOptions::capped();
        opts.rows = 4;
        opts.iters = 1;
        let c = tune_gemm(&w, k, n, &opts, Some(Instant::now()));
        assert_eq!(c.blocking, Blocking::default());
        assert_eq!(c.fused, None); // no verdict past the deadline
    }

    #[test]
    fn fused_sweep_is_gated_and_reports_a_verdict() {
        let (k, n) = (48, 24);
        let w = prop::i8s(58, k * n);
        let mut opts = TuneOptions::full();
        opts.rows = 8;
        opts.iters = 1;
        opts.threads = 1;
        let c = tune_gemm(&w, k, n, &opts, None);
        assert!(c.fused.is_some());
        // capped sweep: the fused axis is off, layers keep their bit
        let mut capped = TuneOptions::capped();
        capped.rows = 4;
        capped.iters = 1;
        let c2 = tune_gemm(&w, k, n, &capped, None);
        assert_eq!(c2.fused, None);
    }
}
