//! Execution planning for the int8 engine and the native FP32 executor
//! (DESIGN.md §5, §7).
//!
//! `quant::export::build_qmodel` compiles the folded graph into an
//! [`ExecPlan`] exactly once: a topological schedule of compute steps
//! with **dense indices** (no name lookups on the hot path), a dense
//! parameter table, and **liveness-based buffer slots** so activations
//! recycle a small [`Arena`] of buffers instead of cloning tensors
//! through a per-call `BTreeMap`. Relu/relu6 nodes whose clamp was fused
//! into their producer compile to nothing: their value aliases the
//! producer's slot. Conv/dense entries of the parameter table carry
//! their weights **prepacked** for the SIMD microkernels
//! (`QLayer::packed`, built alongside this plan in `build_qmodel`; see
//! `int8::kernels` and DESIGN.md §8).
//!
//! The scheduler is generic over the per-node parameter payload `P` and
//! the arena element type `T`: the int8 engine instantiates
//! `ExecPlan<QNode>` / `Arena<i8>` (the defaults), and the native FP32
//! backend (`crate::fp`) instantiates `ExecPlan<fp::FpNode>` /
//! `Arena<f32>` — one planner, two dtypes.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::{GraphDef, Op};

use super::engine::QNode;

/// Recycled buffer pool: freed activation buffers are handed to later
/// steps instead of allocating per node. `T = i8` for the int8 engine,
/// `T = f32` for the native FP32 executor.
#[derive(Debug)]
pub struct Arena<T = i8> {
    free: Vec<Vec<T>>,
    /// Element capacity pooled right now (sum over `free`).
    free_elems: usize,
    /// High-water mark of `free_elems` — the peak activation-buffer
    /// footprint this arena has held, for the scratch census
    /// (`engine::ScratchStats`). Peak pooled capacity is the right
    /// proxy: every buffer cycles through `put` between uses.
    hi_elems: usize,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena { free: Vec::new(), free_elems: 0, hi_elems: 0 }
    }
}

impl<T> Arena<T> {
    /// Pop a recycled buffer (empty but with retained capacity), or a
    /// fresh one.
    pub fn take(&mut self) -> Vec<T> {
        match self.free.pop() {
            Some(buf) => {
                self.free_elems -= buf.capacity();
                buf
            }
            None => Vec::new(),
        }
    }

    /// Return a dead activation's buffer to the pool.
    pub fn put(&mut self, mut buf: Vec<T>) {
        buf.clear();
        self.free_elems += buf.capacity();
        self.hi_elems = self.hi_elems.max(self.free_elems);
        self.free.push(buf);
    }

    /// Pop a recycled buffer and fill it with a copy of `src` — the
    /// row-writable input path of the serving stack: batch rows and
    /// per-shard input chunks are copied into arena-owned buffers
    /// instead of freshly allocated `Vec`s (`QModel::run_rows_sharded`,
    /// `int8::batcher`).
    pub fn take_filled(&mut self, src: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        let mut buf = self.take();
        buf.extend_from_slice(src);
        buf
    }

    /// Number of pooled buffers (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// Peak pooled capacity in **bytes** (diagnostics; see `hi_elems`).
    pub fn hi_bytes(&self) -> usize {
        self.hi_elems * std::mem::size_of::<T>()
    }
}

/// One scheduled compute node.
#[derive(Debug, Clone)]
pub struct PlanStep {
    /// Graph node id (diagnostics only — execution is index-based).
    pub id: String,
    pub op: Op,
    /// Index into [`ExecPlan::params`].
    pub param: usize,
    /// First input's buffer slot.
    pub a: usize,
    /// Second input's buffer slot (residual add).
    pub b: Option<usize>,
    /// Output buffer slot; never aliases a live input slot.
    pub dst: usize,
    pub k: usize,
    pub stride: usize,
    pub cout: usize,
    /// Slots whose values die after this step (buffers go to the arena).
    pub frees: Vec<usize>,
}

/// A compiled schedule: steps + dense params + slot count. `P` is the
/// per-node parameter payload ([`QNode`] for the int8 engine,
/// `fp::FpNode` for the native FP32 executor).
#[derive(Debug, Clone)]
pub struct ExecPlan<P = QNode> {
    pub steps: Vec<PlanStep>,
    /// Dense parameter table in schedule order.
    pub params: Vec<P>,
    /// Total buffer slots needed for one inference (incl. the input).
    pub num_slots: usize,
    /// Slot the (quantized) input tensor is placed in before step 0.
    pub input_slot: usize,
    /// Slot holding the model output after the last step.
    pub output_slot: usize,
    index: BTreeMap<String, usize>,
}

impl<P> ExecPlan<P> {
    /// Parameters of a compute node, if it has any.
    pub fn node(&self, id: &str) -> Option<&P> {
        self.index.get(id).map(|&i| &self.params[i])
    }

    /// Rebuild a plan from deserialized parts (the `.fatm` load path —
    /// `crate::artifact`), re-deriving the private id→param index from
    /// the steps and validating every dense index so a corrupt or
    /// hand-crafted artifact fails here with an error instead of
    /// panicking inside the executor's slot table.
    pub fn from_parts(
        steps: Vec<PlanStep>,
        params: Vec<P>,
        num_slots: usize,
        input_slot: usize,
        output_slot: usize,
    ) -> Result<ExecPlan<P>> {
        anyhow::ensure!(
            input_slot < num_slots && output_slot < num_slots,
            "plan slots out of range: input {input_slot} / output \
             {output_slot} with {num_slots} slots"
        );
        let mut index = BTreeMap::new();
        for s in &steps {
            anyhow::ensure!(
                s.param < params.len(),
                "{}: param index {} out of range ({} params)",
                s.id,
                s.param,
                params.len()
            );
            for slot in std::iter::once(s.a)
                .chain(s.b)
                .chain(std::iter::once(s.dst))
                .chain(s.frees.iter().copied())
            {
                anyhow::ensure!(
                    slot < num_slots,
                    "{}: buffer slot {slot} out of range ({num_slots} slots)",
                    s.id
                );
            }
            anyhow::ensure!(
                index.insert(s.id.clone(), s.param).is_none(),
                "duplicate step id {}",
                s.id
            );
        }
        Ok(ExecPlan { steps, params, num_slots, input_slot, output_slot, index })
    }

    /// Compile schedule + slot assignment from the folded graph and the
    /// per-node parameters (built by `quant::export` for int8, by
    /// `fp::program` for the FP32 backend). `qnodes` must hold an entry
    /// for every compute node; relu/relu6 entries are ignored (their
    /// value aliases the producer's slot).
    pub fn compile(
        g: &GraphDef,
        mut qnodes: BTreeMap<String, P>,
    ) -> Result<ExecPlan<P>> {
        let pos: BTreeMap<&str, usize> = g
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.id.as_str(), i))
            .collect();
        let order = topo_order(g, &pos)?;

        // Value ids per node output; passthrough relu aliases its input.
        let mut val_of = vec![usize::MAX; g.nodes.len()];
        let mut n_vals = 0usize;
        for &ni in &order {
            let node = &g.nodes[ni];
            let v = match node.op {
                Op::Relu | Op::Relu6 => {
                    let src = node.inputs.first().ok_or_else(|| {
                        anyhow::anyhow!("{}: relu without input", node.id)
                    })?;
                    val_of[pos[src.as_str()]]
                }
                _ => {
                    n_vals += 1;
                    n_vals - 1
                }
            };
            val_of[ni] = v;
        }

        // Remaining-use counts per value: compute-step reads + the output.
        let mut uses = vec![0usize; n_vals];
        for &ni in &order {
            let node = &g.nodes[ni];
            if matches!(node.op, Op::Input | Op::Relu | Op::Relu6) {
                continue;
            }
            for inp in &node.inputs {
                uses[val_of[pos[inp.as_str()]]] += 1;
            }
        }
        let out_node =
            *order.last().ok_or_else(|| anyhow::anyhow!("empty graph"))?;
        let output_val = val_of[out_node];
        uses[output_val] += 1; // the caller reads the output

        // Slot assignment with a LIFO free list; allocate a step's dst
        // before releasing its inputs so dst never aliases a live operand.
        let mut slot_of_val = vec![usize::MAX; n_vals];
        let mut free_slots: Vec<usize> = Vec::new();
        let mut num_slots = 0usize;
        let mut steps = Vec::new();
        let mut params: Vec<P> = Vec::new();
        let mut index = BTreeMap::new();
        let mut input_slot = usize::MAX;

        for &ni in &order {
            let node = &g.nodes[ni];
            match node.op {
                Op::Input => {
                    let s = free_slots.pop().unwrap_or_else(|| {
                        num_slots += 1;
                        num_slots - 1
                    });
                    slot_of_val[val_of[ni]] = s;
                    input_slot = s;
                }
                Op::Relu | Op::Relu6 => {} // aliased; no step
                Op::Bn => {
                    anyhow::bail!("{}: bn survived graph folding", node.id)
                }
                _ => {
                    let qn = qnodes.remove(&node.id).ok_or_else(|| {
                        anyhow::anyhow!("no quant params for node {}", node.id)
                    })?;
                    let a_in = node.inputs.first().ok_or_else(|| {
                        anyhow::anyhow!("{}: node without input", node.id)
                    })?;
                    let a_val = val_of[pos[a_in.as_str()]];
                    let b_val =
                        node.inputs.get(1).map(|i| val_of[pos[i.as_str()]]);
                    let dst = free_slots.pop().unwrap_or_else(|| {
                        num_slots += 1;
                        num_slots - 1
                    });
                    slot_of_val[val_of[ni]] = dst;
                    let a_slot = slot_of_val[a_val];
                    let b_slot = b_val.map(|v| slot_of_val[v]);
                    let mut frees = Vec::new();
                    for v in std::iter::once(a_val).chain(b_val) {
                        uses[v] -= 1;
                        if uses[v] == 0 {
                            let s = slot_of_val[v];
                            free_slots.push(s);
                            frees.push(s);
                        }
                    }
                    let param = params.len();
                    params.push(qn);
                    index.insert(node.id.clone(), param);
                    steps.push(PlanStep {
                        id: node.id.clone(),
                        op: node.op,
                        param,
                        a: a_slot,
                        b: b_slot,
                        dst,
                        k: node.k,
                        stride: node.stride,
                        cout: node.out_channels(),
                        frees,
                    });
                }
            }
        }
        anyhow::ensure!(input_slot != usize::MAX, "graph has no input node");
        Ok(ExecPlan {
            steps,
            params,
            num_slots,
            input_slot,
            output_slot: slot_of_val[output_val],
            index,
        })
    }
}

/// Stable Kahn topological sort: among ready nodes the smallest original
/// index runs first, so an already-topological graph keeps its order
/// (and therefore the engine's output node matches the old interpreter's
/// "last node wins" semantics).
fn topo_order(
    g: &GraphDef,
    pos: &BTreeMap<&str, usize>,
) -> Result<Vec<usize>> {
    let n = g.nodes.len();
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in g.nodes.iter().enumerate() {
        for inp in &node.inputs {
            let p = *pos.get(inp.as_str()).ok_or_else(|| {
                anyhow::anyhow!("{}: unknown input {inp}", node.id)
            })?;
            succs[p].push(i);
            indeg[i] += 1;
        }
    }
    let mut ready: std::collections::BTreeSet<usize> =
        (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(i) = ready.pop_first() {
        order.push(i);
        for &s in &succs[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.insert(s);
            }
        }
    }
    anyhow::ensure!(order.len() == n, "graph has a cycle");
    Ok(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::int8::engine::GapParams;
    use crate::quant::scale::QParams;

    fn qp() -> QParams {
        QParams::symmetric_signed(1.0)
    }

    fn gap_node() -> QNode {
        QNode::Gap(GapParams { m: (1 << 30, 0), out_qp: qp() })
    }

    const CHAIN: &str = r#"{
      "name": "chain", "num_classes": 2,
      "nodes": [
        {"id": "input", "op": "input", "inputs": [], "shape": [4,4,1]},
        {"id": "g0", "op": "gap", "inputs": ["input"]},
        {"id": "r0", "op": "relu", "inputs": ["g0"]}
      ]}"#;

    #[test]
    fn chain_plan_aliases_relu_and_reuses_slots() {
        let g = GraphDef::from_json(CHAIN).unwrap();
        let mut qn = BTreeMap::new();
        qn.insert("g0".to_string(), gap_node());
        qn.insert("r0".to_string(), QNode::Passthrough);
        let plan = ExecPlan::compile(&g, qn).unwrap();
        // relu compiles to nothing; one step for the gap
        assert_eq!(plan.steps.len(), 1);
        assert_eq!(plan.steps[0].id, "g0");
        // input dies after the gap reads it
        assert_eq!(plan.steps[0].frees, vec![plan.input_slot]);
        // output is the relu's alias of the gap value
        assert_eq!(plan.output_slot, plan.steps[0].dst);
        assert_eq!(plan.num_slots, 2);
        assert!(plan.node("g0").is_some());
        assert!(plan.node("r0").is_none());
    }

    #[test]
    fn dst_never_aliases_live_input() {
        let g = GraphDef::from_json(CHAIN).unwrap();
        let mut qn = BTreeMap::new();
        qn.insert("g0".to_string(), gap_node());
        qn.insert("r0".to_string(), QNode::Passthrough);
        let plan = ExecPlan::compile(&g, qn).unwrap();
        for s in &plan.steps {
            assert_ne!(s.dst, s.a, "{}", s.id);
            if let Some(b) = s.b {
                assert_ne!(s.dst, b, "{}", s.id);
            }
        }
    }

    #[test]
    fn from_parts_round_trips_and_validates() {
        let g = GraphDef::from_json(CHAIN).unwrap();
        let mut qn = BTreeMap::new();
        qn.insert("g0".to_string(), gap_node());
        qn.insert("r0".to_string(), QNode::Passthrough);
        let plan = ExecPlan::compile(&g, qn).unwrap();
        let re = ExecPlan::from_parts(
            plan.steps.clone(),
            plan.params.clone(),
            plan.num_slots,
            plan.input_slot,
            plan.output_slot,
        )
        .unwrap();
        assert_eq!(re.steps.len(), plan.steps.len());
        assert!(re.node("g0").is_some());
        // hostile indices must error, not panic in the executor
        assert!(ExecPlan::from_parts(
            plan.steps.clone(),
            plan.params.clone(),
            plan.num_slots,
            99,
            plan.output_slot,
        )
        .is_err());
        let mut bad = plan.steps.clone();
        bad[0].param = 7;
        assert!(ExecPlan::from_parts(
            bad,
            plan.params.clone(),
            plan.num_slots,
            plan.input_slot,
            plan.output_slot,
        )
        .is_err());
        let mut bad2 = plan.steps.clone();
        bad2[0].dst = plan.num_slots;
        assert!(ExecPlan::from_parts(
            bad2,
            plan.params.clone(),
            plan.num_slots,
            plan.input_slot,
            plan.output_slot,
        )
        .is_err());
    }

    #[test]
    fn missing_params_rejected() {
        let g = GraphDef::from_json(CHAIN).unwrap();
        assert!(ExecPlan::compile(&g, BTreeMap::<String, QNode>::new()).is_err());
    }

    #[test]
    fn arena_recycles_buffers() {
        let mut a = Arena::default();
        let mut v = a.take();
        assert!(v.is_empty());
        v.extend_from_slice(&[1, 2, 3]);
        let cap = v.capacity();
        a.put(v);
        assert_eq!(a.pooled(), 1);
        let v2 = a.take();
        assert!(v2.is_empty());
        assert!(v2.capacity() >= cap.min(3));
        assert_eq!(a.pooled(), 0);
    }

    #[test]
    fn arena_take_filled_copies_into_recycled_buffer() {
        let mut a = Arena::default();
        a.put(vec![9i8; 64]); // retained capacity
        let v = a.take_filled(&[1i8, 2, 3]);
        assert_eq!(v, vec![1, 2, 3]);
        assert!(v.capacity() >= 64);
        assert_eq!(a.pooled(), 0);
        // empty pool still works (fresh allocation)
        let w = a.take_filled(&[5i8]);
        assert_eq!(w, vec![5]);
    }

    #[test]
    fn topo_handles_out_of_order_nodes() {
        // g0 listed before its producer's producer would break a naive
        // in-order walk; the planner re-sorts
        let g = GraphDef::from_json(
            r#"{"name": "ooo", "num_classes": 2,
                "nodes": [
                  {"id": "input", "op": "input", "inputs": [], "shape": [4,4,1]},
                  {"id": "g1", "op": "gap", "inputs": ["r0"]},
                  {"id": "g0", "op": "gap", "inputs": ["input"]},
                  {"id": "r0", "op": "relu", "inputs": ["g0"]}
                ]}"#,
        )
        .unwrap();
        let mut qn = BTreeMap::new();
        qn.insert("g0".to_string(), gap_node());
        qn.insert("g1".to_string(), gap_node());
        qn.insert("r0".to_string(), QNode::Passthrough);
        let plan = ExecPlan::compile(&g, qn).unwrap();
        assert_eq!(plan.steps.len(), 2);
        assert_eq!(plan.steps[0].id, "g0");
        assert_eq!(plan.steps[1].id, "g1");
    }
}
