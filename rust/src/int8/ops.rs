//! Integer-only layer kernels: conv (im2col+GEMM), depthwise conv, dense,
//! residual add, global average pool — all with fixed-point requantization.

use crate::quant::scale::{apply_multiplier, QParams};

use super::gemm::gemm_i8;
use super::im2col::im2col_i8;
use super::qtensor::QTensor;

/// Requantize an int32 accumulator row into the output domain.
///
/// `acc` holds (n_pix, cout) accumulators at scale `s_in * s_w[c]`;
/// bias is already int32 at the same scale (paper eq. 20).
pub fn requant_store(
    acc: &[i32],
    bias: &[i32],
    requant: &[(i32, i32)],
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
    out: &mut Vec<i8>,
) {
    out.clear();
    out.reserve(acc.len());
    for (i, &a) in acc.iter().enumerate() {
        let c = i % cout;
        let (m0, shift) = requant[c];
        let v = apply_multiplier(a + bias[c], m0, shift)
            + out_qp.zero_point;
        out.push(v.clamp(clamp.0, clamp.1) as i8);
    }
}

/// SAME-padded conv via im2col + int8 GEMM.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &QTensor,
    w_q: &[i8],
    w_sums: &[i32],
    bias: &[i32],
    requant: &[(i32, i32)],
    out_qp: QParams,
    clamp: (i32, i32),
    k: usize,
    stride: usize,
    cout: usize,
) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (patches, oh, ow) =
        im2col_i8(&x.data, n, h, w, c, k, stride, x.qp.zero_point as i8);
    let m = n * oh * ow;
    let kk = k * k * c;
    let mut acc = vec![0i32; m * cout];
    gemm_i8(
        &patches,
        x.qp.zero_point,
        w_q,
        w_sums,
        m,
        kk,
        cout,
        &mut acc,
    );
    let mut data = Vec::new();
    requant_store(&acc, bias, requant, out_qp, clamp, cout, &mut data);
    QTensor { shape: vec![n, oh, ow, cout], data, qp: out_qp }
}

/// Depthwise SAME-padded conv (multiplier 1). `w_q` is (k,k,ch) row-major.
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d(
    x: &QTensor,
    w_q: &[i8],
    bias: &[i32],
    requant: &[(i32, i32)],
    out_qp: QParams,
    clamp: (i32, i32),
    k: usize,
    stride: usize,
) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad_top = (((oh - 1) * stride + k).saturating_sub(h)) / 2;
    let pad_left = (((ow - 1) * stride + k).saturating_sub(w)) / 2;
    let zp = x.qp.zero_point;
    let mut data = Vec::with_capacity(n * oh * ow * c);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut acc = 0i32;
                    for ky in 0..k {
                        let iy =
                            (oy * stride + ky) as isize - pad_top as isize;
                        if iy < 0 || iy >= h as isize {
                            continue; // pad tap: (zp - zp) * w = 0
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize
                                - pad_left as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let xi = ((ni * h + iy as usize) * w
                                + ix as usize)
                                * c
                                + ci;
                            let wi = (ky * k + kx) * c + ci;
                            acc += (x.data[xi] as i32 - zp)
                                * w_q[wi] as i32;
                        }
                    }
                    let (m0, shift) = requant[ci];
                    let v = apply_multiplier(acc + bias[ci], m0, shift)
                        + out_qp.zero_point;
                    data.push(v.clamp(clamp.0, clamp.1) as i8);
                }
            }
        }
    }
    QTensor { shape: vec![n, oh, ow, c], data, qp: out_qp }
}

/// Dense layer over (n, cin) input.
#[allow(clippy::too_many_arguments)]
pub fn dense(
    x: &QTensor,
    w_q: &[i8],
    w_sums: &[i32],
    bias: &[i32],
    requant: &[(i32, i32)],
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
) -> QTensor {
    let n = x.shape[0];
    let cin = x.shape[1];
    let mut acc = vec![0i32; n * cout];
    gemm_i8(&x.data, x.qp.zero_point, w_q, w_sums, n, cin, cout, &mut acc);
    let mut data = Vec::new();
    requant_store(&acc, bias, requant, out_qp, clamp, cout, &mut data);
    QTensor { shape: vec![n, cout], data, qp: out_qp }
}

/// Residual add: rescale both operands into the output domain.
pub fn add(
    a: &QTensor,
    b: &QTensor,
    ma: (i32, i32),
    mb: (i32, i32),
    out_qp: QParams,
    clamp: (i32, i32),
) -> QTensor {
    debug_assert_eq!(a.shape, b.shape);
    // Pre-scale by 2^20 for precision (TFLite-style left shift).
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&qa, &qb)| {
            let va = apply_multiplier(
                ((qa as i32) - a.qp.zero_point) << 20,
                ma.0,
                ma.1,
            );
            let vb = apply_multiplier(
                ((qb as i32) - b.qp.zero_point) << 20,
                mb.0,
                mb.1,
            );
            let v = crate::quant::scale::rounding_rshift(va + vb, 20)
                + out_qp.zero_point;
            v.clamp(clamp.0, clamp.1) as i8
        })
        .collect();
    QTensor { shape: a.shape.clone(), data, qp: out_qp }
}

/// Global average pool over H,W.
pub fn gap(x: &QTensor, m: (i32, i32), out_qp: QParams) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let hw = (h * w) as i32;
    let zp = x.qp.zero_point;
    let mut data = Vec::with_capacity(n * c);
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0i32;
            for p in 0..(h * w) {
                acc += x.data[(ni * h * w + p) * c + ci] as i32 - zp;
            }
            // multiplier m already folds the 1/(h*w)
            let v = apply_multiplier(acc, m.0, m.1) + out_qp.zero_point;
            data.push(v.clamp(out_qp.qmin, out_qp.qmax) as i8);
        }
    }
    let _ = hw;
    QTensor { shape: vec![n, c], data, qp: out_qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scale::{quantize_multiplier, QParams};

    fn qp_sym(t: f32) -> QParams {
        super::super::qtensor::to_i8_domain(QParams::symmetric_signed(t))
    }

    /// Build requant params mapping acc scale (s_in*s_w) to s_out.
    fn rq(s_in: f32, s_w: f32, s_out: f32) -> (i32, i32) {
        quantize_multiplier((s_in as f64 * s_w as f64) / s_out as f64)
    }

    #[test]
    fn conv_1x1_identity_approx() {
        // y = 1.0 * x through a 1x1 conv with unit weight
        let in_qp = qp_sym(1.0);
        let x = QTensor::quantize(vec![1, 2, 2, 1], &[0.5, -0.25, 1.0, 0.0], in_qp);
        let w_t = 1.0f32;
        let w_qp = QParams::symmetric_signed(w_t);
        let w_q = vec![w_qp.quantize(1.0) as i8];
        let sums = vec![w_q[0] as i32];
        let out_qp = qp_sym(1.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let y = conv2d(
            &x, &w_q, &sums, &[0], &req, out_qp,
            (out_qp.qmin, out_qp.qmax), 1, 1, 1,
        );
        let d = y.dequantize();
        for (a, b) in [0.5, -0.25, 1.0, 0.0].iter().zip(&d) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn dwconv_matches_float_reference() {
        // 3x3 depthwise over a 4x4 single-channel ramp
        let xs: Vec<f32> = (0..16).map(|i| i as f32 / 8.0).collect();
        let in_qp = qp_sym(2.0);
        let x = QTensor::quantize(vec![1, 4, 4, 1], &xs, in_qp);
        let wf = [0.1f32, 0.2, 0.1, 0.0, 0.5, 0.0, -0.1, 0.0, -0.2];
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = wf.iter().map(|&v| w_qp.quantize(v) as i8).collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let y = dwconv2d(&x, &w_q, &[0], &req, out_qp, (-127, 127), 3, 1);
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // float reference at centre pixel (1,1): full 3x3 support
        let xr = |r: usize, c: usize| xs[r * 4 + c];
        let mut want = 0.0;
        for ky in 0..3 {
            for kx in 0..3 {
                want += wf[ky * 3 + kx] * xr(ky, kx);
            }
        }
        let got = y.dequantize()[4 * 1 + 1];
        assert!((got - want).abs() < 0.05, "{got} vs {want}");
    }

    #[test]
    fn add_rescales_operands() {
        let qa = qp_sym(1.0);
        let qb = qp_sym(2.0);
        let qo = qp_sym(3.0);
        let a = QTensor::quantize(vec![4], &[0.5, -0.5, 1.0, 0.0], qa);
        let b = QTensor::quantize(vec![4], &[1.5, 0.5, -1.0, 2.0], qb);
        let ma = quantize_multiplier(qa.scale as f64 / qo.scale as f64);
        let mb = quantize_multiplier(qb.scale as f64 / qo.scale as f64);
        let y = add(&a, &b, ma, mb, qo, (qo.qmin, qo.qmax));
        let d = y.dequantize();
        for (want, got) in [2.0f32, 0.0, 0.0, 2.0].iter().zip(&d) {
            assert!((want - got).abs() < 0.06, "{want} vs {got}");
        }
    }

    #[test]
    fn gap_averages() {
        let qi = qp_sym(4.0);
        let qo = qp_sym(4.0);
        let xs = vec![1.0f32, 2.0, 3.0, 4.0];
        let x = QTensor::quantize(vec![1, 2, 2, 1], &xs, qi);
        let m = quantize_multiplier(qi.scale as f64 / qo.scale as f64 / 4.0);
        let y = gap(&x, m, qo);
        let d = y.dequantize();
        assert!((d[0] - 2.5).abs() < 0.05, "{}", d[0]);
    }

    #[test]
    fn relu6_clamp_fused() {
        // conv output clamped at quantized 6.0
        let in_qp = qp_sym(10.0);
        let x = QTensor::quantize(vec![1, 1, 1, 1], &[8.0], in_qp);
        let w_qp = QParams::symmetric_signed(1.0);
        let w_q = vec![w_qp.quantize(1.0) as i8];
        let out_qp =
            super::super::qtensor::to_i8_domain(QParams::symmetric_unsigned(8.0));
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let hi = out_qp.zero_point + (6.0 / out_qp.scale).round() as i32;
        let y = conv2d(
            &x, &w_q, &[w_q[0] as i32], &[0], &req, out_qp,
            (out_qp.zero_point, hi), 1, 1, 1,
        );
        let d = y.dequantize()[0];
        assert!((d - 6.0).abs() < 0.05, "{d}");
    }
}
