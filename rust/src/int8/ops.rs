//! Integer-only layer kernels: conv (im2col+GEMM), depthwise conv, dense,
//! residual add, global average pool — all with fixed-point requantization.
//!
//! Kernels are written for the planned engine (`int8::plan`): each takes
//! its layer parameters as a [`QLayer`]/[`AddParams`]/[`GapParams`]
//! bundle, writes its activation into a caller-provided buffer (recycled
//! through the engine's arena) and reuses im2col/accumulator scratch from
//! an [`OpCtx`] across nodes. `OpCtx::threads` drives row-sharded
//! parallelism inside the GEMM and the depthwise loop (dispatched onto
//! the persistent worker pool, `util::threads::pool`), and
//! `OpCtx::isa` selects the SIMD microkernel level (`int8::kernels`);
//! every thread count and ISA produces bit-identical activations.

use std::sync::OnceLock;

use crate::quant::scale::{apply_multiplier, rounding_rshift, QParams};

use super::engine::{AddParams, GapParams, QLayer};
use super::gemm::gemm_i8_parallel;
use super::im2col::{im2col_into, PatchGeom};
use super::kernels::{self, Isa};
use super::qtensor::QTensor;

/// Reusable per-run execution context: worker count and kernel ISA plus
/// im2col / accumulator scratch shared by all nodes of one inference.
pub struct OpCtx {
    pub threads: usize,
    /// Microkernel ISA; defaults to the process-wide [`Isa::detect`].
    pub isa: Isa,
    pub patches: Vec<i8>,
    pub acc: Vec<i32>,
}

impl Default for OpCtx {
    fn default() -> Self {
        OpCtx {
            threads: 1,
            isa: Isa::detect(),
            patches: Vec::new(),
            acc: Vec::new(),
        }
    }
}

impl OpCtx {
    pub fn with_threads(threads: usize) -> Self {
        OpCtx { threads: threads.max(1), ..Default::default() }
    }

    /// Staged-path scratch footprint in bytes, `(patches, acc)`.
    /// Capacities only grow, so after any sequence of runs these are
    /// high-water marks of the im2col patch matrix and the i32
    /// accumulator buffer. Fused layers touch neither — the drop is
    /// exactly what the `/stats` / `fat info --fatm` scratch census
    /// makes observable.
    pub fn scratch_bytes(&self) -> (usize, usize) {
        (
            self.patches.capacity(),
            self.acc.capacity() * std::mem::size_of::<i32>(),
        )
    }
}

/// Process-wide `FAT_FUSED` gate, read once: `off|0|false` pins every
/// layer to the staged im2col → GEMM → requant pipeline even when its
/// fused bit is set — the escape hatch for A/B runs and regression
/// triage. Unknown values abort (mirroring `FAT_ISA` / `FAT_TUNE`): a
/// typo'd pin must not silently mean "fused".
pub fn fused_enabled() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        match std::env::var("FAT_FUSED").ok().as_deref().map(str::trim) {
            None | Some("") | Some("on") | Some("1") | Some("true") => true,
            Some("off") | Some("0") | Some("false") => false,
            Some(other) => panic!(
                "FAT_FUSED: unknown value {other:?} \
                 (accepted: on, 1, true, off, 0, false)"
            ),
        }
    })
}

/// Whether `l` executes on the fused implicit-GEMM path: its
/// tuner-assigned fused bit, a packed panel to drive the micro-tiles,
/// and the process-wide [`fused_enabled`] gate.
pub fn takes_fused_path(l: &QLayer) -> bool {
    l.fused && l.packed.is_some() && fused_enabled()
}

/// Requantize an int32 accumulator row into the output domain.
///
/// `acc` holds (n_pix, cout) accumulators at scale `s_in * s_w[c]`;
/// bias is already int32 at the same scale (paper eq. 20).
pub fn requant_store(
    acc: &[i32],
    bias: &[i32],
    requant: &[(i32, i32)],
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
    out: &mut Vec<i8>,
) {
    out.clear();
    out.reserve(acc.len());
    for (i, &a) in acc.iter().enumerate() {
        let c = i % cout;
        let (m0, shift) = requant[c];
        let v = apply_multiplier(a + bias[c], m0, shift)
            + out_qp.zero_point;
        out.push(v.clamp(clamp.0, clamp.1) as i8);
    }
}

/// Requantize an int32 accumulator row by per-channel **rounding
/// shifts** — the power-of-two epilogue (DESIGN.md §13). Semantics are
/// exactly `rounding_rshift(acc + bias[c], shift[c])` per element; the
/// SIMD paths use the closed form
/// `(x + (1 << (s-1)) - [x < 0]) >> s` (for `s ≥ 1`), which equals the
/// scalar remainder/threshold form whenever `x + 2^(s-1)` does not
/// overflow i32 — guaranteed here because accumulators are bounded by
/// `k · 255 · 127` plus a bias of similar magnitude, the same headroom
/// assumption `acc + bias` already makes.
///
/// Dispatch: AVX2 handles per-channel shifts via `vpsravd`; SSE2 has no
/// variable-shift instruction, so it takes a uniform-shift fast path
/// (common under per-tensor quantization) and otherwise falls back to
/// scalar. Shifts outside `0..=30` (multiplier > 1, i.e. a left shift)
/// stay scalar everywhere.
#[allow(clippy::too_many_arguments)]
pub fn requant_store_shift(
    acc: &[i32],
    bias: &[i32],
    shift: &[i32],
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
    out: &mut Vec<i8>,
    isa: Isa,
) {
    out.clear();
    out.reserve(acc.len());
    let vector_ok = shift.iter().all(|&s| (0..=30).contains(&s));
    #[cfg(target_arch = "x86_64")]
    {
        if vector_ok && matches!(isa, Isa::Avx2 | Isa::Avx512Vnni) {
            unsafe {
                requant_shift_avx2(acc, bias, shift, out_qp, clamp, cout, out)
            };
            return;
        }
        if vector_ok
            && isa == Isa::Sse2
            && shift.windows(2).all(|w| w[0] == w[1])
        {
            unsafe {
                requant_shift_sse2_uniform(
                    acc, bias, shift[0], out_qp, clamp, cout, out,
                )
            };
            return;
        }
    }
    let _ = (vector_ok, isa);
    for (i, &a) in acc.iter().enumerate() {
        let c = i % cout;
        let v = rounding_rshift(a + bias[c], shift[c]) + out_qp.zero_point;
        out.push(v.clamp(clamp.0, clamp.1) as i8);
    }
}

/// AVX2 shift-only epilogue: 8 channels per iteration inside each
/// `cout`-row, scalar tail per row.
///
/// # Safety
/// Caller must ensure AVX2 is available, `acc.len() % cout == 0`,
/// `bias`/`shift` have at least `cout` entries, and every shift is in
/// `0..=30`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn requant_shift_avx2(
    acc: &[i32],
    bias: &[i32],
    shift: &[i32],
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
    out: &mut Vec<i8>,
) {
    use std::arch::x86_64::*;
    let zpv = _mm256_set1_epi32(out_qp.zero_point);
    let lov = _mm256_set1_epi32(clamp.0);
    let hiv = _mm256_set1_epi32(clamp.1);
    let one = _mm256_set1_epi32(1);
    let zero = _mm256_setzero_si256();
    for row in acc.chunks_exact(cout) {
        let mut j = 0usize;
        while j + 8 <= cout {
            let x = _mm256_add_epi32(
                _mm256_loadu_si256(row.as_ptr().add(j) as *const __m256i),
                _mm256_loadu_si256(bias.as_ptr().add(j) as *const __m256i),
            );
            let s = _mm256_loadu_si256(
                shift.as_ptr().add(j) as *const __m256i
            );
            // 1 << (s-1) as ((1 << s) >> 1): exactly 0 when s == 0,
            // matching rounding_rshift's identity at shift 0.
            let half = _mm256_srli_epi32(_mm256_sllv_epi32(one, s), 1);
            // subtract [x < 0] only when s >= 1 (shift-0 is identity)
            let negadj = _mm256_and_si256(
                _mm256_srli_epi32(x, 31),
                _mm256_cmpgt_epi32(s, zero),
            );
            let t = _mm256_sub_epi32(_mm256_add_epi32(x, half), negadj);
            let r = _mm256_srav_epi32(t, s);
            let v = _mm256_add_epi32(r, zpv);
            let c = _mm256_min_epi32(_mm256_max_epi32(v, lov), hiv);
            let mut tmp = [0i32; 8];
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, c);
            out.extend_from_slice(&tmp.map(|t| t as i8));
            j += 8;
        }
        for (ji, &a) in row.iter().enumerate().skip(j) {
            let v = rounding_rshift(a + bias[ji], shift[ji])
                + out_qp.zero_point;
            out.push(v.clamp(clamp.0, clamp.1) as i8);
        }
    }
}

/// SSE2 shift-only epilogue for a **uniform** shift: 4 channels per
/// iteration inside each `cout`-row, scalar tail per row.
///
/// # Safety
/// Caller must ensure `acc.len() % cout == 0`, `bias` has at least
/// `cout` entries, and `s` is in `0..=30`. SSE2 is the x86_64 baseline.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn requant_shift_sse2_uniform(
    acc: &[i32],
    bias: &[i32],
    s: i32,
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
    out: &mut Vec<i8>,
) {
    use std::arch::x86_64::*;
    let halfv =
        _mm_set1_epi32(if s >= 1 { 1i32 << (s - 1) } else { 0 });
    let adjmask = _mm_set1_epi32(if s >= 1 { -1 } else { 0 });
    let cnt = _mm_cvtsi32_si128(s);
    let zpv = _mm_set1_epi32(out_qp.zero_point);
    let lov = _mm_set1_epi32(clamp.0);
    let hiv = _mm_set1_epi32(clamp.1);
    for row in acc.chunks_exact(cout) {
        let mut j = 0usize;
        while j + 4 <= cout {
            let x = _mm_add_epi32(
                _mm_loadu_si128(row.as_ptr().add(j) as *const __m128i),
                _mm_loadu_si128(bias.as_ptr().add(j) as *const __m128i),
            );
            let negadj = _mm_and_si128(_mm_srli_epi32(x, 31), adjmask);
            let t = _mm_sub_epi32(_mm_add_epi32(x, halfv), negadj);
            let r = _mm_sra_epi32(t, cnt);
            let v = _mm_add_epi32(r, zpv);
            // SSE2 has no pmin/pmax for i32: clamp via cmpgt blends
            let too_lo = _mm_cmpgt_epi32(lov, v);
            let v = _mm_or_si128(
                _mm_and_si128(too_lo, lov),
                _mm_andnot_si128(too_lo, v),
            );
            let too_hi = _mm_cmpgt_epi32(v, hiv);
            let c = _mm_or_si128(
                _mm_and_si128(too_hi, hiv),
                _mm_andnot_si128(too_hi, v),
            );
            let mut tmp = [0i32; 4];
            _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, c);
            out.extend_from_slice(&tmp.map(|t| t as i8));
            j += 4;
        }
        for (ji, &a) in row.iter().enumerate().skip(j) {
            let v = rounding_rshift(a + bias[ji], s) + out_qp.zero_point;
            out.push(v.clamp(clamp.0, clamp.1) as i8);
        }
    }
}

/// Pick the layer's requant epilogue: the shift-only path when the
/// exporter proved every multiplier a power of two
/// (`QLayer::requant_shift`), else the fixed-point multiplier path.
fn store_epilogue(
    acc: &[i32],
    l: &QLayer,
    cout: usize,
    isa: Isa,
    out: &mut Vec<i8>,
) {
    match &l.requant_shift {
        Some(sh) => requant_store_shift(
            acc, &l.bias_q, sh, l.out_qp, l.clamp, cout, out, isa,
        ),
        None => requant_store(
            acc, &l.bias_q, &l.requant, l.out_qp, l.clamp, cout, out,
        ),
    }
}

/// SAME-padded conv via im2col + int8 GEMM, or the fused implicit-GEMM
/// path ([`conv2d_fused`]) when the layer's tuner bit and the
/// `FAT_FUSED` gate select it.
pub fn conv2d(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    cout: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
) -> QTensor {
    if takes_fused_path(l) {
        return conv2d_fused(x, l, k, stride, cout, ctx, out, None);
    }
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let OpCtx { threads, isa, patches, acc } = ctx;
    let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
    // Zero-copy 1×1 stride-1: the patch matrix IS the NHWC input slab
    // (SAME padding is zero, every patch one in-bounds pixel), so alias
    // it as the GEMM A operand instead of memcpy-ing it into `patches`.
    let a: &[i8] = if k == 1 && stride == 1 {
        &x.data
    } else {
        let got = im2col_into(
            &x.data,
            n,
            h,
            w,
            c,
            k,
            stride,
            x.qp.zero_point as i8,
            patches,
        );
        debug_assert_eq!(got, (oh, ow));
        patches.as_slice()
    };
    let m = n * oh * ow;
    let kk = k * k * c;
    acc.clear();
    acc.resize(m * cout, 0);
    gemm_dispatch(a, x.qp.zero_point, l, m, kk, cout, acc, *threads, *isa);
    let mut data = out;
    store_epilogue(acc, l, cout, *isa, &mut data);
    QTensor { shape: vec![n, oh, ow, cout], data, qp: l.out_qp }
}

/// Residual operand of a fused `conv → add` chain
/// (`engine::run_quant_state` detects the chain): the add's second
/// input is consumed inside the conv's epilogue tile.
pub struct ConvResidual<'a> {
    /// The add's other operand (same shape as the conv output).
    pub b: &'a QTensor,
    /// The add's rescale parameters.
    pub params: &'a AddParams,
    /// Whether the conv output is the add's *a* operand ([`add`]
    /// argument order). Picks which multiplier pairs with which
    /// operand; the rescaled i32 sum itself is commutative.
    pub conv_is_a: bool,
}

/// Build the [`kernels::FusedEpilogue`] for layer `l`: same per-channel
/// constants the staged `gemm_dispatch` + `store_epilogue` pair uses,
/// applied per MR×NR register tile instead of per full buffer.
fn fused_epilogue<'a>(
    a_zp: i32,
    l: &'a QLayer,
    residual: Option<&ConvResidual<'a>>,
) -> kernels::FusedEpilogue<'a> {
    let residual = residual.map(|r| {
        let p = r.params;
        let (ma, mb) = if r.conv_is_a { (p.ma, p.mb) } else { (p.mb, p.ma) };
        kernels::FusedResidual {
            b: &r.b.data,
            a_zp: l.out_qp.zero_point,
            b_zp: r.b.qp.zero_point,
            ma,
            mb,
            out_zp: p.out_qp.zero_point,
            clamp: p.clamp,
        }
    });
    kernels::FusedEpilogue {
        a_zp,
        bsums: &l.w_sums,
        bias: &l.bias_q,
        requant: &l.requant,
        shift: l.requant_shift.as_deref(),
        out_zp: l.out_qp.zero_point,
        clamp: l.clamp,
        residual,
    }
}

/// Fused implicit-GEMM conv (kernels module docs, DESIGN.md §14): the
/// micro-panel packer assembles patch rows on the fly from the NHWC
/// input and the register-tile epilogue stores i8 directly — no patch
/// matrix, no i32 accumulator buffer. With `residual`, the conv's sole
/// `add` consumer runs inside the same epilogue and the output lands
/// directly in the add's quantization domain.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fused(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    cout: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
    residual: Option<ConvResidual>,
) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let geom = PatchGeom::new(n, h, w, c, k, stride, x.qp.zero_point as i8);
    let (oh, ow) = (geom.oh, geom.ow);
    let m = geom.rows();
    let pw = l.packed.as_ref().expect("fused layer without packed weights");
    debug_assert_eq!(
        (pw.k, pw.n),
        (geom.cols(), cout),
        "packed shape mismatch"
    );
    // Zero-copy 1×1 stride-1: the virtual patch matrix IS the input
    // slab — feed it to the micro-tiles directly, no per-panel packing.
    let a = if k == 1 && stride == 1 {
        kernels::FusedA::Direct(&x.data)
    } else {
        kernels::FusedA::Implicit { x: &x.data, geom }
    };
    if let Some(r) = &residual {
        debug_assert_eq!(
            r.b.data.len(),
            m * cout,
            "residual operand shape mismatch"
        );
    }
    let out_qp = residual.as_ref().map_or(l.out_qp, |r| r.params.out_qp);
    let ep = fused_epilogue(x.qp.zero_point, l, residual.as_ref());
    let mut data = out;
    data.clear();
    data.resize(m * cout, 0);
    kernels::gemm_fused_parallel(
        &a,
        m,
        pw,
        &ep,
        &mut data,
        ctx.threads,
        ctx.isa,
        l.blocking,
    );
    QTensor { shape: vec![n, oh, ow, cout], data, qp: out_qp }
}

/// Route the conv/dense GEMM: exported layers carry weights prepacked
/// at plan-build time and run the SIMD microkernels
/// ([`kernels::gemm_packed_parallel`]); ad-hoc layers (tests,
/// hand-built) fall back to the unpacked blocked kernel. Both are
/// bit-exact with `gemm_ref`.
#[allow(clippy::too_many_arguments)]
fn gemm_dispatch(
    a: &[i8],
    a_zp: i32,
    l: &QLayer,
    m: usize,
    k: usize,
    n: usize,
    acc: &mut [i32],
    threads: usize,
    isa: Isa,
) {
    match &l.packed {
        Some(pw) => {
            debug_assert_eq!((pw.k, pw.n), (k, n), "packed shape mismatch");
            kernels::gemm_packed_parallel(
                a, a_zp, pw, &l.w_sums, m, acc, threads, isa, l.blocking,
            );
        }
        None => {
            gemm_i8_parallel(a, a_zp, &l.w_q, &l.w_sums, m, k, n, acc, threads)
        }
    }
}

/// Depthwise SAME-padded conv (multiplier 1). `l.w_q` is (k,k,ch)
/// row-major. Output rows are sharded over `ctx.threads` workers.
pub fn dwconv2d(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad_top = (((oh - 1) * stride + k).saturating_sub(h)) / 2;
    let pad_left = (((ow - 1) * stride + k).saturating_sub(w)) / 2;
    let mut data = out;
    data.clear();
    data.resize(n * oh * ow * c, 0);
    let rows = n * oh;
    let row_len = ow * c;
    let t = ctx.threads.max(1).min(rows.max(1));
    if row_len == 0 || rows == 0 {
        // degenerate empty output; nothing to compute
    } else if t <= 1 {
        dw_rows(x, l, k, stride, oh, ow, pad_top, pad_left, 0, &mut data, ctx.isa);
    } else {
        let per = rows.div_ceil(t);
        let isa = ctx.isa;
        crate::util::threads::pool().run_chunks(
            &mut data,
            per * row_len,
            |i, slab| {
                dw_rows(
                    x, l, k, stride, oh, ow, pad_top, pad_left, i * per,
                    slab, isa,
                );
            },
        );
    }
    QTensor { shape: vec![n, oh, ow, c], data, qp: l.out_qp }
}

/// Compute a contiguous range of depthwise output rows (one row =
/// one (image, oy) scanline of ow*c values) into `out`. Taps run
/// channel-vectorized ([`kernels::dw_accum_tap`]); the per-(pixel,
/// channel) sum set is unchanged and i32 adds are associative, so the
/// result is bit-exact with the old channel-inner scalar loop.
#[allow(clippy::too_many_arguments)]
fn dw_rows(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    pad_top: usize,
    pad_left: usize,
    r0: usize,
    out: &mut [i8],
    isa: Isa,
) {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let zp = x.qp.zero_point;
    let mut acc = vec![0i32; c];
    for (ri, orow) in out.chunks_mut(ow * c).enumerate() {
        let r = r0 + ri;
        let ni = r / oh;
        let oy = r % oh;
        for ox in 0..ow {
            acc.fill(0);
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // pad tap: (zp - zp) * w = 0
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xi =
                        ((ni * h + iy as usize) * w + ix as usize) * c;
                    let wi = (ky * k + kx) * c;
                    kernels::dw_accum_tap(
                        &mut acc,
                        &x.data[xi..xi + c],
                        &l.w_q[wi..wi + c],
                        zp,
                        isa,
                    );
                }
            }
            match &l.requant_shift {
                Some(sh) => {
                    for (ci, &a) in acc.iter().enumerate() {
                        let v = rounding_rshift(a + l.bias_q[ci], sh[ci])
                            + l.out_qp.zero_point;
                        orow[ox * c + ci] =
                            v.clamp(l.clamp.0, l.clamp.1) as i8;
                    }
                }
                None => {
                    for (ci, &a) in acc.iter().enumerate() {
                        let (m0, shift) = l.requant[ci];
                        let v = apply_multiplier(a + l.bias_q[ci], m0, shift)
                            + l.out_qp.zero_point;
                        orow[ox * c + ci] =
                            v.clamp(l.clamp.0, l.clamp.1) as i8;
                    }
                }
            }
        }
    }
}

/// Dense layer over (n, cin) input. A dense layer is a 1×1 conv over a
/// 1×1 "image", so the fused path feeds the input slab straight to the
/// micro-tiles ([`kernels::FusedA::Direct`]) and skips the i32 buffer.
pub fn dense(
    x: &QTensor,
    l: &QLayer,
    cout: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
) -> QTensor {
    let n = x.shape[0];
    let cin = x.shape[1];
    if takes_fused_path(l) {
        let pw = l.packed.as_ref().expect("fused layer without packed weights");
        debug_assert_eq!((pw.k, pw.n), (cin, cout), "packed shape mismatch");
        let ep = fused_epilogue(x.qp.zero_point, l, None);
        let mut data = out;
        data.clear();
        data.resize(n * cout, 0);
        kernels::gemm_fused_parallel(
            &kernels::FusedA::Direct(&x.data),
            n,
            pw,
            &ep,
            &mut data,
            ctx.threads,
            ctx.isa,
            l.blocking,
        );
        return QTensor { shape: vec![n, cout], data, qp: l.out_qp };
    }
    ctx.acc.clear();
    ctx.acc.resize(n * cout, 0);
    gemm_dispatch(
        &x.data,
        x.qp.zero_point,
        l,
        n,
        cin,
        cout,
        &mut ctx.acc,
        ctx.threads,
        ctx.isa,
    );
    let mut data = out;
    store_epilogue(&ctx.acc, l, cout, ctx.isa, &mut data);
    QTensor { shape: vec![n, cout], data, qp: l.out_qp }
}

/// Residual add: rescale both operands into the output domain.
pub fn add(a: &QTensor, b: &QTensor, p: &AddParams, out: Vec<i8>) -> QTensor {
    debug_assert_eq!(a.shape, b.shape);
    let mut data = out;
    data.clear();
    data.reserve(a.data.len());
    // Pre-scale by 2^20 for precision (TFLite-style left shift).
    for (&qa, &qb) in a.data.iter().zip(&b.data) {
        let va = apply_multiplier(
            ((qa as i32) - a.qp.zero_point) << 20,
            p.ma.0,
            p.ma.1,
        );
        let vb = apply_multiplier(
            ((qb as i32) - b.qp.zero_point) << 20,
            p.mb.0,
            p.mb.1,
        );
        let v = crate::quant::scale::rounding_rshift(va + vb, 20)
            + p.out_qp.zero_point;
        data.push(v.clamp(p.clamp.0, p.clamp.1) as i8);
    }
    QTensor { shape: a.shape.clone(), data, qp: p.out_qp }
}

/// Global average pool over H,W.
pub fn gap(x: &QTensor, p: &GapParams, out: Vec<i8>) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let zp = x.qp.zero_point;
    let mut data = out;
    data.clear();
    data.reserve(n * c);
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0i32;
            for pix in 0..(h * w) {
                acc += x.data[(ni * h * w + pix) * c + ci] as i32 - zp;
            }
            // multiplier m already folds the 1/(h*w)
            let v = apply_multiplier(acc, p.m.0, p.m.1)
                + p.out_qp.zero_point;
            data.push(v.clamp(p.out_qp.qmin, p.out_qp.qmax) as i8);
        }
    }
    QTensor { shape: vec![n, c], data, qp: p.out_qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scale::{quantize_multiplier, QParams};

    fn qp_sym(t: f32) -> QParams {
        super::super::qtensor::to_i8_domain(QParams::symmetric_signed(t))
    }

    /// Build requant params mapping acc scale (s_in*s_w) to s_out.
    fn rq(s_in: f32, s_w: f32, s_out: f32) -> (i32, i32) {
        quantize_multiplier((s_in as f64 * s_w as f64) / s_out as f64)
    }

    fn layer(
        w_q: Vec<i8>,
        w_sums: Vec<i32>,
        bias_q: Vec<i32>,
        requant: Vec<(i32, i32)>,
        out_qp: QParams,
        clamp: (i32, i32),
    ) -> QLayer {
        QLayer {
            w_q: w_q.into(),
            w_sums,
            bias_q,
            requant,
            requant_shift: None,
            out_qp,
            clamp,
            w_scales: vec![1.0],
            packed: None,
            blocking: Default::default(),
            fused: false,
        }
    }

    #[test]
    fn conv_1x1_identity_approx() {
        // y = 1.0 * x through a 1x1 conv with unit weight
        let in_qp = qp_sym(1.0);
        let x =
            QTensor::quantize(vec![1, 2, 2, 1], &[0.5, -0.25, 1.0, 0.0], in_qp);
        let w_t = 1.0f32;
        let w_qp = QParams::symmetric_signed(w_t);
        let w_q = vec![w_qp.quantize(1.0) as i8];
        let sums = vec![w_q[0] as i32];
        let out_qp = qp_sym(1.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let l = layer(w_q, sums, vec![0], req, out_qp, (out_qp.qmin, out_qp.qmax));
        let y = conv2d(&x, &l, 1, 1, 1, &mut OpCtx::default(), Vec::new());
        let d = y.dequantize();
        for (a, b) in [0.5, -0.25, 1.0, 0.0].iter().zip(&d) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn dwconv_matches_float_reference() {
        // 3x3 depthwise over a 4x4 single-channel ramp
        let xs: Vec<f32> = (0..16).map(|i| i as f32 / 8.0).collect();
        let in_qp = qp_sym(2.0);
        let x = QTensor::quantize(vec![1, 4, 4, 1], &xs, in_qp);
        let wf = [0.1f32, 0.2, 0.1, 0.0, 0.5, 0.0, -0.1, 0.0, -0.2];
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> =
            wf.iter().map(|&v| w_qp.quantize(v) as i8).collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let l = layer(w_q, vec![], vec![0], req, out_qp, (-127, 127));
        let y = dwconv2d(&x, &l, 3, 1, &mut OpCtx::default(), Vec::new());
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // float reference at centre pixel (1,1): full 3x3 support
        let xr = |r: usize, c: usize| xs[r * 4 + c];
        let mut want = 0.0;
        for ky in 0..3 {
            for kx in 0..3 {
                want += wf[ky * 3 + kx] * xr(ky, kx);
            }
        }
        let got = y.dequantize()[4 + 1];
        assert!((got - want).abs() < 0.05, "{got} vs {want}");
    }

    #[test]
    fn dwconv_threaded_matches_serial() {
        let in_qp = qp_sym(2.0);
        let xs = crate::util::prop::f32s(5, 2 * 7 * 7 * 3, -2.0, 2.0);
        let x = QTensor::quantize(vec![2, 7, 7, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = crate::util::prop::f32s(6, 9 * 3, -0.5, 0.5)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 3];
        let l = layer(w_q, vec![], vec![3, -2, 0], req, out_qp, (-127, 127));
        let base =
            dwconv2d(&x, &l, 3, 2, &mut OpCtx::default(), Vec::new());
        for t in [2usize, 5, 16] {
            let y = dwconv2d(&x, &l, 3, 2, &mut OpCtx::with_threads(t), Vec::new());
            assert_eq!(base.shape, y.shape, "t={t}");
            assert_eq!(base.data, y.data, "t={t}");
        }
    }

    #[test]
    fn add_rescales_operands() {
        let qa = qp_sym(1.0);
        let qb = qp_sym(2.0);
        let qo = qp_sym(3.0);
        let a = QTensor::quantize(vec![4], &[0.5, -0.5, 1.0, 0.0], qa);
        let b = QTensor::quantize(vec![4], &[1.5, 0.5, -1.0, 2.0], qb);
        let p = AddParams {
            ma: quantize_multiplier(qa.scale as f64 / qo.scale as f64),
            mb: quantize_multiplier(qb.scale as f64 / qo.scale as f64),
            out_qp: qo,
            clamp: (qo.qmin, qo.qmax),
        };
        let y = add(&a, &b, &p, Vec::new());
        let d = y.dequantize();
        for (want, got) in [2.0f32, 0.0, 0.0, 2.0].iter().zip(&d) {
            assert!((want - got).abs() < 0.06, "{want} vs {got}");
        }
    }

    #[test]
    fn gap_averages() {
        let qi = qp_sym(4.0);
        let qo = qp_sym(4.0);
        let xs = vec![1.0f32, 2.0, 3.0, 4.0];
        let x = QTensor::quantize(vec![1, 2, 2, 1], &xs, qi);
        let p = GapParams {
            m: quantize_multiplier(qi.scale as f64 / qo.scale as f64 / 4.0),
            out_qp: qo,
        };
        let y = gap(&x, &p, Vec::new());
        let d = y.dequantize();
        assert!((d[0] - 2.5).abs() < 0.05, "{}", d[0]);
    }

    #[test]
    fn relu6_clamp_fused() {
        // conv output clamped at quantized 6.0
        let in_qp = qp_sym(10.0);
        let x = QTensor::quantize(vec![1, 1, 1, 1], &[8.0], in_qp);
        let w_qp = QParams::symmetric_signed(1.0);
        let w_q = vec![w_qp.quantize(1.0) as i8];
        let out_qp = super::super::qtensor::to_i8_domain(
            QParams::symmetric_unsigned(8.0),
        );
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let hi = out_qp.zero_point + (6.0 / out_qp.scale).round() as i32;
        let sums = vec![w_q[0] as i32];
        let l = layer(w_q, sums, vec![0], req, out_qp, (out_qp.zero_point, hi));
        let y = conv2d(&x, &l, 1, 1, 1, &mut OpCtx::default(), Vec::new());
        let d = y.dequantize()[0];
        assert!((d - 6.0).abs() < 0.05, "{d}");
    }

    #[test]
    fn conv_packed_matches_unpacked_across_isa_and_threads() {
        // the exported-model path (prepacked SIMD kernels) must be
        // bit-exact with the ad-hoc unpacked path
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(21, 2 * 6 * 6 * 3, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 6, 6, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.6);
        let w_q: Vec<i8> = crate::util::prop::f32s(22, 9 * 3 * 5, -0.6, 0.6)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 27, 5);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 5];
        let plain =
            layer(w_q.clone(), sums, vec![1, -2, 3, 0, 7], req, out_qp, (-127, 127));
        let mut packed = plain.clone();
        packed.packed =
            Some(crate::int8::kernels::PackedWeights::pack(&w_q, 27, 5));
        let base =
            conv2d(&x, &plain, 3, 1, 5, &mut OpCtx::default(), Vec::new());
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = conv2d(&x, &packed, 3, 1, 5, &mut ctx, Vec::new());
                assert_eq!(base.shape, y.shape, "t={t} {}", isa.name());
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn dwconv_isa_sweep_matches_scalar() {
        let in_qp = qp_sym(2.0);
        // 5 channels straddles every vector width remainder
        let xs = crate::util::prop::f32s(25, 2 * 7 * 7 * 5, -2.0, 2.0);
        let x = QTensor::quantize(vec![2, 7, 7, 5], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = crate::util::prop::f32s(26, 9 * 5, -0.5, 0.5)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 5];
        let l = layer(w_q, vec![], vec![3, -2, 0, 1, -1], req, out_qp, (-127, 127));
        let mut sctx = OpCtx { isa: Isa::Scalar, ..Default::default() };
        let base = dwconv2d(&x, &l, 3, 2, &mut sctx, Vec::new());
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = dwconv2d(&x, &l, 3, 2, &mut ctx, Vec::new());
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn requant_store_shift_matches_scalar_reference_across_isas() {
        use crate::quant::scale::rounding_rshift;
        let qp = qp_sym(1.0);
        // channel counts straddling both vector widths and their tails;
        // shift tables: per-channel varied, uniform, zero, and one
        // negative entry (multiplier > 1 → scalar fallback everywhere)
        for &cout in &[1usize, 3, 4, 5, 8, 11, 16, 64] {
            let n_pix = 7usize;
            let acc: Vec<i32> = crate::util::prop::f32s(61, n_pix * cout, -6e4, 6e4)
                .iter()
                .map(|&v| v as i32)
                .collect();
            let bias: Vec<i32> = crate::util::prop::f32s(62, cout, -500.0, 500.0)
                .iter()
                .map(|&v| v as i32)
                .collect();
            let tables: Vec<Vec<i32>> = vec![
                (0..cout).map(|c| (c % 9) as i32).collect(),
                vec![5i32; cout],
                vec![0i32; cout],
                (0..cout).map(|c| if c == 0 { -2 } else { 3 }).collect(),
            ];
            for shift in &tables {
                let mut want = Vec::new();
                for (i, &a) in acc.iter().enumerate() {
                    let c = i % cout;
                    let v = rounding_rshift(a + bias[c], shift[c])
                        + qp.zero_point;
                    want.push(v.clamp(-127, 127) as i8);
                }
                for isa in Isa::available() {
                    let mut got = vec![9i8; 3]; // dirty recycled buffer
                    requant_store_shift(
                        &acc,
                        &bias,
                        shift,
                        qp,
                        (-127, 127),
                        cout,
                        &mut got,
                        isa,
                    );
                    assert_eq!(
                        got,
                        want,
                        "cout={cout} shift={shift:?} {}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn shift_epilogue_is_not_the_multiplier_epilogue() {
        // Why requant_shift is a distinct representation: a pow2
        // multiplier through apply_multiplier rounds TWICE (once in the
        // doubling high mul, once in the shift), so it can differ from
        // the direct rounding shift by 1 — e.g. x=5, m=2^-2:
        use crate::quant::scale::{
            apply_multiplier, quantize_multiplier, rounding_rshift,
        };
        let (m0, shift) = quantize_multiplier(0.25);
        assert_eq!((m0, shift), (1 << 30, 1));
        assert_eq!(apply_multiplier(5, m0, shift), 2);
        assert_eq!(rounding_rshift(5, 2), 1);
    }

    #[test]
    fn conv_shift_epilogue_bit_exact_across_isa_and_threads() {
        // a packed conv layer with a per-channel shift table: every ISA
        // and thread count must reproduce the scalar result exactly
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(63, 2 * 6 * 6 * 3, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 6, 6, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.6);
        let w_q: Vec<i8> = crate::util::prop::f32s(64, 9 * 3 * 5, -0.6, 0.6)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 27, 5);
        let out_qp = qp_sym(2.0);
        let req = vec![(1 << 30, 6); 5]; // unused when shift is set
        let mut l = layer(
            w_q.clone(),
            sums,
            vec![1, -2, 3, 0, 7],
            req,
            out_qp,
            (-127, 127),
        );
        l.requant_shift = Some(vec![7, 6, 8, 7, 5]);
        l.packed =
            Some(crate::int8::kernels::PackedWeights::pack(&w_q, 27, 5));
        let mut sctx = OpCtx { isa: Isa::Scalar, ..Default::default() };
        let base = conv2d(&x, &l, 3, 1, 5, &mut sctx, Vec::new());
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = conv2d(&x, &l, 3, 1, 5, &mut ctx, Vec::new());
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn dwconv_shift_epilogue_matches_rounding_shift() {
        use crate::quant::scale::rounding_rshift;
        let in_qp = qp_sym(2.0);
        let xs = crate::util::prop::f32s(65, 7 * 7 * 5, -2.0, 2.0);
        let x = QTensor::quantize(vec![1, 7, 7, 5], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = crate::util::prop::f32s(66, 9 * 5, -0.5, 0.5)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let out_qp = qp_sym(2.0);
        let mut l = layer(
            w_q,
            vec![],
            vec![3, -2, 0, 1, -1],
            vec![(1 << 30, 3); 5],
            out_qp,
            (-127, 127),
        );
        l.requant_shift = Some(vec![4, 3, 5, 4, 6]);
        let base = dwconv2d(&x, &l, 3, 1, &mut OpCtx::default(), Vec::new());
        // spot-check the epilogue arithmetic at the centre pixel by
        // recomputing the taps scalar-side
        let sh = l.requant_shift.as_ref().unwrap();
        let c = 5usize;
        let mut acc = vec![0i32; c];
        for ky in 0..3 {
            for kx in 0..3 {
                let xi = (((1 + ky) * 7) + 1 + kx) * c;
                let wi = (ky * 3 + kx) * c;
                for ci in 0..c {
                    acc[ci] += (x.data[xi + ci] as i32
                        - x.qp.zero_point)
                        * l.w_q[wi + ci] as i32;
                }
            }
        }
        for ci in 0..c {
            let v = rounding_rshift(acc[ci] + l.bias_q[ci], sh[ci])
                + out_qp.zero_point;
            let want = v.clamp(-127, 127) as i8;
            assert_eq!(base.data[((2 * 7) + 2) * c + ci], want, "ci={ci}");
        }
        // and the threaded/ISA sweep stays bit-exact
        for isa in Isa::available() {
            for t in [2usize, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = dwconv2d(&x, &l, 3, 1, &mut ctx, Vec::new());
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn conv_reuses_stale_scratch_and_out() {
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(11, 2 * 5 * 5 * 2, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 5, 5, 2], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.7);
        let w_q: Vec<i8> = crate::util::prop::f32s(12, 9 * 2 * 3, -0.7, 0.7)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 18, 3);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 3];
        let l = layer(w_q, sums, vec![1, 2, 3], req, out_qp, (-127, 127));
        let mut ctx = OpCtx::with_threads(2);
        let first = conv2d(&x, &l, 3, 1, 3, &mut ctx, Vec::new());
        // second call reuses ctx scratch and a dirty recycled buffer
        let dirty = vec![77i8; 3];
        let second = conv2d(&x, &l, 3, 1, 3, &mut ctx, dirty);
        assert_eq!(first.shape, second.shape);
        assert_eq!(first.data, second.data);
    }

    /// A packed 3×3 conv layer over a 2×6×6×3 input, with its staged
    /// (`fused: false`) result as the oracle.
    fn fused_fixture(
        shift: bool,
    ) -> (QTensor, QLayer, QTensor) {
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(71, 2 * 6 * 6 * 3, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 6, 6, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.6);
        let w_q: Vec<i8> = crate::util::prop::f32s(72, 9 * 3 * 5, -0.6, 0.6)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 27, 5);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 5];
        let mut l = layer(
            w_q.clone(),
            sums,
            vec![1, -2, 3, 0, 7],
            req,
            out_qp,
            (-127, 127),
        );
        if shift {
            l.requant_shift = Some(vec![7, 6, 8, 7, 5]);
        }
        l.packed =
            Some(crate::int8::kernels::PackedWeights::pack(&w_q, 27, 5));
        let base = conv2d(&x, &l, 3, 1, 5, &mut OpCtx::default(), Vec::new());
        (x, l, base)
    }

    #[test]
    fn fused_conv_matches_staged_across_isa_and_threads() {
        // the fused implicit-GEMM path must be bit-exact with the staged
        // im2col + GEMM + requant pipeline, both epilogues
        for use_shift in [false, true] {
            let (x, mut l, base) = fused_fixture(use_shift);
            l.fused = true;
            for isa in Isa::available() {
                for t in [1usize, 2, 8] {
                    let mut ctx = OpCtx::with_threads(t);
                    ctx.isa = isa;
                    let y = conv2d(&x, &l, 3, 1, 5, &mut ctx, Vec::new());
                    assert_eq!(base.shape, y.shape);
                    assert_eq!(
                        base.data,
                        y.data,
                        "shift={use_shift} t={t} {}",
                        isa.name()
                    );
                    // fused layers never touch the staged scratch
                    if super::fused_enabled() {
                        assert_eq!(ctx.scratch_bytes(), (0, 0), "t={t}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_conv_reuses_stale_scratch_and_out() {
        // mirror of conv_reuses_stale_scratch_and_out: a ctx whose
        // scratch is dirty from a staged run, plus a dirty recycled
        // output buffer, must not perturb the fused result
        let (x, mut l, base) = fused_fixture(false);
        l.fused = true;
        let mut ctx = OpCtx::with_threads(2);
        // dirty the staged scratch first
        let staged = layer(
            l.w_q.to_vec(),
            l.w_sums.clone(),
            l.bias_q.clone(),
            l.requant.clone(),
            l.out_qp,
            l.clamp,
        );
        let _ = conv2d(&x, &staged, 3, 1, 5, &mut ctx, Vec::new());
        assert!(ctx.scratch_bytes().0 > 0);
        let first = conv2d(&x, &l, 3, 1, 5, &mut ctx, Vec::new());
        let dirty = vec![77i8; 3];
        let second = conv2d(&x, &l, 3, 1, 5, &mut ctx, dirty);
        assert_eq!(base.data, first.data);
        assert_eq!(first.shape, second.shape);
        assert_eq!(first.data, second.data);
    }

    #[test]
    fn pointwise_conv_aliases_input_no_patch_copy() {
        // 1×1 stride-1 convs alias the input slab as the GEMM A operand
        // on both paths: the patch scratch stays untouched, and staged
        // and fused agree
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(83, 2 * 4 * 4 * 6, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 4, 4, 6], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = crate::util::prop::f32s(84, 6 * 4, -0.5, 0.5)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 6, 4);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 4];
        let mut l =
            layer(w_q.clone(), sums, vec![0, 1, -1, 2], req, out_qp, (-127, 127));
        l.packed = Some(crate::int8::kernels::PackedWeights::pack(&w_q, 6, 4));
        let mut sctx = OpCtx::default();
        let staged = conv2d(&x, &l, 1, 1, 4, &mut sctx, Vec::new());
        assert_eq!(
            sctx.scratch_bytes().0,
            0,
            "staged 1×1 stride-1 must not copy patches"
        );
        l.fused = true;
        let mut fctx = OpCtx::with_threads(2);
        let fused = conv2d(&x, &l, 1, 1, 4, &mut fctx, Vec::new());
        assert_eq!(staged.shape, fused.shape);
        assert_eq!(staged.data, fused.data);
        if super::fused_enabled() {
            assert_eq!(fctx.scratch_bytes(), (0, 0));
        }
    }

    #[test]
    fn fused_dense_matches_staged() {
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(87, 7 * 10, -1.0, 1.0);
        let x = QTensor::quantize(vec![7, 10], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.4);
        let w_q: Vec<i8> = crate::util::prop::f32s(88, 10 * 6, -0.4, 0.4)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 10, 6);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 6];
        let mut l = layer(
            w_q.clone(),
            sums,
            vec![4, -3, 0, 2, 1, -5],
            req,
            out_qp,
            (-127, 127),
        );
        l.packed = Some(crate::int8::kernels::PackedWeights::pack(&w_q, 10, 6));
        let base = dense(&x, &l, 6, &mut OpCtx::default(), Vec::new());
        l.fused = true;
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = dense(&x, &l, 6, &mut ctx, Vec::new());
                assert_eq!(base.shape, y.shape);
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn fused_conv_residual_matches_conv_then_add() {
        // the residual epilogue must reproduce conv2d followed by
        // ops::add exactly, for both operand orders of the add
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(85, 5 * 5 * 3, -1.0, 1.0);
        let x = QTensor::quantize(vec![1, 5, 5, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.6);
        let w_q: Vec<i8> = crate::util::prop::f32s(86, 9 * 3 * 4, -0.6, 0.6)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 27, 4);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 4];
        let mut l =
            layer(w_q.clone(), sums, vec![1, -1, 2, 0], req, out_qp, (-127, 127));
        l.packed =
            Some(crate::int8::kernels::PackedWeights::pack(&w_q, 27, 4));
        l.fused = true;
        let bq = qp_sym(2.0);
        let bs = crate::util::prop::f32s(89, 5 * 5 * 4, -2.0, 2.0);
        let b = QTensor::quantize(vec![1, 5, 5, 4], &bs, bq);
        let qo = qp_sym(3.0);
        let p = AddParams {
            ma: quantize_multiplier(out_qp.scale as f64 / qo.scale as f64),
            mb: quantize_multiplier(bq.scale as f64 / qo.scale as f64),
            out_qp: qo,
            clamp: (-127, 127),
        };
        // oracle: the two-step chain (conv may itself run fused here —
        // it is bit-exact with staged by the tests above)
        let conv = conv2d(&x, &l, 3, 1, 4, &mut OpCtx::default(), Vec::new());
        let want_ab = add(&conv, &b, &p, Vec::new());
        let want_ba = add(&b, &conv, &p, Vec::new());
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = conv2d_fused(
                    &x,
                    &l,
                    3,
                    1,
                    4,
                    &mut ctx,
                    Vec::new(),
                    Some(ConvResidual { b: &b, params: &p, conv_is_a: true }),
                );
                assert_eq!(y.shape, want_ab.shape);
                assert_eq!(y.data, want_ab.data, "ab t={t} {}", isa.name());
                assert_eq!(y.qp.zero_point, want_ab.qp.zero_point);
                let y2 = conv2d_fused(
                    &x,
                    &l,
                    3,
                    1,
                    4,
                    &mut ctx,
                    Vec::new(),
                    Some(ConvResidual { b: &b, params: &p, conv_is_a: false }),
                );
                assert_eq!(y2.data, want_ba.data, "ba t={t} {}", isa.name());
            }
        }
    }
}
