//! Integer-only layer kernels: conv (im2col+GEMM), depthwise conv, dense,
//! residual add, global average pool — all with fixed-point requantization.
//!
//! Kernels are written for the planned engine (`int8::plan`): each takes
//! its layer parameters as a [`QLayer`]/[`AddParams`]/[`GapParams`]
//! bundle, writes its activation into a caller-provided buffer (recycled
//! through the engine's arena) and reuses im2col/accumulator scratch from
//! an [`OpCtx`] across nodes. `OpCtx::threads` drives row-sharded
//! parallelism inside the GEMM and the depthwise loop (dispatched onto
//! the persistent worker pool, `util::threads::pool`), and
//! `OpCtx::isa` selects the SIMD microkernel level (`int8::kernels`);
//! every thread count and ISA produces bit-identical activations.

use crate::quant::scale::{apply_multiplier, QParams};

use super::engine::{AddParams, GapParams, QLayer};
use super::gemm::gemm_i8_parallel;
use super::im2col::im2col_into;
use super::kernels::{self, Isa};
use super::qtensor::QTensor;

/// Reusable per-run execution context: worker count and kernel ISA plus
/// im2col / accumulator scratch shared by all nodes of one inference.
pub struct OpCtx {
    pub threads: usize,
    /// Microkernel ISA; defaults to the process-wide [`Isa::detect`].
    pub isa: Isa,
    pub patches: Vec<i8>,
    pub acc: Vec<i32>,
}

impl Default for OpCtx {
    fn default() -> Self {
        OpCtx {
            threads: 1,
            isa: Isa::detect(),
            patches: Vec::new(),
            acc: Vec::new(),
        }
    }
}

impl OpCtx {
    pub fn with_threads(threads: usize) -> Self {
        OpCtx { threads: threads.max(1), ..Default::default() }
    }
}

/// Requantize an int32 accumulator row into the output domain.
///
/// `acc` holds (n_pix, cout) accumulators at scale `s_in * s_w[c]`;
/// bias is already int32 at the same scale (paper eq. 20).
pub fn requant_store(
    acc: &[i32],
    bias: &[i32],
    requant: &[(i32, i32)],
    out_qp: QParams,
    clamp: (i32, i32),
    cout: usize,
    out: &mut Vec<i8>,
) {
    out.clear();
    out.reserve(acc.len());
    for (i, &a) in acc.iter().enumerate() {
        let c = i % cout;
        let (m0, shift) = requant[c];
        let v = apply_multiplier(a + bias[c], m0, shift)
            + out_qp.zero_point;
        out.push(v.clamp(clamp.0, clamp.1) as i8);
    }
}

/// SAME-padded conv via im2col + int8 GEMM.
pub fn conv2d(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    cout: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let OpCtx { threads, isa, patches, acc } = ctx;
    let (oh, ow) = im2col_into(
        &x.data,
        n,
        h,
        w,
        c,
        k,
        stride,
        x.qp.zero_point as i8,
        patches,
    );
    let m = n * oh * ow;
    let kk = k * k * c;
    acc.clear();
    acc.resize(m * cout, 0);
    gemm_dispatch(
        patches, x.qp.zero_point, l, m, kk, cout, acc, *threads, *isa,
    );
    let mut data = out;
    requant_store(
        acc, &l.bias_q, &l.requant, l.out_qp, l.clamp, cout, &mut data,
    );
    QTensor { shape: vec![n, oh, ow, cout], data, qp: l.out_qp }
}

/// Route the conv/dense GEMM: exported layers carry weights prepacked
/// at plan-build time and run the SIMD microkernels
/// ([`kernels::gemm_packed_parallel`]); ad-hoc layers (tests,
/// hand-built) fall back to the unpacked blocked kernel. Both are
/// bit-exact with `gemm_ref`.
#[allow(clippy::too_many_arguments)]
fn gemm_dispatch(
    a: &[i8],
    a_zp: i32,
    l: &QLayer,
    m: usize,
    k: usize,
    n: usize,
    acc: &mut [i32],
    threads: usize,
    isa: Isa,
) {
    match &l.packed {
        Some(pw) => {
            debug_assert_eq!((pw.k, pw.n), (k, n), "packed shape mismatch");
            kernels::gemm_packed_parallel(
                a, a_zp, pw, &l.w_sums, m, acc, threads, isa, l.blocking,
            );
        }
        None => {
            gemm_i8_parallel(a, a_zp, &l.w_q, &l.w_sums, m, k, n, acc, threads)
        }
    }
}

/// Depthwise SAME-padded conv (multiplier 1). `l.w_q` is (k,k,ch)
/// row-major. Output rows are sharded over `ctx.threads` workers.
pub fn dwconv2d(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pad_top = (((oh - 1) * stride + k).saturating_sub(h)) / 2;
    let pad_left = (((ow - 1) * stride + k).saturating_sub(w)) / 2;
    let mut data = out;
    data.clear();
    data.resize(n * oh * ow * c, 0);
    let rows = n * oh;
    let row_len = ow * c;
    let t = ctx.threads.max(1).min(rows.max(1));
    if row_len == 0 || rows == 0 {
        // degenerate empty output; nothing to compute
    } else if t <= 1 {
        dw_rows(x, l, k, stride, oh, ow, pad_top, pad_left, 0, &mut data, ctx.isa);
    } else {
        let per = rows.div_ceil(t);
        let isa = ctx.isa;
        crate::util::threads::pool().run_chunks(
            &mut data,
            per * row_len,
            |i, slab| {
                dw_rows(
                    x, l, k, stride, oh, ow, pad_top, pad_left, i * per,
                    slab, isa,
                );
            },
        );
    }
    QTensor { shape: vec![n, oh, ow, c], data, qp: l.out_qp }
}

/// Compute a contiguous range of depthwise output rows (one row =
/// one (image, oy) scanline of ow*c values) into `out`. Taps run
/// channel-vectorized ([`kernels::dw_accum_tap`]); the per-(pixel,
/// channel) sum set is unchanged and i32 adds are associative, so the
/// result is bit-exact with the old channel-inner scalar loop.
#[allow(clippy::too_many_arguments)]
fn dw_rows(
    x: &QTensor,
    l: &QLayer,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    pad_top: usize,
    pad_left: usize,
    r0: usize,
    out: &mut [i8],
    isa: Isa,
) {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let zp = x.qp.zero_point;
    let mut acc = vec![0i32; c];
    for (ri, orow) in out.chunks_mut(ow * c).enumerate() {
        let r = r0 + ri;
        let ni = r / oh;
        let oy = r % oh;
        for ox in 0..ow {
            acc.fill(0);
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // pad tap: (zp - zp) * w = 0
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xi =
                        ((ni * h + iy as usize) * w + ix as usize) * c;
                    let wi = (ky * k + kx) * c;
                    kernels::dw_accum_tap(
                        &mut acc,
                        &x.data[xi..xi + c],
                        &l.w_q[wi..wi + c],
                        zp,
                        isa,
                    );
                }
            }
            for (ci, &a) in acc.iter().enumerate() {
                let (m0, shift) = l.requant[ci];
                let v = apply_multiplier(a + l.bias_q[ci], m0, shift)
                    + l.out_qp.zero_point;
                orow[ox * c + ci] = v.clamp(l.clamp.0, l.clamp.1) as i8;
            }
        }
    }
}

/// Dense layer over (n, cin) input.
pub fn dense(
    x: &QTensor,
    l: &QLayer,
    cout: usize,
    ctx: &mut OpCtx,
    out: Vec<i8>,
) -> QTensor {
    let n = x.shape[0];
    let cin = x.shape[1];
    ctx.acc.clear();
    ctx.acc.resize(n * cout, 0);
    gemm_dispatch(
        &x.data,
        x.qp.zero_point,
        l,
        n,
        cin,
        cout,
        &mut ctx.acc,
        ctx.threads,
        ctx.isa,
    );
    let mut data = out;
    requant_store(
        &ctx.acc, &l.bias_q, &l.requant, l.out_qp, l.clamp, cout, &mut data,
    );
    QTensor { shape: vec![n, cout], data, qp: l.out_qp }
}

/// Residual add: rescale both operands into the output domain.
pub fn add(a: &QTensor, b: &QTensor, p: &AddParams, out: Vec<i8>) -> QTensor {
    debug_assert_eq!(a.shape, b.shape);
    let mut data = out;
    data.clear();
    data.reserve(a.data.len());
    // Pre-scale by 2^20 for precision (TFLite-style left shift).
    for (&qa, &qb) in a.data.iter().zip(&b.data) {
        let va = apply_multiplier(
            ((qa as i32) - a.qp.zero_point) << 20,
            p.ma.0,
            p.ma.1,
        );
        let vb = apply_multiplier(
            ((qb as i32) - b.qp.zero_point) << 20,
            p.mb.0,
            p.mb.1,
        );
        let v = crate::quant::scale::rounding_rshift(va + vb, 20)
            + p.out_qp.zero_point;
        data.push(v.clamp(p.clamp.0, p.clamp.1) as i8);
    }
    QTensor { shape: a.shape.clone(), data, qp: p.out_qp }
}

/// Global average pool over H,W.
pub fn gap(x: &QTensor, p: &GapParams, out: Vec<i8>) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let zp = x.qp.zero_point;
    let mut data = out;
    data.clear();
    data.reserve(n * c);
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0i32;
            for pix in 0..(h * w) {
                acc += x.data[(ni * h * w + pix) * c + ci] as i32 - zp;
            }
            // multiplier m already folds the 1/(h*w)
            let v = apply_multiplier(acc, p.m.0, p.m.1)
                + p.out_qp.zero_point;
            data.push(v.clamp(p.out_qp.qmin, p.out_qp.qmax) as i8);
        }
    }
    QTensor { shape: vec![n, c], data, qp: p.out_qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scale::{quantize_multiplier, QParams};

    fn qp_sym(t: f32) -> QParams {
        super::super::qtensor::to_i8_domain(QParams::symmetric_signed(t))
    }

    /// Build requant params mapping acc scale (s_in*s_w) to s_out.
    fn rq(s_in: f32, s_w: f32, s_out: f32) -> (i32, i32) {
        quantize_multiplier((s_in as f64 * s_w as f64) / s_out as f64)
    }

    fn layer(
        w_q: Vec<i8>,
        w_sums: Vec<i32>,
        bias_q: Vec<i32>,
        requant: Vec<(i32, i32)>,
        out_qp: QParams,
        clamp: (i32, i32),
    ) -> QLayer {
        QLayer {
            w_q: w_q.into(),
            w_sums,
            bias_q,
            requant,
            out_qp,
            clamp,
            w_scales: vec![1.0],
            packed: None,
            blocking: Default::default(),
        }
    }

    #[test]
    fn conv_1x1_identity_approx() {
        // y = 1.0 * x through a 1x1 conv with unit weight
        let in_qp = qp_sym(1.0);
        let x =
            QTensor::quantize(vec![1, 2, 2, 1], &[0.5, -0.25, 1.0, 0.0], in_qp);
        let w_t = 1.0f32;
        let w_qp = QParams::symmetric_signed(w_t);
        let w_q = vec![w_qp.quantize(1.0) as i8];
        let sums = vec![w_q[0] as i32];
        let out_qp = qp_sym(1.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let l = layer(w_q, sums, vec![0], req, out_qp, (out_qp.qmin, out_qp.qmax));
        let y = conv2d(&x, &l, 1, 1, 1, &mut OpCtx::default(), Vec::new());
        let d = y.dequantize();
        for (a, b) in [0.5, -0.25, 1.0, 0.0].iter().zip(&d) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn dwconv_matches_float_reference() {
        // 3x3 depthwise over a 4x4 single-channel ramp
        let xs: Vec<f32> = (0..16).map(|i| i as f32 / 8.0).collect();
        let in_qp = qp_sym(2.0);
        let x = QTensor::quantize(vec![1, 4, 4, 1], &xs, in_qp);
        let wf = [0.1f32, 0.2, 0.1, 0.0, 0.5, 0.0, -0.1, 0.0, -0.2];
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> =
            wf.iter().map(|&v| w_qp.quantize(v) as i8).collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let l = layer(w_q, vec![], vec![0], req, out_qp, (-127, 127));
        let y = dwconv2d(&x, &l, 3, 1, &mut OpCtx::default(), Vec::new());
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // float reference at centre pixel (1,1): full 3x3 support
        let xr = |r: usize, c: usize| xs[r * 4 + c];
        let mut want = 0.0;
        for ky in 0..3 {
            for kx in 0..3 {
                want += wf[ky * 3 + kx] * xr(ky, kx);
            }
        }
        let got = y.dequantize()[4 + 1];
        assert!((got - want).abs() < 0.05, "{got} vs {want}");
    }

    #[test]
    fn dwconv_threaded_matches_serial() {
        let in_qp = qp_sym(2.0);
        let xs = crate::util::prop::f32s(5, 2 * 7 * 7 * 3, -2.0, 2.0);
        let x = QTensor::quantize(vec![2, 7, 7, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = crate::util::prop::f32s(6, 9 * 3, -0.5, 0.5)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 3];
        let l = layer(w_q, vec![], vec![3, -2, 0], req, out_qp, (-127, 127));
        let base =
            dwconv2d(&x, &l, 3, 2, &mut OpCtx::default(), Vec::new());
        for t in [2usize, 5, 16] {
            let y = dwconv2d(&x, &l, 3, 2, &mut OpCtx::with_threads(t), Vec::new());
            assert_eq!(base.shape, y.shape, "t={t}");
            assert_eq!(base.data, y.data, "t={t}");
        }
    }

    #[test]
    fn add_rescales_operands() {
        let qa = qp_sym(1.0);
        let qb = qp_sym(2.0);
        let qo = qp_sym(3.0);
        let a = QTensor::quantize(vec![4], &[0.5, -0.5, 1.0, 0.0], qa);
        let b = QTensor::quantize(vec![4], &[1.5, 0.5, -1.0, 2.0], qb);
        let p = AddParams {
            ma: quantize_multiplier(qa.scale as f64 / qo.scale as f64),
            mb: quantize_multiplier(qb.scale as f64 / qo.scale as f64),
            out_qp: qo,
            clamp: (qo.qmin, qo.qmax),
        };
        let y = add(&a, &b, &p, Vec::new());
        let d = y.dequantize();
        for (want, got) in [2.0f32, 0.0, 0.0, 2.0].iter().zip(&d) {
            assert!((want - got).abs() < 0.06, "{want} vs {got}");
        }
    }

    #[test]
    fn gap_averages() {
        let qi = qp_sym(4.0);
        let qo = qp_sym(4.0);
        let xs = vec![1.0f32, 2.0, 3.0, 4.0];
        let x = QTensor::quantize(vec![1, 2, 2, 1], &xs, qi);
        let p = GapParams {
            m: quantize_multiplier(qi.scale as f64 / qo.scale as f64 / 4.0),
            out_qp: qo,
        };
        let y = gap(&x, &p, Vec::new());
        let d = y.dequantize();
        assert!((d[0] - 2.5).abs() < 0.05, "{}", d[0]);
    }

    #[test]
    fn relu6_clamp_fused() {
        // conv output clamped at quantized 6.0
        let in_qp = qp_sym(10.0);
        let x = QTensor::quantize(vec![1, 1, 1, 1], &[8.0], in_qp);
        let w_qp = QParams::symmetric_signed(1.0);
        let w_q = vec![w_qp.quantize(1.0) as i8];
        let out_qp = super::super::qtensor::to_i8_domain(
            QParams::symmetric_unsigned(8.0),
        );
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale)];
        let hi = out_qp.zero_point + (6.0 / out_qp.scale).round() as i32;
        let sums = vec![w_q[0] as i32];
        let l = layer(w_q, sums, vec![0], req, out_qp, (out_qp.zero_point, hi));
        let y = conv2d(&x, &l, 1, 1, 1, &mut OpCtx::default(), Vec::new());
        let d = y.dequantize()[0];
        assert!((d - 6.0).abs() < 0.05, "{d}");
    }

    #[test]
    fn conv_packed_matches_unpacked_across_isa_and_threads() {
        // the exported-model path (prepacked SIMD kernels) must be
        // bit-exact with the ad-hoc unpacked path
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(21, 2 * 6 * 6 * 3, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 6, 6, 3], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.6);
        let w_q: Vec<i8> = crate::util::prop::f32s(22, 9 * 3 * 5, -0.6, 0.6)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 27, 5);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 5];
        let plain =
            layer(w_q.clone(), sums, vec![1, -2, 3, 0, 7], req, out_qp, (-127, 127));
        let mut packed = plain.clone();
        packed.packed =
            Some(crate::int8::kernels::PackedWeights::pack(&w_q, 27, 5));
        let base =
            conv2d(&x, &plain, 3, 1, &mut OpCtx::default(), Vec::new());
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = conv2d(&x, &packed, 3, 1, &mut ctx, Vec::new());
                assert_eq!(base.shape, y.shape, "t={t} {}", isa.name());
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn dwconv_isa_sweep_matches_scalar() {
        let in_qp = qp_sym(2.0);
        // 5 channels straddles every vector width remainder
        let xs = crate::util::prop::f32s(25, 2 * 7 * 7 * 5, -2.0, 2.0);
        let x = QTensor::quantize(vec![2, 7, 7, 5], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.5);
        let w_q: Vec<i8> = crate::util::prop::f32s(26, 9 * 5, -0.5, 0.5)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 5];
        let l = layer(w_q, vec![], vec![3, -2, 0, 1, -1], req, out_qp, (-127, 127));
        let mut sctx = OpCtx { isa: Isa::Scalar, ..Default::default() };
        let base = dwconv2d(&x, &l, 3, 2, &mut sctx, Vec::new());
        for isa in Isa::available() {
            for t in [1usize, 2, 8] {
                let mut ctx = OpCtx::with_threads(t);
                ctx.isa = isa;
                let y = dwconv2d(&x, &l, 3, 2, &mut ctx, Vec::new());
                assert_eq!(base.data, y.data, "t={t} {}", isa.name());
            }
        }
    }

    #[test]
    fn conv_reuses_stale_scratch_and_out() {
        let in_qp = qp_sym(1.0);
        let xs = crate::util::prop::f32s(11, 2 * 5 * 5 * 2, -1.0, 1.0);
        let x = QTensor::quantize(vec![2, 5, 5, 2], &xs, in_qp);
        let w_qp = QParams::symmetric_signed(0.7);
        let w_q: Vec<i8> = crate::util::prop::f32s(12, 9 * 2 * 3, -0.7, 0.7)
            .iter()
            .map(|&v| w_qp.quantize(v) as i8)
            .collect();
        let sums = crate::int8::gemm::col_sums(&w_q, 18, 3);
        let out_qp = qp_sym(2.0);
        let req = vec![rq(in_qp.scale, w_qp.scale, out_qp.scale); 3];
        let l = layer(w_q, sums, vec![1, 2, 3], req, out_qp, (-127, 127));
        let mut ctx = OpCtx::with_threads(2);
        let first = conv2d(&x, &l, 3, 1, &mut ctx, Vec::new());
        // second call reuses ctx scratch and a dirty recycled buffer
        let dirty = vec![77i8; 3];
        let second = conv2d(&x, &l, 3, 1, &mut ctx, dirty);
        assert_eq!(first.shape, second.shape);
        assert_eq!(first.data, second.data);
    }
}
