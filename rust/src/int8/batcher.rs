//! Dynamic micro-batching for the serving stack (DESIGN.md §9).
//!
//! Concurrent [`crate::int8::serve::Int8Engine`] requests are collected
//! into *micro-batches* so the engine executes one well-sharded plan run
//! instead of many contending batch-1 runs. The protocol is
//! leader-elected assembly:
//!
//! * the first request of a batch becomes the **leader**: it takes a row
//!   buffer from the batcher's arena, quantizes its input into row 0 and
//!   publishes the open assembly;
//! * **followers** append their quantized rows in place (no per-request
//!   `QTensor` allocation, no concat copy) and block on the batch's
//!   `ready` [`Notify`] cell;
//! * the leader waits — at most [`BatchOptions::max_wait_us`] — on the
//!   batch's `full` cell; the follower that fills row `max_batch − 1`
//!   seals the assembly and wakes it early;
//! * the leader executes the sealed batch through the engine's ordinary
//!   sharded plan path (on the persistent worker pool), stores the
//!   dequantized logits, and wakes every follower, which **demux** their
//!   own logits rows by the row index they were assigned at join time.
//!
//! Bit-exactness: images are independent through every kernel of the
//! plan (DESIGN.md §8.3), so row *i* of a micro-batch is byte-identical
//! to running request *i* alone — any coalescing schedule returns the
//! same bytes as the unbatched path and as `run_quant_ref`
//! (`rust/tests/serve_stress.rs` hammers exactly this).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::threads::Notify;

use super::plan::Arena;

/// Micro-batching knobs of [`crate::int8::serve::EngineOptions`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchOptions {
    /// Rows per micro-batch at which assembly seals immediately.
    /// Values below 2 disable the batcher (a 1-row batch cannot
    /// coalesce anything).
    pub max_batch: usize,
    /// How long the leader waits for followers before executing a
    /// partial batch. The deadline bounds added latency: a lone request
    /// pays at most this much over the unbatched path.
    pub max_wait_us: u64,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { max_batch: 16, max_wait_us: 200 }
    }
}

/// Point-in-time batcher counters for `/stats`-style introspection
/// (`crate::net::server` serializes these): cumulative totals plus the
/// `waiting` gauge of requests currently inside [`Batcher::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherStats {
    pub requests: u64,
    /// Batches executed; mean occupancy is `rows / batches`.
    pub batches: u64,
    /// Rows executed across all batches.
    pub rows: u64,
    /// Requests currently assembling, executing or demuxing.
    pub waiting: u64,
}

/// What executing one sealed micro-batch produced: the dequantized
/// logits for all rows, the class count to demux by, and — when the
/// executor only borrowed the assembled rows (the sharded path) — the
/// row buffer handed back for the batcher's arena.
pub struct BatchOutput {
    pub logits: Vec<f32>,
    pub classes: usize,
    pub reclaimed: Option<Vec<i8>>,
}

/// One assembling/executing micro-batch. `state` guards the rows and
/// the result; the two [`Notify`] cells carry the only cross-request
/// wakeups (follower→leader `full`, leader→followers `ready`).
struct MicroBatch {
    state: Mutex<Assembly>,
    full: Notify,
    ready: Notify,
}

struct Assembly {
    /// Quantized input rows, `n * per_img` i8 values, written in place
    /// by joining requests.
    rows: Vec<i8>,
    /// Rows filled so far.
    n: usize,
    /// No further joins; set by the filling follower or by the leader's
    /// deadline/execution path.
    sealed: bool,
    /// Execution result: flat logits + class count, or the error text
    /// (`anyhow::Error` is not `Clone`, and every waiter needs a copy).
    out: Option<std::result::Result<(Vec<f32>, usize), String>>,
}

/// The engine's micro-batch collector. One instance per
/// [`crate::int8::serve::Int8Engine`]; requests enter through
/// [`Batcher::submit`].
pub struct Batcher {
    opts: BatchOptions,
    per_img: usize,
    /// The currently open assembly, if any. Join order: this lock, then
    /// the assembly's `state` lock (never the reverse), so joins and
    /// the leader's unpublish cannot deadlock.
    current: Mutex<Option<Arc<MicroBatch>>>,
    /// Recycled row buffers; executed batches hand theirs back.
    arena: Mutex<Arena<i8>>,
    requests: AtomicU64,
    batches: AtomicU64,
    rows_run: AtomicU64,
    /// Requests currently inside [`Batcher::submit`] (gauge).
    waiting: AtomicU64,
}

/// RAII decrement for the `waiting` gauge: submit's early returns and
/// error paths all pass through it.
struct DecOnDrop<'a>(&'a AtomicU64);

impl Drop for DecOnDrop<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Batcher {
    /// Collector for inputs of `per_img` i8 values per row.
    pub fn new(per_img: usize, opts: BatchOptions) -> Self {
        Batcher {
            opts,
            per_img,
            current: Mutex::new(None),
            arena: Mutex::new(Arena::default()),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows_run: AtomicU64::new(0),
            waiting: AtomicU64::new(0),
        }
    }

    /// Configured knobs.
    pub fn options(&self) -> BatchOptions {
        self.opts
    }

    /// `(requests, batches executed, rows executed)` so far — mean
    /// occupancy is `rows / batches`.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.rows_run.load(Ordering::Relaxed),
        )
    }

    /// Point-in-time counter snapshot (the tuple [`Batcher::stats`]
    /// plus the `waiting` gauge), for `/stats`-style introspection.
    pub fn snapshot(&self) -> BatcherStats {
        let (requests, batches, rows) = self.stats();
        BatcherStats {
            requests,
            batches,
            rows,
            waiting: self.waiting.load(Ordering::Relaxed),
        }
    }

    /// Submit a `k`-row request (`1 ≤ k ≤ max_batch`; the serving layer
    /// routes larger requests straight to the unbatched path). `write`
    /// quantizes the request's rows into the assembly buffer; `exec`
    /// runs a sealed batch (called on exactly one request's thread per
    /// batch — the leader's). Returns this request's dequantized logits
    /// rows, bit-exact with running the request alone.
    pub fn submit(
        &self,
        k: usize,
        write: impl FnOnce(&mut Vec<i8>),
        exec: impl FnOnce(Vec<i8>, usize) -> Result<BatchOutput>,
    ) -> Result<Vec<f32>> {
        debug_assert!(k >= 1 && k <= self.opts.max_batch);
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.waiting.fetch_add(1, Ordering::Relaxed);
        let _waiting = DecOnDrop(&self.waiting);
        let mut write = Some(write);
        let (mb, row0, leader) = self.join(k, &mut write);
        if leader {
            self.lead(&mb, exec);
        } else {
            mb.ready.wait();
        }
        let st = mb.state.lock().unwrap();
        match st.out.as_ref().expect("sealed batch stored a result") {
            Ok((logits, classes)) => {
                let lo = row0 * classes;
                let hi = (row0 + k) * classes;
                Ok(logits[lo..hi].to_vec())
            }
            Err(msg) => Err(anyhow::anyhow!("batched inference failed: {msg}")),
        }
    }

    /// Join the open assembly (appending `k` rows) or open a new one as
    /// its leader. Returns `(batch, first row index, is_leader)`.
    fn join(
        &self,
        k: usize,
        write: &mut Option<impl FnOnce(&mut Vec<i8>)>,
    ) -> (Arc<MicroBatch>, usize, bool) {
        let mut cur = self.current.lock().unwrap();
        if let Some(existing) = cur.clone() {
            let mut st = existing.state.lock().unwrap();
            if !st.sealed && st.n + k <= self.opts.max_batch {
                let row0 = st.n;
                (write.take().expect("row writer used once"))(&mut st.rows);
                debug_assert_eq!(st.rows.len(), (row0 + k) * self.per_img);
                st.n += k;
                let filled = st.n >= self.opts.max_batch;
                if filled {
                    st.sealed = true;
                }
                drop(st);
                if filled {
                    *cur = None;
                    existing.full.notify();
                }
                return (existing, row0, false);
            }
            // Sealed, or no room for k rows: detach it (sealing first if
            // the leader hasn't yet, so it executes now) and lead a
            // fresh assembly.
            let newly_sealed = !st.sealed;
            if newly_sealed {
                st.sealed = true;
            }
            drop(st);
            *cur = None;
            if newly_sealed {
                existing.full.notify();
            }
        }
        let mut rows = self.arena.lock().unwrap().take();
        rows.reserve(self.opts.max_batch * self.per_img);
        (write.take().expect("row writer used once"))(&mut rows);
        debug_assert_eq!(rows.len(), k * self.per_img);
        let sealed = k >= self.opts.max_batch;
        let mb = Arc::new(MicroBatch {
            state: Mutex::new(Assembly { rows, n: k, sealed, out: None }),
            full: Notify::new(),
            ready: Notify::new(),
        });
        if !sealed {
            *cur = Some(Arc::clone(&mb));
        }
        (mb, 0, true)
    }

    /// Leader duty: wait out the assembly window, seal, unpublish,
    /// execute, store the result and wake the followers. Panics in
    /// `exec` still wake the followers (with an error) before
    /// propagating.
    fn lead(
        &self,
        mb: &Arc<MicroBatch>,
        exec: impl FnOnce(Vec<i8>, usize) -> Result<BatchOutput>,
    ) {
        let deadline =
            Instant::now() + Duration::from_micros(self.opts.max_wait_us);
        loop {
            if mb.state.lock().unwrap().sealed {
                break;
            }
            if !mb.full.wait_deadline(deadline) {
                break; // window elapsed; seal below
            }
        }
        {
            let mut st = mb.state.lock().unwrap();
            st.sealed = true; // idempotent (deadline path)
        }
        {
            // Unpublish so late arrivals open a fresh assembly. A
            // follower that raced ahead may already have replaced
            // `current` — only clear our own batch.
            let mut cur = self.current.lock().unwrap();
            if cur.as_ref().is_some_and(|c| Arc::ptr_eq(c, mb)) {
                *cur = None;
            }
        }
        let (rows, n) = {
            let mut st = mb.state.lock().unwrap();
            (std::mem::take(&mut st.rows), st.n)
        };
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows_run.fetch_add(n as u64, Ordering::Relaxed);
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || exec(rows, n),
        ));
        let (stored, panic) = match run {
            Ok(Ok(out)) => {
                if let Some(buf) = out.reclaimed {
                    self.arena.lock().unwrap().put(buf);
                }
                (Ok((out.logits, out.classes)), None)
            }
            Ok(Err(e)) => (Err(e.to_string()), None),
            Err(p) => (Err("batch execution panicked".to_string()), Some(p)),
        };
        {
            let mut st = mb.state.lock().unwrap();
            st.out = Some(stored);
        }
        mb.ready.notify();
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Identity "engine": logits = rows as f32, one class per element.
    fn echo_exec(
        per_img: usize,
    ) -> impl Fn(Vec<i8>, usize) -> Result<BatchOutput> {
        move |rows, n| {
            assert_eq!(rows.len(), n * per_img);
            Ok(BatchOutput {
                logits: rows.iter().map(|&v| v as f32).collect(),
                classes: per_img,
                reclaimed: Some(rows),
            })
        }
    }

    #[test]
    fn lone_request_executes_after_deadline() {
        let b = Batcher::new(
            3,
            BatchOptions { max_batch: 8, max_wait_us: 50 },
        );
        let out = b
            .submit(1, |rows| rows.extend_from_slice(&[1, 2, 3]), echo_exec(3))
            .unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        let (req, bat, rows) = b.stats();
        assert_eq!((req, bat, rows), (1, 1, 1));
        let snap = b.snapshot();
        assert_eq!(
            snap,
            BatcherStats { requests: 1, batches: 1, rows: 1, waiting: 0 }
        );
        // the row buffer came back to the arena
        assert_eq!(b.arena.lock().unwrap().pooled(), 1);
    }

    #[test]
    fn filling_request_seals_at_birth() {
        let b = Batcher::new(
            2,
            BatchOptions { max_batch: 2, max_wait_us: 1_000_000 },
        );
        // k == max_batch: must not wait out the huge window
        let t0 = Instant::now();
        let out = b
            .submit(2, |rows| rows.extend_from_slice(&[5, 6, 7, 8]), echo_exec(2))
            .unwrap();
        assert_eq!(out, vec![5.0, 6.0, 7.0, 8.0]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn concurrent_requests_coalesce_and_demux() {
        let b = Arc::new(Batcher::new(
            2,
            BatchOptions { max_batch: 4, max_wait_us: 20_000 },
        ));
        let mut outs = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for c in 0..8u8 {
                let b = Arc::clone(&b);
                handles.push(s.spawn(move || {
                    let base = 2 * c as i8;
                    b.submit(
                        1,
                        |rows| rows.extend_from_slice(&[base, base + 1]),
                        echo_exec(2),
                    )
                    .unwrap()
                }));
            }
            for h in handles {
                outs.push(h.join().unwrap());
            }
        });
        // every request got exactly its own rows back
        for (c, out) in outs.iter().enumerate() {
            let base = (2 * c) as f32;
            assert_eq!(out, &vec![base, base + 1.0], "client {c}");
        }
        let (req, bat, rows) = b.stats();
        assert_eq!(req, 8);
        assert_eq!(rows, 8);
        assert!(bat >= 2, "8 rows cannot fit one 4-row batch");
        assert!(bat <= 8);
    }

    #[test]
    fn exec_error_reaches_every_waiter() {
        let b = Batcher::new(
            1,
            BatchOptions { max_batch: 4, max_wait_us: 50 },
        );
        let err = b
            .submit(
                1,
                |rows| rows.push(0),
                |_rows, _n| anyhow::bail!("boom"),
            )
            .unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }

    #[test]
    fn oversized_join_seals_current_and_leads_fresh() {
        // A 3-row request over an assembly holding 2/4 rows must not
        // block forever: it seals the open batch and leads its own.
        let b = Arc::new(Batcher::new(
            1,
            BatchOptions { max_batch: 4, max_wait_us: 50_000 },
        ));
        std::thread::scope(|s| {
            let b2 = Arc::clone(&b);
            let first = s.spawn(move || {
                b2.submit(2, |rows| rows.extend_from_slice(&[1, 2]), echo_exec(1))
                    .unwrap()
            });
            // let the 2-row leader publish its assembly
            std::thread::sleep(Duration::from_millis(20));
            let big = b
                .submit(3, |rows| rows.extend_from_slice(&[7, 8, 9]), echo_exec(1))
                .unwrap();
            assert_eq!(big, vec![7.0, 8.0, 9.0]);
            assert_eq!(first.join().unwrap(), vec![1.0, 2.0]);
        });
        let (req, bat, rows) = b.stats();
        assert_eq!((req, rows), (2, 5));
        assert_eq!(bat, 2, "the big request must not join the open batch");
    }
}
