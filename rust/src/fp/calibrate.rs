//! Native calibration passes (DESIGN.md §7): run the plain FP32 program
//! over calibration batches and aggregate exactly what the AOT
//! `calib_stats` / `calib_hist` artifacts produce — per-site (min, max),
//! per-conv-channel (min, max) of the pre-activation output (feeding the
//! §3.3 DWS rescale), and per-site histograms over the calibrated ranges
//! (feeding [`CalibStats::apply_calibrator`]'s percentile/KL path).
//!
//! Images shard across the `FAT_THREADS` worker pool with one
//! [`StatsSink`]/[`HistSink`] per worker; min/max and histogram counts
//! are order-insensitive, so the merged statistics are deterministic for
//! every thread count.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::{Batcher, Split};
use crate::quant::calibrate::{CalibStats, MinMax};

use super::program::{FpProgram, FpState, Observer};

/// Calibration batch size of the native backend (the artifact path reads
/// its batch size from the manifest; the native executor is shape-agnostic).
pub const CALIB_BATCH: usize = 25;

/// Histogram bins of the native `calib_hist` pass (`CalibStats::site_hist`
/// documents 128-bin histograms; the calibrators only need density).
pub const HIST_BINS: usize = 128;

/// Per-worker min/max aggregation sink.
#[derive(Debug, Clone)]
pub struct StatsSink {
    pub minmax: Vec<MinMax>,
    pub channels: BTreeMap<String, Vec<MinMax>>,
}

impl StatsSink {
    pub fn new(num_sites: usize) -> Self {
        StatsSink {
            minmax: vec![MinMax::default(); num_sites],
            channels: BTreeMap::new(),
        }
    }
}

impl Observer for StatsSink {
    fn site(&mut self, site: usize, values: &[f32]) {
        let mm = &mut self.minmax[site];
        for &v in values {
            mm.update(v, v);
        }
    }

    fn channels(&mut self, node_id: &str, cout: usize, preact: &[f32]) {
        let entry = self
            .channels
            .entry(node_id.to_string())
            .or_insert_with(|| vec![MinMax::default(); cout]);
        for (i, &v) in preact.iter().enumerate() {
            entry[i % cout].update(v, v);
        }
    }
}

/// Per-worker histogram sink over fixed per-site ranges.
#[derive(Debug, Clone)]
pub struct HistSink {
    ranges: Vec<(f32, f32)>,
    pub hists: Vec<Vec<u32>>,
}

impl HistSink {
    pub fn new(stats: &CalibStats) -> Self {
        HistSink {
            ranges: stats
                .site_minmax
                .iter()
                .map(|mm| (mm.min, mm.max))
                .collect(),
            hists: vec![vec![0u32; HIST_BINS]; stats.site_minmax.len()],
        }
    }
}

impl Observer for HistSink {
    fn site(&mut self, site: usize, values: &[f32]) {
        let (lo, hi) = self.ranges[site];
        let span = hi - lo;
        let h = &mut self.hists[site];
        if span.is_nan() || span <= 0.0 {
            h[0] += values.len() as u32;
            return;
        }
        let scale = HIST_BINS as f32 / span;
        for &v in values {
            let b = ((v - lo) * scale) as usize;
            h[b.min(HIST_BINS - 1)] += 1;
        }
    }

    fn channels(&mut self, _node_id: &str, _cout: usize, _preact: &[f32]) {}
}

/// Run one observed batch, sharding images across `threads` workers with
/// one sink per worker; returns the per-worker sinks in shard order.
fn observe_batch<S>(
    prog: &FpProgram,
    xd: &[f32],
    n: usize,
    threads: usize,
    mk: impl Fn() -> S + Sync,
) -> Result<Vec<S>>
where
    S: Observer + Send,
{
    let per = prog.input_len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let t = threads.max(1).min(n);
    let chunk = n.div_ceil(t);
    let shards = n.div_ceil(chunk);
    // One sink cell per shard; image ranges fan out over the persistent
    // worker pool (util::threads::pool).
    let mut cells: Vec<Option<Result<S>>> = (0..shards).map(|_| None).collect();
    crate::util::threads::pool().run_chunks(&mut cells, 1, |wi, cell| {
        let i0 = wi * chunk;
        let i1 = (i0 + chunk).min(n);
        let mut sink = mk();
        let mut st = FpState::default();
        let mut r = Ok(());
        for i in i0..i1 {
            let img = &xd[i * per..(i + 1) * per];
            match prog.run_image(img, &mut st, Some(&mut sink)) {
                Ok(logits) => st.recycle(logits.data),
                Err(e) => {
                    r = Err(e);
                    break;
                }
            }
        }
        cell[0] = Some(r.map(|()| sink));
    });
    cells
        .into_iter()
        .map(|c| c.expect("pool shard ran"))
        .collect()
}

/// Native `calib_stats` pass: per-site and per-channel (min, max) over
/// `images` training images (values below one batch round up to a full
/// batch, like the artifact path).
pub fn calib_stats(
    prog: &FpProgram,
    images: usize,
    threads: usize,
) -> Result<CalibStats> {
    let bs = CALIB_BATCH;
    let indices: Vec<u64> = (0..images.max(bs) as u64).collect();
    let batcher = Batcher::new(Split::Train, indices, bs);
    let mut stats = CalibStats::new(prog.num_sites);
    for (x, _) in batcher.epoch_iter(0) {
        let n = x.shape[0];
        let sinks = observe_batch(prog, x.as_f32()?, n, threads, || {
            StatsSink::new(prog.num_sites)
        })?;
        for sink in sinks {
            for (dst, src) in stats.site_minmax.iter_mut().zip(&sink.minmax)
            {
                dst.update(src.min, src.max);
            }
            for (node, mms) in sink.channels {
                let entry = stats
                    .channel_minmax
                    .entry(node)
                    .or_insert_with(|| vec![MinMax::default(); mms.len()]);
                for (dst, src) in entry.iter_mut().zip(&mms) {
                    dst.update(src.min, src.max);
                }
            }
        }
        stats.batches += 1;
    }
    Ok(stats)
}

/// Native `calib_hist` pass: per-site histograms (128 bins spanning each
/// site's calibrated range) over `images` training images.
pub fn calib_hist(
    prog: &FpProgram,
    stats: &CalibStats,
    images: usize,
    threads: usize,
) -> Result<Vec<Vec<u32>>> {
    let bs = CALIB_BATCH;
    let indices: Vec<u64> = (0..images.max(bs) as u64).collect();
    let batcher = Batcher::new(Split::Train, indices, bs);
    let mut hists = vec![vec![0u32; HIST_BINS]; prog.num_sites];
    for (x, _) in batcher.epoch_iter(0) {
        let n = x.shape[0];
        let sinks = observe_batch(prog, x.as_f32()?, n, threads, || {
            HistSink::new(stats)
        })?;
        for sink in sinks {
            for (dst, src) in hists.iter_mut().zip(&sink.hists) {
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
    }
    Ok(hists)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn stats_cover_every_site_and_are_thread_invariant() {
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let s1 = calib_stats(&prog, 25, 1).unwrap();
        let s4 = calib_stats(&prog, 25, 4).unwrap();
        assert_eq!(s1.site_minmax.len(), sites.sites.len());
        assert_eq!(s1.batches, 1);
        for (a, b) in s1.site_minmax.iter().zip(&s4.site_minmax) {
            assert!(a.min <= a.max);
            assert_eq!(a.min.to_bits(), b.min.to_bits());
            assert_eq!(a.max.to_bits(), b.max.to_bits());
        }
        // input site spans the synth pixel range, unsigned sites >= 0
        let input_mm = &s1.site_minmax[0];
        assert!(input_mm.min >= 0.0 && input_mm.max <= 3.0);
        // per-channel stats exist for every conv-like (non-dense) node
        for cs in &sites.channel_stats {
            let ch = s1.channel_minmax.get(&cs.id).unwrap();
            assert_eq!(ch.len(), cs.channels, "{}", cs.id);
        }
    }

    #[test]
    fn hists_count_every_observed_value() {
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats = calib_stats(&prog, 25, 2).unwrap();
        let hists = calib_hist(&prog, &stats, 25, 2).unwrap();
        assert_eq!(hists.len(), sites.sites.len());
        // every site histogram holds one count per observed value:
        // 25 images x site size; the input site has 32*32*3 values/img
        let total: u64 = hists[0].iter().map(|&c| c as u64).sum();
        assert_eq!(total, 25 * 32 * 32 * 3);
    }
}
