//! Planned FP32 graph executor (DESIGN.md §7).
//!
//! An [`FpProgram`] is the float twin of the int8 engine's `QModel`: the
//! folded graph is compiled **once** into an
//! [`ExecPlan`]`<`[`FpNode`]`>` — the same topological schedule,
//! liveness-based buffer slots and recycled [`Arena`] the int8 plan
//! uses, instantiated at `f32` — and then executed per image with no
//! name lookups on the hot path. Relu/relu6 nodes compile to nothing:
//! their activation is fused into the producing step ([`Act`]), exactly
//! mirroring how the int8 exporter fuses the clamp into the producer's
//! requantization.
//!
//! Every step knows its **effective quant site** (the paper's eq. 4–9
//! insertion points): a plain program reports site values to an
//! [`Observer`] (native calibration), and a program compiled with
//! per-site [`QParams`] applies the fake-quant transfer function at each
//! site (the native quantized forward). Batches shard across the
//! `FAT_THREADS` worker pool image-by-image; images are independent, so
//! every thread count is bit-exact.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::int8::plan::{Arena, ExecPlan};
use crate::model::store::SitesJson;
use crate::model::{GraphDef, Op};
use crate::quant::scale::QParams;
use crate::tensor::Tensor;

/// Activation fused into a compute step (the relu/relu6 node that is the
/// step's sole consumer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Relu6,
}

impl Act {
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Relu6 => v.clamp(0.0, 6.0),
        }
    }
}

/// Parameters of one conv-like FP32 layer. Weight layout matches the
/// folded `.fatw` tensors: conv `(k, k, cin, cout)` row-major, dwconv
/// `(k, k, ch)`, dense `(cin, cout)`.
#[derive(Debug, Clone)]
pub struct FpLayer {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
}

/// Op payload of one scheduled FP32 step.
#[derive(Debug, Clone)]
pub enum FpKind {
    Conv(FpLayer),
    DwConv(FpLayer),
    Dense(FpLayer),
    Add,
    Gap,
}

/// One scheduled FP32 node: op parameters + fused activation + the
/// effective quant site its output lands in (+ that site's fake-quant
/// parameters, for quantized programs).
#[derive(Debug, Clone)]
pub struct FpNode {
    pub kind: FpKind,
    pub act: Act,
    /// Index into the model's site list of this step's effective output
    /// site (the fused relu's site when the activation was folded in).
    pub site: usize,
    /// Fake-quant applied to the step output (`None` in plain programs).
    pub qp: Option<QParams>,
}

/// A dense float activation: shape (per image, no batch axis) + data.
#[derive(Debug, Clone, Default)]
pub struct FTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Per-worker execution state: slot table + recycled f32 arena. One
/// state serves one image at a time and is reused across images.
#[derive(Default)]
pub struct FpState {
    slots: Vec<Option<FTensor>>,
    arena: Arena<f32>,
}

impl FpState {
    /// Hand a dead buffer (e.g. consumed logits) back to the arena.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        self.arena.put(buf);
    }
}

/// Observation hook for calibration passes: called once per quant site
/// per image (post-activation values) and once per conv-like node
/// (pre-activation values, for per-channel stats).
pub trait Observer {
    fn site(&mut self, site: usize, values: &[f32]);
    fn channels(&mut self, node_id: &str, cout: usize, preact: &[f32]);
}

/// A compiled FP32 program: plan + input metadata.
#[derive(Debug, Clone)]
pub struct FpProgram {
    pub plan: ExecPlan<FpNode>,
    /// Input image shape `[h, w, c]`.
    pub input_shape: Vec<usize>,
    /// Site index of the model input.
    pub input_site: usize,
    /// Fake-quant applied to the input (`None` in plain programs).
    pub input_qp: Option<QParams>,
    pub num_sites: usize,
    pub num_classes: usize,
}

impl FpProgram {
    /// Compile `g` + folded weights into an executable FP32 program.
    /// `site_qp` (keyed by site id, as produced by
    /// `quant::export::site_qparams`) turns the program into a
    /// fake-quant forward; `None` compiles the plain FP32 teacher.
    pub fn compile(
        g: &GraphDef,
        weights: &BTreeMap<String, Tensor>,
        sites: &SitesJson,
        site_qp: Option<&BTreeMap<String, QParams>>,
    ) -> Result<FpProgram> {
        let site_idx: BTreeMap<&str, usize> = sites
            .sites
            .iter()
            .enumerate()
            .map(|(i, s)| (s.id.as_str(), i))
            .collect();
        let cons = g.consumers();
        let mut nodes: BTreeMap<String, FpNode> = BTreeMap::new();
        let mut input_shape = None;
        for n in &g.nodes {
            let kind = match n.op {
                Op::Input => {
                    input_shape = Some(
                        n.input_shape.clone().unwrap_or(vec![32, 32, 3]),
                    );
                    continue;
                }
                Op::Relu | Op::Relu6 => {
                    // The plan aliases relu outputs to their producer,
                    // so the activation must be fusable: reject graphs
                    // where the producer has other consumers too (the
                    // int8 engine has the same constraint).
                    let src = n.inputs.first().ok_or_else(|| {
                        anyhow::anyhow!("{}: relu without input", n.id)
                    })?;
                    anyhow::ensure!(
                        cons[src.as_str()].len() == 1,
                        "{}: relu over a multi-consumer value cannot be \
                         fused",
                        n.id
                    );
                    continue; // fused into producer
                }
                Op::Bn => anyhow::bail!(
                    "{}: bn survived graph folding",
                    n.id
                ),
                Op::Conv | Op::DwConv | Op::Dense => {
                    let w = weights
                        .get(&format!("{}.w", n.id))
                        .ok_or_else(|| {
                            anyhow::anyhow!("missing weight {}.w", n.id)
                        })?
                        .as_f32()?
                        .to_vec();
                    let b = weights
                        .get(&format!("{}.b", n.id))
                        .ok_or_else(|| {
                            anyhow::anyhow!("missing bias {}.b", n.id)
                        })?
                        .as_f32()?
                        .to_vec();
                    let (cin, cout) = match n.op {
                        Op::Conv => (n.cin, n.cout),
                        Op::DwConv => (n.ch, n.ch),
                        Op::Dense => (n.cin, n.cout),
                        _ => unreachable!(),
                    };
                    anyhow::ensure!(
                        b.len() == cout,
                        "{}: bias len {} != cout {cout}",
                        n.id,
                        b.len()
                    );
                    let l = FpLayer { w, b, k: n.k, stride: n.stride, cin, cout };
                    match n.op {
                        Op::Conv => FpKind::Conv(l),
                        Op::DwConv => FpKind::DwConv(l),
                        _ => FpKind::Dense(l),
                    }
                }
                Op::Add => FpKind::Add,
                Op::Gap => FpKind::Gap,
            };
            // Effective site + fused activation: the sole relu/relu6
            // consumer absorbs both (mirror of quant::export).
            let cs = &cons[n.id.as_str()];
            let (act, site_id) = if cs.len() == 1
                && matches!(cs[0].op, Op::Relu | Op::Relu6)
            {
                let a = if cs[0].op == Op::Relu { Act::Relu } else { Act::Relu6 };
                (a, cs[0].id.as_str())
            } else {
                (Act::None, n.id.as_str())
            };
            let site = *site_idx.get(site_id).ok_or_else(|| {
                anyhow::anyhow!(
                    "{}: effective site {site_id} is not a quant site",
                    n.id
                )
            })?;
            let qp = match site_qp {
                None => None,
                Some(m) => Some(*m.get(site_id).ok_or_else(|| {
                    anyhow::anyhow!("no site qparams for {site_id}")
                })?),
            };
            nodes.insert(n.id.clone(), FpNode { kind, act, site, qp });
        }
        let input_node = g
            .nodes
            .iter()
            .find(|n| n.op == Op::Input)
            .ok_or_else(|| anyhow::anyhow!("graph has no input node"))?;
        let input_site = *site_idx
            .get(input_node.id.as_str())
            .ok_or_else(|| anyhow::anyhow!("input is not a quant site"))?;
        let input_qp = match site_qp {
            None => None,
            Some(m) => Some(*m.get(input_node.id.as_str()).ok_or_else(
                || anyhow::anyhow!("no site qparams for the input"),
            )?),
        };
        let plan = ExecPlan::compile(g, nodes)?;
        Ok(FpProgram {
            plan,
            input_shape: input_shape
                .ok_or_else(|| anyhow::anyhow!("input node has no shape"))?,
            input_site,
            input_qp,
            num_sites: sites.sites.len(),
            num_classes: g.num_classes,
        })
    }

    /// Floats per input image.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Execute one image (`img` is `input_len()` HWC floats). Returns
    /// the logits tensor; hand its buffer back via [`FpState::recycle`]
    /// to avoid steady-state allocation.
    pub fn run_image(
        &self,
        img: &[f32],
        state: &mut FpState,
        mut obs: Option<&mut dyn Observer>,
    ) -> Result<FTensor> {
        anyhow::ensure!(
            img.len() == self.input_len(),
            "run_image: expected {} input floats, got {}",
            self.input_len(),
            img.len()
        );
        let plan = &self.plan;
        for s in state.slots.iter_mut() {
            if let Some(dead) = s.take() {
                state.arena.put(dead.data);
            }
        }
        state.slots.resize_with(plan.num_slots, || None);

        let mut xbuf = state.arena.take();
        xbuf.extend_from_slice(img);
        if let Some(qp) = self.input_qp {
            for v in xbuf.iter_mut() {
                *v = qp.fake_quant(*v);
            }
        }
        if let Some(o) = obs.as_mut() {
            o.site(self.input_site, &xbuf);
        }
        state.slots[plan.input_slot] =
            Some(FTensor { shape: self.input_shape.clone(), data: xbuf });

        for step in &plan.steps {
            let out_buf = state.arena.take();
            let p = &plan.params[step.param];
            let mut out = {
                let a = state.slots[step.a].as_ref().ok_or_else(|| {
                    anyhow::anyhow!("{}: input slot {} empty", step.id, step.a)
                })?;
                match &p.kind {
                    FpKind::Conv(l) => conv_fwd(a, l, out_buf),
                    FpKind::DwConv(l) => dwconv_fwd(a, l, out_buf),
                    FpKind::Dense(l) => dense_fwd(a, l, out_buf),
                    FpKind::Add => {
                        let bs = step.b.ok_or_else(|| {
                            anyhow::anyhow!(
                                "{}: add without 2nd input",
                                step.id
                            )
                        })?;
                        let b =
                            state.slots[bs].as_ref().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "{}: input slot {bs} empty",
                                    step.id
                                )
                            })?;
                        add_fwd(a, b, out_buf)
                    }
                    FpKind::Gap => gap_fwd(a, out_buf),
                }
            };
            if let Some(o) = obs.as_mut() {
                if let FpKind::Conv(l) | FpKind::DwConv(l) = &p.kind {
                    o.channels(&step.id, l.cout, &out.data);
                }
            }
            if p.act != Act::None {
                for v in out.data.iter_mut() {
                    *v = p.act.apply(*v);
                }
            }
            if let Some(qp) = p.qp {
                for v in out.data.iter_mut() {
                    *v = qp.fake_quant(*v);
                }
            }
            if let Some(o) = obs.as_mut() {
                o.site(p.site, &out.data);
            }
            for &f in &step.frees {
                if let Some(dead) = state.slots[f].take() {
                    state.arena.put(dead.data);
                }
            }
            state.slots[step.dst] = Some(out);
        }
        state.slots[plan.output_slot]
            .take()
            .ok_or_else(|| anyhow::anyhow!("plan produced no output"))
    }

    /// Run a float NHWC batch, sharding images across `threads` workers
    /// of the persistent pool (`util::threads::pool`), each with its own
    /// reusable [`FpState`]. Images are independent, so the stitched
    /// logits are bit-exact for every thread count. Returns
    /// `(n, num_classes)` f32 logits.
    pub fn run_batch(&self, x: &Tensor, threads: usize) -> Result<Tensor> {
        let xd = x.as_f32()?;
        anyhow::ensure!(
            x.shape.len() == 4
                && x.shape[1..] == self.input_shape[..],
            "run_batch: input shape {:?} != (n, {:?})",
            x.shape,
            self.input_shape
        );
        let n = x.shape[0];
        let per = self.input_len();
        let classes = self.num_classes;
        let mut out = vec![0f32; n * classes];
        if n == 0 {
            return Ok(Tensor::f32(vec![0, classes], out));
        }
        let t = threads.max(1).min(n);
        let chunk = n.div_ceil(t);
        let errs = std::sync::Mutex::new(Vec::new());
        crate::util::threads::pool().run_chunks(
            &mut out,
            chunk * classes,
            |wi, ochunk| {
                let i0 = wi * chunk;
                let mut st = FpState::default();
                for (j, orow) in ochunk.chunks_mut(classes).enumerate() {
                    let img = &xd[(i0 + j) * per..(i0 + j + 1) * per];
                    match self.run_image(img, &mut st, None) {
                        Ok(logits) => {
                            orow.copy_from_slice(&logits.data);
                            st.recycle(logits.data);
                        }
                        Err(e) => {
                            errs.lock().unwrap().push(e);
                            return;
                        }
                    }
                }
            },
        );
        if let Some(e) = errs.into_inner().unwrap().into_iter().next() {
            return Err(e);
        }
        Ok(Tensor::f32(vec![n, classes], out))
    }
}

/// SAME padding on one axis: `((o-1)*stride + k - size) / 2` (matches
/// the int8 engine's im2col and XLA).
#[inline]
pub fn same_pad(size: usize, k: usize, stride: usize) -> (usize, usize) {
    let o = size.div_ceil(stride);
    (o, (((o - 1) * stride + k).saturating_sub(size)) / 2)
}

pub(crate) fn conv_fwd(x: &FTensor, l: &FpLayer, out: Vec<f32>) -> FTensor {
    let (h, w, cin) = (x.shape[0], x.shape[1], x.shape[2]);
    debug_assert_eq!(cin, l.cin);
    let (oh, pad_top) = same_pad(h, l.k, l.stride);
    let (ow, pad_left) = same_pad(w, l.k, l.stride);
    let cout = l.cout;
    let mut data = out;
    data.clear();
    data.resize(oh * ow * cout, 0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let orow = &mut data[(oy * ow + ox) * cout..][..cout];
            orow.copy_from_slice(&l.b);
            for ky in 0..l.k {
                let iy = (oy * l.stride + ky) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..l.k {
                    let ix =
                        (ox * l.stride + kx) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xoff = (iy as usize * w + ix as usize) * cin;
                    for ci in 0..cin {
                        let xv = x.data[xoff + ci];
                        if xv == 0.0 {
                            continue;
                        }
                        let woff = ((ky * l.k + kx) * cin + ci) * cout;
                        let wrow = &l.w[woff..woff + cout];
                        for (o, &wv) in orow.iter_mut().zip(wrow) {
                            *o += xv * wv;
                        }
                    }
                }
            }
        }
    }
    FTensor { shape: vec![oh, ow, cout], data }
}

pub(crate) fn dwconv_fwd(x: &FTensor, l: &FpLayer, out: Vec<f32>) -> FTensor {
    let (h, w, c) = (x.shape[0], x.shape[1], x.shape[2]);
    debug_assert_eq!(c, l.cout);
    let (oh, pad_top) = same_pad(h, l.k, l.stride);
    let (ow, pad_left) = same_pad(w, l.k, l.stride);
    let mut data = out;
    data.clear();
    data.resize(oh * ow * c, 0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let orow = &mut data[(oy * ow + ox) * c..][..c];
            orow.copy_from_slice(&l.b);
            for ky in 0..l.k {
                let iy = (oy * l.stride + ky) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..l.k {
                    let ix =
                        (ox * l.stride + kx) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xoff = (iy as usize * w + ix as usize) * c;
                    let woff = (ky * l.k + kx) * c;
                    for ci in 0..c {
                        orow[ci] += x.data[xoff + ci] * l.w[woff + ci];
                    }
                }
            }
        }
    }
    FTensor { shape: vec![oh, ow, c], data }
}

pub(crate) fn dense_fwd(x: &FTensor, l: &FpLayer, out: Vec<f32>) -> FTensor {
    let cin = x.data.len();
    debug_assert_eq!(cin, l.cin);
    let cout = l.cout;
    let mut data = out;
    data.clear();
    data.extend_from_slice(&l.b);
    for (ci, &xv) in x.data.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &l.w[ci * cout..(ci + 1) * cout];
        for (o, &wv) in data.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }
    FTensor { shape: vec![cout], data }
}

pub(crate) fn add_fwd(a: &FTensor, b: &FTensor, out: Vec<f32>) -> FTensor {
    debug_assert_eq!(a.shape, b.shape);
    let mut data = out;
    data.clear();
    data.extend(a.data.iter().zip(&b.data).map(|(&x, &y)| x + y));
    FTensor { shape: a.shape.clone(), data }
}

pub(crate) fn gap_fwd(x: &FTensor, out: Vec<f32>) -> FTensor {
    let (h, w, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let hw = (h * w).max(1);
    let mut data = out;
    data.clear();
    data.resize(c, 0.0);
    for pix in 0..(h * w) {
        let row = &x.data[pix * c..(pix + 1) * c];
        for (o, &v) in data.iter_mut().zip(row) {
            *o += v;
        }
    }
    let inv = 1.0 / hw as f32;
    for o in data.iter_mut() {
        *o *= inv;
    }
    FTensor { shape: vec![c], data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    fn graph(json: &str) -> GraphDef {
        GraphDef::from_json(json).unwrap()
    }

    fn run_one(
        g: &GraphDef,
        w: &BTreeMap<String, Tensor>,
        img: &[f32],
    ) -> Vec<f32> {
        let sites = builtin::sites_of(g);
        let prog = FpProgram::compile(g, w, &sites, None).unwrap();
        let mut st = FpState::default();
        prog.run_image(img, &mut st, None).unwrap().data
    }

    #[test]
    fn dense_head_golden() {
        // input(1x1x2) -> gap -> dense(2->2): y = x @ W + b
        let g = graph(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[1,1,2]},
             {"id":"g","op":"gap","inputs":["input"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":2,"cout":2,"bias":true}]}"#,
        );
        let mut w = BTreeMap::new();
        w.insert(
            "d.w".into(),
            Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, -1.0]),
        );
        w.insert("d.b".into(), Tensor::f32(vec![2], vec![0.5, -0.5]));
        let y = run_one(&g, &w, &[2.0, 1.0]);
        // y0 = 2*1 + 1*3 + 0.5 = 5.5 ; y1 = 2*2 + 1*(-1) - 0.5 = 2.5
        assert_eq!(y, vec![5.5, 2.5]);
    }

    #[test]
    fn conv_1x1_and_relu_fuse_golden() {
        let g = graph(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[1,2,1]},
             {"id":"c","op":"conv","inputs":["input"],"k":1,"stride":1,"cin":1,"cout":2,"bias":true},
             {"id":"r","op":"relu","inputs":["c"]},
             {"id":"g","op":"gap","inputs":["r"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":2,"cout":2,"bias":true}]}"#,
        );
        let mut w = BTreeMap::new();
        w.insert("c.w".into(), Tensor::f32(vec![1, 1, 1, 2], vec![1.0, -1.0]));
        w.insert("c.b".into(), Tensor::f32(vec![2], vec![0.0, 0.0]));
        w.insert(
            "d.w".into(),
            Tensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]),
        );
        w.insert("d.b".into(), Tensor::f32(vec![2], vec![0.0, 0.0]));
        // pixels [3, -1]: conv ch0 = x, ch1 = -x; relu; gap
        // ch0: relu(3)=3, relu(-1)=0 -> mean 1.5 ; ch1: relu(-3)=0, relu(1)=1 -> 0.5
        let y = run_one(&g, &w, &[3.0, -1.0]);
        assert_eq!(y, vec![1.5, 0.5]);
    }

    #[test]
    fn conv_3x3_same_padding_golden() {
        // 2x2 single-channel image, 3x3 kernel of ones, stride 1:
        // each output = sum of in-image taps (SAME zero padding).
        let g = graph(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[2,2,1]},
             {"id":"c","op":"conv","inputs":["input"],"k":3,"stride":1,"cin":1,"cout":1,"bias":true},
             {"id":"g","op":"gap","inputs":["c"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":1,"cout":2,"bias":true}]}"#,
        );
        let mut w = BTreeMap::new();
        w.insert("c.w".into(), Tensor::f32(vec![3, 3, 1, 1], vec![1.0; 9]));
        w.insert("c.b".into(), Tensor::f32(vec![1], vec![0.0]));
        w.insert("d.w".into(), Tensor::f32(vec![1, 2], vec![1.0, 2.0]));
        w.insert("d.b".into(), Tensor::f32(vec![2], vec![0.0, 0.0]));
        // all four 3x3 windows cover the whole 2x2 image -> each out = 10
        let y = run_one(&g, &w, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(y, vec![10.0, 20.0]);
    }

    #[test]
    fn dwconv_add_relu6_golden() {
        let g = graph(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[1,1,2]},
             {"id":"dw","op":"dwconv","inputs":["input"],"k":1,"stride":1,"ch":2,"bias":true},
             {"id":"r","op":"relu6","inputs":["dw"]},
             {"id":"ad","op":"add","inputs":["r","input"]},
             {"id":"g","op":"gap","inputs":["ad"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":2,"cout":2,"bias":true}]}"#,
        );
        let mut w = BTreeMap::new();
        w.insert("dw.w".into(), Tensor::f32(vec![1, 1, 2], vec![4.0, -1.0]));
        w.insert("dw.b".into(), Tensor::f32(vec![2], vec![0.0, 0.0]));
        w.insert(
            "d.w".into(),
            Tensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]),
        );
        w.insert("d.b".into(), Tensor::f32(vec![2], vec![0.0, 0.0]));
        // x = [2, 3]: dw -> [8, -3]; relu6 -> [6, 0]; + x -> [8, 3]
        let y = run_one(&g, &w, &[2.0, 3.0]);
        assert_eq!(y, vec![8.0, 3.0]);
    }

    #[test]
    fn stride2_shapes_match_int8_engine_convention() {
        assert_eq!(same_pad(32, 3, 2), (16, 0));
        assert_eq!(same_pad(5, 3, 2), (3, 1));
        assert_eq!(same_pad(4, 3, 1), (4, 1));
    }

    #[test]
    fn batch_sharding_bit_exact_across_threads() {
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let xs = crate::util::prop::f32s(3, 5 * prog.input_len(), 0.0, 1.0);
        let x = Tensor::f32(vec![5, 32, 32, 3], xs);
        let base = prog.run_batch(&x, 1).unwrap();
        for t in [2usize, 3, 8] {
            let y = prog.run_batch(&x, t).unwrap();
            assert_eq!(base.shape, y.shape, "t={t}");
            let (a, b) = (base.as_f32().unwrap(), y.as_f32().unwrap());
            for i in 0..a.len() {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "t={t} logit {i}");
            }
        }
    }

    #[test]
    fn fake_quant_program_matches_reference_transfer() {
        // conv identity + known site params: program output must equal
        // applying QParams::fake_quant at every site by hand.
        let g = graph(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[1,1,1]},
             {"id":"g","op":"gap","inputs":["input"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":1,"cout":2,"bias":true}]}"#,
        );
        let mut w = BTreeMap::new();
        w.insert("d.w".into(), Tensor::f32(vec![1, 2], vec![1.0, -1.0]));
        w.insert("d.b".into(), Tensor::f32(vec![2], vec![0.0, 0.0]));
        let sites = builtin::sites_of(&g);
        let mut qp = BTreeMap::new();
        let q_in = QParams::symmetric_unsigned(2.0);
        let q_mid = QParams::symmetric_unsigned(2.0);
        let q_out = QParams::symmetric_signed(1.5);
        qp.insert("input".to_string(), q_in);
        qp.insert("g".to_string(), q_mid);
        qp.insert("d".to_string(), q_out);
        let prog = FpProgram::compile(&g, &w, &sites, Some(&qp)).unwrap();
        let mut st = FpState::default();
        let y = prog.run_image(&[1.234], &mut st, None).unwrap().data;
        let xh = q_in.fake_quant(1.234);
        let gh = q_mid.fake_quant(xh);
        assert_eq!(y[0].to_bits(), q_out.fake_quant(gh).to_bits());
        assert_eq!(y[1].to_bits(), q_out.fake_quant(-gh).to_bits());
    }
}
