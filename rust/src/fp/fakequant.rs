//! Native fake-quant forward (paper eq. 4–9, DESIGN.md §7).
//!
//! Builds an [`FpProgram`] whose weights went through the **same**
//! quantize→dequantize the int8 exporter applies
//! ([`export::quantize_weights`]) and whose quant sites apply the
//! transfer function of the **same** per-site parameters
//! ([`export::site_qparams`]). Sharing those two functions with
//! `quant::export` is what keeps the native fake-quant forward, the
//! trainer's objective and the exported integer model mutually
//! consistent — the property the artifact path got from lowering one
//! JAX source of truth.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::store::SitesJson;
use crate::model::{GraphDef, Op};
use crate::quant::calibrate::CalibStats;
use crate::quant::export::{self, QuantMode, Trained};
use crate::tensor::Tensor;

use super::program::FpProgram;

/// Fake-quantized weight map: every conv-like `.w` replaced by its
/// quantize→dequantize image under the mode's weight thresholds and the
/// trained per-layer scales (`w_a`). Biases stay float, as in the JAX
/// fake-quant forward.
pub fn fq_weights(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    mode: QuantMode,
    tr: &Trained,
) -> Result<BTreeMap<String, Tensor>> {
    let mut out = weights.clone();
    let ones = vec![1.0f32];
    for n in g.conv_like() {
        let key = format!("{}.w", n.id);
        let w = weights
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("missing weight {key}"))?;
        let cout = n.out_channels();
        let vector = mode.vector() && n.op != Op::Dense;
        let wa = tr.w_a.get(&n.id).unwrap_or(&ones);
        let (w_q, scales) = export::quantize_weights(w, cout, vector, wa)?;
        let deq: Vec<f32> = w_q
            .iter()
            .enumerate()
            .map(|(i, &q)| q as f32 * scales[i % scales.len()])
            .collect();
        out.insert(key, Tensor::f32(w.shape.clone(), deq));
    }
    Ok(out)
}

/// Compile the native fake-quant forward for `(mode, stats, trained)`.
pub fn quantized_program(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
) -> Result<FpProgram> {
    let site_qp = export::site_qparams(sites, stats, mode, tr);
    let fqw = fq_weights(g, weights, mode, tr)?;
    FpProgram::compile(g, &fqw, sites, Some(&site_qp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::program::FpState;
    use crate::model::builtin;

    #[test]
    fn fq_weights_snap_to_int8_grid() {
        let (g, _, w) = builtin::load("tiny_cnn").unwrap();
        let tr = Trained::identity(&g, QuantMode::SymVector, 4);
        let fq = fq_weights(&g, &w, QuantMode::SymVector, &tr).unwrap();
        for n in g.conv_like() {
            let key = format!("{}.w", n.id);
            let orig = w[&key].as_f32().unwrap();
            let q = fq[&key].as_f32().unwrap();
            assert_eq!(orig.len(), q.len());
            // quantization error bounded by half a grid step of the
            // per-tensor/per-channel threshold
            let t = crate::quant::thresholds::per_tensor_w_threshold(orig);
            for (a, b) in orig.iter().zip(q) {
                assert!((a - b).abs() <= t / 127.0, "{key}: {a} vs {b}");
            }
            // at least one weight actually moved (snapped to the grid)
            assert!(orig.iter().zip(q).any(|(a, b)| a != b), "{key}");
            // biases untouched
            let bkey = format!("{}.b", n.id);
            assert_eq!(
                w[&bkey].as_f32().unwrap(),
                fq[&bkey].as_f32().unwrap()
            );
        }
    }

    #[test]
    fn identity_alpha_one_is_plain_range_quant() {
        // with alpha = 1 the fake-quant forward equals quantizing at the
        // calibrated ranges; spot-check it runs and stays finite
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog0 = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats = crate::fp::calibrate::calib_stats(&prog0, 25, 2).unwrap();
        let tr = Trained::identity(&g, QuantMode::SymScalar, sites.sites.len());
        let prog =
            quantized_program(&g, &w, &sites, &stats, QuantMode::SymScalar, &tr)
                .unwrap();
        let (x, _) = crate::data::loader::batch(
            crate::data::Split::Val,
            &[0, 1, 2],
        );
        let y = prog.run_batch(&x, 2).unwrap();
        assert_eq!(y.shape, vec![3, 10]);
        assert!(y.as_f32().unwrap().iter().all(|v| v.is_finite()));
        // and it differs from the plain FP32 forward (quantization bites)
        let y0 = prog0.run_batch(&x, 2).unwrap();
        assert_ne!(y.as_f32().unwrap(), y0.as_f32().unwrap());
        // ...but not by much on a tame net
        let mut st = FpState::default();
        let one = prog
            .run_image(&x.as_f32().unwrap()[..prog.input_len()], &mut st, None)
            .unwrap();
        assert_eq!(one.data.len(), 10);
    }
}
