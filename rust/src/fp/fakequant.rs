//! Native fake-quant forward (paper eq. 4–9, DESIGN.md §7).
//!
//! Builds an [`FpProgram`] whose weights went through the **same**
//! quantize→dequantize the int8 exporter applies
//! ([`export::quantize_weights`]) and whose quant sites apply the
//! transfer function of the **same** per-site parameters
//! ([`export::site_qparams`]). Sharing those two functions with
//! `quant::export` is what keeps the native fake-quant forward, the
//! trainer's objective and the exported integer model mutually
//! consistent — the property the artifact path got from lowering one
//! JAX source of truth.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::store::SitesJson;
use crate::model::{GraphDef, Op};
use crate::quant::calibrate::CalibStats;
use crate::quant::export::{self, QuantKnobs, QuantMode, Trained};
use crate::tensor::Tensor;

use super::program::FpProgram;

/// Fake-quantized weight map: every conv-like `.w` replaced by its
/// quantize→dequantize image under the mode's weight thresholds and the
/// trained per-layer scales (`w_a`). Biases stay float, as in the JAX
/// fake-quant forward.
pub fn fq_weights(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    mode: QuantMode,
    tr: &Trained,
) -> Result<BTreeMap<String, Tensor>> {
    fq_weights_with(g, weights, mode, tr, QuantKnobs::default())
}

/// [`fq_weights`] under explicit export knobs: pow2 snaps the weight
/// scales and `w_bits = 4` quantizes on the `[-7, 7]` grid, exactly as
/// [`export::quantize_weights_with`] will at export time.
pub fn fq_weights_with(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    mode: QuantMode,
    tr: &Trained,
    knobs: QuantKnobs,
) -> Result<BTreeMap<String, Tensor>> {
    let mut out = weights.clone();
    let ones = vec![1.0f32];
    for n in g.conv_like() {
        let key = format!("{}.w", n.id);
        let w = weights
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("missing weight {key}"))?;
        let cout = n.out_channels();
        let vector = mode.vector() && n.op != Op::Dense;
        let wa = tr.w_a.get(&n.id).unwrap_or(&ones);
        let (w_q, scales) =
            export::quantize_weights_with(w, cout, vector, wa, knobs)?;
        let deq: Vec<f32> = w_q
            .iter()
            .enumerate()
            .map(|(i, &q)| q as f32 * scales[i % scales.len()])
            .collect();
        out.insert(key, Tensor::f32(w.shape.clone(), deq));
    }
    Ok(out)
}

/// Compile the native fake-quant forward for `(mode, stats, trained)`.
pub fn quantized_program(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
) -> Result<FpProgram> {
    quantized_program_with(
        g,
        weights,
        sites,
        stats,
        mode,
        tr,
        QuantKnobs::default(),
    )
}

/// [`quantized_program`] under explicit export knobs, sharing
/// [`export::site_qparams_with`] / [`export::quantize_weights_with`]
/// with the exporter — so the fake-quant forward models the deployed
/// pow2/int4 numerics bit-for-bit on the float side.
pub fn quantized_program_with(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
    knobs: QuantKnobs,
) -> Result<FpProgram> {
    let site_qp = export::site_qparams_with(sites, stats, mode, tr, knobs);
    let fqw = fq_weights_with(g, weights, mode, tr, knobs)?;
    FpProgram::compile(g, &fqw, sites, Some(&site_qp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::program::FpState;
    use crate::model::builtin;

    #[test]
    fn fq_weights_snap_to_int8_grid() {
        let (g, _, w) = builtin::load("tiny_cnn").unwrap();
        let tr = Trained::identity(&g, QuantMode::SymVector, 4);
        let fq = fq_weights(&g, &w, QuantMode::SymVector, &tr).unwrap();
        for n in g.conv_like() {
            let key = format!("{}.w", n.id);
            let orig = w[&key].as_f32().unwrap();
            let q = fq[&key].as_f32().unwrap();
            assert_eq!(orig.len(), q.len());
            // quantization error bounded by half a grid step of the
            // per-tensor/per-channel threshold
            let t = crate::quant::thresholds::per_tensor_w_threshold(orig);
            for (a, b) in orig.iter().zip(q) {
                assert!((a - b).abs() <= t / 127.0, "{key}: {a} vs {b}");
            }
            // at least one weight actually moved (snapped to the grid)
            assert!(orig.iter().zip(q).any(|(a, b)| a != b), "{key}");
            // biases untouched
            let bkey = format!("{}.b", n.id);
            assert_eq!(
                w[&bkey].as_f32().unwrap(),
                fq[&bkey].as_f32().unwrap()
            );
        }
    }

    #[test]
    fn fq_weights_with_knobs_follow_the_export_grid() {
        let (g, _, w) = builtin::load("tiny_cnn").unwrap();
        let tr = Trained::identity(&g, QuantMode::SymScalar, 4);

        // int4: per-tensor scale → at most 15 distinct dequantized
        // levels per layer (q ∈ [-7, 7])
        let fq4 = fq_weights_with(
            &g,
            &w,
            QuantMode::SymScalar,
            &tr,
            QuantKnobs { pow2: false, w_bits: 4 },
        )
        .unwrap();
        for n in g.conv_like() {
            let q = fq4[&format!("{}.w", n.id)].as_f32().unwrap();
            let mut vals: Vec<u32> = q.iter().map(|v| v.to_bits()).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(
                vals.len() <= 15,
                "{}: {} distinct int4 levels",
                n.id,
                vals.len()
            );
        }

        // pow2 snaps the scale, so the dequantized grid moves vs default
        let fq8 = fq_weights(&g, &w, QuantMode::SymScalar, &tr).unwrap();
        let fqp = fq_weights_with(
            &g,
            &w,
            QuantMode::SymScalar,
            &tr,
            QuantKnobs { pow2: true, w_bits: 8 },
        )
        .unwrap();
        let moved = g.conv_like().any(|n| {
            let key = format!("{}.w", n.id);
            fq8[&key].as_f32().unwrap() != fqp[&key].as_f32().unwrap()
        });
        assert!(moved, "pow2 snapping changed no weight grid");

        // bad knobs propagate as an error
        assert!(fq_weights_with(
            &g,
            &w,
            QuantMode::SymScalar,
            &tr,
            QuantKnobs { pow2: false, w_bits: 3 },
        )
        .is_err());
    }

    #[test]
    fn identity_alpha_one_is_plain_range_quant() {
        // with alpha = 1 the fake-quant forward equals quantizing at the
        // calibrated ranges; spot-check it runs and stays finite
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog0 = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats = crate::fp::calibrate::calib_stats(&prog0, 25, 2).unwrap();
        let tr = Trained::identity(&g, QuantMode::SymScalar, sites.sites.len());
        let prog =
            quantized_program(&g, &w, &sites, &stats, QuantMode::SymScalar, &tr)
                .unwrap();
        let (x, _) = crate::data::loader::batch(
            crate::data::Split::Val,
            &[0, 1, 2],
        );
        let y = prog.run_batch(&x, 2).unwrap();
        assert_eq!(y.shape, vec![3, 10]);
        assert!(y.as_f32().unwrap().iter().all(|v| v.is_finite()));
        // and it differs from the plain FP32 forward (quantization bites)
        let y0 = prog0.run_batch(&x, 2).unwrap();
        assert_ne!(y.as_f32().unwrap(), y0.as_f32().unwrap());
        // ...but not by much on a tame net
        let mut st = FpState::default();
        let one = prog
            .run_image(&x.as_f32().unwrap()[..prog.input_len()], &mut st, None)
            .unwrap();
        assert_eq!(one.data.len(), 10);
    }
}
