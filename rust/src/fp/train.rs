//! Native FAT threshold trainer (DESIGN.md §7).
//!
//! Implements the paper's fine-tuning objective without any AOT
//! artifact: per optimizer step, the **teacher** is the plain FP32
//! forward and the **student** is the fake-quant forward under the
//! current threshold scales; the loss is the RMSE between their logits
//! (unlabeled distillation, §4.1), and the gradients w.r.t. the scales
//! — `act_a` (symmetric α, eq. 12–13), `act_at`/`act_ar` (asymmetric
//! α_T/α_R, eq. 21–23) and per-layer `w_a` — are the analytic
//! straight-through construction that TQT (Jain et al., 1903.08066)
//! formalizes on top of the fake-quant scheme of Jacob et al.
//! (1712.05877):
//!
//! * inside the clip range, `∂x̂/∂T = (x̂ − x)/T` (the rounding
//!   residual divided by the threshold) and `∂x̂/∂x = 1`;
//! * at a clipped element, `∂x̂/∂T = x̂/T` (symmetric) or
//!   `∂x̂/∂left = 1`, `∂x̂/∂width ∈ {0, 1}` (asymmetric) and
//!   `∂x̂/∂x = 0`;
//! * `∂T/∂α = T_cal` through the empiric clip, with the parameters
//!   clamped back into their paper ranges after each Adam step so the
//!   clip never strands a gradient.
//!
//! Backprop through conv/dwconv/dense/add/gap is exact; Adam runs on
//! the threshold scales only (weights and biases are frozen, as in the
//! paper). Images of a batch shard across the `FAT_THREADS` worker pool
//! and per-worker gradient partial sums merge in shard order.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::finetune::{StepOut, TrainStep};
use crate::model::store::SitesJson;
use crate::model::{GraphDef, Op};
use crate::quant::calibrate::CalibStats;
use crate::quant::export::{QuantKnobs, QuantMode};
use crate::quant::scale::{snap_pow2, QParams};
use crate::quant::thresholds as th;
use crate::tensor::Tensor;

use super::program::{
    add_fwd, conv_fwd, dense_fwd, dwconv_fwd, gap_fwd, same_pad, Act, FpKind,
    FpLayer, FpProgram, FpState, FTensor,
};

/// Fine-tune batch size of the native backend.
pub const TRAIN_BATCH: usize = 25;

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Where a tape step reads its operand from.
#[derive(Debug, Clone, Copy)]
enum Src {
    Input,
    Step(usize),
}

/// Per-site calibration metadata.
#[derive(Debug, Clone, Copy)]
struct SiteMeta {
    unsigned: bool,
    t_l: f32,
    t_r: f32,
}

/// Per conv-like tape step: trainable-key id + static weight thresholds.
#[derive(Debug, Clone)]
struct WInfo {
    id: String,
    /// Calibrated weight thresholds (len 1 per-tensor, `cout` per-filter).
    t_cal: Vec<f32>,
}

/// Per-site quant parameters derived from the current trainables.
#[derive(Debug, Clone, Copy)]
enum SiteQ {
    Sym { qp: QParams, t: f32, t_cal: f32 },
    Asym { qp: QParams, width: f32, r: f32 },
}

impl SiteQ {
    #[inline]
    fn fq(&self, v: f32) -> f32 {
        match self {
            SiteQ::Sym { qp, .. } | SiteQ::Asym { qp, .. } => qp.fake_quant(v),
        }
    }
}

/// Per conv-like tape step under the current trainables: fake-quant
/// weights (as an [`FpLayer`], so the forward kernels run unchanged)
/// plus the scales/thresholds the backward pass needs.
struct WQuant {
    layer: FpLayer,
    sw: Vec<f32>,
    tw: Vec<f32>,
}

/// Per-worker gradient accumulator (summed over the worker's images).
struct Acc {
    sse: f64,
    da: Vec<f32>,
    dat: Vec<f32>,
    dar: Vec<f32>,
    /// dS/dŵ per conv-like tape step.
    dw: BTreeMap<usize, Vec<f32>>,
}

impl Acc {
    fn new(num_sites: usize) -> Self {
        Acc {
            sse: 0.0,
            da: vec![0.0; num_sites],
            dat: vec![0.0; num_sites],
            dar: vec![0.0; num_sites],
            dw: BTreeMap::new(),
        }
    }

    fn merge(&mut self, other: Acc) {
        self.sse += other.sse;
        for (d, s) in self.da.iter_mut().zip(&other.da) {
            *d += s;
        }
        for (d, s) in self.dat.iter_mut().zip(&other.dat) {
            *d += s;
        }
        for (d, s) in self.dar.iter_mut().zip(&other.dar) {
            *d += s;
        }
        for (i, sv) in other.dw {
            match self.dw.get_mut(&i) {
                Some(dv) => {
                    for (d, s) in dv.iter_mut().zip(&sv) {
                        *d += s;
                    }
                }
                None => {
                    self.dw.insert(i, sv);
                }
            }
        }
    }
}

/// The native threshold trainer: one per `(model, mode, knobs, stats)`
/// tuple.
pub struct Trainer {
    prog: FpProgram,
    mode: QuantMode,
    /// Export-time knobs the student mirrors (pow2 scales, int4 weight
    /// grid). See [`Trainer::new_with`].
    knobs: QuantKnobs,
    /// Weight quantization ceiling as f32: 127 (int8) or 7 (int4).
    w_qmax: f32,
    site_meta: Vec<SiteMeta>,
    /// Per tape step: weight-trainable info for conv-like steps.
    winfo: Vec<Option<WInfo>>,
    /// Per tape step: operand sources (resolved through the plan slots).
    tape: Vec<(Src, Option<Src>)>,
    /// Tape index producing the model output.
    out_idx: usize,
    threads: usize,
}

impl Trainer {
    pub fn new(
        g: &GraphDef,
        weights: &BTreeMap<String, Tensor>,
        sites: &SitesJson,
        stats: &CalibStats,
        mode: QuantMode,
        threads: usize,
    ) -> Result<Trainer> {
        Trainer::new_with(
            g,
            weights,
            sites,
            stats,
            mode,
            QuantKnobs::default(),
            threads,
        )
    }

    /// [`Trainer::new`] under explicit export knobs. With `knobs.pow2`
    /// the student's forward snaps every scale to a power of two (the
    /// same [`snap_pow2`] the exporter applies), so the thresholds
    /// fine-tune against the deployed shift-only numerics; the snap is
    /// a **straight-through rounding in the log2 domain** — the
    /// analytic backward keeps the unsnapped threshold as divisor
    /// (∂snap(s)/∂s ≈ 1 between snap points, exactly the TQT treatment
    /// of the log2 round, DESIGN.md §13). `knobs.w_bits = 4` puts the
    /// weight student on the `[-7, 7]` grid with scale `t/7`.
    pub fn new_with(
        g: &GraphDef,
        weights: &BTreeMap<String, Tensor>,
        sites: &SitesJson,
        stats: &CalibStats,
        mode: QuantMode,
        knobs: QuantKnobs,
        threads: usize,
    ) -> Result<Trainer> {
        knobs.validate()?;
        let prog = FpProgram::compile(g, weights, sites, None)?;
        anyhow::ensure!(
            stats.site_minmax.len() == sites.sites.len(),
            "trainer: {} calibrated sites for {} model sites",
            stats.site_minmax.len(),
            sites.sites.len()
        );
        let site_meta: Vec<SiteMeta> = sites
            .sites
            .iter()
            .zip(&stats.site_minmax)
            .map(|(s, mm)| SiteMeta {
                unsigned: s.unsigned,
                t_l: mm.min,
                t_r: mm.max,
            })
            .collect();

        // Resolve each step's operands through the slot table (slots are
        // recycled, so the resolution must happen in schedule order).
        let mut cur: Vec<Option<Src>> = vec![None; prog.plan.num_slots];
        cur[prog.plan.input_slot] = Some(Src::Input);
        let mut tape = Vec::with_capacity(prog.plan.steps.len());
        let mut winfo = Vec::with_capacity(prog.plan.steps.len());
        for (i, step) in prog.plan.steps.iter().enumerate() {
            let a = cur[step.a].ok_or_else(|| {
                anyhow::anyhow!("{}: unresolved input slot", step.id)
            })?;
            let b = match step.b {
                None => None,
                Some(bs) => Some(cur[bs].ok_or_else(|| {
                    anyhow::anyhow!("{}: unresolved 2nd input slot", step.id)
                })?),
            };
            tape.push((a, b));
            let p = &prog.plan.params[step.param];
            winfo.push(match &p.kind {
                FpKind::Conv(l) | FpKind::DwConv(l) | FpKind::Dense(l) => {
                    let vector = mode.vector() && step.op != Op::Dense;
                    let t_cal = if vector {
                        th::per_channel_w_thresholds(&l.w, l.cout)
                    } else {
                        vec![th::per_tensor_w_threshold(&l.w)]
                    };
                    Some(WInfo { id: step.id.clone(), t_cal })
                }
                _ => None,
            });
            cur[step.dst] = Some(Src::Step(i));
        }
        let out_idx = match cur[prog.plan.output_slot] {
            Some(Src::Step(i)) => i,
            _ => anyhow::bail!("model output is not produced by a step"),
        };
        Ok(Trainer {
            prog,
            mode,
            knobs,
            w_qmax: knobs.w_qmax() as f32,
            site_meta,
            winfo,
            tape,
            out_idx,
            threads: threads.max(1),
        })
    }

    /// The plain FP32 teacher program.
    pub fn program(&self) -> &FpProgram {
        &self.prog
    }

    /// Identity trainables for this mode, shaped exactly like the maps
    /// the artifact trainer produces: α = 1, α_T = 0, α_R = 1.
    /// (Delegates to [`identity_trainables`]; the trainer's per-step
    /// `winfo` lengths follow the same cout-or-1 grammar by
    /// construction.)
    pub fn init_trainables(&self) -> BTreeMap<String, Tensor> {
        identity_trainables(
            self.prog.num_sites,
            self.mode,
            self.winfo
                .iter()
                .flatten()
                .map(|wi| (wi.id.clone(), wi.t_cal.len())),
        )
    }

    /// Per-site quant parameters under the current trainables.
    fn site_quants(
        &self,
        act_a: &[f32],
        act_at: &[f32],
        act_ar: &[f32],
    ) -> Vec<SiteQ> {
        self.site_meta
            .iter()
            .enumerate()
            .map(|(i, sm)| {
                // Under pow2 knobs the *forward* qp snaps to the scale
                // grid the exporter ships; the backward keeps the
                // unsnapped threshold/width (straight-through rounding
                // in the log2 domain — see `Trainer::new_with`).
                let snap = |qp: QParams| {
                    if self.knobs.pow2 {
                        qp.snap_pow2()
                    } else {
                        qp
                    }
                };
                if self.mode.asym() {
                    let (left, width) = th::adjust_asym(
                        act_at[i], act_ar[i], sm.t_l, sm.t_r, sm.unsigned,
                    );
                    SiteQ::Asym {
                        qp: snap(QParams::asymmetric(left, width)),
                        width: width.max(1e-8),
                        r: sm.t_r - sm.t_l,
                    }
                } else {
                    let t_cal = th::sym_t_from_minmax(sm.t_l, sm.t_r);
                    let t = th::adjust_sym(act_a[i], t_cal);
                    let qp = if sm.unsigned {
                        QParams::symmetric_unsigned(t)
                    } else {
                        QParams::symmetric_signed(t)
                    };
                    SiteQ::Sym { qp: snap(qp), t: t.max(1e-12), t_cal }
                }
            })
            .collect()
    }

    /// Fake-quant weight layers under the current trainables (shared by
    /// all workers of one step).
    fn weight_quants(
        &self,
        tr: &BTreeMap<String, Tensor>,
    ) -> Result<Vec<Option<WQuant>>> {
        let mut out = Vec::with_capacity(self.winfo.len());
        for (i, wi) in self.winfo.iter().enumerate() {
            let Some(wi) = wi else {
                out.push(None);
                continue;
            };
            let p = &self.prog.plan.params[self.prog.plan.steps[i].param];
            let (FpKind::Conv(l) | FpKind::DwConv(l) | FpKind::Dense(l)) =
                &p.kind
            else {
                anyhow::bail!("{}: weight info on a non-layer step", wi.id);
            };
            let key = format!("w_a:{}", wi.id);
            let wa = tr
                .get(&key)
                .ok_or_else(|| anyhow::anyhow!("missing trainable {key}"))?
                .as_f32()?;
            anyhow::ensure!(
                wa.len() == wi.t_cal.len(),
                "{key}: expected {} scales, got {}",
                wi.t_cal.len(),
                wa.len()
            );
            let n = wa.len();
            let qmax = self.w_qmax;
            let tw: Vec<f32> = (0..n)
                .map(|c| th::adjust_sym(wa[c], wi.t_cal[c]).max(1e-12))
                .collect();
            // The snapped scale drives the forward (and the backward's
            // clip test, which must agree with the forward); `tw` stays
            // unsnapped as the STE divisor.
            let sw: Vec<f32> = tw
                .iter()
                .map(|t| {
                    let s = t / qmax;
                    if self.knobs.pow2 {
                        snap_pow2(s)
                    } else {
                        s
                    }
                })
                .collect();
            let what: Vec<f32> = l
                .w
                .iter()
                .enumerate()
                .map(|(j, &wv)| {
                    let si = if n == 1 { 0 } else { j % l.cout };
                    let s = sw[si];
                    let q = (wv / s).round_ties_even().clamp(-qmax, qmax);
                    q * s
                })
                .collect();
            out.push(Some(WQuant {
                layer: FpLayer {
                    w: what,
                    b: l.b.clone(),
                    k: l.k,
                    stride: l.stride,
                    cin: l.cin,
                    cout: l.cout,
                },
                sw,
                tw,
            }));
        }
        Ok(out)
    }

    /// One distillation batch: RMSE loss + analytic gradients w.r.t.
    /// every trainable, summed over the batch and already scaled to
    /// `∂loss/∂θ`. Returns `(loss, grads)`.
    pub fn loss_and_grads(
        &self,
        tr: &BTreeMap<String, Tensor>,
        x: &Tensor,
    ) -> Result<(f32, BTreeMap<String, Vec<f32>>)> {
        let s = self.prog.num_sites;
        let empty: Vec<f32> = Vec::new();
        let (act_a, act_at, act_ar);
        if self.mode.asym() {
            act_a = empty;
            act_at = take_vec(tr, "act_at", s)?;
            act_ar = take_vec(tr, "act_ar", s)?;
        } else {
            act_a = take_vec(tr, "act_a", s)?;
            act_at = vec![0.0; s];
            act_ar = vec![1.0; s];
        }
        let siteq = self.site_quants(&act_a, &act_at, &act_ar);
        let wq = self.weight_quants(tr)?;

        let xd = x.as_f32()?;
        let n = x.shape[0];
        let per = self.prog.input_len();
        anyhow::ensure!(
            xd.len() == n * per && n > 0,
            "train step: bad batch shape {:?}",
            x.shape
        );
        let t = self.threads.min(n).max(1);
        let chunk = n.div_ceil(t);
        let shards = n.div_ceil(chunk);
        // One result cell per shard; the persistent pool fans the image
        // ranges out and each worker accumulates its own partial `Acc`.
        let mut parts: Vec<Option<Result<Acc>>> =
            (0..shards).map(|_| None).collect();
        crate::util::threads::pool().run_chunks(&mut parts, 1, |wi, cell| {
            let i0 = wi * chunk;
            let i1 = (i0 + chunk).min(n);
            let mut acc = Acc::new(s);
            let mut st = FpState::default();
            let mut r = Ok(());
            for i in i0..i1 {
                let img = &xd[i * per..(i + 1) * per];
                if let Err(e) =
                    self.image_pass(img, &siteq, &wq, &mut st, &mut acc)
                {
                    r = Err(e);
                    break;
                }
            }
            cell[0] = Some(r.map(|()| acc));
        });
        let mut acc = Acc::new(s);
        for p in parts {
            acc.merge(p.expect("pool shard ran")?);
        }

        let total = (n * self.prog.num_classes) as f64;
        let loss = (acc.sse / total).sqrt();
        // L = sqrt(S/N)  =>  dL/dθ = dS/dθ / (2 L N); workers accumulated
        // dS/dθ (their backward seed was 2·error).
        let scale = if loss > 1e-12 {
            (1.0 / (2.0 * loss * total)) as f32
        } else {
            0.0
        };

        let mut grads: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        if self.mode.asym() {
            grads.insert(
                "act_at".to_string(),
                acc.dat.iter().map(|g| g * scale).collect(),
            );
            grads.insert(
                "act_ar".to_string(),
                acc.dar.iter().map(|g| g * scale).collect(),
            );
        } else {
            grads.insert(
                "act_a".to_string(),
                acc.da.iter().map(|g| g * scale).collect(),
            );
        }
        for (i, dwv) in &acc.dw {
            let (Some(wi), Some(wqi)) = (&self.winfo[*i], &wq[*i]) else {
                continue;
            };
            let p = &self.prog.plan.params[self.prog.plan.steps[*i].param];
            let (FpKind::Conv(l) | FpKind::DwConv(l) | FpKind::Dense(l)) =
                &p.kind
            else {
                continue;
            };
            let nsc = wi.t_cal.len();
            let mut ga = vec![0f32; nsc];
            for (j, &d) in dwv.iter().enumerate() {
                let si = if nsc == 1 { 0 } else { j % l.cout };
                let sw = wqi.sw[si];
                let tw = wqi.tw[si];
                let what = wqi.layer.w[j];
                let raw = l.w[j];
                let q = (raw / sw).round_ties_even();
                let dt = if !(-self.w_qmax..=self.w_qmax).contains(&q) {
                    what / tw
                } else {
                    (what - raw) / tw
                };
                ga[si] += d * dt * wi.t_cal[si];
            }
            for g in ga.iter_mut() {
                *g *= scale;
            }
            grads.insert(format!("w_a:{}", wi.id), ga);
        }
        Ok((loss as f32, grads))
    }

    /// Forward + backward for one image, accumulating dS/dθ into `acc`.
    fn image_pass(
        &self,
        img: &[f32],
        siteq: &[SiteQ],
        wq: &[Option<WQuant>],
        st: &mut FpState,
        acc: &mut Acc,
    ) -> Result<()> {
        let plan = &self.prog.plan;
        // Teacher: plain FP32 logits.
        let teacher = self.prog.run_image(img, st, None)?;

        // Student forward with caches (a = post-act pre-fq, y = post-fq).
        let x0 = FTensor {
            shape: self.prog.input_shape.clone(),
            data: img.to_vec(),
        };
        let in_q = &siteq[self.prog.input_site];
        let x0h = FTensor {
            shape: x0.shape.clone(),
            data: x0.data.iter().map(|&v| in_q.fq(v)).collect(),
        };
        let mut caches: Vec<(FTensor, FTensor)> =
            Vec::with_capacity(plan.steps.len());
        for (i, step) in plan.steps.iter().enumerate() {
            let p = &plan.params[step.param];
            let (a_src, b_src) = self.tape[i];
            let a_t = match a_src {
                Src::Input => &x0h,
                Src::Step(j) => &caches[j].1,
            };
            let mut z = match (&p.kind, &wq[i]) {
                (FpKind::Conv(_), Some(q)) => conv_fwd(a_t, &q.layer, Vec::new()),
                (FpKind::DwConv(_), Some(q)) => {
                    dwconv_fwd(a_t, &q.layer, Vec::new())
                }
                (FpKind::Dense(_), Some(q)) => {
                    dense_fwd(a_t, &q.layer, Vec::new())
                }
                (FpKind::Add, _) => {
                    let b_t = match b_src.ok_or_else(|| {
                        anyhow::anyhow!("{}: add without 2nd input", step.id)
                    })? {
                        Src::Input => &x0h,
                        Src::Step(j) => &caches[j].1,
                    };
                    add_fwd(a_t, b_t, Vec::new())
                }
                (FpKind::Gap, _) => gap_fwd(a_t, Vec::new()),
                _ => anyhow::bail!("{}: missing weight quant", step.id),
            };
            if p.act != Act::None {
                for v in z.data.iter_mut() {
                    *v = p.act.apply(*v);
                }
            }
            let sq = &siteq[p.site];
            let y = FTensor {
                shape: z.shape.clone(),
                data: z.data.iter().map(|&v| sq.fq(v)).collect(),
            };
            caches.push((z, y));
        }

        // Seed: dS/dlogit = 2 * (student - teacher).
        let student = &caches[self.out_idx].1;
        let mut seed = vec![0f32; student.data.len()];
        for (k, sd) in seed.iter_mut().enumerate() {
            let e = student.data[k] - teacher.data[k];
            acc.sse += (e as f64) * (e as f64);
            *sd = 2.0 * e;
        }
        st.recycle(teacher.data);

        let mut grads: Vec<Option<Vec<f32>>> = vec![None; plan.steps.len()];
        let mut g_input: Option<Vec<f32>> = None;
        grads[self.out_idx] = Some(seed);

        for i in (0..plan.steps.len()).rev() {
            let Some(gy) = grads[i].take() else { continue };
            let step = &plan.steps[i];
            let p = &plan.params[step.param];
            let (a_pre, y) = &caches[i];

            // Site fake-quant backward (STE + threshold grads).
            let mut ga = vec![0f32; gy.len()];
            site_bwd(
                &siteq[p.site],
                &a_pre.data,
                &y.data,
                &gy,
                &mut ga,
                p.site,
                acc,
            );

            // Fused activation backward (mask from the post-act cache).
            match p.act {
                Act::None => {}
                Act::Relu => {
                    for (g, &a) in ga.iter_mut().zip(&a_pre.data) {
                        if a <= 0.0 {
                            *g = 0.0;
                        }
                    }
                }
                Act::Relu6 => {
                    for (g, &a) in ga.iter_mut().zip(&a_pre.data) {
                        if a <= 0.0 || a >= 6.0 {
                            *g = 0.0;
                        }
                    }
                }
            }

            // Op backward.
            let (a_src, b_src) = self.tape[i];
            let a_t = match a_src {
                Src::Input => &x0h,
                Src::Step(j) => &caches[j].1,
            };
            match (&p.kind, &wq[i]) {
                (FpKind::Conv(_), Some(q)) => {
                    let dw = acc
                        .dw
                        .entry(i)
                        .or_insert_with(|| vec![0.0; q.layer.w.len()]);
                    let gx = grad_buf(
                        &mut grads,
                        &mut g_input,
                        a_src,
                        a_t.data.len(),
                    );
                    conv_bwd(a_t, &q.layer, &ga, gx, dw);
                }
                (FpKind::DwConv(_), Some(q)) => {
                    let dw = acc
                        .dw
                        .entry(i)
                        .or_insert_with(|| vec![0.0; q.layer.w.len()]);
                    let gx = grad_buf(
                        &mut grads,
                        &mut g_input,
                        a_src,
                        a_t.data.len(),
                    );
                    dwconv_bwd(a_t, &q.layer, &ga, gx, dw);
                }
                (FpKind::Dense(_), Some(q)) => {
                    let dw = acc
                        .dw
                        .entry(i)
                        .or_insert_with(|| vec![0.0; q.layer.w.len()]);
                    let gx = grad_buf(
                        &mut grads,
                        &mut g_input,
                        a_src,
                        a_t.data.len(),
                    );
                    dense_bwd(a_t, &q.layer, &ga, gx, dw);
                }
                (FpKind::Add, _) => {
                    let gx = grad_buf(
                        &mut grads,
                        &mut g_input,
                        a_src,
                        ga.len(),
                    );
                    for (g, &d) in gx.iter_mut().zip(&ga) {
                        *g += d;
                    }
                    let b_src = b_src.expect("add without 2nd input");
                    let gx = grad_buf(
                        &mut grads,
                        &mut g_input,
                        b_src,
                        ga.len(),
                    );
                    for (g, &d) in gx.iter_mut().zip(&ga) {
                        *g += d;
                    }
                }
                (FpKind::Gap, _) => {
                    let gx = grad_buf(
                        &mut grads,
                        &mut g_input,
                        a_src,
                        a_t.data.len(),
                    );
                    gap_bwd(&a_t.shape, &ga, gx);
                }
                _ => anyhow::bail!("{}: missing weight quant", step.id),
            }
        }

        // Input-site fake-quant backward (grads stop at the image).
        if let Some(gin) = g_input {
            let mut sink = vec![0f32; gin.len()];
            site_bwd(
                in_q,
                &x0.data,
                &x0h.data,
                &gin,
                &mut sink,
                self.prog.input_site,
                acc,
            );
        }
        Ok(())
    }

    /// One full optimizer step: loss + grads, then Adam on the scales,
    /// then the paper's empiric clamps. Matches the artifact trainer's
    /// contract: `(loss, trainables', m', v')`.
    #[allow(clippy::type_complexity)]
    pub fn step(
        &self,
        tr: &BTreeMap<String, Tensor>,
        m: &BTreeMap<String, Tensor>,
        v: &BTreeMap<String, Tensor>,
        adam_step: f32,
        lr: f32,
        x: &Tensor,
    ) -> Result<(f32, BTreeMap<String, Tensor>, BTreeMap<String, Tensor>, BTreeMap<String, Tensor>)>
    {
        let (loss, grads) = self.loss_and_grads(tr, x)?;
        let bc1 = 1.0 - B1.powf(adam_step);
        let bc2 = 1.0 - B2.powf(adam_step);
        let mut tr2 = BTreeMap::new();
        let mut m2 = BTreeMap::new();
        let mut v2 = BTreeMap::new();
        for (key, pt) in tr {
            let p = pt.as_f32()?;
            let zeros = vec![0f32; p.len()];
            let g = grads.get(key).unwrap_or(&zeros);
            anyhow::ensure!(
                g.len() == p.len(),
                "grad/param length mismatch for {key}"
            );
            let mv = m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing m state {key}"))?
                .as_f32()?;
            let vv = v
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing v state {key}"))?
                .as_f32()?;
            let mut pn = Vec::with_capacity(p.len());
            let mut mn = Vec::with_capacity(p.len());
            let mut vn = Vec::with_capacity(p.len());
            for j in 0..p.len() {
                let gm = B1 * mv[j] + (1.0 - B1) * g[j];
                let gv = B2 * vv[j] + (1.0 - B2) * g[j] * g[j];
                let mh = gm / bc1.max(1e-12);
                let vh = gv / bc2.max(1e-12);
                let mut pj = p[j] - lr * mh / (vh.sqrt() + ADAM_EPS);
                pj = self.clamp_trainable(key, j, pj);
                pn.push(pj);
                mn.push(gm);
                vn.push(gv);
            }
            tr2.insert(key.clone(), Tensor::f32(pt.shape.clone(), pn));
            m2.insert(key.clone(), Tensor::f32(pt.shape.clone(), mn));
            v2.insert(key.clone(), Tensor::f32(pt.shape.clone(), vn));
        }
        Ok((loss, tr2, m2, v2))
    }

    /// The paper's empiric parameter ranges, applied after each update
    /// so the STE-through-clip gradients never strand a parameter.
    fn clamp_trainable(&self, key: &str, j: usize, v: f32) -> f32 {
        if key == "act_at" {
            let lo = if self.site_meta[j].unsigned {
                th::AT_MIN_UNSIGNED
            } else {
                th::AT_MIN_SIGNED
            };
            v.clamp(lo, th::AT_MAX)
        } else {
            // act_a, act_ar and every w_a share the [0.5, 1.0] range.
            v.clamp(th::ALPHA_MIN, th::ALPHA_MAX)
        }
    }
}

/// The one construction of the identity trainable map (α = 1, α_T = 0,
/// α_R = 1 + per-layer `w_a:<node>` scales): every native producer of
/// trainables — the trainer and the backend's `identity_trainables` —
/// goes through here, so the key/shape grammar cannot desynchronize
/// from [`crate::quant::session::ThresholdSet::from_trainables`].
pub fn identity_trainables(
    num_sites: usize,
    mode: QuantMode,
    w_lens: impl IntoIterator<Item = (String, usize)>,
) -> BTreeMap<String, Tensor> {
    let s = num_sites;
    let mut out = BTreeMap::new();
    if mode.asym() {
        out.insert("act_at".to_string(), Tensor::f32(vec![s], vec![0.0; s]));
        out.insert("act_ar".to_string(), Tensor::f32(vec![s], vec![1.0; s]));
    } else {
        out.insert("act_a".to_string(), Tensor::f32(vec![s], vec![1.0; s]));
    }
    for (id, len) in w_lens {
        out.insert(format!("w_a:{id}"), Tensor::f32(vec![len], vec![1.0; len]));
    }
    out
}

/// [`identity_trainables`] with the per-layer lengths derived from the
/// graph (the `mode.vector()`-and-not-dense cout-or-1 rule shared with
/// `Trained::identity`).
pub fn identity_trainables_for_graph(
    g: &GraphDef,
    mode: QuantMode,
    num_sites: usize,
) -> BTreeMap<String, Tensor> {
    identity_trainables(
        num_sites,
        mode,
        g.conv_like().map(|n| {
            let len = if mode.vector() && n.op != Op::Dense {
                n.out_channels()
            } else {
                1
            };
            (n.id.clone(), len)
        }),
    )
}

fn take_vec(
    tr: &BTreeMap<String, Tensor>,
    key: &str,
    len: usize,
) -> Result<Vec<f32>> {
    let t = tr
        .get(key)
        .ok_or_else(|| anyhow::anyhow!("missing trainable {key}"))?;
    let v = t.as_f32()?;
    anyhow::ensure!(
        v.len() == len,
        "trainable {key}: expected {len} values, got {}",
        v.len()
    );
    Ok(v.to_vec())
}

/// Fetch (creating on first use) the gradient buffer of a source value.
fn grad_buf<'a>(
    grads: &'a mut [Option<Vec<f32>>],
    g_input: &'a mut Option<Vec<f32>>,
    src: Src,
    len: usize,
) -> &'a mut Vec<f32> {
    match src {
        Src::Input => g_input.get_or_insert_with(|| vec![0.0; len]),
        Src::Step(j) => grads[j].get_or_insert_with(|| vec![0.0; len]),
    }
}

/// Site fake-quant backward: writes the STE-masked input gradient into
/// `ga` and accumulates dS/dα (or dS/dα_T, dS/dα_R) into `acc`.
fn site_bwd(
    sq: &SiteQ,
    a: &[f32],
    y: &[f32],
    gy: &[f32],
    ga: &mut [f32],
    site: usize,
    acc: &mut Acc,
) {
    match sq {
        SiteQ::Sym { qp, t, t_cal } => {
            let mut d = 0f32;
            for j in 0..gy.len() {
                let q = (a[j] / qp.scale).round_ties_even() as i64;
                let clipped = q < qp.qmin as i64 || q > qp.qmax as i64;
                if clipped {
                    d += gy[j] * (y[j] / t);
                } else {
                    d += gy[j] * ((y[j] - a[j]) / t);
                    ga[j] = gy[j];
                }
            }
            acc.da[site] += d * t_cal;
        }
        SiteQ::Asym { qp, width, r } => {
            let mut dt = 0f32;
            let mut dr = 0f32;
            for j in 0..gy.len() {
                let q = (a[j] / qp.scale).round_ties_even() as i64
                    + qp.zero_point as i64;
                if q < qp.qmin as i64 {
                    dt += gy[j]; // ∂x̂/∂left = 1 at the low clip
                } else if q > qp.qmax as i64 {
                    dt += gy[j]; // ∂x̂/∂left = 1, ∂x̂/∂width = 1
                    dr += gy[j];
                } else {
                    dr += gy[j] * ((y[j] - a[j]) / width);
                    ga[j] = gy[j];
                }
            }
            acc.dat[site] += dt * r;
            acc.dar[site] += dr * r;
        }
    }
}

fn conv_bwd(
    x: &FTensor,
    l: &FpLayer,
    gz: &[f32],
    gx: &mut [f32],
    dw: &mut [f32],
) {
    let (h, w, cin) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, pad_top) = same_pad(h, l.k, l.stride);
    let (ow, pad_left) = same_pad(w, l.k, l.stride);
    let cout = l.cout;
    for oy in 0..oh {
        for ox in 0..ow {
            let gz_row = &gz[(oy * ow + ox) * cout..][..cout];
            for ky in 0..l.k {
                let iy = (oy * l.stride + ky) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..l.k {
                    let ix =
                        (ox * l.stride + kx) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xoff = (iy as usize * w + ix as usize) * cin;
                    for ci in 0..cin {
                        let woff = ((ky * l.k + kx) * cin + ci) * cout;
                        let xv = x.data[xoff + ci];
                        let wrow = &l.w[woff..woff + cout];
                        let dwrow = &mut dw[woff..woff + cout];
                        let mut a = 0f32;
                        for co in 0..cout {
                            let g = gz_row[co];
                            a += g * wrow[co];
                            dwrow[co] += g * xv;
                        }
                        gx[xoff + ci] += a;
                    }
                }
            }
        }
    }
}

fn dwconv_bwd(
    x: &FTensor,
    l: &FpLayer,
    gz: &[f32],
    gx: &mut [f32],
    dw: &mut [f32],
) {
    let (h, w, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, pad_top) = same_pad(h, l.k, l.stride);
    let (ow, pad_left) = same_pad(w, l.k, l.stride);
    for oy in 0..oh {
        for ox in 0..ow {
            let gz_row = &gz[(oy * ow + ox) * c..][..c];
            for ky in 0..l.k {
                let iy = (oy * l.stride + ky) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..l.k {
                    let ix =
                        (ox * l.stride + kx) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let xoff = (iy as usize * w + ix as usize) * c;
                    let woff = (ky * l.k + kx) * c;
                    for ci in 0..c {
                        let g = gz_row[ci];
                        gx[xoff + ci] += g * l.w[woff + ci];
                        dw[woff + ci] += g * x.data[xoff + ci];
                    }
                }
            }
        }
    }
}

fn dense_bwd(
    x: &FTensor,
    l: &FpLayer,
    gz: &[f32],
    gx: &mut [f32],
    dw: &mut [f32],
) {
    let cout = l.cout;
    for (ci, &xv) in x.data.iter().enumerate() {
        let wrow = &l.w[ci * cout..(ci + 1) * cout];
        let dwrow = &mut dw[ci * cout..(ci + 1) * cout];
        let mut a = 0f32;
        for co in 0..cout {
            let g = gz[co];
            a += g * wrow[co];
            dwrow[co] += g * xv;
        }
        gx[ci] += a;
    }
}

fn gap_bwd(x_shape: &[usize], gz: &[f32], gx: &mut [f32]) {
    let (h, w, c) = (x_shape[0], x_shape[1], x_shape[2]);
    let inv = 1.0 / (h * w).max(1) as f32;
    for pix in 0..(h * w) {
        let row = &mut gx[pix * c..(pix + 1) * c];
        for (g, &d) in row.iter_mut().zip(gz) {
            *g += d * inv;
        }
    }
}

// ---------------------------------------------------------------------
// TrainStep adapter for the shared fine-tune loop
// ---------------------------------------------------------------------

/// Native implementation of the fine-tune loop's step contract.
pub struct NativeStep {
    pub trainer: Trainer,
}

impl TrainStep for NativeStep {
    fn batch_size(&self) -> usize {
        TRAIN_BATCH
    }

    fn init_trainables(&self) -> Result<BTreeMap<String, Tensor>> {
        Ok(self.trainer.init_trainables())
    }

    fn step(
        &self,
        tr: &BTreeMap<String, Tensor>,
        m: &BTreeMap<String, Tensor>,
        v: &BTreeMap<String, Tensor>,
        adam_step: f32,
        lr: f32,
        x: &Tensor,
    ) -> Result<StepOut> {
        let (loss, tr2, m2, v2) = self.trainer.step(tr, m, v, adam_step, lr, x)?;
        Ok(StepOut { loss, tr: tr2, m: m2, v: v2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;
    use crate::util::prop;

    fn ft(shape: Vec<usize>, data: Vec<f32>) -> FTensor {
        FTensor { shape, data }
    }

    /// Central finite difference of a scalar function of one input
    /// element; the fp ops are linear in x and w, so the analytic
    /// gradients must match to fp noise.
    fn check_linear_bwd(
        fwd: impl Fn(&FTensor) -> Vec<f32>,
        bwd_gx: &[f32],
        x: &FTensor,
        r: &[f32],
    ) {
        let h = 1e-2f32;
        for j in 0..x.data.len() {
            let mut xp = x.clone();
            xp.data[j] += h;
            let mut xm = x.clone();
            xm.data[j] -= h;
            let yp = fwd(&xp);
            let ym = fwd(&xm);
            let lp: f32 = yp.iter().zip(r).map(|(a, b)| a * b).sum();
            let lm: f32 = ym.iter().zip(r).map(|(a, b)| a * b).sum();
            let num = (lp - lm) / (2.0 * h);
            assert!(
                (num - bwd_gx[j]).abs() <= 1e-3 * (1.0 + num.abs()),
                "elem {j}: numeric {num} vs analytic {}",
                bwd_gx[j]
            );
        }
    }

    #[test]
    fn conv_backward_matches_finite_difference() {
        let l = FpLayer {
            w: prop::f32s(1, 3 * 3 * 2 * 3, -0.5, 0.5),
            b: vec![0.1, -0.2, 0.3],
            k: 3,
            stride: 2,
            cin: 2,
            cout: 3,
        };
        let x = ft(vec![5, 5, 2], prop::f32s(2, 50, -1.0, 1.0));
        let y0 = conv_fwd(&x, &l, Vec::new());
        let r = prop::f32s(3, y0.data.len(), -1.0, 1.0);
        let mut gx = vec![0f32; x.data.len()];
        let mut dw = vec![0f32; l.w.len()];
        conv_bwd(&x, &l, &r, &mut gx, &mut dw);
        check_linear_bwd(|xx| conv_fwd(xx, &l, Vec::new()).data, &gx, &x, &r);
        // weight grad: finite difference on one weight element
        let h = 1e-2f32;
        for j in [0usize, 7, 23, l.w.len() - 1] {
            let mut lp = l.clone();
            lp.w[j] += h;
            let mut lm = l.clone();
            lm.w[j] -= h;
            let yp = conv_fwd(&x, &lp, Vec::new());
            let ym = conv_fwd(&x, &lm, Vec::new());
            let dp: f32 = yp.data.iter().zip(&r).map(|(a, b)| a * b).sum();
            let dm: f32 = ym.data.iter().zip(&r).map(|(a, b)| a * b).sum();
            let num = (dp - dm) / (2.0 * h);
            assert!(
                (num - dw[j]).abs() <= 1e-3 * (1.0 + num.abs()),
                "w {j}: numeric {num} vs analytic {}",
                dw[j]
            );
        }
    }

    #[test]
    fn dwconv_and_dense_and_gap_backward_match_finite_difference() {
        let l = FpLayer {
            w: prop::f32s(5, 9 * 3, -0.5, 0.5),
            b: vec![0.0; 3],
            k: 3,
            stride: 1,
            cin: 3,
            cout: 3,
        };
        let x = ft(vec![4, 4, 3], prop::f32s(6, 48, -1.0, 1.0));
        let y0 = dwconv_fwd(&x, &l, Vec::new());
        let r = prop::f32s(7, y0.data.len(), -1.0, 1.0);
        let mut gx = vec![0f32; x.data.len()];
        let mut dw = vec![0f32; l.w.len()];
        dwconv_bwd(&x, &l, &r, &mut gx, &mut dw);
        check_linear_bwd(|xx| dwconv_fwd(xx, &l, Vec::new()).data, &gx, &x, &r);

        let d = FpLayer {
            w: prop::f32s(8, 4 * 3, -0.5, 0.5),
            b: vec![0.0; 3],
            k: 0,
            stride: 0,
            cin: 4,
            cout: 3,
        };
        let xv = ft(vec![4], prop::f32s(9, 4, -1.0, 1.0));
        let r2 = prop::f32s(10, 3, -1.0, 1.0);
        let mut gx2 = vec![0f32; 4];
        let mut dw2 = vec![0f32; 12];
        dense_bwd(&xv, &d, &r2, &mut gx2, &mut dw2);
        check_linear_bwd(
            |xx| dense_fwd(xx, &d, Vec::new()).data,
            &gx2,
            &xv,
            &r2,
        );

        let xg = ft(vec![2, 2, 3], prop::f32s(11, 12, -1.0, 1.0));
        let rg = prop::f32s(12, 3, -1.0, 1.0);
        let mut gxg = vec![0f32; 12];
        gap_bwd(&xg.shape, &rg, &mut gxg);
        check_linear_bwd(|xx| gap_fwd(xx, Vec::new()).data, &gxg, &xg, &rg);
    }

    #[test]
    fn trainer_shapes_and_finite_grads() {
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats = crate::fp::calibrate::calib_stats(&prog, 25, 2).unwrap();
        for mode in [QuantMode::SymScalar, QuantMode::AsymVector] {
            let trainer =
                Trainer::new(&g, &w, &sites, &stats, mode, 2).unwrap();
            let tr = trainer.init_trainables();
            if mode.asym() {
                assert!(tr.contains_key("act_at") && tr.contains_key("act_ar"));
            } else {
                assert!(tr.contains_key("act_a"));
            }
            assert!(tr.keys().any(|k| k.starts_with("w_a:")));
            let (x, _) = crate::data::loader::batch(
                crate::data::Split::Train,
                &[0, 1, 2, 4, 5],
            );
            let (loss, grads) = trainer.loss_and_grads(&tr, &x).unwrap();
            assert!(loss.is_finite() && loss >= 0.0, "{mode:?}: {loss}");
            assert!(loss > 0.0, "{mode:?}: quantization error must be > 0");
            let mut any_nonzero = false;
            for (k, gv) in &grads {
                assert!(
                    gv.iter().all(|v| v.is_finite()),
                    "{mode:?} {k}: non-finite grad"
                );
                any_nonzero |= gv.iter().any(|&v| v != 0.0);
            }
            assert!(any_nonzero, "{mode:?}: all gradients are zero");
        }
    }

    #[test]
    fn trainer_with_knobs_trains_the_deployed_numerics() {
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats = crate::fp::calibrate::calib_stats(&prog, 25, 2).unwrap();
        let (x, _) = crate::data::loader::batch(
            crate::data::Split::Train,
            &[0, 1, 2],
        );
        let base =
            Trainer::new(&g, &w, &sites, &stats, QuantMode::SymVector, 2)
                .unwrap();
        let tr = base.init_trainables();
        let (loss0, _) = base.loss_and_grads(&tr, &x).unwrap();
        for knobs in [
            QuantKnobs { pow2: true, w_bits: 8 },
            QuantKnobs { pow2: false, w_bits: 4 },
            QuantKnobs { pow2: true, w_bits: 4 },
        ] {
            let t = Trainer::new_with(
                &g,
                &w,
                &sites,
                &stats,
                QuantMode::SymVector,
                knobs,
                2,
            )
            .unwrap();
            // knobs leave the trainable grammar unchanged
            assert_eq!(
                t.init_trainables().keys().collect::<Vec<_>>(),
                tr.keys().collect::<Vec<_>>(),
                "{knobs:?}"
            );
            let (loss, grads) = t.loss_and_grads(&tr, &x).unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{knobs:?}: {loss}");
            // the student actually runs the knob'd numerics: the coarser
            // / snapped grid shows up in the objective
            assert_ne!(loss, loss0, "{knobs:?}: same loss as default");
            let mut any_nonzero = false;
            for (k, gv) in &grads {
                assert!(
                    gv.iter().all(|v| v.is_finite()),
                    "{knobs:?} {k}: non-finite grad"
                );
                any_nonzero |= gv.iter().any(|&v| v != 0.0);
            }
            assert!(any_nonzero, "{knobs:?}: all gradients are zero");
        }
        assert!(Trainer::new_with(
            &g,
            &w,
            &sites,
            &stats,
            QuantMode::SymVector,
            QuantKnobs { pow2: false, w_bits: 5 },
            2,
        )
        .is_err());
    }

    #[test]
    fn adam_step_moves_and_clamps_trainables() {
        let (g, sites, w) = builtin::load("tiny_cnn").unwrap();
        let prog = FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats = crate::fp::calibrate::calib_stats(&prog, 25, 2).unwrap();
        let trainer =
            Trainer::new(&g, &w, &sites, &stats, QuantMode::SymScalar, 2)
                .unwrap();
        let tr = trainer.init_trainables();
        let zeros: BTreeMap<String, Tensor> = tr
            .iter()
            .map(|(k, t)| (k.clone(), Tensor::zeros_f32(t.shape.clone())))
            .collect();
        let (x, _) =
            crate::data::loader::batch(crate::data::Split::Train, &[0, 1, 2]);
        let (_, tr2, m2, v2) = trainer
            .step(&tr, &zeros, &zeros, 1.0, 0.05, &x)
            .unwrap();
        assert_eq!(tr2.len(), tr.len());
        assert_eq!(m2.len(), tr.len());
        assert_eq!(v2.len(), tr.len());
        let moved = tr2.iter().any(|(k, t)| {
            t.as_f32().unwrap() != tr[k].as_f32().unwrap()
        });
        assert!(moved, "one Adam step moved no trainable");
        for (k, t) in &tr2 {
            for &v in t.as_f32().unwrap() {
                assert!(
                    (th::ALPHA_MIN..=th::ALPHA_MAX).contains(&v),
                    "{k}: {v} outside the empiric clamp"
                );
            }
        }
    }
}
