//! Native FP32 backend (DESIGN.md §7) — the float side of the paper's
//! pipeline with no PJRT and no AOT artifacts.
//!
//! Four pieces, mirroring the four stubbed artifact stages:
//!
//! * [`program`] — a planned FP32 graph executor: the int8 engine's plan
//!   machinery (`int8::plan`) instantiated at `f32`, with fused
//!   activations, per-site fake-quant hooks, calibration observers and
//!   `FAT_THREADS` batch sharding (replaces `fp_forward` and, with site
//!   parameters, `quant_fwd_*`).
//! * [`calibrate`] — min/max + per-channel + histogram collection over
//!   calibration batches (replaces `calib_stats` / `calib_hist`),
//!   feeding the existing `CalibStats::apply_calibrator` percentile/KL
//!   path unchanged.
//! * [`fakequant`] — the eq. 4–9 fake-quant forward built from the same
//!   `site_qparams` / `quantize_weights` the int8 exporter uses.
//! * [`train`] — the RMSE-distillation trainer with analytic
//!   straight-through gradients for the threshold scales (replaces
//!   `train_step_*`), driven by the shared Adam/cosine loop in
//!   `coordinator::finetune`.
//!
//! The backend is selected automatically by `quant::backend::resolve`
//! (native is the default whenever AOT artifacts are absent) and can be
//! forced with `FAT_BACKEND=native|artifact`.

pub mod calibrate;
pub mod fakequant;
pub mod program;
pub mod train;

pub use program::{FpProgram, FpState, FTensor, Observer};
pub use train::Trainer;
