//! Minimal JSON parser (RFC 8259 subset sufficient for our artifacts:
//! no \u surrogate pairs needed, numbers as f64).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn entries(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Ok(kv),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// usize with default when the key is absent.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_f64().ok())
            .map(|f| f as usize)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .and_then(|v| v.as_bool().ok())
            .unwrap_or(default)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.obj(),
            b'[' => self.arr(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.num(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn obj(&mut self) -> Result<Json> {
        self.i += 1; // {
        let mut kv = vec![];
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.peek()? != b':' {
                bail!("expected ':' at {}", self.i);
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}', got {} at {}", c as char, self.i),
            }
        }
    }

    fn arr(&mut self) -> Result<Json> {
        self.i += 1; // [
        let mut a = vec![];
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        if self.peek()? != b'"' {
            bail!("expected string at {}", self.i);
        }
        self.i += 1;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn num(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64().unwrap(), -150.0);
        assert!(Json::parse("true").unwrap().as_bool().unwrap());
        assert_eq!(Json::parse("\"hi\"").unwrap().as_str().unwrap(), "hi");
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}, "e": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
        assert!(!j.get("c").unwrap().get("d").unwrap().as_bool().unwrap());
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn defaults() {
        let j = Json::parse(r#"{"k": 3}"#).unwrap();
        assert_eq!(j.usize_or("k", 0), 3);
        assert_eq!(j.usize_or("missing", 7), 7);
        assert!(j.bool_or("missing", true));
    }
}
