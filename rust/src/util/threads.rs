//! Worker-count knob and the persistent worker pool shared by every
//! parallel stage of the engine.
//!
//! ## Worker-count precedence
//!
//! 1. An explicit count wins: `EngineOptions.threads` on the serving
//!    handle, or any `*_with(threads)` entry point
//!    (`QModel::run_batch_with`, `run_quant_with`, `gemm_i8_parallel`).
//! 2. Otherwise `FAT_THREADS=<n>` pins the default. The env var is
//!    parsed **once per process** ([`fat_threads`] caches it in a
//!    `OnceLock`), so tests sweeping thread counts go through the
//!    explicit entry points rather than mutating the environment.
//! 3. Otherwise the machine's `available_parallelism`.
//!
//! ## The pool
//!
//! [`pool`] returns the process-wide [`WorkerPool`]: long-lived parked
//! worker threads fed by a job queue, replacing the per-call
//! `std::thread::scope` spawning the kernels used before PR 4. Submitting
//! a job is a queue push + condvar notify instead of N `clone`/`spawn`
//! syscalls, which makes parallelism profitable even for small layers.
//!
//! Jobs are *sharded*: [`WorkerPool::run_sharded`]`(n, f)` runs `f(0)`,
//! …, `f(n-1)` across the workers **and the calling thread** (the caller
//! claims shards too, so the pool can never deadlock on nested jobs:
//! an unclaimed shard is always runnable by its submitter). The call
//! blocks until every shard finished, so `f` may borrow from the
//! caller's stack — the same borrow-friendliness `std::thread::scope`
//! gave the old call sites. [`WorkerPool::run_chunks`] layers the common
//! "disjoint `&mut` slabs of one output buffer" pattern on top, so the
//! former `chunks_mut`+`spawn` sites port mechanically.
//!
//! Shards are claimed dynamically (atomic counter), so `n_shards` may
//! exceed the worker count — extra shards multiplex onto whichever
//! thread frees up first, and every schedule is bit-exact because shard
//! payloads own disjoint outputs.
//!
//! ## IO tasks
//!
//! The shard workers above are compute-bound and *must not block*: a
//! socket read parked on one of them would stall GEMM shards. The
//! serving front-end (`crate::net`, DESIGN.md §10) instead submits its
//! accept loop and per-connection handlers through
//! [`WorkerPool::spawn_io`]: detached, long-lived **IO workers** parked
//! on their own queue, spawned on demand (capped at [`MAX_IO_WORKERS`])
//! and reused across connections — serving a new connection is a queue
//! push, not a thread spawn. Panics inside an IO task are contained to
//! the task; the worker survives and returns to the queue.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Hard cap: more workers than this never helps the engine's shard sizes.
pub const MAX_THREADS: usize = 256;

/// Parse a `FAT_THREADS`-style value: positive integers only, capped.
pub fn parse_threads(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .map(|n| n.min(MAX_THREADS))
}

/// Machine default when `FAT_THREADS` is unset.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// The engine's worker count: `$FAT_THREADS`, else available parallelism.
/// Resolved once per process (the env var is read a single time); see the
/// module docs for the full precedence order.
pub fn fat_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        parse_threads(std::env::var("FAT_THREADS").ok().as_deref())
            .unwrap_or_else(default_threads)
    })
}

/// One queued sharded job. `f` is a type-erased reference into the
/// submitting caller's stack; the `'static` is a lie upheld by
/// [`WorkerPool::run_sharded`], which does not return (and therefore does
/// not release the borrow) until `remaining` hits zero and the job has
/// been unlinked from the queue.
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    /// Next shard index to claim (may overshoot `n_shards`; claims
    /// at or above it are no-ops).
    next: AtomicUsize,
    n_shards: usize,
    /// Shards not yet finished; guarded by a mutex so the submitter's
    /// condvar wait cannot miss the final decrement.
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Job {
    /// Claim and run shards until none are left. Shared by workers and
    /// the submitting thread.
    fn run_claimed(&self) {
        loop {
            let s = self.next.fetch_add(1, Ordering::Relaxed);
            if s >= self.n_shards {
                return;
            }
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| (self.f)(s)),
            );
            if r.is_err() {
                self.panicked.store(true, Ordering::Relaxed);
            }
            let mut rem = self.remaining.lock().unwrap();
            *rem -= 1;
            if *rem == 0 {
                self.done.notify_all();
            }
        }
    }
}

/// One-shot blocking wait/notify cell — the request-level counterpart of
/// the pool's sharded jobs, used by the micro-batching serving scheduler
/// (`crate::int8::batcher`): followers block on the batch's `ready` cell
/// while the leader assembles and executes the batch on the pool, and
/// the leader blocks (with a deadline) on the `full` cell until a
/// follower fills the last row.
///
/// The notified flag is sticky: a `notify` that races ahead of the
/// `wait` is never lost, and later waiters return immediately. There is
/// no reset — one cell serves one event.
#[derive(Default)]
pub struct Notify {
    flag: Mutex<bool>,
    cv: Condvar,
}

impl Notify {
    /// Fresh, un-notified cell.
    pub fn new() -> Self {
        Notify::default()
    }

    /// Mark the event as happened and wake every waiter (idempotent).
    pub fn notify(&self) {
        let mut f = self.flag.lock().unwrap();
        *f = true;
        drop(f);
        self.cv.notify_all();
    }

    /// Whether the event already happened.
    pub fn is_notified(&self) -> bool {
        *self.flag.lock().unwrap()
    }

    /// Block until [`Notify::notify`] was called.
    pub fn wait(&self) {
        let mut f = self.flag.lock().unwrap();
        while !*f {
            f = self.cv.wait(f).unwrap();
        }
    }

    /// Block until notified or `deadline` passes; `true` iff notified.
    pub fn wait_deadline(&self, deadline: Instant) -> bool {
        let mut f = self.flag.lock().unwrap();
        while !*f {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _timeout) =
                self.cv.wait_timeout(f, deadline - now).unwrap();
            f = g;
        }
        true
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    work: Condvar,
}

/// Hard cap on detached IO workers — a backstop far above any sane
/// connection-slot configuration (`net::ServerOptions::max_conns`
/// bounds live connections long before this bites).
pub const MAX_IO_WORKERS: usize = 512;

type IoJob = Box<dyn FnOnce() + Send + 'static>;

#[derive(Default)]
struct IoState {
    jobs: VecDeque<IoJob>,
    /// Workers currently parked on `work` (tracked under the same lock
    /// as `jobs`, so the spawn-on-demand decision cannot race a worker
    /// that is about to wait).
    idle: usize,
    spawned: usize,
}

#[derive(Default)]
struct IoShared {
    state: Mutex<IoState>,
    work: Condvar,
}

fn io_worker_loop(shared: Arc<IoShared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                st.idle += 1;
                st = shared.work.wait(st).unwrap();
                st.idle -= 1;
            }
        };
        // A panicking connection handler must not take the worker down.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

/// Process-wide persistent worker pool (see the module docs). Workers
/// are spawned lazily up to the machine parallelism (or an explicit
/// `FAT_THREADS` ask, hard-capped at [`MAX_THREADS`]) and then park on
/// the job queue's condvar between jobs.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    spawned: Mutex<usize>,
    io: Arc<IoShared>,
}

/// The process-wide pool. Initialised on first use; worker threads are
/// detached and die with the process.
pub fn pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool {
        shared: Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
        }),
        spawned: Mutex::new(0),
        io: Arc::new(IoShared::default()),
    })
}

fn worker_loop(shared: Arc<PoolShared>) {
    enum Next {
        Wait,
        Pop,
        Run(Arc<Job>),
    }
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Drop exhausted jobs, grab the first with open shards.
                let next = match q.front() {
                    None => Next::Wait,
                    Some(j)
                        if j.next.load(Ordering::Relaxed) >= j.n_shards =>
                    {
                        Next::Pop
                    }
                    Some(j) => Next::Run(j.clone()),
                };
                match next {
                    Next::Wait => q = shared.work.wait(q).unwrap(),
                    Next::Pop => drop(q.pop_front()),
                    Next::Run(j) => break j,
                }
            }
        };
        job.run_claimed();
    }
}

impl WorkerPool {
    /// Number of live worker threads (diagnostics).
    pub fn workers(&self) -> usize {
        *self.spawned.lock().unwrap()
    }

    /// Run a long-lived or blocking task on the pool's detached IO
    /// workers (see the module docs): the serving front-end's accept
    /// loop and per-connection handlers go through here so blocking
    /// socket reads never occupy a compute shard worker. An idle IO
    /// worker picks the task up immediately; otherwise a new worker is
    /// spawned (up to [`MAX_IO_WORKERS`], beyond which tasks queue until
    /// a worker frees up). Tasks are fire-and-forget; a panic inside the
    /// task is contained to the task.
    pub fn spawn_io(&self, f: impl FnOnce() + Send + 'static) {
        {
            let mut st = self.io.state.lock().unwrap();
            st.jobs.push_back(Box::new(f));
            if st.idle == 0 && st.spawned < MAX_IO_WORKERS {
                let n = st.spawned;
                st.spawned += 1;
                let shared = Arc::clone(&self.io);
                std::thread::Builder::new()
                    .name(format!("fat-io-{n}"))
                    .spawn(move || io_worker_loop(shared))
                    .expect("spawn io worker");
            }
        }
        self.io.work.notify_one();
    }

    /// Number of live IO worker threads (diagnostics).
    pub fn io_workers(&self) -> usize {
        self.io.state.lock().unwrap().spawned
    }

    /// IO workers currently parked with no queued task (diagnostics).
    pub fn io_idle(&self) -> usize {
        let st = self.io.state.lock().unwrap();
        st.idle.saturating_sub(st.jobs.len())
    }

    fn ensure_workers(&self, want: usize) {
        // Workers beyond the hardware (or an explicit FAT_THREADS ask)
        // can't add throughput — larger shard counts multiplex instead.
        let cap = fat_threads().max(default_threads()).min(MAX_THREADS);
        let want = want.min(cap);
        let mut count = self.spawned.lock().unwrap();
        while *count < want {
            let shared = Arc::clone(&self.shared);
            std::thread::Builder::new()
                .name(format!("fat-pool-{count}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn pool worker");
            *count += 1;
        }
    }

    /// Run `f(0..n_shards)` across the pool workers and the calling
    /// thread; blocks until every shard finished, so `f` may borrow
    /// caller state. Shards must touch disjoint data (the callers all
    /// write disjoint output slabs; prefer [`WorkerPool::run_chunks`]).
    /// Panics (after all shards drained) if any shard panicked.
    pub fn run_sharded<F: Fn(usize) + Sync>(&self, n_shards: usize, f: F) {
        if n_shards <= 1 {
            if n_shards == 1 {
                f(0);
            }
            return;
        }
        self.ensure_workers(n_shards - 1);
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the job is removed from the queue and fully drained
        // before this function returns, so the erased borrow of `f`
        // never outlives the real closure.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let job = Arc::new(Job {
            f: f_static,
            next: AtomicUsize::new(0),
            n_shards,
            remaining: Mutex::new(n_shards),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(job.clone());
        }
        self.shared.work.notify_all();
        // The submitter claims shards too: an unclaimed shard is always
        // runnable right here, so nested run_sharded calls cannot
        // deadlock even with every worker busy.
        job.run_claimed();
        let mut rem = job.remaining.lock().unwrap();
        while *rem > 0 {
            rem = job.done.wait(rem).unwrap();
        }
        drop(rem);
        // Unlink the job so no queue entry can outlive `f`'s borrow.
        {
            let mut q = self.shared.queue.lock().unwrap();
            if let Some(pos) = q.iter().position(|j| Arc::ptr_eq(j, &job)) {
                drop(q.remove(pos));
            }
        }
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker pool shard panicked");
        }
    }

    /// Split `data` into `chunk_len`-element slabs and run
    /// `f(shard, slab)` across the pool — the safe port of the old
    /// `chunks_mut` + `thread::scope` pattern. Blocks until done.
    pub fn run_chunks<T: Send>(
        &self,
        data: &mut [T],
        chunk_len: usize,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        let chunk_len = chunk_len.max(1);
        let n_shards = data.len().div_ceil(chunk_len);
        if n_shards <= 1 {
            if !data.is_empty() {
                f(0, data);
            }
            return;
        }
        let total = data.len();
        let base = data.as_mut_ptr() as usize;
        self.run_sharded(n_shards, |i| {
            let start = i * chunk_len;
            let len = chunk_len.min(total - start);
            // SAFETY: shard `i` owns exactly [start, start+len) — the
            // ranges are disjoint across shards — and run_sharded blocks
            // until every shard completes, so the reconstructed slab
            // never outlives the `data` borrow.
            let slab = unsafe {
                std::slice::from_raw_parts_mut(
                    (base as *mut T).add(start),
                    len,
                )
            };
            f(i, slab);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parse_accepts_positive_integers() {
        assert_eq!(parse_threads(Some("4")), Some(4));
        assert_eq!(parse_threads(Some(" 2 ")), Some(2));
        assert_eq!(parse_threads(Some("1")), Some(1));
    }

    #[test]
    fn parse_rejects_invalid() {
        assert_eq!(parse_threads(Some("0")), None);
        assert_eq!(parse_threads(Some("-3")), None);
        assert_eq!(parse_threads(Some("many")), None);
        assert_eq!(parse_threads(None), None);
    }

    #[test]
    fn parse_caps_huge_values() {
        assert_eq!(parse_threads(Some("100000")), Some(MAX_THREADS));
    }

    #[test]
    fn defaults_are_sane() {
        assert!(default_threads() >= 1);
        assert!(fat_threads() >= 1);
    }

    #[test]
    fn run_sharded_runs_every_shard_exactly_once() {
        for n in [0usize, 1, 2, 7, 32] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool().run_sharded(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "n={n} shard={i}");
            }
        }
    }

    #[test]
    fn run_chunks_writes_disjoint_slabs() {
        let mut data = vec![0usize; 103];
        pool().run_chunks(&mut data, 10, |i, slab| {
            for v in slab.iter_mut() {
                *v = i + 1;
            }
        });
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, j / 10 + 1, "elem {j}");
        }
    }

    #[test]
    fn run_chunks_handles_empty_and_single() {
        let mut empty: Vec<u8> = Vec::new();
        pool().run_chunks(&mut empty, 4, |_, _| panic!("no shards"));
        let mut one = vec![1u8, 2, 3];
        pool().run_chunks(&mut one, 8, |i, slab| {
            assert_eq!(i, 0);
            slab.iter_mut().for_each(|v| *v += 1);
        });
        assert_eq!(one, vec![2, 3, 4]);
    }

    #[test]
    fn nested_jobs_do_not_deadlock() {
        let mut out = vec![0usize; 16];
        pool().run_chunks(&mut out, 4, |i, slab| {
            // Each outer shard submits an inner sharded job.
            let total = AtomicUsize::new(0);
            pool().run_sharded(3, |j| {
                total.fetch_add(j + 1, Ordering::Relaxed);
            });
            let t = total.load(Ordering::Relaxed);
            for v in slab.iter_mut() {
                *v = 100 * (i + 1) + t;
            }
        });
        for (j, &v) in out.iter().enumerate() {
            assert_eq!(v, 100 * (j / 4 + 1) + 6, "elem {j}");
        }
    }

    #[test]
    fn more_shards_than_workers_still_complete() {
        let n = MAX_THREADS + 37;
        let hits: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool().run_sharded(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert!(pool().workers() <= MAX_THREADS);
    }

    #[test]
    fn notify_is_sticky_and_wakes_waiters() {
        let n = Arc::new(Notify::new());
        assert!(!n.is_notified());
        // notify-before-wait is not lost
        n.notify();
        n.wait();
        assert!(n.is_notified());
        // already-notified deadline wait returns immediately
        assert!(n.wait_deadline(Instant::now()));

        // wait-before-notify across threads
        let m = Arc::new(Notify::new());
        let m2 = Arc::clone(&m);
        let h = std::thread::spawn(move || {
            m2.wait();
            true
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.notify();
        assert!(h.join().unwrap());
    }

    #[test]
    fn notify_deadline_times_out_without_notify() {
        let n = Notify::new();
        let t0 = Instant::now();
        let hit = n.wait_deadline(
            Instant::now() + std::time::Duration::from_millis(10),
        );
        assert!(!hit);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
    }

    #[test]
    fn spawn_io_runs_detached_tasks() {
        let done = Arc::new(Notify::new());
        let d = Arc::clone(&done);
        pool().spawn_io(move || d.notify());
        done.wait();
        assert!(pool().io_workers() >= 1);
    }

    #[test]
    fn spawn_io_blockers_get_distinct_workers() {
        // N tasks that all block until every one of them has started:
        // this only completes if each got its own worker (tasks must
        // not queue behind a blocked sibling while under the cap).
        let n = 6usize;
        let started = Arc::new(AtomicUsize::new(0));
        let release = Arc::new(Notify::new());
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..n {
            let (s, r, d) = (
                Arc::clone(&started),
                Arc::clone(&release),
                Arc::clone(&done),
            );
            pool().spawn_io(move || {
                s.fetch_add(1, Ordering::SeqCst);
                r.wait();
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        let t0 = Instant::now();
        while started.load(Ordering::SeqCst) < n
            && t0.elapsed() < std::time::Duration::from_secs(10)
        {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(started.load(Ordering::SeqCst), n, "all tasks started");
        release.notify();
        while done.load(Ordering::SeqCst) < n
            && t0.elapsed() < std::time::Duration::from_secs(10)
        {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(done.load(Ordering::SeqCst), n);
    }

    #[test]
    fn spawn_io_panics_are_contained() {
        pool().spawn_io(|| panic!("io task panic (expected in test)"));
        // The pool keeps serving tasks afterwards.
        let done = Arc::new(Notify::new());
        let d = Arc::clone(&done);
        pool().spawn_io(move || d.notify());
        done.wait();
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut data = vec![0usize; 40];
                    pool().run_chunks(&mut data, 5, |i, slab| {
                        slab.iter_mut().for_each(|v| *v = t * 1000 + i);
                    });
                    for (j, &v) in data.iter().enumerate() {
                        assert_eq!(v, t * 1000 + j / 5);
                    }
                });
            }
        });
    }
}
