//! Worker-count knob for the int8 engine (and any future parallel stage).
//!
//! `FAT_THREADS=<n>` pins the worker count; unset or invalid values fall
//! back to the machine's available parallelism. The engine also accepts
//! explicit counts through the `*_with` entry points
//! (`QModel::run_batch_with`, `run_quant_with`, `gemm_i8_parallel`) — the
//! env knob only feeds the default paths, so tests can sweep thread
//! counts deterministically without touching the environment.

use std::sync::OnceLock;

/// Hard cap: more workers than this never helps the engine's shard sizes.
pub const MAX_THREADS: usize = 256;

/// Parse a `FAT_THREADS`-style value: positive integers only, capped.
pub fn parse_threads(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .map(|n| n.min(MAX_THREADS))
}

/// Machine default when `FAT_THREADS` is unset.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// The engine's worker count: `$FAT_THREADS`, else available parallelism.
/// Resolved once per process (the env var is read a single time).
pub fn fat_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        parse_threads(std::env::var("FAT_THREADS").ok().as_deref())
            .unwrap_or_else(default_threads)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_positive_integers() {
        assert_eq!(parse_threads(Some("4")), Some(4));
        assert_eq!(parse_threads(Some(" 2 ")), Some(2));
        assert_eq!(parse_threads(Some("1")), Some(1));
    }

    #[test]
    fn parse_rejects_invalid() {
        assert_eq!(parse_threads(Some("0")), None);
        assert_eq!(parse_threads(Some("-3")), None);
        assert_eq!(parse_threads(Some("many")), None);
        assert_eq!(parse_threads(None), None);
    }

    #[test]
    fn parse_caps_huge_values() {
        assert_eq!(parse_threads(Some("100000")), Some(MAX_THREADS));
    }

    #[test]
    fn defaults_are_sane() {
        assert!(default_threads() >= 1);
        assert!(fat_threads() >= 1);
    }
}
