//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Cargo benches with `harness = false` are plain binaries; this module
//! gives them warmup + repeated timing + simple statistics, printed in a
//! stable, grep-friendly format:
//!
//! `BENCH <name> mean_ms=<..> min_ms=<..> p50_ms=<..> iters=<..>`

use std::time::Instant;

pub struct BenchOpts {
    pub warmup: usize,
    pub iters: usize,
    /// stop early once this much wall time was spent (seconds)
    pub max_secs: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 1, iters: 10, max_secs: 30.0 }
    }
}

impl BenchOpts {
    /// Defaults with `FAT_BENCH_ITERS` / `FAT_BENCH_MAX_SECS` env
    /// overrides, so thread-scaling runs (EXPERIMENTS.md §Perf) can be
    /// lengthened without recompiling.
    pub fn from_env() -> Self {
        let mut o = BenchOpts::default();
        if let Some(n) =
            std::env::var("FAT_BENCH_ITERS").ok().and_then(|v| v.parse().ok())
        {
            o.iters = n;
        }
        if let Some(s) = std::env::var("FAT_BENCH_MAX_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
        {
            o.max_secs = s;
        }
        o
    }
}

/// Time `f` and print a stable summary line. Returns mean seconds.
pub fn bench(name: &str, opts: &BenchOpts, mut f: impl FnMut()) -> f64 {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = vec![];
    let start = Instant::now();
    for _ in 0..opts.iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > opts.max_secs {
            break;
        }
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples[0];
    let p50 = samples[samples.len() / 2];
    println!(
        "BENCH {name} mean_ms={:.3} min_ms={:.3} p50_ms={:.3} iters={}",
        mean * 1e3,
        min * 1e3,
        p50 * 1e3,
        samples.len()
    );
    mean
}

/// Throughput variant: prints items/sec too.
pub fn bench_throughput(
    name: &str,
    opts: &BenchOpts,
    items: usize,
    mut f: impl FnMut(),
) -> f64 {
    let mean = bench(name, opts, &mut f);
    println!(
        "BENCH {name} items_per_sec={:.1}",
        items as f64 / mean.max(1e-12)
    );
    mean
}

/// Latency percentiles in seconds (nearest-rank), as aggregated by
/// [`percentiles`] for the serving benches.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Nearest-rank percentile aggregation over per-request latency samples
/// (sorts `samples` in place; empty input yields zeros). Used by
/// `benches/bench_serve.rs` and the `serve-bench` CLI subcommand.
pub fn percentiles(samples: &mut [f64]) -> Percentiles {
    if samples.is_empty() {
        return Percentiles::default();
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let pick = |p: f64| {
        let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize;
        samples[rank.clamp(1, samples.len()) - 1]
    };
    Percentiles { p50: pick(50.0), p95: pick(95.0), p99: pick(99.0) }
}

/// Print a stable `speedup=` line relating a baseline to a variant
/// (used by the thread-scaling sweeps in `bench_int8`).
pub fn report_speedup(name: &str, base_secs: f64, variant_secs: f64) -> f64 {
    let s = base_secs / variant_secs.max(1e-12);
    println!("BENCH {name} speedup={s:.2}x");
    s
}

/// Machine-readable bench log: flat JSON records accumulated during a
/// bench run and written as one array (e.g. `BENCH_int8.json`), so the
/// perf trajectory can be populated and diffed PR over PR without
/// scraping stdout.
#[derive(Default)]
pub struct BenchLog {
    entries: Vec<String>,
}

impl BenchLog {
    /// Record one measurement. `ops` is the logical operation count per
    /// iteration (MACs for GEMM benches, images for model benches) from
    /// which GOP/s is derived; `isa` is the kernel level the variant ran
    /// (`"spawn"`/`"pooled"`-style tags are fine for non-kernel rows).
    pub fn add(
        &mut self,
        name: &str,
        shape: &str,
        threads: usize,
        isa: &str,
        mean_secs: f64,
        ops: usize,
    ) {
        let ns = mean_secs * 1e9;
        let gops = ops as f64 / mean_secs.max(1e-12) / 1e9;
        self.entries.push(format!(
            "  {{\"name\": \"{name}\", \"shape\": \"{shape}\", \
             \"threads\": {threads}, \"isa\": \"{isa}\", \
             \"ns_per_iter\": {ns:.0}, \"gops\": {gops:.4}}}"
        ));
    }

    /// Record one serving measurement: closed-loop client count, total
    /// requests, wall time and per-request latency [`Percentiles`]
    /// (seconds in, milliseconds in the log). `mode` tags the serving
    /// path (`"batched"` / `"unbatched"`).
    #[allow(clippy::too_many_arguments)]
    pub fn add_latency(
        &mut self,
        name: &str,
        mode: &str,
        clients: usize,
        threads: usize,
        requests: usize,
        wall_secs: f64,
        lat: Percentiles,
    ) {
        let rps = requests as f64 / wall_secs.max(1e-12);
        self.entries.push(format!(
            "  {{\"name\": \"{name}\", \"mode\": \"{mode}\", \
             \"clients\": {clients}, \"threads\": {threads}, \
             \"requests\": {requests}, \"rps\": {rps:.1}, \
             \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"p99_ms\": {:.3}}}",
            lat.p50 * 1e3,
            lat.p95 * 1e3,
            lat.p99 * 1e3
        ));
    }

    /// Number of recorded entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to a JSON array string.
    pub fn to_json(&self) -> String {
        format!("[\n{}\n]\n", self.entries.join(",\n"))
    }

    /// Write the array to `path` and print where it went.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("BENCH log: {} entries -> {path}", self.entries.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_ratio() {
        assert!((report_speedup("x", 2.0, 1.0) - 2.0).abs() < 1e-9);
        assert!(report_speedup("y", 1.0, 0.0) > 1.0);
    }

    #[test]
    fn bench_log_serializes_valid_json() {
        let mut log = BenchLog::default();
        assert!(log.is_empty());
        log.add("gemm", "1024x144x64", 4, "avx2", 0.001, 9_437_184);
        log.add("model", "batch50", 1, "pooled", 0.5, 50);
        assert_eq!(log.len(), 2);
        let j = crate::util::json::Json::parse(&log.to_json()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("isa").unwrap().as_str().unwrap(), "avx2");
        assert_eq!(arr[0].get("threads").unwrap().as_f64().unwrap(), 4.0);
        assert!(arr[0].get("gops").unwrap().as_f64().unwrap() > 9.0);
        assert!(arr[1].get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let mut empty: Vec<f64> = vec![];
        assert_eq!(percentiles(&mut empty), Percentiles::default());
        let mut one = vec![4.0];
        let p = percentiles(&mut one);
        assert_eq!((p.p50, p.p95, p.p99), (4.0, 4.0, 4.0));
        // 1..=100 reversed: nearest-rank pN is exactly N
        let mut v: Vec<f64> = (1..=100).rev().map(|i| i as f64).collect();
        let p = percentiles(&mut v);
        assert_eq!((p.p50, p.p95, p.p99), (50.0, 95.0, 99.0));
        // sorted in place
        assert_eq!(v[0], 1.0);
        assert_eq!(v[99], 100.0);
    }

    #[test]
    fn latency_rows_serialize_valid_json() {
        let mut log = BenchLog::default();
        log.add_latency(
            "serve_tiny_cnn",
            "batched",
            16,
            8,
            256,
            0.5,
            Percentiles { p50: 0.001, p95: 0.002, p99: 0.004 },
        );
        let j = crate::util::json::Json::parse(&log.to_json()).unwrap();
        let row = &j.as_arr().unwrap()[0];
        assert_eq!(row.get("mode").unwrap().as_str().unwrap(), "batched");
        assert_eq!(row.get("clients").unwrap().as_f64().unwrap(), 16.0);
        assert_eq!(row.get("rps").unwrap().as_f64().unwrap(), 512.0);
        assert!(
            (row.get("p99_ms").unwrap().as_f64().unwrap() - 4.0).abs()
                < 1e-9
        );
    }

    #[test]
    fn bench_runs_and_returns_mean() {
        let m = bench(
            "noop",
            &BenchOpts { warmup: 0, iters: 3, max_secs: 5.0 },
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(m >= 0.0);
    }
}
