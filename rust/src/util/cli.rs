//! Tiny CLI argument parser: `prog subcommand --key value --key=value --flag`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args`, treating the first non-flag token as the
    /// subcommand. `bool_flags` lists options that take no value.
    pub fn parse(bool_flags: &[&str]) -> Args {
        Self::from_vec(std::env::args().skip(1).collect(), bool_flags)
    }

    pub fn from_vec(tokens: Vec<String>, bool_flags: &[&str]) -> Args {
        let mut a = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    a.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        a.flags.push(name.to_string());
                    } else {
                        a.opts.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    a.flags.push(name.to_string());
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok);
            }
        }
        a
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::from_vec(
            v(&["pipeline", "--model", "m", "--epochs=3", "--dws"]),
            &["dws"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("pipeline"));
        assert_eq!(a.get("model"), Some("m"));
        assert_eq!(a.usize_or("epochs", 0), 3);
        assert!(a.flag("dws"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::from_vec(v(&["x", "--verbose"]), &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = Args::from_vec(v(&[]), &[]);
        assert_eq!(a.get_or("model", "def"), "def");
        assert_eq!(a.f32_or("lr", 0.5), 0.5);
    }
}
