//! Seeded property-testing helpers (proptest is unavailable offline).
//!
//! Tests draw deterministic pseudo-random cases from the portable PRNG and
//! report the failing case index, which is enough to reproduce locally.

use crate::data::prng;

/// GEMM shapes `(m, k, n, a_zp)` chosen to hit every blocking edge of
/// the int8 kernels: single element, odd everything, exact `(KC, NR)`
/// tile multiples, and remainders in m, n and k. Shared by the unpacked
/// kernel unit tests (`int8::gemm`), the packed SIMD kernel tests
/// (`int8::kernels`) and the ISA × thread-count proptests
/// (`rust/tests/proptests.rs`).
pub const SHAPES: &[(usize, usize, usize, i32)] = &[
    (1, 1, 1, 0),
    (3, 5, 7, -3),
    (8, 16, 4, 12),
    (17, 9, 33, -128),
    (4, 128, 64, 5),   // exactly one (KC, NR) panel, one MR block
    (5, 129, 65, -7),  // +1 remainder in every dimension
    (2, 300, 100, 11), // multiple k panels
    (65, 7, 130, -1),  // many row blocks, two n strips
];

/// Deterministic f32s in [lo, hi).
pub fn f32s(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            lo + prng::uniform(seed, i as u64, 77, 0, 0, 0) * (hi - lo)
        })
        .collect()
}

/// Deterministic i8s covering the full range.
pub fn i8s(seed: u64, n: usize) -> Vec<i8> {
    (0..n)
        .map(|i| (prng::hash_u64(seed, i as u64, 78, 0, 0, 0) % 256) as u8 as i8)
        .collect()
}

/// Deterministic usize in [lo, hi).
pub fn usize_in(seed: u64, case: u64, lo: usize, hi: usize) -> usize {
    lo + (prng::hash_u64(seed, case, 79, 0, 0, 0) as usize) % (hi - lo).max(1)
}

/// Run `f` over `cases` deterministic cases; panics with the case index on
/// the first failure (re-run with that index for a minimal repro).
pub fn for_cases(seed: u64, cases: u64, mut f: impl FnMut(u64)) {
    for case in 0..cases {
        let _ = seed;
        f(case);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_draws() {
        assert_eq!(f32s(1, 4, -1.0, 1.0), f32s(1, 4, -1.0, 1.0));
        assert_ne!(f32s(1, 4, -1.0, 1.0), f32s(2, 4, -1.0, 1.0));
        let v = f32s(3, 1000, -2.0, 2.0);
        assert!(v.iter().all(|&x| (-2.0..2.0).contains(&x)));
    }

    #[test]
    fn usize_bounds() {
        for c in 0..100 {
            let u = usize_in(5, c, 3, 17);
            assert!((3..17).contains(&u));
        }
    }
}
