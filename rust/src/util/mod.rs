//! In-tree utilities replacing unavailable third-party crates on this
//! offline build box: a JSON parser, a CLI argument parser, a micro-bench
//! harness and seeded property-testing helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod threads;

pub use json::Json;
pub use threads::fat_threads;
