//! In-tree utilities replacing unavailable third-party crates on this
//! offline build box: a JSON parser, a CLI argument parser, a micro-bench
//! harness with a perf-regression gate over its logs, and seeded
//! property-testing helpers.

pub mod bench;
pub mod cli;
pub mod gate;
pub mod json;
pub mod prop;
pub mod threads;

pub use json::Json;
pub use threads::fat_threads;
