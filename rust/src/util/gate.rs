//! Perf-trajectory regression gate over the machine-readable bench logs
//! (DESIGN.md §12.5).
//!
//! Bench runs emit flat JSON arrays ([`crate::util::bench::BenchLog`]) —
//! `BENCH_int8.json`, `BENCH_serve.json`, `BENCH_load.json` — and a
//! snapshot per machine class is committed under `bench/baselines/`.
//! This module compares a fresh run against that snapshot row by row and
//! fails when any metric regresses past a threshold (default 15%), so a
//! PR that slows a kernel, the serving path or artifact cold-start shows
//! up red in CI instead of silently eroding the trajectory.
//!
//! Rows are keyed by their identity fields (`name`, `shape`, `mode`,
//! `clients`, `threads`, `isa` — whichever are present), and only the
//! metrics both sides report are compared: `ns_per_iter` and `p95_ms`
//! (lower is better), `rps` (higher is better). Derived duplicates like
//! `gops` and `p50`/`p99` are deliberately not gated — `gops` is
//! `ns_per_iter` restated, and median/p99 are too noisy on shared CI
//! boxes; p95 is the stability/throughput compromise. A baseline row
//! with no current counterpart fails the gate (a vanished row is how a
//! regression hides); current rows with no baseline are informational.
//!
//! The comparator is pure string → report so it can be unit-tested
//! without filesystem or bench runs; `fat perf-gate` is a thin CLI shim.
//! `inject_slowdown_pct` exists for CI's negative self-test: it degrades
//! every current metric by that much before comparing, proving the gate
//! actually fails when perf moves.

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Gated metrics: `(field, lower_is_better)`.
const METRICS: &[(&str, bool)] =
    &[("ns_per_iter", true), ("rps", false), ("p95_ms", true)];

/// Identity fields, in key order. Absent fields are skipped, so GEMM
/// rows and serving-latency rows key cleanly from the same list.
const KEY_FIELDS: &[&str] =
    &["name", "shape", "mode", "clients", "threads", "isa"];

#[derive(Debug, Clone)]
pub struct GateOptions {
    /// Fail when a metric is more than this % worse than baseline.
    pub max_regress_pct: f64,
    /// Degrade every current metric by this % before comparing —
    /// the CI negative self-test knob. 0 = off.
    pub inject_slowdown_pct: f64,
}

impl Default for GateOptions {
    fn default() -> Self {
        GateOptions { max_regress_pct: 15.0, inject_slowdown_pct: 0.0 }
    }
}

/// One metric comparison on one row.
#[derive(Debug, Clone)]
pub struct GateCheck {
    pub key: String,
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// Positive = worse than baseline, negative = improvement.
    pub regress_pct: f64,
    pub ok: bool,
}

/// Outcome of one gate run.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    pub checks: Vec<GateCheck>,
    /// Baseline row keys with no counterpart in the current run.
    pub missing: Vec<String>,
    /// Current rows with no baseline counterpart (not a failure: new
    /// benches seed their baseline on the next snapshot refresh).
    pub new_rows: usize,
}

impl GateReport {
    pub fn pass(&self) -> bool {
        self.missing.is_empty() && self.checks.iter().all(|c| c.ok)
    }

    pub fn failures(&self) -> usize {
        self.checks.iter().filter(|c| !c.ok).count() + self.missing.len()
    }

    /// Stable, grep-friendly text: one `GATE ok|FAIL` line per check.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.checks {
            let tag = if c.ok { "ok  " } else { "FAIL" };
            out.push_str(&format!(
                "GATE {tag} {} {}: {:.1} -> {:.1} ({:+.1}%)\n",
                c.key, c.metric, c.baseline, c.current, c.regress_pct
            ));
        }
        for k in &self.missing {
            out.push_str(&format!(
                "GATE FAIL {k}: row missing from current run\n"
            ));
        }
        if self.new_rows > 0 {
            out.push_str(&format!(
                "GATE note: {} current row(s) have no baseline yet\n",
                self.new_rows
            ));
        }
        out.push_str(&format!(
            "GATE {}: {} checks, {} failures\n",
            if self.pass() { "PASS" } else { "FAIL" },
            self.checks.len(),
            self.failures()
        ));
        out
    }
}

fn row_key(r: &Json) -> String {
    let mut parts = Vec::new();
    for f in KEY_FIELDS {
        if let Some(v) = r.get(f) {
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => format!("{n}"),
                other => format!("{other:?}"),
            };
            parts.push(format!("{f}={s}"));
        }
    }
    parts.join(" ")
}

/// Parse a BenchLog array into `(key, row)` pairs. Later rows win on a
/// duplicate key (a bench rerun within one log overwrites itself).
fn rows(doc: &str, label: &str) -> Result<Vec<(String, Json)>> {
    let j = Json::parse(doc).with_context(|| format!("parsing {label}"))?;
    let arr = j.as_arr().with_context(|| format!("{label}: want array"))?;
    let mut out: Vec<(String, Json)> = Vec::new();
    for r in arr {
        let key = row_key(r);
        match out.iter_mut().find(|(k, _)| *k == key) {
            Some((_, slot)) => *slot = r.clone(),
            None => out.push((key, r.clone())),
        }
    }
    Ok(out)
}

/// Compare a current bench log against its committed baseline.
/// Both arguments are raw JSON documents (arrays of flat records).
pub fn check(
    baseline_doc: &str,
    current_doc: &str,
    opts: &GateOptions,
) -> Result<GateReport> {
    let base = rows(baseline_doc, "baseline")?;
    let cur = rows(current_doc, "current")?;
    let inject = 1.0 + opts.inject_slowdown_pct / 100.0;

    let mut report = GateReport::default();
    for (key, brow) in &base {
        let Some((_, crow)) = cur.iter().find(|(k, _)| k == key) else {
            report.missing.push(key.clone());
            continue;
        };
        for &(metric, lower_better) in METRICS {
            let (Some(bv), Some(cv)) = (brow.get(metric), crow.get(metric))
            else {
                continue;
            };
            let (bv, cv) = (bv.as_f64()?, cv.as_f64()?);
            if bv <= 0.0 {
                continue; // degenerate baseline; nothing to compare against
            }
            let cv = if lower_better { cv * inject } else { cv / inject };
            let regress_pct = if lower_better {
                (cv - bv) / bv * 100.0
            } else {
                (bv - cv) / bv * 100.0
            };
            report.checks.push(GateCheck {
                key: key.clone(),
                metric,
                baseline: bv,
                current: cv,
                regress_pct,
                ok: regress_pct <= opts.max_regress_pct,
            });
        }
    }
    report.new_rows =
        cur.iter().filter(|(k, _)| !base.iter().any(|(b, _)| b == k)).count();
    Ok(report)
}

/// Render a bench log as a GitHub-flavored markdown table for
/// `fat perf-report` (EXPERIMENTS.md §Perf rows are pasted from this).
pub fn markdown_table(doc: &str) -> Result<String> {
    let all = rows(doc, "bench log")?;
    const COLS: &[&str] = &[
        "name", "shape", "mode", "clients", "threads", "isa",
        "ns_per_iter", "gops", "rps", "p50_ms", "p95_ms", "p99_ms",
    ];
    let used: Vec<&str> = COLS
        .iter()
        .copied()
        .filter(|c| all.iter().any(|(_, r)| r.get(c).is_some()))
        .collect();
    let mut out = String::new();
    out.push_str(&format!("| {} |\n", used.join(" | ")));
    out.push_str(&format!(
        "|{}\n",
        used.iter().map(|_| "---|").collect::<String>()
    ));
    for (_, r) in &all {
        let cells: Vec<String> = used
            .iter()
            .map(|c| match r.get(c) {
                Some(Json::Str(s)) => s.clone(),
                Some(Json::Num(n)) if n.fract() == 0.0 => {
                    format!("{}", *n as i64)
                }
                Some(Json::Num(n)) => format!("{n:.3}"),
                _ => String::new(),
            })
            .collect();
        out.push_str(&format!("| {} |\n", cells.join(" | ")));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::{BenchLog, Percentiles};

    fn sample_log() -> String {
        let mut log = BenchLog::default();
        log.add("gemm", "196x288x64", 1, "avx2", 0.001, 9_000_000);
        log.add("gemm", "196x288x64", 8, "avx2", 0.0002, 9_000_000);
        log.add_latency(
            "serve_tiny",
            "batched",
            16,
            8,
            1000,
            0.5,
            Percentiles { p50: 0.001, p95: 0.002, p99: 0.004 },
        );
        log.to_json()
    }

    #[test]
    fn identical_logs_pass_and_cover_all_metrics() {
        let doc = sample_log();
        let rep = check(&doc, &doc, &GateOptions::default()).unwrap();
        assert!(rep.pass(), "{}", rep.render());
        // 2 gemm rows × ns_per_iter + 1 latency row × (rps, p95)
        assert_eq!(rep.checks.len(), 4);
        assert_eq!(rep.failures(), 0);
        assert_eq!(rep.new_rows, 0);
        assert!(rep.render().contains("GATE PASS"));
    }

    #[test]
    fn injected_slowdown_past_threshold_fails_every_metric() {
        let doc = sample_log();
        let opts = GateOptions {
            inject_slowdown_pct: 30.0,
            ..GateOptions::default()
        };
        let rep = check(&doc, &doc, &opts).unwrap();
        assert!(!rep.pass());
        // every gated metric moved by 30% > 15%, in the right direction
        assert_eq!(rep.failures(), rep.checks.len());
        for c in &rep.checks {
            assert!(
                (c.regress_pct - 30.0).abs() < 1.0,
                "{} {}: {:.2}%",
                c.key,
                c.metric,
                c.regress_pct
            );
        }
        assert!(rep.render().contains("GATE FAIL"));
    }

    #[test]
    fn slowdown_within_threshold_passes() {
        let doc = sample_log();
        let opts = GateOptions {
            inject_slowdown_pct: 10.0,
            ..GateOptions::default()
        };
        assert!(check(&doc, &doc, &opts).unwrap().pass());
    }

    #[test]
    fn real_regression_in_one_row_is_pinned_to_that_row() {
        let base = r#"[
          {"name": "gemm", "shape": "a", "threads": 1, "isa": "avx2",
           "ns_per_iter": 1000, "gops": 9.0},
          {"name": "gemm", "shape": "b", "threads": 1, "isa": "avx2",
           "ns_per_iter": 1000, "gops": 9.0}
        ]"#;
        let cur = r#"[
          {"name": "gemm", "shape": "a", "threads": 1, "isa": "avx2",
           "ns_per_iter": 1300, "gops": 7.0},
          {"name": "gemm", "shape": "b", "threads": 1, "isa": "avx2",
           "ns_per_iter": 700, "gops": 12.0}
        ]"#;
        let rep = check(base, cur, &GateOptions::default()).unwrap();
        assert!(!rep.pass());
        assert_eq!(rep.failures(), 1);
        let bad = rep.checks.iter().find(|c| !c.ok).unwrap();
        assert!(bad.key.contains("shape=a"));
        assert!((bad.regress_pct - 30.0).abs() < 1e-9);
        // the improved row reports a negative regression
        let good = rep.checks.iter().find(|c| c.ok).unwrap();
        assert!(good.regress_pct < 0.0);
    }

    #[test]
    fn rps_drop_is_a_regression_even_though_smaller_number() {
        let base = r#"[{"name": "s", "mode": "batched", "clients": 4,
           "threads": 2, "rps": 1000.0, "p95_ms": 2.0}]"#;
        let cur = r#"[{"name": "s", "mode": "batched", "clients": 4,
           "threads": 2, "rps": 800.0, "p95_ms": 2.0}]"#;
        let rep = check(base, cur, &GateOptions::default()).unwrap();
        assert!(!rep.pass());
        let bad = rep.checks.iter().find(|c| !c.ok).unwrap();
        assert_eq!(bad.metric, "rps");
        assert!((bad.regress_pct - 20.0).abs() < 1e-9);
    }

    #[test]
    fn vanished_baseline_row_fails_new_rows_do_not() {
        let base = r#"[{"name": "gemm", "shape": "a", "threads": 1,
           "isa": "avx2", "ns_per_iter": 1000}]"#;
        let cur = r#"[{"name": "gemm", "shape": "b", "threads": 1,
           "isa": "avx2", "ns_per_iter": 1000}]"#;
        let rep = check(base, cur, &GateOptions::default()).unwrap();
        assert!(!rep.pass());
        assert_eq!(rep.missing.len(), 1);
        assert!(rep.missing[0].contains("shape=a"));
        assert_eq!(rep.new_rows, 1);
        // new rows alone never fail
        let rep = check("[]", cur, &GateOptions::default()).unwrap();
        assert!(rep.pass());
        assert_eq!(rep.new_rows, 1);
    }

    #[test]
    fn garbage_docs_are_errors_not_panics() {
        assert!(check("not json", "[]", &GateOptions::default()).is_err());
        assert!(check("[]", "{\"k\": 1}", &GateOptions::default()).is_err());
    }

    #[test]
    fn markdown_table_renders_only_used_columns() {
        let t = markdown_table(&sample_log()).unwrap();
        assert!(t.starts_with("| name |"));
        assert!(t.contains("ns_per_iter"));
        assert!(t.contains("| gemm |"));
        assert!(t.contains("serve_tiny"));
        // no latency-only column header duplication issues: p95 present,
        // and gemm rows leave latency cells blank rather than erroring
        assert!(t.contains("p95_ms"));
    }
}
