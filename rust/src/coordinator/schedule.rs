//! Cosine annealing with warm restarts + optimizer reset (paper §4.1.2:
//! "cosine annealing with the reset of optimizer parameters").

/// Learning-rate schedule over fine-tuning.
#[derive(Debug, Clone)]
pub struct CosineRestarts {
    pub lr_max: f32,
    pub lr_min: f32,
    /// steps per annealing cycle (a restart happens after each)
    pub cycle: usize,
    /// cycle-length multiplier after each restart (1 = fixed cycles)
    pub t_mult: usize,
}

impl CosineRestarts {
    pub fn new(lr_max: f32, cycle: usize) -> Self {
        CosineRestarts { lr_max, lr_min: lr_max * 0.01, cycle, t_mult: 1 }
    }

    /// (lr, is_restart) at global step `t` (0-based). `is_restart` is true
    /// on the first step of each cycle (optimizer state must be reset,
    /// including the Adam step counter).
    pub fn at(&self, t: usize) -> (f32, bool) {
        let (pos, len) = self.cycle_pos(t);
        let x = pos as f32 / len.max(1) as f32;
        let lr = self.lr_min
            + 0.5 * (self.lr_max - self.lr_min)
                * (1.0 + (std::f32::consts::PI * x).cos());
        (lr, pos == 0)
    }

    /// (step within cycle, cycle length) at global step t.
    fn cycle_pos(&self, mut t: usize) -> (usize, usize) {
        let mut len = self.cycle.max(1);
        loop {
            if t < len {
                return (t, len);
            }
            t -= len;
            len *= self.t_mult.max(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_max_and_decays() {
        let s = CosineRestarts::new(1.0, 10);
        let (lr0, r0) = s.at(0);
        assert!(r0);
        assert!((lr0 - 1.0).abs() < 1e-6);
        let (lr5, _) = s.at(5);
        assert!(lr5 < lr0);
        let (lr9, r9) = s.at(9);
        assert!(!r9);
        assert!(lr9 < lr5);
    }

    #[test]
    fn restarts_reset_lr() {
        let s = CosineRestarts::new(1.0, 10);
        let (lr10, r10) = s.at(10);
        assert!(r10);
        assert!((lr10 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn t_mult_grows_cycles() {
        let s = CosineRestarts { lr_max: 1.0, lr_min: 0.0, cycle: 4, t_mult: 2 };
        // cycles: [0..4), [4..12), [12..28)
        assert!(s.at(4).1);
        assert!(!s.at(8).1);
        assert!(s.at(12).1);
    }

    #[test]
    fn lr_bounded() {
        let s = CosineRestarts::new(0.01, 7);
        for t in 0..100 {
            let (lr, _) = s.at(t);
            assert!(lr >= s.lr_min - 1e-9 && lr <= s.lr_max + 1e-9);
        }
    }
}
