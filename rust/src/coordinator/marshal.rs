//! Manifest-driven marshalling: maps named tensor groups onto the flat
//! positional argument lists of the AOT executables.
//!
//! Artifact input names look like `"<argpos>/<key>"` (pytree leaves) or
//! `"<argpos>"` (scalars/arrays); outputs likewise. The coordinator never
//! hard-codes an argument order — everything flows through the manifest.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::ArtifactManifest;
use crate::tensor::Tensor;

/// One positional argument group.
pub enum Group<'a> {
    /// A single tensor (e.g. the batch, a scalar).
    Single(&'a Tensor),
    /// A dict-of-tensors pytree (weights, trainables, optimizer state).
    Map(&'a BTreeMap<String, Tensor>),
}

/// Assemble the positional input list in manifest order.
pub fn build_inputs(
    man: &ArtifactManifest,
    groups: &[Group],
) -> Result<Vec<Tensor>> {
    let mut out = Vec::with_capacity(man.inputs.len());
    for spec in &man.inputs {
        let (pos, key) = split_name(&spec.name);
        anyhow::ensure!(
            pos < groups.len(),
            "{}: input {} references arg {} but only {} groups given",
            man.name,
            spec.name,
            pos,
            groups.len()
        );
        let t = match (&groups[pos], key) {
            (Group::Single(t), None) => (*t).clone(),
            (Group::Map(m), Some(k)) => m
                .get(k)
                .ok_or_else(|| {
                    anyhow::anyhow!("{}: missing key {k} in arg {pos}", man.name)
                })?
                .clone(),
            (Group::Single(_), Some(k)) => {
                anyhow::bail!("{}: arg {pos} is single but key {k} given", man.name)
            }
            (Group::Map(_), None) => {
                anyhow::bail!("{}: arg {pos} is a map but no key", man.name)
            }
        };
        out.push(t);
    }
    Ok(out)
}

/// Split outputs back into groups: scalar outputs keyed `"<pos>"`,
/// map outputs keyed `"<pos>/<key>"`.
pub struct Outputs {
    pub singles: BTreeMap<usize, Tensor>,
    pub maps: BTreeMap<usize, BTreeMap<String, Tensor>>,
}

pub fn split_outputs(
    man: &ArtifactManifest,
    outs: Vec<Tensor>,
) -> Result<Outputs> {
    anyhow::ensure!(outs.len() == man.outputs.len(), "output arity mismatch");
    let mut res = Outputs { singles: BTreeMap::new(), maps: BTreeMap::new() };
    for (t, spec) in outs.into_iter().zip(&man.outputs) {
        let (pos, key) = split_name(&spec.name);
        match key {
            None => {
                res.singles.insert(pos, t);
            }
            Some(k) => {
                res.maps.entry(pos).or_default().insert(k.to_string(), t);
            }
        }
    }
    Ok(res)
}

fn split_name(name: &str) -> (usize, Option<&str>) {
    match name.split_once('/') {
        Some((pos, key)) => (pos.parse().unwrap_or(0), Some(key)),
        None => (name.parse().unwrap_or(0), None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn man() -> ArtifactManifest {
        ArtifactManifest::from_json(
            r#"{"name":"t","inputs":[
                {"name":"0/b.w","shape":[2],"dtype":"f32"},
                {"name":"0/a.w","shape":[1],"dtype":"f32"},
                {"name":"1","shape":[],"dtype":"f32"}],
              "outputs":[
                {"name":"0","shape":[],"dtype":"f32"},
                {"name":"1/x","shape":[2],"dtype":"f32"}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn builds_in_manifest_order() {
        let mut w = BTreeMap::new();
        w.insert("a.w".to_string(), Tensor::f32(vec![1], vec![1.0]));
        w.insert("b.w".to_string(), Tensor::f32(vec![2], vec![2.0, 3.0]));
        let s = Tensor::scalar_f32(7.0);
        let ins =
            build_inputs(&man(), &[Group::Map(&w), Group::Single(&s)]).unwrap();
        assert_eq!(ins.len(), 3);
        assert_eq!(ins[0].shape, vec![2]); // b.w first (manifest order)
        assert_eq!(ins[1].shape, vec![1]);
        assert_eq!(ins[2].as_f32().unwrap(), &[7.0]);
    }

    #[test]
    fn missing_key_errors() {
        let w = BTreeMap::new();
        let s = Tensor::scalar_f32(0.0);
        assert!(
            build_inputs(&man(), &[Group::Map(&w), Group::Single(&s)])
                .is_err()
        );
    }

    #[test]
    fn outputs_split() {
        let outs = vec![
            Tensor::scalar_f32(0.5),
            Tensor::f32(vec![2], vec![1.0, 2.0]),
        ];
        let o = split_outputs(&man(), outs).unwrap();
        assert_eq!(o.singles[&0].as_f32().unwrap(), &[0.5]);
        assert_eq!(o.maps[&1]["x"].as_f32().unwrap(), &[1.0, 2.0]);
    }
}
