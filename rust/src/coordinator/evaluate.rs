//! Accuracy evaluation through the AOT forward artifacts.

use std::sync::Arc;

use anyhow::Result;

use crate::data::{Batcher, Split};
use crate::runtime::Artifact;
use crate::tensor::Tensor;

/// Argmax accuracy of `logits` (n, classes) against labels.
pub fn argmax_accuracy(logits: &Tensor, labels: &[i32]) -> Result<(usize, usize)> {
    let n = logits.shape[0];
    let c = logits.shape[1];
    let d = logits.as_f32()?;
    let mut correct = 0;
    for i in 0..n {
        let row = &d[i * c..(i + 1) * c];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if arg as i32 == labels[i] {
            correct += 1;
        }
    }
    Ok((correct, n))
}

/// Evaluate accuracy over the validation split. `forward` maps an input
/// batch to logits through some artifact; `val_images` of 0 = full split.
pub fn accuracy_with(
    batch_size: usize,
    val_images: usize,
    mut forward: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let total = if val_images == 0 {
        crate::data::synth::VAL_SIZE
    } else {
        val_images.min(crate::data::synth::VAL_SIZE)
    };
    let batcher =
        Batcher::new(Split::Val, (0..total as u64).collect(), batch_size);
    let mut correct = 0usize;
    let mut seen = 0usize;
    for (x, labels) in batcher.epoch_iter(0) {
        let logits = forward(&x)?;
        let (c, n) = argmax_accuracy(&logits, &labels)?;
        correct += c;
        seen += n;
    }
    anyhow::ensure!(seen > 0, "no evaluation batches (batch {batch_size})");
    Ok(correct as f64 / seen as f64)
}

/// Accuracy of the integer-only int8 engine over the val split
/// (`val_images` of 0 = full split). The engine batch-shards each
/// 50-image batch across its configured workers and reuses its pooled
/// execution states, so this is the canonical (and parallel) int8
/// evaluation used by the launcher, the experiment drivers and the
/// benches.
pub fn int8_accuracy(
    engine: &crate::int8::Int8Engine,
    val_images: usize,
) -> Result<f64> {
    let total = if val_images == 0 {
        crate::data::synth::VAL_SIZE
    } else {
        val_images.min(crate::data::synth::VAL_SIZE)
    };
    let batcher = Batcher::new(Split::Val, (0..total as u64).collect(), 50);
    let mut correct = 0usize;
    let mut seen = 0usize;
    for (x, labels) in batcher.epoch_iter(0) {
        let logits = engine.infer_batch(&x)?;
        let (c, b) = argmax_accuracy(&logits, &labels)?;
        correct += c;
        seen += b;
    }
    anyhow::ensure!(seen > 0, "no int8 evaluation batches (val {val_images})");
    Ok(correct as f64 / seen as f64)
}

/// Batch size of an artifact's designated input-batch argument.
pub fn batch_size_of(art: &Arc<Artifact>, arg_name: &str) -> Result<usize> {
    art.manifest
        .inputs
        .iter()
        .find(|s| s.name == arg_name)
        .map(|s| s.shape[0])
        .ok_or_else(|| {
            anyhow::anyhow!("{}: no input {arg_name}", art.manifest.name)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_counts() {
        let l = Tensor::f32(vec![2, 3], vec![0.1, 0.9, 0.0, 1.0, 0.2, 0.3]);
        let (c, n) = argmax_accuracy(&l, &[1, 0]).unwrap();
        assert_eq!((c, n), (2, 2));
        let (c, _) = argmax_accuracy(&l, &[0, 0]).unwrap();
        assert_eq!(c, 1);
    }

    #[test]
    fn accuracy_with_synthetic_forward() {
        // forward that always predicts class = label via peeking batches
        let acc = accuracy_with(50, 200, |x| {
            let n = x.shape[0];
            // labels for val indices are idx % 10 in batch order
            let mut data = vec![0f32; n * 10];
            for i in 0..n {
                data[i * 10 + (i % 10)] = 1.0;
            }
            Ok(Tensor::f32(vec![n, 10], data))
        })
        .unwrap();
        assert_eq!(acc, 1.0);
    }
}
