//! Experiment drivers regenerating every table and figure of the paper
//! (DESIGN.md §4): shared by the `table1`/`table2`/`fig12`/`dws_ladder`/
//! `ablations` binaries and the bench harnesses. All drivers run on the
//! staged `quant::session` API.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::int8::serve::EngineOptions;
use crate::quant::calibrate::Calibrator;
use crate::quant::export::QuantMode;
use crate::quant::session::{CalibOpts, QuantSession, QuantSpec};
use crate::runtime::Registry;

use super::config::PipelineConfig;
use super::report::Report;

pub struct Ctx {
    pub reg: Arc<Registry>,
    pub artifacts: PathBuf,
}

impl Ctx {
    pub fn new(reg: Arc<Registry>, artifacts: impl AsRef<Path>) -> Self {
        Ctx { reg, artifacts: artifacts.as_ref().to_path_buf() }
    }

    /// Open a staged quantization session for `model`.
    pub fn session(&self, model: &str) -> Result<QuantSession> {
        QuantSession::open(self.reg.clone(), &self.artifacts, model)
    }

    pub fn results_dir(&self) -> PathBuf {
        self.artifacts.join("results")
    }
}

pub const TABLE_MODELS: [&str; 3] =
    ["mobilenet_v2_mini", "mnas_mini_10", "mnas_mini_13"];

/// Per-filter spread injected into the MobileNet-v2 analog before
/// quantization (log2 span; DESIGN.md §2): emulates the >100x per-filter
/// range disparity of real ImageNet checkpoints that our briefly-trained
/// mini net lacks. Function-preserving (FP accuracy is unchanged).
pub const MOBILENET_SPREAD_LOG2: f32 = 7.0;
pub const SPREAD_SEED: u64 = 0xD15;

fn prepare(ctx: &Ctx, model: &str) -> Result<QuantSession> {
    let mut s = ctx.session(model)?;
    if model == "mobilenet_v2_mini" {
        s.inject_spread(SPREAD_SEED, MOBILENET_SPREAD_LOG2)?;
    }
    Ok(s)
}

/// Tables 1 & 2: FAT-fine-tuned accuracy under symmetric vs asymmetric
/// thresholds, in scalar (`vector=false`, Table 1) or vector (Table 2)
/// weight-quantization mode.
pub fn accuracy_table(
    ctx: &Ctx,
    vector: bool,
    cfg: &PipelineConfig,
    log: impl Fn(&str),
) -> Result<Report> {
    let (m_sym, m_asym, title) = if vector {
        (QuantMode::SymVector, QuantMode::AsymVector, "Table 2: 8-bit vector mode")
    } else {
        (QuantMode::SymScalar, QuantMode::AsymScalar, "Table 1: 8-bit scalar mode")
    };
    let mut rep = Report::new(title);
    let opts = cfg.finetune_opts(false);
    let calibrator = cfg.quant_spec()?.calibrator;
    for model in TABLE_MODELS {
        let session = prepare(ctx, model)?;
        let cal = session.calibrate(CalibOpts::images(cfg.calib_images))?;
        let fp = cal.fp_accuracy(cfg.val_images)?;
        log(&format!("[{model}] FP {:.2}%", fp * 100.0));
        let mut cells = vec![];
        for mode in [m_sym, m_asym] {
            let spec =
                QuantSpec::from_mode(mode).with_calibrator(calibrator);
            let th = cal.finetune(&spec, &opts, |_, _, _| {})?;
            let acc = th.quant_accuracy(cfg.val_images)?;
            let losses = th.losses();
            log(&format!(
                "[{model}] {} fine-tuned {} steps (rmse {:.4}→{:.4}): {:.2}%",
                mode.name(),
                losses.len(),
                losses.first().unwrap_or(&0.0),
                losses.last().unwrap_or(&0.0),
                acc * 100.0
            ));
            let label = if mode.asym() {
                "Asymmetric thresholds"
            } else {
                "Symmetric thresholds"
            };
            cells.push((label.to_string(), acc));
        }
        cells.push(("Original accuracy".to_string(), fp));
        rep.add(model, cells);
    }
    Ok(rep)
}

/// Figures 1-2: weight histograms of the reference net before and after
/// symmetric per-tensor quantization (the paper's ResNet plots).
pub fn weight_histograms(
    ctx: &Ctx,
    model: &str,
    bins: usize,
) -> Result<WeightHists> {
    let s = ctx.session(model)?;
    let core = s.core();
    let mut all: Vec<f32> = vec![];
    let mut all_q: Vec<f32> = vec![];
    for n in core.graph.conv_like() {
        let w = core.weights[&format!("{}.w", n.id)].as_f32()?;
        all.extend_from_slice(w);
        // per-tensor symmetric fake-quant at T = max|w| (paper's Fig. 2)
        let t = crate::quant::thresholds::per_tensor_w_threshold(w);
        let qp = crate::quant::scale::QParams::symmetric_signed(t);
        all_q.extend(w.iter().map(|&v| qp.fake_quant(v)));
    }
    let lim = all.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let hist = |vals: &[f32]| -> Vec<(f64, f64)> {
        let mut h = vec![0u64; bins];
        for &v in vals {
            let i = (((v + lim) / (2.0 * lim)) * bins as f32) as usize;
            h[i.min(bins - 1)] += 1;
        }
        h.iter()
            .enumerate()
            .map(|(i, &c)| {
                let centre =
                    -lim + 2.0 * lim * (i as f32 + 0.5) / bins as f32;
                (centre as f64, c as f64)
            })
            .collect()
    };
    let zeros = |v: &[f32]| v.iter().filter(|&&x| x == 0.0).count();
    Ok(WeightHists {
        before: hist(&all),
        after: hist(&all_q),
        zeros_before: zeros(&all),
        zeros_after: zeros(&all_q),
        total: all.len(),
    })
}

/// Figures 1-2 data: histograms + exact-zero counts (the paper's Fig. 2
/// "values in bins near zero increased significantly" shows up most
/// sharply as weights snapping to the zero grid point).
pub struct WeightHists {
    pub before: Vec<(f64, f64)>,
    pub after: Vec<(f64, f64)>,
    pub zeros_before: usize,
    pub zeros_after: usize,
    pub total: usize,
}

/// §4.2 ladder on MobileNet-v2: scalar quant → + DWS rescale → + rescale
/// with point-wise fine-tune (and FAT thresholds as the paper's framing).
pub fn dws_ladder(
    ctx: &Ctx,
    cfg: &PipelineConfig,
    log: impl Fn(&str),
) -> Result<Report> {
    let model = "mobilenet_v2_mini";
    let spec = QuantSpec::from_mode(QuantMode::SymScalar)
        .with_calibrator(cfg.quant_spec()?.calibrator);
    let mut rep = Report::new("§4.2 ladder: MobileNet-v2, 8-bit scalar");

    // rung 0: plain scalar quantization (paper: ~1.6%)
    let cal0 = prepare(ctx, model)?
        .calibrate(CalibOpts::images(cfg.calib_images))?;
    let fp = cal0.fp_accuracy(cfg.val_images)?;
    let plain = cal0.identity(&spec)?.quant_accuracy(cfg.val_images)?;
    log(&format!("plain scalar: {:.2}%", plain * 100.0));

    // rung 1: + §3.3 weight rescaling (paper: ~67%); the stage
    // transition re-calibrates the thresholds after the weights move.
    // The session is scoped to its statement so dws_rescale holds the
    // only reference to the model state (mutates in place, no copy).
    let cal1 = prepare(ctx, model)?
        .calibrate(CalibOpts::images(cfg.calib_images))?;
    let cal1 = cal1.dws_rescale()?;
    for r in cal1.rescale_reports() {
        log(&format!(
            "  rescale {}: spread {:.1}→{:.1} ({} locked/{})",
            r.dw, r.spread_before, r.spread_after, r.locked, r.channels
        ));
    }
    let rescaled = cal1.identity(&spec)?.quant_accuracy(cfg.val_images)?;
    log(&format!("+ rescale: {:.2}%", rescaled * 100.0));

    // rung 2: + point-wise weight fine-tuning (paper: ~71%)
    let (pw, losses) =
        cal1.finetune_pointwise(&spec, &cfg.finetune_opts(true), |_, _, _| {})?;
    let pw_acc = cal1.pointwise_accuracy(&spec, &pw, cfg.val_images)?;
    log(&format!(
        "+ pointwise ft ({} steps, rmse {:.4}→{:.4}): {:.2}%",
        losses.len(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0),
        pw_acc * 100.0
    ));

    // reference rung: FAT threshold fine-tuning on the rescaled model
    let fat_acc = cal1
        .finetune(&spec, &cfg.finetune_opts(false), |_, _, _| {})?
        .quant_accuracy(cfg.val_images)?;
    log(&format!("+ FAT thresholds: {:.2}%", fat_acc * 100.0));

    rep.add(
        model,
        vec![
            ("FP".into(), fp),
            ("Scalar quant".into(), plain),
            ("+ DWS rescale".into(), rescaled),
            ("+ pointwise FT".into(), pw_acc),
            ("+ FAT thresholds".into(), fat_acc),
        ],
    );
    Ok(rep)
}

/// A1 ablation: calibration-set size sweep and baseline calibrators
/// (max / percentile / KL) without fine-tuning.
pub fn ablations(
    ctx: &Ctx,
    model: &str,
    cfg: &PipelineConfig,
    log: impl Fn(&str),
) -> Result<Report> {
    let spec = QuantSpec::from_mode(QuantMode::SymVector);
    let mut rep = Report::new("A1 ablations (no fine-tune, sym vector)");
    let session = ctx.session(model)?;
    let fp = session.fp_accuracy(cfg.val_images)?;

    // calibration-size sweep (the open stage is reusable)
    let mut cells = vec![("FP".to_string(), fp)];
    for n in [25usize, 100, 500] {
        let cal = session.calibrate(CalibOpts::images(n))?;
        let acc = cal.identity(&spec)?.quant_accuracy(cfg.val_images)?;
        log(&format!("calib {n}: {:.2}%", acc * 100.0));
        cells.push((format!("calib={n}"), acc));
    }

    // baseline calibrators, through the same spec-driven path the
    // launcher's `--calibrator` flag uses
    let cal = session.calibrate(CalibOpts::images(cfg.calib_images))?;
    for c in [Calibrator::Percentile(9990), Calibrator::Kl] {
        match cal.identity(&spec.with_calibrator(c)) {
            Ok(th) => {
                let acc = th.quant_accuracy(cfg.val_images)?;
                log(&format!("calibrator {}: {:.2}%", c.name(), acc * 100.0));
                cells.push((format!("cal={}", c.name()), acc));
            }
            Err(e) => log(&format!("calibrator {} unavailable: {e}", c.name())),
        }
    }
    rep.add(model, cells);
    Ok(rep)
}

/// Helper shared by bins: no-finetune accuracy row with both int8-engine
/// and fake-quant numbers.
pub fn int8_agreement(
    ctx: &Ctx,
    model: &str,
    mode: QuantMode,
    val: usize,
) -> Result<(f64, f64)> {
    let th = ctx
        .session(model)?
        .calibrate(CalibOpts::images(100))?
        .identity(&QuantSpec::from_mode(mode))?;
    let fake = th.quant_accuracy(val)?;
    let engine = th.serve(EngineOptions::default())?;
    let acc = super::evaluate::int8_accuracy(&engine, val)?;
    Ok((fake, acc))
}

/// Accuracy of the integer engine over the val split (the canonical
/// implementation lives in `evaluate`; re-exported here for the bins,
/// benches and examples that import it from the experiments module).
pub fn int8_accuracy(
    engine: &crate::int8::Int8Engine,
    val: usize,
) -> Result<f64> {
    super::evaluate::int8_accuracy(engine, val)
}

/// Quick-run configuration for benches.
pub fn default_cfg_fast() -> PipelineConfig {
    PipelineConfig::default().fast()
}
