//! Experiment reporting: paper-shaped tables + CSV artifacts.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// One row of a paper-style accuracy table.
#[derive(Debug, Clone)]
pub struct Row {
    pub arch: String,
    pub cells: Vec<(String, f64)>,
}

/// A named table (mirrors a table/figure of the paper).
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub title: String,
    pub rows: Vec<Row>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), rows: vec![] }
    }

    pub fn add(&mut self, arch: &str, cells: Vec<(String, f64)>) {
        self.rows.push(Row { arch: arch.to_string(), cells });
    }

    /// Render as a GitHub-flavoured markdown table (accuracies in %).
    pub fn markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}", self.title);
        if self.rows.is_empty() {
            return s;
        }
        let headers: Vec<&str> = self.rows[0]
            .cells
            .iter()
            .map(|(h, _)| h.as_str())
            .collect();
        let _ = writeln!(s, "| Architecture | {} |", headers.join(" | "));
        let _ = writeln!(
            s,
            "|---|{}|",
            headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let cells: Vec<String> = r
                .cells
                .iter()
                .map(|(_, v)| format!("{:.2}", v * 100.0))
                .collect();
            let _ = writeln!(s, "| {} | {} |", r.arch, cells.join(" | "));
        }
        s
    }

    /// Write rows as CSV to `artifacts/results/<name>.csv`.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut s = String::new();
        if let Some(r0) = self.rows.first() {
            let heads: Vec<&str> =
                r0.cells.iter().map(|(h, _)| h.as_str()).collect();
            let _ = writeln!(s, "arch,{}", heads.join(","));
        }
        for r in &self.rows {
            let vals: Vec<String> =
                r.cells.iter().map(|(_, v)| format!("{v:.6}")).collect();
            let _ = writeln!(s, "{},{}", r.arch, vals.join(","));
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

/// Write a simple two-column CSV (e.g. histograms, loss curves).
pub fn write_series_csv<P: AsRef<Path>>(
    path: P,
    header: &str,
    rows: impl IntoIterator<Item = (f64, f64)>,
) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut s = format!("{header}\n");
    for (a, b) in rows {
        let _ = writeln!(s, "{a},{b}");
    }
    std::fs::write(path, s)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_render() {
        let mut r = Report::new("Table 1");
        r.add(
            "net",
            vec![("Symmetric".into(), 0.7242), ("Original".into(), 0.7434)],
        );
        let md = r.markdown();
        assert!(md.contains("Table 1"));
        assert!(md.contains("72.42"));
        assert!(md.contains("74.34"));
    }

    #[test]
    fn csv_write() {
        let mut r = Report::new("t");
        r.add("a", vec![("x".into(), 0.5)]);
        let p = std::env::temp_dir().join("fat_report_test.csv");
        r.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("arch,x"));
        assert!(s.contains("a,0.5"));
    }
}
