//! L3 coordinator: the FAT quantization pipeline.
//!
//! Orchestrates the paper's end-to-end flow with Python long gone:
//! calibrate → (optional §3.3 DWS rescale) → init α → fine-tune thresholds
//! (RMSE distillation via the `train_step_*` artifacts, Adam + cosine
//! annealing with optimizer reset) → evaluate → export int8.
//!
//! The staged public API lives in [`crate::quant::session`]
//! ([`crate::quant::QuantSession`] → `Calibrated` → `Thresholded` →
//! [`crate::int8::Int8Engine`]); the loose [`Pipeline`] handle here is a
//! deprecated shim kept for one release.

pub mod config;
pub mod evaluate;
pub mod experiments;
pub mod finetune;
pub mod marshal;
pub mod pipeline;
pub mod report;
pub mod schedule;

pub use config::PipelineConfig;
#[allow(deprecated)]
pub use pipeline::Pipeline;
pub use report::Report;
