//! L3 coordinator: the FAT quantization pipeline.
//!
//! Orchestrates the paper's end-to-end flow with Python long gone:
//! calibrate → (optional §3.3 DWS rescale) → init α → fine-tune thresholds
//! (RMSE distillation via the `train_step_*` artifacts, Adam + cosine
//! annealing with optimizer reset) → evaluate → export int8.
//!
//! The staged public API lives in [`crate::quant::session`]
//! ([`crate::quant::QuantSession`] → `Calibrated` → `Thresholded` →
//! [`crate::int8::Int8Engine`]). The deprecated loose `Pipeline` shim
//! that used to live here was removed after its one grace release; the
//! session core ([`crate::quant::session::SessionCore`]) exposes the
//! same primitives.

pub mod config;
pub mod evaluate;
pub mod experiments;
pub mod finetune;
pub mod marshal;
pub mod report;
pub mod schedule;

pub use config::PipelineConfig;
pub use report::Report;
