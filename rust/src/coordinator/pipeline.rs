//! The FAT pipeline: one struct that owns a model's artifacts + weights
//! and exposes every stage of the paper's flow.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::data::{Batcher, Split};
use crate::int8::QModel;
use crate::model::{GraphDef, ModelStore};
use crate::quant::calibrate::CalibStats;
use crate::quant::dws::{self, PatternReport};
use crate::quant::export::{self, QuantMode, Trained};
use crate::quant::fold;
use crate::runtime::{Artifact, Registry};
use crate::tensor::Tensor;

use super::config::PipelineConfig;
use super::evaluate::{accuracy_with, batch_size_of};
use super::finetune::{self, FinetuneOpts};
use super::marshal::{build_inputs, split_outputs, Group};

pub struct Pipeline {
    pub reg: Arc<Registry>,
    pub store: ModelStore,
    pub graph: GraphDef,
    pub sites: crate::model::store::SitesJson,
    /// Rust-folded weights (mutated in place by §3.3 rescaling).
    pub weights: BTreeMap<String, Tensor>,
}

impl Pipeline {
    pub fn new<P: AsRef<Path>>(
        reg: Arc<Registry>,
        artifacts: P,
        model: &str,
    ) -> Result<Self> {
        let store = ModelStore::open(&artifacts, model)?;
        let raw_graph = store.graph()?;
        let graph = store.folded_graph()?;
        let sites = store.sites()?;
        let raw = store.raw_weights()?;
        // BN folding happens here, in Rust (eq. 10-11); the Python-folded
        // weights only serve as a golden cross-check in tests.
        let weights = fold::fold_bn(&raw_graph, &raw)?;
        Ok(Pipeline { reg, store, graph, sites, weights })
    }

    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        self.reg.get(self.store.artifact_path(name))
    }

    // -- calibration --------------------------------------------------

    /// Run the calibration pass over `images` training images (paper: 100).
    pub fn calibrate(&self, images: usize) -> Result<CalibStats> {
        let art = self.artifact("calib_stats")?;
        let bs = batch_size_of(&art, "1")?;
        let mut stats = CalibStats::new(self.sites.sites.len());
        let indices: Vec<u64> = (0..images.max(bs) as u64).collect();
        let batcher = Batcher::new(Split::Train, indices, bs);
        for (x, _) in batcher.epoch_iter(0) {
            let inputs = build_inputs(
                &art.manifest,
                &[Group::Map(&self.weights), Group::Single(&x)],
            )?;
            let outs = art.execute(&inputs)?;
            let o = split_outputs(&art.manifest, outs)?;
            let mm = o.singles[&0].as_f32()?;
            for (i, s) in stats.site_minmax.iter_mut().enumerate() {
                s.update(mm[i * 2], mm[i * 2 + 1]);
            }
            for (key, t) in &o.maps[&1] {
                let nid = key.trim_start_matches("ch:").to_string();
                let d = t.as_f32()?;
                let c = t.shape[1];
                let entry = stats
                    .channel_minmax
                    .entry(nid)
                    .or_insert_with(|| {
                        vec![Default::default(); c]
                    });
                for (ci, e) in entry.iter_mut().enumerate() {
                    e.update(d[ci], d[c + ci]);
                }
            }
            stats.batches += 1;
        }
        Ok(stats)
    }

    /// Second pass: per-site histograms over the calibrated ranges
    /// (used by the baseline-calibrator ablation).
    pub fn calibrate_hist(
        &self,
        stats: &CalibStats,
        images: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let art = self.artifact("calib_hist")?;
        let bs = batch_size_of(&art, "2")?;
        let act_t = stats.act_t_tensor();
        let nsites = self.sites.sites.len();
        let mut hists: Vec<Vec<u32>> = vec![];
        let indices: Vec<u64> = (0..images.max(bs) as u64).collect();
        let batcher = Batcher::new(Split::Train, indices, bs);
        for (x, _) in batcher.epoch_iter(0) {
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(&self.weights),
                    Group::Single(&act_t),
                    Group::Single(&x),
                ],
            )?;
            let outs = art.execute(&inputs)?;
            let o = split_outputs(&art.manifest, outs)?;
            let h = o.singles[&0].as_i32()?;
            let bins = h.len() / nsites;
            if hists.is_empty() {
                hists = vec![vec![0u32; bins]; nsites];
            }
            for s in 0..nsites {
                for b in 0..bins {
                    hists[s][b] += h[s * bins + b] as u32;
                }
            }
        }
        Ok(hists)
    }

    // -- evaluation ---------------------------------------------------

    pub fn fp_accuracy(&self, val_images: usize) -> Result<f64> {
        let art = self.artifact("fp_forward")?;
        let bs = batch_size_of(&art, "1")?;
        accuracy_with(bs, val_images, |x| {
            let inputs = build_inputs(
                &art.manifest,
                &[Group::Map(&self.weights), Group::Single(x)],
            )?;
            Ok(art.execute(&inputs)?.remove(0))
        })
    }

    /// Accuracy of the fake-quant forward under `trained` thresholds.
    pub fn quant_accuracy(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        let art = self.artifact(&format!("quant_fwd_{}", mode.name()))?;
        let bs = batch_size_of(&art, "3")?;
        let act_t = stats.act_t_tensor();
        accuracy_with(bs, val_images, |x| {
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(&self.weights),
                    Group::Single(&act_t),
                    Group::Map(trained),
                    Group::Single(x),
                ],
            )?;
            Ok(art.execute(&inputs)?.remove(0))
        })
    }

    /// §4.2 point-wise variant (mobilenet only).
    pub fn pointwise_accuracy(
        &self,
        stats: &CalibStats,
        pw: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        let art = self.artifact("quant_fwd_pw")?;
        let bs = batch_size_of(&art, "3")?;
        let act_t = stats.act_t_tensor();
        accuracy_with(bs, val_images, |x| {
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(&self.weights),
                    Group::Single(&act_t),
                    Group::Map(pw),
                    Group::Single(x),
                ],
            )?;
            Ok(art.execute(&inputs)?.remove(0))
        })
    }

    // -- fine-tuning ----------------------------------------------------

    pub fn finetune(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        cfg: &PipelineConfig,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        let art = self.artifact(&format!("train_step_{}", mode.name()))?;
        let opts = FinetuneOpts {
            epochs: cfg.epochs,
            stride: cfg.finetune_stride,
            lr: cfg.lr,
            cycle: cfg.cycle,
            max_steps: cfg.max_steps,
            seed: cfg.seed,
        };
        finetune::run(&art, &self.weights, &stats.act_t_tensor(), &opts, progress)
    }

    /// §4.2 point-wise fine-tuning (same loop, `train_step_pw` artifact).
    pub fn finetune_pointwise(
        &self,
        stats: &CalibStats,
        cfg: &PipelineConfig,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        let art = self.artifact("train_step_pw")?;
        let opts = FinetuneOpts {
            epochs: cfg.epochs,
            stride: cfg.finetune_stride,
            lr: cfg.pw_lr,
            cycle: cfg.cycle,
            max_steps: cfg.max_steps,
            seed: cfg.seed,
        };
        finetune::run(&art, &self.weights, &stats.act_t_tensor(), &opts, progress)
    }

    /// Inject per-filter range disparity (DESIGN.md §2 substitution for
    /// the disparity of real ImageNet checkpoints). Function-preserving.
    pub fn inject_spread(&mut self, seed: u64, span_log2: f32) -> Result<usize> {
        dws::inject_spread(&self.graph, &mut self.weights, seed, span_log2)
    }

    // -- §3.3 DWS rescaling -------------------------------------------

    /// Apply §3.3 weight rescaling in place (before quantization).
    pub fn dws_rescale(
        &mut self,
        stats: &CalibStats,
    ) -> Result<Vec<PatternReport>> {
        let ch_max: BTreeMap<String, Vec<f32>> = stats
            .channel_minmax
            .iter()
            .map(|(k, v)| {
                (k.clone(), v.iter().map(|mm| mm.max).collect())
            })
            .collect();
        dws::rescale_model(&self.graph, &mut self.weights, &ch_max)
    }

    // -- export ---------------------------------------------------------

    /// Convert trainable-map thresholds into the exporter's form.
    pub fn trained_of_map(
        &self,
        mode: QuantMode,
        tr: &BTreeMap<String, Tensor>,
    ) -> Result<Trained> {
        let mut out = Trained::identity(
            &self.graph,
            mode,
            self.sites.sites.len(),
        );
        for (k, t) in tr {
            let v = t.as_f32()?.to_vec();
            if k == "act_a" {
                out.act_a = v;
            } else if k == "act_at" {
                out.act_at = v;
            } else if k == "act_ar" {
                out.act_ar = v;
            } else if let Some(node) = k.strip_prefix("w_a:") {
                out.w_a.insert(node.to_string(), v);
            }
        }
        Ok(out)
    }

    /// Build the integer-only deployment model. This also compiles the
    /// engine's execution plan once (topological schedule, dense param
    /// indices, liveness-based buffer slots — `int8::plan`); the
    /// returned [`QModel`] then serves any number of `run_batch` calls,
    /// batch-sharded across `$FAT_THREADS` workers.
    pub fn export_int8(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        trained: &Trained,
    ) -> Result<QModel> {
        export::build_qmodel(
            &self.graph,
            &self.weights,
            &self.sites,
            stats,
            mode,
            trained,
        )
    }

    /// Identity thresholds (α=1): "quantization without fine-tuning".
    pub fn identity_trained(&self, mode: QuantMode) -> Trained {
        Trained::identity(&self.graph, mode, self.sites.sites.len())
    }

    /// Identity trainable map shaped from the artifact manifest.
    pub fn identity_trainables(
        &self,
        mode: QuantMode,
    ) -> Result<BTreeMap<String, Tensor>> {
        let art = self.artifact(&format!("train_step_{}", mode.name()))?;
        Ok(finetune::init_trainables(&art))
    }
}
