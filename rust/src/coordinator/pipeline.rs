//! Legacy [`Pipeline`] — a thin, deprecated shim over
//! [`SessionCore`](crate::quant::session::SessionCore).
//!
//! The loose per-stage methods here let callers thread `(mode, stats,
//! trained)` by hand and silently skip or reorder the paper's dataflow;
//! new code should drive the staged
//! [`QuantSession`](crate::quant::session::QuantSession) API instead,
//! which encodes calibrate → rescale → threshold → export in the type
//! system and serves inference through
//! [`Int8Engine`](crate::int8::serve::Int8Engine). The shim is kept for
//! one release; every method simply delegates to the session core.

use std::collections::BTreeMap;
use std::ops::{Deref, DerefMut};
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::int8::QModel;
use crate::quant::calibrate::CalibStats;
use crate::quant::dws::PatternReport;
use crate::quant::export::{QuantMode, Trained};
use crate::quant::session::{export_with, QuantSpec, SessionCore, ThresholdSet};
use crate::runtime::{Artifact, Registry};
use crate::tensor::Tensor;

use super::config::PipelineConfig;

/// Deprecated pre-session pipeline handle. Field access (`.graph`,
/// `.weights`, …) still works through `Deref` to the session core.
#[deprecated(
    since = "0.2.0",
    note = "use quant::session::QuantSession (staged API) and \
            int8::serve::Int8Engine (serving handle) instead"
)]
pub struct Pipeline {
    /// The shared session core this shim delegates to.
    pub core: SessionCore,
}

#[allow(deprecated)]
impl Deref for Pipeline {
    type Target = SessionCore;

    fn deref(&self) -> &SessionCore {
        &self.core
    }
}

#[allow(deprecated)]
impl DerefMut for Pipeline {
    fn deref_mut(&mut self) -> &mut SessionCore {
        &mut self.core
    }
}

#[allow(deprecated)]
impl Pipeline {
    pub fn new<P: AsRef<Path>>(
        reg: Arc<Registry>,
        artifacts: P,
        model: &str,
    ) -> Result<Self> {
        Ok(Pipeline { core: SessionCore::open(reg, artifacts, model)? })
    }

    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        self.core.artifact(name)
    }

    // -- calibration --------------------------------------------------

    /// Run the calibration pass over `images` training images (paper: 100).
    pub fn calibrate(&self, images: usize) -> Result<CalibStats> {
        self.core.calibrate(images)
    }

    /// Second pass: per-site histograms over the calibrated ranges.
    pub fn calibrate_hist(
        &self,
        stats: &CalibStats,
        images: usize,
    ) -> Result<Vec<Vec<u32>>> {
        self.core.calibrate_hist(stats, images)
    }

    // -- evaluation ---------------------------------------------------

    pub fn fp_accuracy(&self, val_images: usize) -> Result<f64> {
        self.core.fp_accuracy(val_images)
    }

    /// Accuracy of the fake-quant forward under `trained` thresholds.
    pub fn quant_accuracy(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        self.core.quant_accuracy(mode, stats, trained, val_images)
    }

    /// §4.2 point-wise variant (mobilenet only).
    pub fn pointwise_accuracy(
        &self,
        stats: &CalibStats,
        pw: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        self.core.pointwise_accuracy(stats, pw, val_images)
    }

    // -- fine-tuning ----------------------------------------------------

    pub fn finetune(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        cfg: &PipelineConfig,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        self.core.finetune(mode, stats, &cfg.finetune_opts(false), progress)
    }

    /// §4.2 point-wise fine-tuning (same loop, `train_step_pw` artifact).
    pub fn finetune_pointwise(
        &self,
        stats: &CalibStats,
        cfg: &PipelineConfig,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        self.core.finetune_pointwise(stats, &cfg.finetune_opts(true), progress)
    }

    /// Inject per-filter range disparity (DESIGN.md §2). Function-preserving.
    pub fn inject_spread(&mut self, seed: u64, span_log2: f32) -> Result<usize> {
        self.core.inject_spread(seed, span_log2)
    }

    // -- §3.3 DWS rescaling -------------------------------------------

    /// Apply §3.3 weight rescaling in place (before quantization).
    pub fn dws_rescale(
        &mut self,
        stats: &CalibStats,
    ) -> Result<Vec<PatternReport>> {
        self.core.dws_rescale(stats)
    }

    // -- export ---------------------------------------------------------

    /// Convert trainable-map thresholds into the exporter's form.
    /// Unknown keys are an error (see [`ThresholdSet::from_trainables`]).
    pub fn trained_of_map(
        &self,
        mode: QuantMode,
        tr: &BTreeMap<String, Tensor>,
    ) -> Result<Trained> {
        Ok(ThresholdSet::from_trainables(
            &self.core.graph,
            mode,
            self.core.sites.sites.len(),
            tr,
        )?
        .into_trained())
    }

    /// Build the integer-only deployment model (compiles the engine's
    /// execution plan once — `int8::plan`).
    pub fn export_int8(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        trained: &Trained,
    ) -> Result<QModel> {
        export_with(
            &self.core.graph,
            &self.core.weights,
            &self.core.sites,
            stats,
            &QuantSpec::from_mode(mode),
            &ThresholdSet::from_parts(mode, trained.clone()),
        )
    }

    /// Identity thresholds (α=1): "quantization without fine-tuning".
    pub fn identity_trained(&self, mode: QuantMode) -> Trained {
        Trained::identity(&self.core.graph, mode, self.core.sites.sites.len())
    }

    /// Identity trainable map shaped from the artifact manifest.
    pub fn identity_trainables(
        &self,
        mode: QuantMode,
    ) -> Result<BTreeMap<String, Tensor>> {
        self.core.identity_trainables(mode)
    }
}
