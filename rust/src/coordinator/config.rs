//! Pipeline configuration — the launcher's contract.
//!
//! Loaded from a flat `key = value` TOML-subset file (full TOML is not
//! needed: all settings are scalars).

use std::path::Path;

use anyhow::{Context, Result};

use super::finetune::FinetuneOpts;

/// Full configuration of one FAT pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// model name under `artifacts/models/`
    pub model: String,
    /// quantization mode: sym_scalar | sym_vector | asym_scalar | asym_vector
    pub mode: String,
    /// static threshold calibrator: max | p99 | p999 | p9999 | kl
    /// (paper default: max; others need the `calib_hist` artifact)
    pub calibrator: String,
    /// calibration images (paper: 100)
    pub calib_images: usize,
    /// fine-tune epochs over the unlabeled subset (paper: 6-8)
    pub epochs: usize,
    /// every `finetune_stride`-th train image is used (paper: 10 => ~10%)
    pub finetune_stride: usize,
    /// Adam peak learning rate for threshold scales
    pub lr: f32,
    /// Adam peak learning rate for §4.2 point-wise weight scales (much
    /// smaller: it perturbs every weight element)
    pub pw_lr: f32,
    /// cosine-annealing cycle in steps (0 = one cycle per epoch)
    pub cycle: usize,
    /// cap on fine-tune steps (0 = no cap) — useful on slow boxes
    pub max_steps: usize,
    /// validation images for accuracy reporting (0 = full split)
    pub val_images: usize,
    /// apply §3.3 DWS rescaling before quantization
    pub dws_rescale: bool,
    /// deterministic shuffle seed
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            model: "mobilenet_v2_mini".into(),
            mode: "sym_scalar".into(),
            calibrator: "max".into(),
            calib_images: 100,
            epochs: 6,
            finetune_stride: 10,
            lr: 2e-2,
            pw_lr: 5e-4,
            cycle: 0,
            max_steps: 0,
            val_images: 0,
            dws_rescale: false,
            seed: 0xFA7,
        }
    }
}

impl PipelineConfig {
    /// Parse a flat `key = value` config (strings may be quoted; `#`
    /// starts a comment).
    pub fn from_str(s: &str) -> Result<Self> {
        let mut c = PipelineConfig::default();
        for (lineno, line) in s.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("config line {}: expected key = value", lineno + 1)
            })?;
            let k = k.trim();
            let v = v.trim().trim_matches('"').trim_matches('\'');
            match k {
                "model" => c.model = v.to_string(),
                "mode" => c.mode = v.to_string(),
                "calibrator" => c.calibrator = v.to_string(),
                "calib_images" => c.calib_images = v.parse()?,
                "epochs" => c.epochs = v.parse()?,
                "finetune_stride" => c.finetune_stride = v.parse()?,
                "lr" => c.lr = v.parse()?,
                "pw_lr" => c.pw_lr = v.parse()?,
                "cycle" => c.cycle = v.parse()?,
                "max_steps" => c.max_steps = v.parse()?,
                "val_images" => c.val_images = v.parse()?,
                "dws_rescale" => c.dws_rescale = v.parse()?,
                "seed" => c.seed = v.parse()?,
                other => anyhow::bail!("unknown config key {other}"),
            }
        }
        Ok(c)
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let s = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_str(&s)
    }

    /// Quick-run override used by examples/benches on slow machines.
    pub fn fast(mut self) -> Self {
        self.epochs = 2;
        self.max_steps = 40;
        self.val_images = 500;
        self
    }

    /// The fine-tune stage's options (`pointwise` switches to the much
    /// smaller §4.2 point-wise learning rate).
    pub fn finetune_opts(&self, pointwise: bool) -> FinetuneOpts {
        FinetuneOpts {
            epochs: self.epochs,
            stride: self.finetune_stride,
            lr: if pointwise { self.pw_lr } else { self.lr },
            cycle: self.cycle,
            max_steps: self.max_steps,
            seed: self.seed,
        }
    }

    /// The quantization spec encoded by `mode` + `calibrator`.
    pub fn quant_spec(&self) -> Result<crate::quant::QuantSpec> {
        crate::quant::QuantSpec::parse(&self.mode, &self.calibrator)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = PipelineConfig::default();
        assert_eq!(c.calib_images, 100);
        assert_eq!(c.finetune_stride, 10);
        assert!(c.epochs >= 6);
    }

    #[test]
    fn parse_roundtrip() {
        let c = PipelineConfig::from_str(
            "model = 'mnas_mini_10'\nmode = \"asym_vector\"\nepochs = 2\n# comment\ndws_rescale = true\n",
        )
        .unwrap();
        assert_eq!(c.model, "mnas_mini_10");
        assert_eq!(c.mode, "asym_vector");
        assert_eq!(c.epochs, 2);
        assert!(c.dws_rescale);
        assert_eq!(c.calib_images, 100); // default preserved
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(PipelineConfig::from_str("nope = 3").is_err());
    }

    #[test]
    fn calibrator_key_flows_into_spec() {
        let c = PipelineConfig::from_str(
            "mode = \"asym_vector\"\ncalibrator = \"p999\"\n",
        )
        .unwrap();
        let spec = c.quant_spec().unwrap();
        assert_eq!(spec.mode(), crate::quant::QuantMode::AsymVector);
        assert_eq!(
            spec.calibrator,
            crate::quant::calibrate::Calibrator::Percentile(9990)
        );
        // default is the paper's max calibrator
        let spec = PipelineConfig::default().quant_spec().unwrap();
        assert_eq!(spec.calibrator, crate::quant::calibrate::Calibrator::Max);
    }

    #[test]
    fn finetune_opts_pick_lr() {
        let c = PipelineConfig::default();
        assert_eq!(c.finetune_opts(false).lr, c.lr);
        assert_eq!(c.finetune_opts(true).lr, c.pw_lr);
        assert_eq!(c.finetune_opts(false).max_steps, c.max_steps);
    }
}
