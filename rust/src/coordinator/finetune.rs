//! The FAT fine-tune loop: drives the `train_step_<mode>` artifact with
//! RMSE-distillation batches (unlabeled — labels are generated but unused,
//! exactly as the paper discards them), Adam on threshold scales only,
//! cosine annealing with optimizer reset.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::data::{Batcher, Split};
use crate::runtime::Artifact;
use crate::tensor::Tensor;

use super::marshal::{build_inputs, split_outputs, Group};
use super::schedule::CosineRestarts;

/// Build the initial trainable map straight from the artifact manifest
/// (group 2 of `train_step_*`): α=1, α_T=0, α_R=1.
pub fn init_trainables(art: &Artifact) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for spec in &art.manifest.inputs {
        if let Some(key) = spec.name.strip_prefix("2/") {
            let n: usize = spec.shape.iter().product();
            let v = if key == "act_at" { 0.0 } else { 1.0 };
            out.insert(
                key.to_string(),
                Tensor::f32(spec.shape.clone(), vec![v; n]),
            );
        }
    }
    out
}

fn zeros_like(m: &BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
    m.iter()
        .map(|(k, t)| (k.clone(), Tensor::zeros_f32(t.shape.clone())))
        .collect()
}

/// Fine-tuning hyper-parameters (resolved from `PipelineConfig`).
#[derive(Debug, Clone)]
pub struct FinetuneOpts {
    pub epochs: usize,
    pub stride: usize,
    pub lr: f32,
    pub cycle: usize,
    pub max_steps: usize,
    pub seed: u64,
}

/// Run fine-tuning. Returns (trained map, per-step losses).
pub fn run(
    art: &Arc<Artifact>,
    weights: &BTreeMap<String, Tensor>,
    act_t: &Tensor,
    opts: &FinetuneOpts,
    mut progress: impl FnMut(usize, f32, f32),
) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
    let batch_size = art
        .manifest
        .inputs
        .iter()
        .find(|s| s.name == "7")
        .map(|s| s.shape[0])
        .ok_or_else(|| anyhow::anyhow!("train_step: no batch input"))?;

    let indices: Vec<u64> = (0..crate::data::synth::TRAIN_SIZE as u64)
        .step_by(opts.stride.max(1))
        .collect();
    let batcher =
        Batcher::new(Split::Train, indices, batch_size).shuffled(opts.seed);
    let steps_per_epoch = batcher.batches_per_epoch().max(1);
    let cycle = if opts.cycle == 0 { steps_per_epoch } else { opts.cycle };
    let sched = CosineRestarts::new(opts.lr, cycle);

    let mut tr = init_trainables(art);
    let mut m = zeros_like(&tr);
    let mut v = zeros_like(&tr);
    let mut adam_step = 0f32; // resets with the optimizer (paper §4.1.2)
    let mut losses = vec![];
    let mut global = 0usize;

    'outer: for epoch in 0..opts.epochs {
        for (x, _unused_labels) in batcher.epoch_iter(epoch as u64) {
            let (lr, restart) = sched.at(global);
            if restart && global > 0 {
                m = zeros_like(&tr);
                v = zeros_like(&tr);
                adam_step = 0.0;
            }
            adam_step += 1.0;
            let step_t = Tensor::scalar_f32(adam_step);
            let lr_t = Tensor::scalar_f32(lr);
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(weights),
                    Group::Single(act_t),
                    Group::Map(&tr),
                    Group::Map(&m),
                    Group::Map(&v),
                    Group::Single(&step_t),
                    Group::Single(&lr_t),
                    Group::Single(&x),
                ],
            )?;
            let outs = art.execute(&inputs)?;
            let o = split_outputs(&art.manifest, outs)?;
            let loss = o.singles[&0].as_f32()?[0];
            tr = o.maps[&1].clone();
            m = o.maps[&2].clone();
            v = o.maps[&3].clone();
            losses.push(loss);
            progress(global, loss, lr);
            global += 1;
            if opts.max_steps > 0 && global >= opts.max_steps {
                break 'outer;
            }
        }
    }
    Ok((tr, losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finetune_opts_defaults_sane() {
        let o = FinetuneOpts {
            epochs: 6,
            stride: 10,
            lr: 2e-2,
            cycle: 0,
            max_steps: 0,
            seed: 1,
        };
        assert_eq!(o.epochs, 6);
    }
}
