//! The FAT fine-tune loop: RMSE-distillation batches (unlabeled — labels
//! are generated but unused, exactly as the paper discards them), Adam on
//! threshold scales only, cosine annealing with optimizer reset.
//!
//! The loop is backend-agnostic: it drives any [`TrainStep`] — the
//! AOT-artifact stepper ([`ArtifactStep`], whose Adam update runs inside
//! the lowered `train_step_<mode>` executable) or the native trainer
//! (`crate::fp::train::NativeStep`, whose analytic gradients and Adam
//! update run in Rust). Scheduling, shuffling, restarts and the
//! trainable/optimizer-state plumbing are shared, so both backends see
//! the identical schedule.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::data::{Batcher, Split};
use crate::runtime::Artifact;
use crate::tensor::Tensor;

use super::marshal::{build_inputs, split_outputs, Group};
use super::schedule::CosineRestarts;

/// Build the initial trainable map straight from the artifact manifest
/// (group 2 of `train_step_*`): α=1, α_T=0, α_R=1.
pub fn init_trainables(art: &Artifact) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for spec in &art.manifest.inputs {
        if let Some(key) = spec.name.strip_prefix("2/") {
            let n: usize = spec.shape.iter().product();
            let v = if key == "act_at" { 0.0 } else { 1.0 };
            out.insert(
                key.to_string(),
                Tensor::f32(spec.shape.clone(), vec![v; n]),
            );
        }
    }
    out
}

fn zeros_like(m: &BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
    m.iter()
        .map(|(k, t)| (k.clone(), Tensor::zeros_f32(t.shape.clone())))
        .collect()
}

/// Fine-tuning hyper-parameters (resolved from `PipelineConfig`).
#[derive(Debug, Clone)]
pub struct FinetuneOpts {
    pub epochs: usize,
    pub stride: usize,
    pub lr: f32,
    pub cycle: usize,
    pub max_steps: usize,
    pub seed: u64,
}

/// Result of one optimizer step: loss + updated trainables and Adam
/// moment maps.
pub struct StepOut {
    pub loss: f32,
    pub tr: BTreeMap<String, Tensor>,
    pub m: BTreeMap<String, Tensor>,
    pub v: BTreeMap<String, Tensor>,
}

/// One backend's fine-tune step: everything the shared loop needs to
/// drive it. `adam_step` is the in-cycle Adam step counter (it resets
/// with the optimizer on every cosine restart, paper §4.1.2).
pub trait TrainStep {
    fn batch_size(&self) -> usize;
    fn init_trainables(&self) -> Result<BTreeMap<String, Tensor>>;
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        tr: &BTreeMap<String, Tensor>,
        m: &BTreeMap<String, Tensor>,
        v: &BTreeMap<String, Tensor>,
        adam_step: f32,
        lr: f32,
        x: &Tensor,
    ) -> Result<StepOut>;
}

/// The AOT-artifact stepper: marshals `(weights, act_t, trainables, m,
/// v, step, lr, batch)` through the `train_step_<mode>` executable.
pub struct ArtifactStep<'a> {
    pub art: &'a Arc<Artifact>,
    pub weights: &'a BTreeMap<String, Tensor>,
    pub act_t: &'a Tensor,
}

impl TrainStep for ArtifactStep<'_> {
    fn batch_size(&self) -> usize {
        self.art
            .manifest
            .inputs
            .iter()
            .find(|s| s.name == "7")
            .map(|s| s.shape[0])
            .unwrap_or(0)
    }

    fn init_trainables(&self) -> Result<BTreeMap<String, Tensor>> {
        Ok(init_trainables(self.art))
    }

    fn step(
        &self,
        tr: &BTreeMap<String, Tensor>,
        m: &BTreeMap<String, Tensor>,
        v: &BTreeMap<String, Tensor>,
        adam_step: f32,
        lr: f32,
        x: &Tensor,
    ) -> Result<StepOut> {
        let step_t = Tensor::scalar_f32(adam_step);
        let lr_t = Tensor::scalar_f32(lr);
        let inputs = build_inputs(
            &self.art.manifest,
            &[
                Group::Map(self.weights),
                Group::Single(self.act_t),
                Group::Map(tr),
                Group::Map(m),
                Group::Map(v),
                Group::Single(&step_t),
                Group::Single(&lr_t),
                Group::Single(x),
            ],
        )?;
        let outs = self.art.execute(&inputs)?;
        let o = split_outputs(&self.art.manifest, outs)?;
        Ok(StepOut {
            loss: o.singles[&0].as_f32()?[0],
            tr: o.maps[&1].clone(),
            m: o.maps[&2].clone(),
            v: o.maps[&3].clone(),
        })
    }
}

/// Run the shared fine-tune loop over any stepper. Returns (trained
/// map, per-step losses).
pub fn run_loop(
    stepper: &dyn TrainStep,
    opts: &FinetuneOpts,
    mut progress: impl FnMut(usize, f32, f32),
) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
    let batch_size = stepper.batch_size();
    anyhow::ensure!(batch_size > 0, "fine-tune: no batch input");

    let indices: Vec<u64> = (0..crate::data::synth::TRAIN_SIZE as u64)
        .step_by(opts.stride.max(1))
        .collect();
    let batcher =
        Batcher::new(Split::Train, indices, batch_size).shuffled(opts.seed);
    let steps_per_epoch = batcher.batches_per_epoch().max(1);
    let cycle = if opts.cycle == 0 { steps_per_epoch } else { opts.cycle };
    let sched = CosineRestarts::new(opts.lr, cycle);

    let mut tr = stepper.init_trainables()?;
    let mut m = zeros_like(&tr);
    let mut v = zeros_like(&tr);
    let mut adam_step = 0f32; // resets with the optimizer (paper §4.1.2)
    let mut losses = vec![];
    let mut global = 0usize;

    'outer: for epoch in 0..opts.epochs {
        for (x, _unused_labels) in batcher.epoch_iter(epoch as u64) {
            let (lr, restart) = sched.at(global);
            if restart && global > 0 {
                m = zeros_like(&tr);
                v = zeros_like(&tr);
                adam_step = 0.0;
            }
            adam_step += 1.0;
            let out = stepper.step(&tr, &m, &v, adam_step, lr, &x)?;
            tr = out.tr;
            m = out.m;
            v = out.v;
            losses.push(out.loss);
            progress(global, out.loss, lr);
            global += 1;
            if opts.max_steps > 0 && global >= opts.max_steps {
                break 'outer;
            }
        }
    }
    Ok((tr, losses))
}

/// Run fine-tuning through an AOT `train_step_*` artifact. Returns
/// (trained map, per-step losses).
pub fn run(
    art: &Arc<Artifact>,
    weights: &BTreeMap<String, Tensor>,
    act_t: &Tensor,
    opts: &FinetuneOpts,
    progress: impl FnMut(usize, f32, f32),
) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
    run_loop(&ArtifactStep { art, weights, act_t }, opts, progress)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finetune_opts_defaults_sane() {
        let o = FinetuneOpts {
            epochs: 6,
            stride: 10,
            lr: 2e-2,
            cycle: 0,
            max_steps: 0,
            seed: 1,
        };
        assert_eq!(o.epochs, 6);
    }

    /// A stepper that just counts calls and echoes its state: checks the
    /// loop's restart/step bookkeeping without any backend.
    struct Probe;

    impl TrainStep for Probe {
        fn batch_size(&self) -> usize {
            50
        }

        fn init_trainables(&self) -> Result<BTreeMap<String, Tensor>> {
            let mut m = BTreeMap::new();
            m.insert("act_a".to_string(), Tensor::f32(vec![1], vec![1.0]));
            Ok(m)
        }

        fn step(
            &self,
            tr: &BTreeMap<String, Tensor>,
            m: &BTreeMap<String, Tensor>,
            _v: &BTreeMap<String, Tensor>,
            adam_step: f32,
            lr: f32,
            _x: &Tensor,
        ) -> Result<StepOut> {
            // optimizer state must arrive zeroed right after a restart
            if adam_step == 1.0 {
                assert_eq!(m["act_a"].as_f32()?[0], 0.0);
            }
            let mut tr2 = tr.clone();
            let cur = tr2["act_a"].as_f32()?[0];
            tr2.insert(
                "act_a".to_string(),
                Tensor::f32(vec![1], vec![cur - 0.01]),
            );
            let mut m2 = m.clone();
            m2.insert("act_a".to_string(), Tensor::f32(vec![1], vec![1.0]));
            Ok(StepOut {
                loss: lr, // echo lr so the test can see the schedule
                tr: tr2,
                m: m2,
                v: m.clone(),
            })
        }
    }

    #[test]
    fn loop_steps_caps_and_threads_state() {
        let opts = FinetuneOpts {
            epochs: 3,
            stride: 40,
            lr: 0.5,
            cycle: 4,
            max_steps: 9,
            seed: 7,
        };
        let (tr, losses) = run_loop(&Probe, &opts, |_, _, _| {}).unwrap();
        assert_eq!(losses.len(), 9);
        // trainables threaded through every step
        let a = tr["act_a"].as_f32().unwrap()[0];
        assert!((a - (1.0 - 0.09)).abs() < 1e-5, "{a}");
        // cosine restarts: step 4 starts a new cycle at peak lr
        assert!((losses[4] - 0.5).abs() < 1e-6);
    }
}
