//! Minimal dense host tensor shared by the runtime, quant and int8 layers.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
    U8,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i32" => DType::I32,
            "u8" => DType::U8,
            other => bail!("unknown dtype {other}"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

/// A host tensor: shape + typed row-major data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::F32(data) }
    }

    pub fn i8(shape: Vec<usize>, data: Vec<i8>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::I8(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::I32(data) }
    }

    pub fn u8(shape: Vec<usize>, data: Vec<u8>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::U8(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn ones_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![1.0; n])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I8(_) => DType::I8,
            Data::I32(_) => DType::I32,
            Data::U8(_) => DType::U8,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Data::I8(v) => Ok(v),
            _ => bail!("tensor is not i8"),
        }
    }

    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            Data::F32(v) => bytemuck_cast(v),
            Data::I32(v) => bytemuck_cast(v),
            Data::I8(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len())
            },
            Data::U8(v) => v,
        }
    }

    /// Flat index helper for NHWC tensors.
    pub fn idx4(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        let s = &self.shape;
        ((n * s[1] + h) * s[2] + w) * s[3] + c
    }
}

fn bytemuck_cast<T>(v: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn raw_bytes_roundtrip() {
        let t = Tensor::i32(vec![2], vec![1, -1]);
        assert_eq!(t.raw_bytes().len(), 8);
        assert_eq!(&t.raw_bytes()[0..4], &1i32.to_le_bytes());
    }

    #[test]
    fn idx4_nhwc() {
        let t = Tensor::zeros_f32(vec![2, 4, 4, 3]);
        assert_eq!(t.idx4(0, 0, 0, 0), 0);
        assert_eq!(t.idx4(0, 0, 0, 2), 2);
        assert_eq!(t.idx4(0, 0, 1, 0), 3);
        assert_eq!(t.idx4(1, 0, 0, 0), 48);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::from_str("f32").unwrap(), DType::F32);
        assert!(DType::from_str("f64").is_err());
    }
}
