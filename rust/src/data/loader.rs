//! Batch iteration over SynthShapes splits: deterministic shuffling,
//! calibration subsets, and the paper's 10% unlabeled fine-tune stream.

use crate::tensor::Tensor;

use super::{prng, synth};

/// A dataset split (seed region).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

impl Split {
    pub fn seed(self) -> u64 {
        match self {
            Split::Train => synth::SEED_TRAIN,
            Split::Val => synth::SEED_VAL,
        }
    }

    pub fn size(self) -> usize {
        match self {
            Split::Train => synth::TRAIN_SIZE,
            Split::Val => synth::VAL_SIZE,
        }
    }
}

/// Render a batch as an NHWC f32 tensor + labels.
pub fn batch(split: Split, indices: &[u64]) -> (Tensor, Vec<i32>) {
    let (data, labels) = synth::generate(split.seed(), indices);
    (
        Tensor::f32(
            vec![indices.len(), synth::IMG, synth::IMG, synth::CHANNELS],
            data,
        ),
        labels,
    )
}

/// Deterministic Fisher-Yates shuffle driven by the portable PRNG, so a
/// fine-tune run is reproducible across machines and languages.
pub fn shuffle(indices: &mut [u64], seed: u64, epoch: u64) {
    let n = indices.len();
    for i in (1..n).rev() {
        let r = prng::hash_u64(seed, epoch, 1000 + i as u64, 0, 0, 0);
        let j = (r % (i as u64 + 1)) as usize;
        indices.swap(i, j);
    }
}

/// Epoch-based batcher over a fixed index set. Partial trailing batches are
/// dropped (fixed-shape AOT executables need a constant batch size).
pub struct Batcher {
    split: Split,
    indices: Vec<u64>,
    batch_size: usize,
    shuffle_seed: Option<u64>,
}

impl Batcher {
    pub fn new(split: Split, indices: Vec<u64>, batch_size: usize) -> Self {
        Batcher { split, indices, batch_size, shuffle_seed: None }
    }

    /// Enable per-epoch deterministic shuffling.
    pub fn shuffled(mut self, seed: u64) -> Self {
        self.shuffle_seed = Some(seed);
        self
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.indices.len() / self.batch_size
    }

    /// Iterate one epoch of batches.
    pub fn epoch(&self, epoch: u64) -> Vec<(Tensor, Vec<i32>)> {
        let mut idx = self.indices.clone();
        if let Some(seed) = self.shuffle_seed {
            shuffle(&mut idx, seed, epoch);
        }
        idx.chunks_exact(self.batch_size)
            .map(|chunk| batch(self.split, chunk))
            .collect()
    }

    /// Lazily iterate one epoch (generation happens per batch).
    pub fn epoch_iter(
        &self,
        epoch: u64,
    ) -> impl Iterator<Item = (Tensor, Vec<i32>)> + '_ {
        let mut idx = self.indices.clone();
        if let Some(seed) = self.shuffle_seed {
            shuffle(&mut idx, seed, epoch);
        }
        (0..idx.len() / self.batch_size).map(move |i| {
            let chunk =
                &idx[i * self.batch_size..(i + 1) * self.batch_size];
            batch(self.split, chunk)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let (t, y) = batch(Split::Val, &[0, 1, 2]);
        assert_eq!(t.shape, vec![3, 32, 32, 3]);
        assert_eq!(y, vec![0, 1, 2]);
    }

    #[test]
    fn shuffle_is_deterministic_and_permutes() {
        let mut a: Vec<u64> = (0..100).collect();
        let mut b: Vec<u64> = (0..100).collect();
        shuffle(&mut a, 7, 0);
        shuffle(&mut b, 7, 0);
        assert_eq!(a, b);
        let mut c: Vec<u64> = (0..100).collect();
        shuffle(&mut c, 7, 1);
        assert_ne!(a, c);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn batcher_drops_partial_batches() {
        let b = Batcher::new(Split::Val, (0..10).collect(), 4);
        assert_eq!(b.batches_per_epoch(), 2);
        let e = b.epoch(0);
        assert_eq!(e.len(), 2);
        assert_eq!(e[0].0.shape[0], 4);
    }

    #[test]
    fn shuffled_batcher_changes_across_epochs() {
        let b = Batcher::new(Split::Train, (0..32).collect(), 8).shuffled(3);
        let e0 = b.epoch(0);
        let e1 = b.epoch(1);
        assert_ne!(e0[0].1, e1[0].1);
    }

    #[test]
    fn epoch_iter_matches_epoch() {
        let b = Batcher::new(Split::Val, (0..12).collect(), 4).shuffled(9);
        let a = b.epoch(2);
        let c: Vec<_> = b.epoch_iter(2).collect();
        assert_eq!(a.len(), c.len());
        assert_eq!(a[0].1, c[0].1);
        assert_eq!(a[2].0.as_f32().unwrap(), c[2].0.as_f32().unwrap());
    }
}
