//! SynthShapes procedural image generator — bit-exact mirror of
//! `python/compile/dataset.py` (see that file for the dataset design).
//!
//! Every arithmetic expression mirrors the numpy formula *order* exactly;
//! only IEEE-exact f32 ops are used (+ - * /, floor, abs, min/max, cmp).

use super::prng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 10;

pub const SEED_TRAIN: u64 = 0x5EED_0001;
pub const SEED_VAL: u64 = 0x5EED_0002;

pub const TRAIN_SIZE: usize = 12_000;
pub const VAL_SIZE: usize = 2_000;
pub const CALIB_SIZE: usize = 100;
pub const FINETUNE_FRACTION: usize = 10;

// Parameter slots (must match python/compile/dataset.py)
const S_BG: u64 = 0;
const S_CX: u64 = 9;
const S_CY: u64 = 10;
const S_R: u64 = 11;
const S_FG: u64 = 12;
const S_FREQ: u64 = 15;
const S_EDGE: u64 = 16;

struct Params {
    bg: [f32; 9],
    cx: f32,
    cy: f32,
    r: f32,
    fg: [f32; 3],
    freq: f32,
    edge: f32,
}

fn params(seed: u64, idx: u64) -> Params {
    let mut bg = [0f32; 9];
    for (k, b) in bg.iter_mut().enumerate() {
        *b = prng::uniform(seed, idx, S_BG + k as u64, 0, 0, 0);
    }
    let mut fg = [0f32; 3];
    for (k, f) in fg.iter_mut().enumerate() {
        *f = prng::uniform_range(0.35, 1.0, seed, idx, S_FG + k as u64);
    }
    Params {
        bg,
        cx: prng::uniform_range(0.30, 0.70, seed, idx, S_CX),
        cy: prng::uniform_range(0.30, 0.70, seed, idx, S_CY),
        r: prng::uniform_range(0.12, 0.30, seed, idx, S_R),
        fg,
        freq: 3.0f32 + (prng::uniform(seed, idx, S_FREQ, 0, 0, 0) * 3.0f32).floor(),
        edge: prng::uniform_range(0.55, 0.95, seed, idx, S_EDGE),
    }
}

#[inline]
fn frac(x: f32) -> f32 {
    x - x.floor()
}

#[inline]
fn mask(label: u32, u: f32, v: f32, p: &Params) -> bool {
    let du = u - p.cx;
    let dv = v - p.cy;
    let adu = du.abs();
    let adv = dv.abs();
    let d2 = du * du + dv * dv;
    let r2 = p.r * p.r;
    let boxed = adu.max(adv) < p.r * 1.1f32;
    match label {
        0 => d2 < r2,
        1 => adu.max(adv) < p.r * 0.9f32,
        2 => (adu + adv) < p.r * 1.2f32,
        3 => d2 < r2 && d2 > r2 * 0.3f32,
        4 => (adu < p.r * 0.32f32 || adv < p.r * 0.32f32) && adu.max(adv) < p.r,
        5 => frac(v * p.freq) < 0.5f32 && boxed,
        6 => frac(u * p.freq) < 0.5f32 && boxed,
        7 => {
            frac(((u * p.freq).floor() + (v * p.freq).floor()) * 0.5f32)
                < 0.25f32
                && boxed
        }
        8 => {
            let gx = frac(u * p.freq) - 0.5f32;
            let gy = frac(v * p.freq) - 0.5f32;
            (gx * gx + gy * gy) < 0.06f32 && boxed
        }
        9 => dv > -p.r && dv < p.r && adu < (dv + p.r) * p.edge * 0.5f32,
        _ => unreachable!(),
    }
}

/// Render images for `indices`. Returns (NHWC f32 data, labels).
pub fn generate(seed: u64, indices: &[u64]) -> (Vec<f32>, Vec<i32>) {
    let b = indices.len();
    let mut img = vec![0f32; b * IMG * IMG * CHANNELS];
    let mut labels = vec![0i32; b];
    for (bi, &idx) in indices.iter().enumerate() {
        let label = (idx % NUM_CLASSES as u64) as u32;
        labels[bi] = label as i32;
        let p = params(seed, idx);
        let base_off = bi * IMG * IMG * CHANNELS;
        for y in 0..IMG {
            // pixel centre coords (match python: (k + 0.5) * (1/32))
            let vv = (y as f32 + 0.5f32) * (1.0f32 / IMG as f32);
            for x in 0..IMG {
                let uu = (x as f32 + 0.5f32) * (1.0f32 / IMG as f32);
                let m = mask(label, uu, vv, &p);
                let off = base_off + (y * IMG + x) * CHANNELS;
                let outlier = prng::uniform(
                    seed,
                    idx,
                    prng::SLOT_OUTLIER,
                    x as u64,
                    y as u64,
                    0,
                ) < (1.0f32 / 96.0f32);
                for ch in 0..CHANNELS {
                    let a = p.bg[3 * ch];
                    let bcoef = p.bg[3 * ch + 1];
                    let c = p.bg[3 * ch + 2];
                    let base = 0.15f32
                        + 0.5f32 * (a * uu + bcoef * vv + c * (uu * vv));
                    let mut pix = if m { p.fg[ch] } else { base };
                    let noise = prng::uniform(
                        seed,
                        idx,
                        prng::SLOT_NOISE,
                        x as u64,
                        y as u64,
                        ch as u64,
                    );
                    pix += (noise - 0.5f32) * 0.12f32;
                    if outlier {
                        pix *= 3.0f32;
                    }
                    img[off + ch] = pix.max(0.0f32).min(3.0f32);
                }
            }
        }
    }
    (img, labels)
}

/// The paper's "100 images from the training set" calibration subset.
pub fn calib_indices() -> Vec<u64> {
    (0..CALIB_SIZE as u64).collect()
}

/// The paper's "~10% of the train set" unlabeled fine-tuning subset.
pub fn finetune_indices() -> Vec<u64> {
    (0..TRAIN_SIZE as u64)
        .step_by(FINETUNE_FRACTION)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Goldens from python/compile/dataset.py (see test_prng.py session).
    #[test]
    fn pixel_goldens() {
        let (img, labels) = generate(SEED_TRAIN, &[0, 1]);
        assert_eq!(labels, vec![0, 1]);
        // img[0, 0, 0, :]
        assert_eq!(img[0], 0.12980656325817108_f32);
        assert_eq!(img[1], 0.13350321352481842_f32);
        assert_eq!(img[2], 0.21155627071857452_f32);
        // img[1, 16, 16, :]
        let off = IMG * IMG * CHANNELS + (16 * IMG + 16) * CHANNELS;
        assert_eq!(img[off], 0.6571217775344849_f32);
        assert_eq!(img[off + 1], 0.4670751392841339_f32);
        assert_eq!(img[off + 2], 0.5961712002754211_f32);
    }

    #[test]
    fn image_sum_golden() {
        let (img, _) = generate(SEED_TRAIN, &[0]);
        let sum: f64 = img.iter().map(|&v| v as f64).sum();
        assert!((sum - 1804.62514).abs() < 5e-3, "sum={sum}");
    }

    #[test]
    fn deterministic_and_range() {
        let (a, _) = generate(SEED_VAL, &[3, 17]);
        let (b, _) = generate(SEED_VAL, &[3, 17]);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| (0.0..=3.0).contains(&v)));
    }

    #[test]
    fn subset_helpers() {
        assert_eq!(calib_indices().len(), 100);
        assert_eq!(finetune_indices().len(), TRAIN_SIZE / 10);
    }
}
