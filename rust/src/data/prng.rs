//! Stateless splitmix64-style PRNG, bit-exact with `python/compile/prng.py`.
//!
//! All integer ops are wrapping u64; uniforms come from the top 24 bits so
//! every float is exactly representable. Do not "improve" the formulas —
//! both language sides must stay identical (golden tests enforce this).

const M1: u64 = 0x9E3779B97F4A7C15;
const M2: u64 = 0xC2B2AE3D27D4EB4F;
const M3: u64 = 0x165667B19E3779F9;
const S1: u64 = 0xBF58476D1CE4E5B9;
const S2: u64 = 0x94D049BB133111EB;

/// Pixel-noise slot (scalar per-sample parameters use slots 0..63).
pub const SLOT_NOISE: u64 = 64;
/// Outlier-pixel slot.
pub const SLOT_OUTLIER: u64 = 65;

const INV24: f32 = 1.0 / 16777216.0;

/// splitmix64 finalising mix.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(S1);
    z = (z ^ (z >> 27)).wrapping_mul(S2);
    z ^ (z >> 31)
}

/// Stateless hash of the full key tuple.
#[inline]
pub fn hash_u64(seed: u64, index: u64, slot: u64, x: u64, y: u64, c: u64) -> u64 {
    let z = seed
        .wrapping_mul(M1)
        ^ index.wrapping_mul(M2)
        ^ slot.wrapping_mul(M3)
        ^ (x << 40)
        ^ (y << 20)
        ^ c;
    // second avalanche pass (python: splitmix64(splitmix64(z) + M1))
    splitmix64(splitmix64(z).wrapping_add(M1))
}

/// Uniform f32 in [0, 1) with 24-bit resolution.
#[inline]
pub fn uniform(seed: u64, index: u64, slot: u64, x: u64, y: u64, c: u64) -> f32 {
    (hash_u64(seed, index, slot, x, y, c) >> 40) as f32 * INV24
}

/// `lo + u * (hi - lo)`, matching the Python formula order exactly.
#[inline]
pub fn uniform_range(
    lo: f64,
    hi: f64,
    seed: u64,
    index: u64,
    slot: u64,
) -> f32 {
    lo as f32 + uniform(seed, index, slot, 0, 0, 0) * ((hi - lo) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Goldens mirrored in python/tests/test_prng.py.
    #[test]
    fn splitmix_goldens() {
        assert_eq!(splitmix64(0), 0);
        assert_eq!(splitmix64(1), 0x5692161D100B05E5);
        assert_eq!(splitmix64(0xDEADBEEF), 0x4E062702EC929EEA);
    }

    #[test]
    fn hash_goldens() {
        assert_eq!(hash_u64(1, 2, 3, 4, 5, 6), 0x472D0DD1FD5C3C80);
        assert_eq!(hash_u64(42, 7, 0, 0, 0, 0), 0x66E2C29779EF6A7B);
    }

    #[test]
    fn uniform_goldens() {
        assert_eq!(uniform(42, 7, 0, 0, 0, 0), 0.40189755_f32);
        assert_eq!(uniform(1, 0, SLOT_NOISE, 3, 5, 2), 0.103233337_f32);
    }

    #[test]
    fn uniform_in_range() {
        for i in 0..10_000u64 {
            let u = uniform(9, i, 1, 0, 0, 0);
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn hash_sensitive_to_all_components() {
        let base = hash_u64(1, 2, 3, 4, 5, 6);
        assert_ne!(base, hash_u64(2, 2, 3, 4, 5, 6));
        assert_ne!(base, hash_u64(1, 3, 3, 4, 5, 6));
        assert_ne!(base, hash_u64(1, 2, 4, 4, 5, 6));
        assert_ne!(base, hash_u64(1, 2, 3, 5, 5, 6));
        assert_ne!(base, hash_u64(1, 2, 3, 4, 6, 6));
        assert_ne!(base, hash_u64(1, 2, 3, 4, 5, 7));
    }
}
