//! SynthShapes data substrate: portable PRNG, procedural generator, batcher.
//!
//! Bit-exact mirror of `python/compile/{prng,dataset}.py` — golden-tested
//! in both suites and cross-checked against `artifacts/goldens/dataset.fatw`.

pub mod loader;
pub mod prng;
pub mod synth;

pub use loader::{Batcher, Split};
pub use synth::{generate, IMG, CHANNELS, NUM_CLASSES};
