//! **A1 ablations** (ours, DESIGN.md §4): calibration-set size sweep and
//! baseline threshold calibrators (max / percentile / KL) compared without
//! fine-tuning — quantifies how much of FAT's gain comes from the trained
//! scales rather than better static calibration.
//!
//!   cargo run --release --bin ablations -- [--model mnas_mini_10] [--val N]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::experiments::{ablations, Ctx};
use fat::coordinator::PipelineConfig;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["fast"]);
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu()?))),
        args.get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(fat::artifacts_dir),
    );
    let model = args.get_or("model", "mnas_mini_10");
    let mut cfg = PipelineConfig::default();
    cfg.val_images = args.usize_or("val", 1000);

    let rep = ablations(&ctx, model, &cfg, |s| println!("{s}"))?;
    print!("{}", rep.markdown());
    let csv = ctx.results_dir().join("ablations.csv");
    rep.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}
