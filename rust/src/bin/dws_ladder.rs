//! Regenerates the **§4.2 experiment**: the accuracy ladder of scalar-
//! quantized MobileNet-v2 — plain scalar quantization collapses, §3.3
//! DWS weight rescaling recovers most of it, point-wise weight fine-tuning
//! (scales in [0.75, 1.25]) recovers the rest.
//!
//!   cargo run --release --bin dws_ladder -- [--fast] [--val N]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::experiments::{dws_ladder, Ctx};
use fat::coordinator::PipelineConfig;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["fast"]);
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu()?))),
        args.get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(fat::artifacts_dir),
    );
    let mut cfg = PipelineConfig::default();
    if args.flag("fast") {
        cfg = cfg.fast();
    }
    cfg.epochs = args.usize_or("epochs", cfg.epochs);
    cfg.val_images = args.usize_or("val", cfg.val_images);
    cfg.max_steps = args.usize_or("max-steps", cfg.max_steps);

    let rep = dws_ladder(&ctx, &cfg, |s| println!("{s}"))?;
    print!("{}", rep.markdown());
    let csv = ctx.results_dir().join("dws_ladder.csv");
    rep.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}
