//! Regenerates **Table 1** of the paper: 8-bit *scalar* quantization,
//! symmetric vs asymmetric trained thresholds vs original accuracy, for
//! the three mobile architectures.
//!
//!   cargo run --release --bin table1 -- [--fast] [--epochs N] [--val N]
//!
//! Writes `artifacts/results/table1.csv` and prints the markdown table.

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::experiments::{accuracy_table, Ctx};
use fat::coordinator::PipelineConfig;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["fast"]);
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu()?))),
        args.get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(fat::artifacts_dir),
    );
    let mut cfg = PipelineConfig::default();
    if args.flag("fast") {
        cfg = cfg.fast();
    }
    cfg.epochs = args.usize_or("epochs", cfg.epochs);
    cfg.val_images = args.usize_or("val", cfg.val_images);
    cfg.max_steps = args.usize_or("max-steps", cfg.max_steps);

    let rep = accuracy_table(&ctx, false, &cfg, |s| println!("{s}"))?;
    print!("{}", rep.markdown());
    let csv = ctx.results_dir().join("table1.csv");
    rep.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}
