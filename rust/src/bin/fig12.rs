//! Regenerates **Figures 1-2** of the paper: the weight distribution of a
//! residual network before (Fig. 1) and after (Fig. 2) symmetric
//! per-tensor quantization — the post-quantization histogram piles mass
//! into the bins near zero, which is the failure mode FAT addresses.
//!
//!   cargo run --release --bin fig12 -- [--model resnet_mini] [--bins 101]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::experiments::{weight_histograms, Ctx};
use fat::coordinator::report::write_series_csv;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu()?))),
        args.get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(fat::artifacts_dir),
    );
    let model = args.get_or("model", "resnet_mini");
    let bins = args.usize_or("bins", 401);

    let h = weight_histograms(&ctx, model, bins)?;
    let (before, after) = (h.before, h.after);
    let dir = ctx.results_dir();
    write_series_csv(dir.join("fig1.csv"), "weight,count", before.clone())?;
    write_series_csv(dir.join("fig2.csv"), "weight,count", after.clone())?;

    // The paper's qualitative claim: mass near zero increases.
    let near_zero = |h: &[(f64, f64)]| -> f64 {
        let lim = h.iter().map(|(x, _)| x.abs()).fold(0.0, f64::max);
        h.iter()
            .filter(|(x, _)| x.abs() < lim * 0.004)
            .map(|(_, c)| c)
            .sum()
    };
    let nz_before = near_zero(&before);
    let nz_after = near_zero(&after);
    println!("model {model}, {bins} bins over symmetric weight range");
    println!(
        "Fig1 (before): {} weights in the central bins, {} exactly zero",
        nz_before, h.zeros_before
    );
    println!(
        "Fig2 (after):  {} weights in the central bins, {} exactly zero",
        nz_after, h.zeros_after
    );
    println!(
        "central-bin mass ratio after/before = {:.2}; exact zeros {} -> {} \
         of {} (paper: near-zero mass increases significantly)",
        nz_after / nz_before.max(1.0),
        h.zeros_before,
        h.zeros_after,
        h.total
    );
    println!("wrote {}/fig1.csv and fig2.csv", dir.display());
    Ok(())
}
