//! Regenerates **Table 2** of the paper: 8-bit *vector* (per-filter)
//! quantization — the mode where all three nets recover to within a
//! fraction of a percent of FP accuracy.
//!
//!   cargo run --release --bin table2 -- [--fast] [--epochs N] [--val N]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::experiments::{accuracy_table, Ctx};
use fat::coordinator::PipelineConfig;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["fast"]);
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu()?))),
        args.get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(fat::artifacts_dir),
    );
    let mut cfg = PipelineConfig::default();
    if args.flag("fast") {
        cfg = cfg.fast();
    }
    cfg.epochs = args.usize_or("epochs", cfg.epochs);
    cfg.val_images = args.usize_or("val", cfg.val_images);
    cfg.max_steps = args.usize_or("max-steps", cfg.max_steps);

    let rep = accuracy_table(&ctx, true, &cfg, |s| println!("{s}"))?;
    print!("{}", rep.markdown());
    let csv = ctx.results_dir().join("table2.csv");
    rep.write_csv(&csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}
