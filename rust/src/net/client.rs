//! Loopback clients for both wire protocols, implementing
//! [`InferClient`] so the transport-agnostic driver
//! ([`crate::int8::serve::drive_with`]) and its bit-exactness oracle
//! run unchanged over live sockets — the socket columns of
//! `BENCH_serve.json` and the fault-injection tests both ride on these.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::int8::serve::InferClient;

use super::{frame, http, Limits, Step};

const CLIENT_TIMEOUT: Duration = Duration::from_secs(10);

fn connect_stream(addr: SocketAddr) -> Result<TcpStream> {
    let stream = TcpStream::connect_timeout(&addr, CLIENT_TIMEOUT)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    stream.set_read_timeout(Some(CLIENT_TIMEOUT))?;
    stream.set_write_timeout(Some(CLIENT_TIMEOUT))?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

/// Keep-alive HTTP/1.1 client for one model endpoint.
pub struct HttpClient {
    stream: TcpStream,
    model: String,
    limits: Limits,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr, model: &str) -> Result<Self> {
        Ok(HttpClient {
            stream: connect_stream(addr)?,
            model: model.to_string(),
            limits: Limits::default(),
            buf: Vec::new(),
        })
    }

    fn read_response(&mut self) -> Result<http::Response> {
        let mut tmp = [0u8; 16 * 1024];
        loop {
            match http::parse_response(&self.buf, &self.limits) {
                Ok(Step::Done(resp, used)) => {
                    self.buf.drain(..used);
                    return Ok(resp);
                }
                Ok(Step::Incomplete) => {}
                Err(e) => bail!("bad response from server: {e}"),
            }
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                bail!("server closed the connection mid-response");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }

    /// POST one image; returns `(status, body)` without interpreting
    /// the status — overload tests tally `429`s through this.
    pub fn infer_status(&mut self, pixels: &[u8]) -> Result<(u16, Vec<u8>)> {
        let path = format!("/v1/models/{}/infer", self.model);
        let wire = http::request(
            "POST",
            &path,
            "application/octet-stream",
            pixels,
        );
        self.stream.write_all(&wire)?;
        let resp = self.read_response()?;
        Ok((resp.status, resp.body))
    }

    /// Fetch and return the raw `/stats` JSON document.
    pub fn stats(&mut self) -> Result<String> {
        let wire = http::request("GET", "/stats", "text/plain", b"");
        self.stream.write_all(&wire)?;
        let resp = self.read_response()?;
        if resp.status != 200 {
            bail!("/stats answered {}", resp.status);
        }
        Ok(String::from_utf8(resp.body)?)
    }
}

/// Extract the logits row from a `POST .../infer` 200 body. Parses
/// each token with the correctly-rounded `str::parse::<f32>`, so the
/// bits of the server's shortest-round-trip formatting are recovered
/// exactly (never through an f64 intermediate, which double-rounds).
pub fn parse_logits_json(body: &str) -> Result<Vec<f32>> {
    let Some(tail) = body.split("\"logits\":[").nth(1) else {
        bail!("no logits array in response: {body}");
    };
    let Some(inner) = tail.split(']').next() else {
        bail!("unterminated logits array: {body}");
    };
    let inner = inner.trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|tok| {
            tok.trim()
                .parse::<f32>()
                .map_err(|e| anyhow::anyhow!("bad logit {tok:?}: {e}"))
        })
        .collect()
}

impl InferClient for HttpClient {
    fn infer_one(&mut self, pixels: &[u8]) -> Result<Vec<f32>> {
        let (status, body) = self.infer_status(pixels)?;
        if status != 200 {
            bail!(
                "infer answered {status}: {}",
                String::from_utf8_lossy(&body).trim()
            );
        }
        parse_logits_json(std::str::from_utf8(&body)?)
    }
}

/// Binary frame-protocol client for one model endpoint. Logits travel
/// as raw little-endian `f32` bits — bit-exact by construction.
pub struct FrameClient {
    stream: TcpStream,
    model: String,
    limits: Limits,
    buf: Vec<u8>,
}

impl FrameClient {
    pub fn connect(addr: SocketAddr, model: &str) -> Result<Self> {
        Ok(FrameClient {
            stream: connect_stream(addr)?,
            model: model.to_string(),
            limits: Limits::default(),
            buf: Vec::new(),
        })
    }

    fn read_response(&mut self) -> Result<frame::FrameResponse> {
        let mut tmp = [0u8; 16 * 1024];
        loop {
            match frame::parse_response(&self.buf, &self.limits) {
                Ok(Step::Done(resp, used)) => {
                    self.buf.drain(..used);
                    return Ok(resp);
                }
                Ok(Step::Incomplete) => {}
                Err(e) => bail!("bad frame from server: {e}"),
            }
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                bail!("server closed the connection mid-frame");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }

    /// Send one infer frame; returns `(status, body)` uninterpreted.
    pub fn infer_status(&mut self, pixels: &[u8]) -> Result<(u8, Vec<u8>)> {
        let wire = frame::encode_request(frame::OP_INFER, &self.model, pixels);
        self.stream.write_all(&wire)?;
        let resp = self.read_response()?;
        Ok((resp.status, resp.body))
    }

    /// Fetch and return the raw stats JSON over the frame protocol.
    pub fn stats(&mut self) -> Result<String> {
        let wire = frame::encode_request(frame::OP_STATS, "", b"");
        self.stream.write_all(&wire)?;
        let resp = self.read_response()?;
        if resp.status != frame::ST_OK {
            bail!("stats frame answered status {}", resp.status);
        }
        Ok(String::from_utf8(resp.body)?)
    }
}

impl InferClient for FrameClient {
    fn infer_one(&mut self, pixels: &[u8]) -> Result<Vec<f32>> {
        let (status, body) = self.infer_status(pixels)?;
        if status != frame::ST_OK {
            bail!(
                "infer frame answered status {status}: {}",
                String::from_utf8_lossy(&body).trim()
            );
        }
        if body.len() % 4 != 0 {
            bail!("logits body of {} bytes is not f32-aligned", body.len());
        }
        Ok(body
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_json_parsing_is_bit_exact() {
        let vals = [0.1f32, -0.0, 1.0 / 3.0, f32::MIN_POSITIVE, -3.4e38];
        let mut body = String::from("{\"model\":\"m\",\"logits\":[");
        for (i, v) in vals.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push_str(&format!("{v}"));
        }
        body.push_str("]}");
        let got = parse_logits_json(&body).unwrap();
        assert_eq!(got.len(), vals.len());
        for (g, w) in got.iter().zip(vals.iter()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn empty_logits_and_garbage() {
        assert_eq!(
            parse_logits_json("{\"model\":\"m\",\"logits\":[]}").unwrap(),
            Vec::<f32>::new()
        );
        assert!(parse_logits_json("{}").is_err());
        assert!(parse_logits_json("{\"logits\":[1,x]}").is_err());
    }
}
