//! Multi-model registry: routes requests by model name to per-model
//! [`Int8Engine`] handles (DESIGN.md §10.3).
//!
//! The registry is a cheaply clonable handle over a name → engine map.
//! Lookups clone the engine (an `Arc` bump), so the read lock is held
//! only for the map probe — never across inference. [`insert`] replaces
//! atomically, which doubles as hot reload: in-flight requests finish
//! on the engine they resolved, new requests resolve the new one.
//!
//! [`insert`]: ModelRegistry::insert

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use crate::int8::serve::Int8Engine;

/// Shared name → engine routing table.
#[derive(Clone, Default)]
pub struct ModelRegistry {
    inner: Arc<RwLock<BTreeMap<String, Int8Engine>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `engine` under `name`, returning the engine it replaced
    /// (if any). Replacement is atomic — this is the hot-reload path.
    pub fn insert(&self, name: &str, engine: Int8Engine) -> Option<Int8Engine> {
        self.inner.write().unwrap().insert(name.to_string(), engine)
    }

    /// Resolve a model name to a serving handle (an `Arc` clone).
    pub fn get(&self, name: &str) -> Option<Int8Engine> {
        self.inner.read().unwrap().get(name).cloned()
    }

    /// Unregister a model; in-flight requests on it finish normally.
    pub fn remove(&self, name: &str) -> Option<Int8Engine> {
        self.inner.write().unwrap().remove(name)
    }

    /// Registered model names, sorted (BTreeMap order).
    pub fn names(&self) -> Vec<String> {
        self.inner.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
