//! Multi-model registry: routes requests by model name to per-model
//! [`Int8Engine`] handles (DESIGN.md §10.3), and loads compiled `.fatm`
//! artifacts straight into serving slots (DESIGN.md §11.4).
//!
//! The registry is a cheaply clonable handle over a name → entry map.
//! Lookups clone the engine (an `Arc` bump), so the read lock is held
//! only for the map probe — never across inference. [`insert`] replaces
//! atomically, which doubles as hot reload: in-flight requests finish
//! on the engine they resolved, new requests resolve the new one.
//!
//! Every entry carries a [`ModelMeta`] sidecar: the artifact content
//! digest (`etag`), where the model came from, when it was (re)loaded
//! and how many times. `/stats` and `GET /models` serialize it, and
//! [`sync_dir`] uses the etag as the change detector — a rescan first
//! compares the file's `(mtime, len)` stat signature against the one
//! recorded at load time (no read at all when it matches), then falls
//! back to the cheap [`crate::artifact::peek_etag`] (one 64-byte header
//! read), and only pays for a full load when the digest moved.
//!
//! [`insert`]: ModelRegistry::insert
//! [`sync_dir`]: ModelRegistry::sync_dir

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::artifact::{self, LoadOptions, LoadReport};
use crate::int8::serve::{EngineOptions, Int8Engine};

/// Provenance + freshness sidecar for one registered model.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ModelMeta {
    /// Artifact content digest (`fnv64-…`); `None` for models built
    /// in-process (no artifact to digest).
    pub etag: Option<String>,
    /// Where the model came from: a `.fatm` path for artifact loads,
    /// `None` for in-process exports.
    pub source: Option<String>,
    /// `(mtime, len)` of the source file when it was last examined —
    /// [`ModelRegistry::sync_dir`]'s cheap pre-check: a file whose stat
    /// signature is unchanged skips even the header-peek read. `None`
    /// when the source was never statted (in-process exports).
    pub source_stat: Option<(std::time::SystemTime, u64)>,
    /// Unix seconds when this entry was last (re)inserted.
    pub loaded_at_unix: u64,
    /// How many times this name has been (re)loaded since registration.
    pub loads: u64,
}

struct Entry {
    engine: Int8Engine,
    meta: ModelMeta,
}

/// What one [`ModelRegistry::sync_dir`] pass did.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SyncReport {
    /// Names (re)loaded this pass because their etag moved (or they
    /// were new).
    pub loaded: Vec<String>,
    /// `.fatm` files whose etag matched the registered entry.
    pub unchanged: usize,
    /// Subset of `unchanged` settled by the `(mtime, len)` stat
    /// pre-check alone — no header read at all.
    pub stat_skipped: usize,
    /// Names removed because their source file under the dir vanished.
    pub removed: Vec<String>,
}

/// `(mtime, len)` signature used by the sync pre-check. `None` when the
/// filesystem can't answer (then every pass falls through to the etag
/// peek, which stays correct, just slower).
fn file_stat(p: &Path) -> Option<(std::time::SystemTime, u64)> {
    let md = std::fs::metadata(p).ok()?;
    Some((md.modified().ok()?, md.len()))
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Shared name → engine routing table.
#[derive(Clone, Default)]
pub struct ModelRegistry {
    inner: Arc<RwLock<BTreeMap<String, Entry>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `engine` under `name`, returning the engine it replaced
    /// (if any). Replacement is atomic — this is the hot-reload path.
    /// For in-process builds; artifact loads go through
    /// [`Self::load_artifact`] so the etag rides along.
    pub fn insert(&self, name: &str, engine: Int8Engine) -> Option<Int8Engine> {
        self.insert_with_meta(name, engine, None, None)
    }

    /// [`Self::insert`] with artifact provenance: `etag` is the `.fatm`
    /// content digest, `source` the path it was loaded from. The load
    /// counter carries over from the replaced entry.
    pub fn insert_with_meta(
        &self,
        name: &str,
        engine: Int8Engine,
        etag: Option<String>,
        source: Option<String>,
    ) -> Option<Int8Engine> {
        self.insert_entry(name, engine, etag, source, None)
    }

    fn insert_entry(
        &self,
        name: &str,
        engine: Int8Engine,
        etag: Option<String>,
        source: Option<String>,
        source_stat: Option<(std::time::SystemTime, u64)>,
    ) -> Option<Int8Engine> {
        let mut m = self.inner.write().unwrap();
        let loads = m.get(name).map_or(1, |e| e.meta.loads + 1);
        let meta = ModelMeta {
            etag,
            source,
            source_stat,
            loaded_at_unix: now_unix(),
            loads,
        };
        m.insert(name.to_string(), Entry { engine, meta })
            .map(|e| e.engine)
    }

    /// Record the stat signature for every entry loaded from `source`,
    /// so the next [`Self::sync_dir`] pass can skip even the header
    /// peek for that file.
    fn set_source_stat(&self, source: &str, stat: (std::time::SystemTime, u64)) {
        let mut m = self.inner.write().unwrap();
        for e in m.values_mut() {
            if e.meta.source.as_deref() == Some(source) {
                e.meta.source_stat = Some(stat);
            }
        }
    }

    /// Resolve a model name to a serving handle (an `Arc` clone).
    pub fn get(&self, name: &str) -> Option<Int8Engine> {
        self.inner.read().unwrap().get(name).map(|e| e.engine.clone())
    }

    /// The provenance sidecar for a registered model.
    pub fn meta(&self, name: &str) -> Option<ModelMeta> {
        self.inner.read().unwrap().get(name).map(|e| e.meta.clone())
    }

    /// Unregister a model; in-flight requests on it finish normally.
    pub fn remove(&self, name: &str) -> Option<Int8Engine> {
        self.inner.write().unwrap().remove(name).map(|e| e.engine)
    }

    /// Registered model names, sorted (BTreeMap order).
    pub fn names(&self) -> Vec<String> {
        self.inner.read().unwrap().keys().cloned().collect()
    }

    /// `(name, meta)` for every registered model, sorted by name.
    pub fn entries(&self) -> Vec<(String, ModelMeta)> {
        self.inner
            .read()
            .unwrap()
            .iter()
            .map(|(k, e)| (k.clone(), e.meta.clone()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Load a compiled `.fatm` artifact and register it under its graph
    /// name (falling back to the file stem for unnamed graphs). Returns
    /// the registered name and the loader's [`LoadReport`].
    pub fn load_artifact<P: AsRef<Path>>(
        &self,
        path: P,
        opts: EngineOptions,
    ) -> Result<(String, LoadReport)> {
        let path = path.as_ref();
        // Stat *before* the load: if the file is replaced mid-load, the
        // stale signature just costs one extra header peek next pass —
        // the safe direction to be wrong in.
        let stat = file_stat(path);
        let (qm, report) = artifact::load(path, LoadOptions::default())?;
        let name = if qm.graph.name.is_empty() {
            path.file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "model".to_string())
        } else {
            qm.graph.name.clone()
        };
        let engine = Int8Engine::new(qm, opts);
        self.insert_entry(
            &name,
            engine,
            Some(report.etag.clone()),
            Some(path.display().to_string()),
            stat,
        );
        Ok((name, report))
    }

    /// One hot-reload pass over an artifact directory: for every
    /// `*.fatm` file (sorted), peek the header etag and fully load only
    /// the new/changed ones; drop registered models whose source file
    /// under `dir` disappeared. Models registered from other sources
    /// (in-process exports, other dirs) are left alone. Idempotent —
    /// call it from a timer for `fat serve --models <dir>` hot reload.
    pub fn sync_dir<P: AsRef<Path>>(
        &self,
        dir: P,
        opts: EngineOptions,
    ) -> Result<SyncReport> {
        let dir = dir.as_ref();
        let mut files: Vec<std::path::PathBuf> = Vec::new();
        for e in std::fs::read_dir(dir)
            .with_context(|| format!("scanning artifact dir {dir:?}"))?
        {
            let p = e?.path();
            if p.extension().is_some_and(|x| x == "fatm") && p.is_file() {
                files.push(p);
            }
        }
        files.sort();

        let mut report = SyncReport::default();
        let mut live_sources: Vec<String> = Vec::new();
        for p in &files {
            let source = p.display().to_string();
            live_sources.push(source.clone());
            let stat = file_stat(p);
            let current = self.entries().into_iter().find_map(|(_, m)| {
                (m.source.as_deref() == Some(source.as_str())).then_some(m)
            });
            // Cheap pre-check: an unchanged (mtime, len) signature on a
            // file we already digested means the etag can't have moved —
            // skip even the header read. A matching signature with no
            // recorded etag proves nothing, so fall through.
            if let (Some(st), Some(cur)) = (stat, current.as_ref()) {
                if cur.etag.is_some() && cur.source_stat == Some(st) {
                    report.unchanged += 1;
                    report.stat_skipped += 1;
                    continue;
                }
            }
            let on_disk = artifact::peek_etag(p)
                .with_context(|| format!("peeking {p:?}"))?;
            if current.and_then(|m| m.etag).as_deref() == Some(on_disk.as_str()) {
                report.unchanged += 1;
                // Same content under a fresh mtime (touch, re-copy):
                // remember the new signature so the next pass skips
                // the peek too.
                if let Some(st) = stat {
                    self.set_source_stat(&source, st);
                }
                continue;
            }
            let (name, _) = self
                .load_artifact(p, opts)
                .with_context(|| format!("loading {p:?}"))?;
            // If the file's embedded graph name changed, retire the
            // entry its previous content was registered under — one
            // source file owns at most one serving slot.
            for (other, m) in self.entries() {
                if other != name
                    && m.source.as_deref() == Some(source.as_str())
                {
                    self.remove(&other);
                    report.removed.push(other);
                }
            }
            report.loaded.push(name);
        }
        // Retire entries whose .fatm under this dir was deleted.
        for (name, meta) in self.entries() {
            let Some(src) = meta.source.as_deref() else { continue };
            let managed = Path::new(src).parent() == Some(dir);
            if managed && !live_sources.iter().any(|s| s == src) {
                self.remove(&name);
                report.removed.push(name);
            }
        }
        Ok(report)
    }
}
