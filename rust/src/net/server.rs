//! The socket server (DESIGN.md §10.3–10.5): listener, accept loop,
//! per-connection protocol handling, admission control and graceful
//! drain over the [`ModelRegistry`].
//!
//! Concurrency model: the accept loop and every connection handler run
//! as detached IO tasks on the process worker pool
//! ([`crate::util::threads::WorkerPool::spawn_io`]) — blocking socket
//! reads therefore never occupy a compute shard, and inference inside a
//! handler still runs on the shard workers exactly as in-process
//! serving does. Backpressure is admission control, not queueing:
//!
//! * over [`ServerOptions::max_conns`] open connections → the accept
//!   loop answers `503` and drops the socket;
//! * over [`ServerOptions::max_inflight`] executing requests → the
//!   handler answers `429` without touching the engine.
//!
//! Both caps bound memory: a connection holds at most
//! [`super::Limits`] buffered bytes, and rejected work is never
//! buffered at all. Read/write deadlines bound how long a slow or dead
//! peer can hold a handler. [`Server::drain`] stops the accept loop,
//! waits for open connections and in-flight work to finish within a
//! grace period, then force-closes stragglers.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::threads::{pool, Notify};

use super::registry::ModelRegistry;
use super::{frame, http, Limits, Step, WireError};

/// Server construction knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerOptions {
    /// Open-connection cap; the accept loop answers `503` beyond it.
    pub max_conns: usize,
    /// Executing-request cap; handlers answer `429` beyond it.
    pub max_inflight: usize,
    /// Socket read deadline (slow-loris bound).
    pub read_timeout: Duration,
    /// Socket write deadline (dead-peer bound).
    pub write_timeout: Duration,
    /// Parser size caps.
    pub limits: Limits,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            max_conns: 256,
            max_inflight: 128,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            limits: Limits::default(),
        }
    }
}

/// Monotonic counters and gauges; `/stats` serializes these and the
/// overload tests reconcile them against client-side tallies.
#[derive(Default)]
struct Counters {
    accepted_conns: AtomicU64,
    rejected_conns: AtomicU64,
    /// Gauge: connections currently owned by a handler.
    open_conns: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Gauge: requests past admission, not yet answered.
    in_flight: AtomicU64,
    malformed: AtomicU64,
    timeouts: AtomicU64,
    disconnects: AtomicU64,
}

/// Point-in-time server counter snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStats {
    pub accepted_conns: u64,
    pub rejected_conns: u64,
    pub open_conns: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub in_flight: u64,
    pub malformed: u64,
    pub timeouts: u64,
    pub disconnects: u64,
    pub draining: bool,
}

struct Inner {
    registry: ModelRegistry,
    opts: ServerOptions,
    addr: SocketAddr,
    counters: Counters,
    draining: AtomicBool,
    /// Clones of every open connection, for force-shutdown at drain.
    conns: Mutex<BTreeMap<u64, TcpStream>>,
    conn_seq: AtomicU64,
    /// Signaled when the accept loop exits.
    accept_done: Notify,
}

/// A running socket server; dropping the handle does **not** stop it —
/// call [`Server::drain`] for a graceful shutdown.
#[derive(Clone)]
pub struct Server {
    inner: Arc<Inner>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting on the worker pool's IO tasks.
    pub fn bind(
        addr: &str,
        registry: ModelRegistry,
        opts: ServerOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let inner = Arc::new(Inner {
            registry,
            opts,
            addr: local,
            counters: Counters::default(),
            draining: AtomicBool::new(false),
            conns: Mutex::new(BTreeMap::new()),
            conn_seq: AtomicU64::new(0),
            accept_done: Notify::new(),
        });
        let accept_inner = Arc::clone(&inner);
        pool().spawn_io(move || accept_loop(accept_inner, listener));
        Ok(Server { inner })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.addr
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.inner.registry
    }

    pub fn is_draining(&self) -> bool {
        self.inner.draining.load(Ordering::SeqCst)
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ServerStats {
        let c = &self.inner.counters;
        ServerStats {
            accepted_conns: c.accepted_conns.load(Ordering::Relaxed),
            rejected_conns: c.rejected_conns.load(Ordering::Relaxed),
            open_conns: c.open_conns.load(Ordering::SeqCst),
            admitted: c.admitted.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            in_flight: c.in_flight.load(Ordering::SeqCst),
            malformed: c.malformed.load(Ordering::Relaxed),
            timeouts: c.timeouts.load(Ordering::Relaxed),
            disconnects: c.disconnects.load(Ordering::Relaxed),
            draining: self.is_draining(),
        }
    }

    /// The `/stats` JSON document (also served over both protocols).
    pub fn stats_json(&self) -> String {
        stats_json(&self.inner)
    }

    /// Graceful shutdown: stop accepting, wait up to `grace` for open
    /// connections and in-flight requests to finish, then force-close
    /// stragglers. Idempotent; new requests answer `503` from the
    /// moment this is called.
    pub fn drain(&self, grace: Duration) {
        let inner = &self.inner;
        if !inner.draining.swap(true, Ordering::SeqCst) {
            // Wake the accept loop: it re-checks `draining` once per
            // accepted connection, so connect to ourselves.
            let _ = TcpStream::connect_timeout(
                &inner.addr,
                Duration::from_millis(250),
            );
        }
        let deadline = Instant::now() + grace;
        inner.accept_done.wait_deadline(deadline);
        loop {
            let quiet = inner.counters.open_conns.load(Ordering::SeqCst) == 0
                && inner.counters.in_flight.load(Ordering::SeqCst) == 0;
            if quiet {
                return;
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // Grace expired: cut stragglers loose. Their handlers observe
        // the shutdown as a read/write error and unwind normally.
        let stragglers: Vec<TcpStream> = {
            let mut m = inner.conns.lock().unwrap();
            std::mem::take(&mut *m).into_values().collect()
        };
        for s in &stragglers {
            let _ = s.shutdown(Shutdown::Both);
        }
        let hard = Instant::now() + Duration::from_millis(500);
        while inner.counters.open_conns.load(Ordering::SeqCst) != 0
            && Instant::now() < hard
        {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

fn accept_loop(inner: Arc<Inner>, listener: TcpListener) {
    for conn in listener.incoming() {
        if inner.draining.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let c = &inner.counters;
        if c.open_conns.load(Ordering::SeqCst)
            >= inner.opts.max_conns as u64
        {
            c.rejected_conns.fetch_add(1, Ordering::Relaxed);
            // Best-effort refusal; the peer may be gone already.
            let mut s = stream;
            let _ = s.set_write_timeout(Some(Duration::from_millis(250)));
            let _ = s.write_all(&http::response(
                503,
                "text/plain",
                b"connection limit\n",
                false,
            ));
            continue;
        }
        c.accepted_conns.fetch_add(1, Ordering::Relaxed);
        c.open_conns.fetch_add(1, Ordering::SeqCst);
        let id = inner.conn_seq.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            inner.conns.lock().unwrap().insert(id, clone);
        }
        let conn_inner = Arc::clone(&inner);
        pool().spawn_io(move || {
            // Deregisters + decrements even if the handler panics (the
            // IO worker catches the unwind after Drop runs).
            let _guard = ConnGuard { inner: &conn_inner, id };
            handle_conn(&conn_inner, stream);
        });
    }
    // Listener drops here: the port closes, post-drain connects fail.
    inner.accept_done.notify();
}

struct ConnGuard<'a> {
    inner: &'a Arc<Inner>,
    id: u64,
}

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.inner.conns.lock().unwrap().remove(&self.id);
        self.inner.counters.open_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Drive one connection to completion: accumulate bytes, parse as many
/// complete messages as the buffer holds (protocol sniffed from the
/// first byte), dispatch, answer. Every exit path is bounded: parse
/// errors close after a well-formed error answer, read deadlines close
/// after a best-effort timeout answer, and EOF just closes.
fn handle_conn(inner: &Arc<Inner>, mut stream: TcpStream) {
    let opts = &inner.opts;
    let c = &inner.counters;
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(opts.read_timeout)).is_err()
        || stream.set_write_timeout(Some(opts.write_timeout)).is_err()
    {
        return;
    }
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 16 * 1024];
    'conn: loop {
        // Parse phase: drain every complete pipelined message.
        while !buf.is_empty() {
            if buf[0] == frame::MAGIC[0] {
                match frame::parse_request(&buf, &opts.limits) {
                    Ok(Step::Done(f, used)) => {
                        let resp = dispatch_frame(inner, f);
                        if stream.write_all(&resp).is_err() {
                            break 'conn;
                        }
                        buf.drain(..used);
                    }
                    Ok(Step::Incomplete) => break,
                    Err(e) => {
                        c.malformed.fetch_add(1, Ordering::Relaxed);
                        let _ = stream.write_all(&frame::encode_response(
                            frame::status_for(e.status),
                            e.msg.as_bytes(),
                        ));
                        break 'conn;
                    }
                }
            } else {
                match http::parse_request(&buf, &opts.limits) {
                    Ok(Step::Done(req, used)) => {
                        let keep = req.keep_alive;
                        let resp = dispatch_http(inner, &req);
                        if stream.write_all(&resp).is_err() {
                            break 'conn;
                        }
                        buf.drain(..used);
                        if !keep {
                            break 'conn;
                        }
                    }
                    Ok(Step::Incomplete) => break,
                    Err(e) => {
                        c.malformed.fetch_add(1, Ordering::Relaxed);
                        let _ = stream.write_all(&http::response(
                            e.status,
                            "text/plain",
                            format!("{}\n", e.msg).as_bytes(),
                            false,
                        ));
                        break 'conn;
                    }
                }
            }
        }
        if inner.draining.load(Ordering::SeqCst) && buf.is_empty() {
            break;
        }
        // Read phase.
        match stream.read(&mut tmp) {
            Ok(0) => {
                if !buf.is_empty() {
                    // EOF mid-request: the peer hung up on us.
                    c.disconnects.fetch_add(1, Ordering::Relaxed);
                }
                break;
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                if !buf.is_empty() {
                    // Deadline fired with a request half-arrived:
                    // slow-loris. Answer and cut the connection. An
                    // idle keep-alive connection (empty buffer) just
                    // closes quietly.
                    c.timeouts.fetch_add(1, Ordering::Relaxed);
                    let resp = if buf[0] == frame::MAGIC[0] {
                        frame::encode_response(
                            frame::ST_BAD_REQUEST,
                            b"read timeout",
                        )
                    } else {
                        http::response(
                            408,
                            "text/plain",
                            b"read timeout\n",
                            false,
                        )
                    };
                    let _ = stream.write_all(&resp);
                }
                break;
            }
            Err(_) => {
                c.disconnects.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// RAII decrement for the admission `in_flight` gauge.
struct InflightGuard<'a>(&'a AtomicU64);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The one inference path both protocols dispatch into: drain check,
/// model routing, admission control, engine call, counter bookkeeping.
fn infer(
    inner: &Inner,
    model: &str,
    pixels: &[u8],
) -> Result<Vec<f32>, WireError> {
    if inner.draining.load(Ordering::SeqCst) {
        return Err(WireError::new(503, "draining"));
    }
    let engine = inner
        .registry
        .get(model)
        .ok_or_else(|| WireError::new(404, format!("unknown model {model}")))?;
    let c = &inner.counters;
    // Admission: claim a slot first, give it back if over the cap. The
    // claim-first order makes the gauge an upper bound, so the cap can
    // never be exceeded by a race.
    let prev = c.in_flight.fetch_add(1, Ordering::SeqCst);
    if prev >= inner.opts.max_inflight as u64 {
        c.in_flight.fetch_sub(1, Ordering::SeqCst);
        c.rejected.fetch_add(1, Ordering::Relaxed);
        return Err(WireError::new(429, "over capacity"));
    }
    let _slot = InflightGuard(&c.in_flight);
    c.admitted.fetch_add(1, Ordering::Relaxed);
    match engine.infer(pixels) {
        Ok(logits) => {
            c.completed.fetch_add(1, Ordering::Relaxed);
            Ok(logits)
        }
        Err(e) => {
            c.failed.fetch_add(1, Ordering::Relaxed);
            Err(WireError::new(400, e.to_string()))
        }
    }
}

/// `/v1/models/<name>/infer` → `<name>` (no empty or nested names).
fn infer_path(path: &str) -> Option<&str> {
    let name = path.strip_prefix("/v1/models/")?.strip_suffix("/infer")?;
    (!name.is_empty() && !name.contains('/')).then_some(name)
}

fn dispatch_http(inner: &Inner, req: &http::Request) -> Vec<u8> {
    let keep = req.keep_alive;
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            http::response(200, "text/plain", b"ok\n", keep)
        }
        ("GET", "/stats") => http::response(
            200,
            "application/json",
            stats_json(inner).as_bytes(),
            keep,
        ),
        ("GET", "/models") => http::response(
            200,
            "application/json",
            models_json(inner).as_bytes(),
            keep,
        ),
        (method, path) => match infer_path(path) {
            Some(name) => {
                if method != "POST" {
                    return http::response(
                        405,
                        "text/plain",
                        b"use POST\n",
                        keep,
                    );
                }
                match infer(inner, name, &req.body) {
                    Ok(logits) => http::response(
                        200,
                        "application/json",
                        logits_json(name, &logits).as_bytes(),
                        keep,
                    ),
                    Err(e) => http::response(
                        e.status,
                        "text/plain",
                        format!("{}\n", e.msg).as_bytes(),
                        keep,
                    ),
                }
            }
            None => http::response(404, "text/plain", b"not found\n", keep),
        },
    }
}

fn dispatch_frame(inner: &Inner, f: frame::Frame) -> Vec<u8> {
    match f.op {
        frame::OP_INFER => match infer(inner, &f.model, &f.body) {
            Ok(logits) => {
                let mut body = Vec::with_capacity(logits.len() * 4);
                for v in &logits {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                frame::encode_response(frame::ST_OK, &body)
            }
            Err(e) => frame::encode_response(
                frame::status_for(e.status),
                e.msg.as_bytes(),
            ),
        },
        frame::OP_STATS => frame::encode_response(
            frame::ST_OK,
            stats_json(inner).as_bytes(),
        ),
        _ => frame::encode_response(frame::ST_BAD_REQUEST, b"unknown opcode"),
    }
}

/// Logits answer body. Each value prints with Rust's shortest
/// round-trip `f32` formatting, so `str::parse::<f32>` on the client
/// recovers the exact bits — the bit-exactness oracle holds across the
/// text protocol (DESIGN.md §10.5).
fn logits_json(model: &str, logits: &[f32]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(24 + 16 * logits.len());
    let _ = write!(s, "{{\"model\":\"{}\",\"logits\":[", esc(model));
    for (i, v) in logits.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{v}");
    }
    s.push_str("]}");
    s
}

/// Minimal JSON string escape (registry names are CLI identifiers, but
/// never emit a syntactically broken document).
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn stats_json(inner: &Inner) -> String {
    use std::fmt::Write as _;
    let c = &inner.counters;
    let p = pool();
    let mut s = String::with_capacity(768);
    let _ = write!(
        s,
        "{{\"draining\":{},\"accepted_conns\":{},\"rejected_conns\":{},\
         \"open_conns\":{},\"admitted\":{},\"rejected\":{},\
         \"completed\":{},\"failed\":{},\"in_flight\":{},\
         \"malformed\":{},\"timeouts\":{},\"disconnects\":{},\
         \"max_conns\":{},\"max_inflight\":{},\
         \"pool_workers\":{},\"io_workers\":{},\"io_idle\":{},\
         \"models\":{{",
        inner.draining.load(Ordering::SeqCst),
        c.accepted_conns.load(Ordering::Relaxed),
        c.rejected_conns.load(Ordering::Relaxed),
        c.open_conns.load(Ordering::SeqCst),
        c.admitted.load(Ordering::Relaxed),
        c.rejected.load(Ordering::Relaxed),
        c.completed.load(Ordering::Relaxed),
        c.failed.load(Ordering::Relaxed),
        c.in_flight.load(Ordering::SeqCst),
        c.malformed.load(Ordering::Relaxed),
        c.timeouts.load(Ordering::Relaxed),
        c.disconnects.load(Ordering::Relaxed),
        inner.opts.max_conns,
        inner.opts.max_inflight,
        p.workers(),
        p.io_workers(),
        p.io_idle(),
    );
    for (i, name) in inner.registry.names().iter().enumerate() {
        let Some(engine) = inner.registry.get(name) else {
            continue;
        };
        let st = engine.stats();
        if i > 0 {
            s.push(',');
        }
        let meta = inner.registry.meta(name).unwrap_or_default();
        let _ = write!(
            s,
            "\"{}\":{{\"threads\":{},\"isa\":\"{}\",\
             \"pooled_states\":{},\
             \"in_flight\":{},\"requests\":{},\"param_bytes\":{},\
             \"etag\":{},\"loaded_at\":{},\"loads\":{},\
             \"blockings\":[",
            esc(name),
            st.threads,
            st.isa,
            st.pooled_states,
            st.in_flight,
            st.requests,
            engine.param_bytes(),
            json_opt_str(meta.etag.as_deref()),
            meta.loaded_at_unix,
            meta.loads,
        );
        // Active GEMM blocking table (autotuner output; one entry per
        // distinct schedule with its layer count).
        for (j, (bk, layers)) in
            engine.model().blocking_summary().iter().enumerate()
        {
            if j > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"kc\":{},\"nr\":{},\"mr\":{},\"grain\":{},\
                 \"layers\":{}}}",
                bk.kc, bk.nr, bk.mr, bk.grain, layers
            );
        }
        // Requant-epilogue and weight-panel census (ISSUE-9): how many
        // layers run the shift-only epilogue vs fixed-point multipliers,
        // and how many serve nibble-packed int4 panels.
        let (shift, mul, int4, int8) = engine.model().epilogue_summary();
        // Conv-path census (ISSUE-10: fused implicit GEMM) and the peak
        // per-worker scratch footprint — fused layers bypass the staged
        // patches/acc scratch, so the memory win is observable here.
        let (fused, staged) = engine.model().fused_summary();
        let _ = write!(
            s,
            "],\"epilogues\":{{\"shift\":{shift},\"multiplier\":{mul}}},\
             \"weight_bits\":{{\"int4\":{int4},\"int8\":{int8}}},\
             \"conv_path\":{{\"fused\":{fused},\"staged\":{staged}}},\
             \"scratch_bytes\":{{\"patches\":{},\"acc\":{},\"arena\":{}}},\
             \"batcher\":",
            st.scratch.patches_bytes,
            st.scratch.acc_bytes,
            st.scratch.arena_bytes,
        );
        match st.batcher {
            Some(b) => {
                let _ = write!(
                    s,
                    "{{\"requests\":{},\"batches\":{},\"rows\":{},\
                     \"waiting\":{}}}",
                    b.requests, b.batches, b.rows, b.waiting
                );
            }
            None => s.push_str("null"),
        }
        s.push('}');
    }
    s.push_str("}}");
    s
}

/// `null` or a quoted, escaped JSON string.
fn json_opt_str(v: Option<&str>) -> String {
    match v {
        Some(s) => format!("\"{}\"", esc(s)),
        None => "null".to_string(),
    }
}

/// The `GET /models` document: every registered model with its artifact
/// provenance ([`super::registry::ModelMeta`]) — the etag is the `.fatm`
/// content digest, so clients can poll this endpoint to detect hot
/// reloads without re-downloading anything.
fn models_json(inner: &Inner) -> String {
    use std::fmt::Write as _;
    let mut s = String::from("{\"models\":[");
    for (i, (name, meta)) in inner.registry.entries().iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"name\":\"{}\",\"etag\":{},\"source\":{},\
             \"loaded_at\":{},\"loads\":{}}}",
            esc(name),
            json_opt_str(meta.etag.as_deref()),
            json_opt_str(meta.source.as_deref()),
            meta.loaded_at_unix,
            meta.loads,
        );
    }
    s.push_str("]}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_opt_str_escapes() {
        assert_eq!(json_opt_str(None), "null");
        assert_eq!(json_opt_str(Some("fnv64-0abc")), "\"fnv64-0abc\"");
        assert_eq!(json_opt_str(Some("a\"b\\c")), "\"a\\\"b\\\\c\"");
    }

    #[test]
    fn infer_path_routing() {
        assert_eq!(infer_path("/v1/models/tiny_cnn/infer"), Some("tiny_cnn"));
        assert_eq!(infer_path("/v1/models/a.b-c/infer"), Some("a.b-c"));
        assert_eq!(infer_path("/v1/models//infer"), None);
        assert_eq!(infer_path("/v1/models/a/b/infer"), None);
        assert_eq!(infer_path("/v1/models/a"), None);
        assert_eq!(infer_path("/stats"), None);
    }

    #[test]
    fn logits_json_round_trips_awkward_floats() {
        let vals = [0.1f32, -0.0, f32::MIN_POSITIVE, 3.4e38, 1.0 / 3.0];
        let s = logits_json("m", &vals);
        let inner = s
            .split("\"logits\":[")
            .nth(1)
            .and_then(|t| t.strip_suffix("]}"))
            .unwrap();
        for (tok, want) in inner.split(',').zip(vals.iter()) {
            let got: f32 = tok.parse().unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "token {tok}");
        }
    }
}
