//! Drain-on-signal for the `fat serve` subcommand: SIGINT/SIGTERM flip
//! one async-signal-safe flag that the serve loop polls, then
//! [`super::server::Server::drain`] does the actual graceful shutdown
//! on the main thread. The handler itself only stores an atomic — the
//! full async-signal-safety story is that nothing else happens in
//! signal context.
//!
//! Zero-dependency by design (the repo bans crates the container lacks,
//! DESIGN.md §1): on Unix we declare libc's `signal(2)` ourselves
//! instead of pulling in the `libc` crate; elsewhere installation is a
//! no-op and the serve loop simply runs until killed.

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the signal handler; polled by the serve loop.
static DRAIN: AtomicBool = AtomicBool::new(false);
/// Guards against double-installation.
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// Has a drain been requested (SIGINT/SIGTERM since install)?
pub fn drain_requested() -> bool {
    DRAIN.load(Ordering::Acquire)
}

/// Install the SIGINT/SIGTERM → drain-flag handler (idempotent).
pub fn install_drain_handler() {
    if INSTALLED.swap(true, Ordering::AcqRel) {
        return;
    }
    platform::install();
}

#[cfg(unix)]
mod platform {
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        /// POSIX `signal(2)`. The return value (previous disposition)
        /// is deliberately opaque — we never restore it.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_signal(_signum: i32) {
        // Only an atomic store: async-signal-safe.
        super::DRAIN.store(true, Ordering::Release);
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }
}

#[cfg(not(unix))]
mod platform {
    pub fn install() {}
}
