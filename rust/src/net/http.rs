//! Hand-rolled HTTP/1.1 wire format (DESIGN.md §10.1): an incremental,
//! pure request/response parser plus the serializers the server and
//! loopback clients share.
//!
//! The subset is deliberately small and strict — exactly what the
//! serving front-end speaks, with every violation mapped to a precise
//! status code instead of a panic or a hang:
//!
//! * request line `METHOD SP PATH SP HTTP/1.1|HTTP/1.0` (else `400`,
//!   unknown versions `505`);
//! * `Name: value` headers, names lower-cased on parse (malformed `400`,
//!   head over [`Limits::max_head`] `431`);
//! * bodies sized by `Content-Length` only (`Transfer-Encoding` answers
//!   `501`, a `POST`/`PUT` without a length `411`, a length over
//!   [`Limits::max_body`] `413`);
//! * keep-alive by default on 1.1, `Connection: close` honored.
//!
//! Parsers never mutate their input: callers accumulate bytes and
//! re-parse on [`Step::Incomplete`], which makes "split across reads"
//! handling trivial and directly testable (`rust/tests/net_proto.rs`
//! feeds every prefix of valid and garbage byte soups).

use super::{Limits, Step, WireError};

/// One parsed HTTP request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `true` for HTTP/1.1, `false` for HTTP/1.0.
    pub version_11: bool,
    /// Header names lower-cased, values trimmed, in wire order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Resolved keep-alive: the version default overridden by any
    /// `Connection` header.
    pub keep_alive: bool,
}

impl Request {
    /// First header value by lower-case name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// One parsed HTTP response (the loopback clients' half).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse the shared `Name: value` header block; returns
/// `(headers, content_length)`.
fn parse_headers<'a>(
    lines: impl Iterator<Item = &'a str>,
    limits: &Limits,
) -> Result<(Vec<(String, String)>, Option<usize>), WireError> {
    let mut headers = Vec::new();
    let mut content_length: Option<usize> = None;
    for line in lines {
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| WireError::new(400, "malformed header line"))?;
        if name.is_empty()
            || name.contains(' ')
            || name.contains('\t')
        {
            return Err(WireError::new(400, "malformed header name"));
        }
        let name = name.to_ascii_lowercase();
        let value = value.trim().to_string();
        match name.as_str() {
            "content-length" => {
                let n: usize = value.parse().map_err(|_| {
                    WireError::new(400, "bad content-length")
                })?;
                if content_length.is_some_and(|prev| prev != n) {
                    return Err(WireError::new(
                        400,
                        "conflicting content-length",
                    ));
                }
                if n > limits.max_body {
                    return Err(WireError::new(413, "body too large"));
                }
                content_length = Some(n);
            }
            "transfer-encoding" => {
                return Err(WireError::new(
                    501,
                    "transfer-encoding not supported",
                ));
            }
            _ => {}
        }
        headers.push((name, value));
    }
    Ok((headers, content_length))
}

/// Incrementally parse one request from the front of `buf`.
pub fn parse_request(
    buf: &[u8],
    limits: &Limits,
) -> Result<Step<Request>, WireError> {
    let head_end = match find_head_end(buf) {
        Some(e) => e,
        None => {
            return if buf.len() > limits.max_head {
                Err(WireError::new(431, "request head too large"))
            } else {
                Ok(Step::Incomplete)
            };
        }
    };
    if head_end > limits.max_head {
        return Err(WireError::new(431, "request head too large"));
    }
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| WireError::new(400, "non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let req_line = lines.next().unwrap_or("");
    let mut parts = req_line.split(' ');
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None)
                if !m.is_empty() && !p.is_empty() =>
            {
                (m, p, v)
            }
            _ => return Err(WireError::new(400, "malformed request line")),
        };
    if !(1..=16).contains(&method.len())
        || !method.bytes().all(|b| b.is_ascii_uppercase())
    {
        return Err(WireError::new(400, "malformed method"));
    }
    if !path.starts_with('/') {
        return Err(WireError::new(400, "malformed path"));
    }
    let version_11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(WireError::new(505, "unsupported HTTP version")),
    };
    let (headers, content_length) = parse_headers(lines, limits)?;
    let body_len = match content_length {
        Some(n) => n,
        None => {
            if method == "POST" || method == "PUT" {
                return Err(WireError::new(411, "length required"));
            }
            0
        }
    };
    let total = head_end + 4 + body_len;
    if buf.len() < total {
        return Ok(Step::Incomplete);
    }
    let mut keep_alive = version_11;
    let mut req = Request {
        method: method.to_string(),
        path: path.to_string(),
        version_11,
        headers,
        body: buf[head_end + 4..total].to_vec(),
        keep_alive,
    };
    if let Some(conn) = req.header("connection") {
        let conn = conn.to_ascii_lowercase();
        if conn.contains("close") {
            keep_alive = false;
        } else if conn.contains("keep-alive") {
            keep_alive = true;
        }
    }
    req.keep_alive = keep_alive;
    Ok(Step::Done(req, total))
}

/// Incrementally parse one response from the front of `buf`. A missing
/// `Content-Length` is an error — every response this stack emits
/// carries one, so its absence means a framing bug, not a legal
/// read-until-close body.
pub fn parse_response(
    buf: &[u8],
    limits: &Limits,
) -> Result<Step<Response>, WireError> {
    let head_end = match find_head_end(buf) {
        Some(e) => e,
        None => {
            return if buf.len() > limits.max_head {
                Err(WireError::new(431, "response head too large"))
            } else {
                Ok(Step::Incomplete)
            };
        }
    };
    if head_end > limits.max_head {
        return Err(WireError::new(431, "response head too large"));
    }
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| WireError::new(400, "non-utf8 response head"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let (version, code) = match (parts.next(), parts.next()) {
        (Some(v), Some(c)) => (v, c),
        _ => return Err(WireError::new(400, "malformed status line")),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(WireError::new(400, "malformed status line"));
    }
    let status: u16 = code
        .parse()
        .map_err(|_| WireError::new(400, "malformed status code"))?;
    let (headers, content_length) = parse_headers(lines, limits)?;
    let body_len = content_length
        .ok_or_else(|| WireError::new(400, "response missing content-length"))?;
    let total = head_end + 4 + body_len;
    if buf.len() < total {
        return Ok(Step::Incomplete);
    }
    Ok(Step::Done(
        Response {
            status,
            headers,
            body: buf[head_end + 4..total].to_vec(),
        },
        total,
    ))
}

/// Serialize a response with `Content-Length` and an explicit
/// `Connection` header (the server's one response shape).
pub fn response(
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Vec<u8> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    let mut v = head.into_bytes();
    v.extend_from_slice(body);
    v
}

/// Serialize a request (the loopback clients' half).
pub fn request(
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Vec<u8> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: fat\r\n\
         Content-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    let mut v = head.into_bytes();
    v.extend_from_slice(body);
    v
}

/// Canonical reason phrase for the status codes this stack emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: Limits = Limits { max_head: 1024, max_body: 4096 };

    #[test]
    fn parses_a_post_with_body() {
        let wire = request("POST", "/v1/models/m/infer", "application/octet-stream", b"abc");
        match parse_request(&wire, &L).unwrap() {
            Step::Done(req, used) => {
                assert_eq!(used, wire.len());
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/models/m/infer");
                assert_eq!(req.body, b"abc");
                assert!(req.keep_alive);
                assert_eq!(req.header("host"), Some("fat"));
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn every_prefix_is_incomplete() {
        let wire = request("POST", "/x", "text/plain", b"hello");
        for cut in 0..wire.len() {
            assert_eq!(
                parse_request(&wire[..cut], &L).unwrap(),
                Step::Incomplete,
                "prefix {cut}"
            );
        }
    }

    #[test]
    fn pipelined_requests_consume_exactly_one() {
        let mut wire = request("GET", "/stats", "text/plain", b"");
        let first_len = wire.len();
        wire.extend_from_slice(&request("GET", "/healthz", "text/plain", b""));
        match parse_request(&wire, &L).unwrap() {
            Step::Done(req, used) => {
                assert_eq!(used, first_len);
                assert_eq!(req.path, "/stats");
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn connection_close_and_http10_default() {
        let wire = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let Step::Done(req, _) = parse_request(wire, &L).unwrap() else {
            panic!("incomplete");
        };
        assert!(!req.keep_alive);
        let wire = b"GET / HTTP/1.0\r\n\r\n";
        let Step::Done(req, _) = parse_request(wire, &L).unwrap() else {
            panic!("incomplete");
        };
        assert!(!req.keep_alive);
    }

    #[test]
    fn malformed_inputs_get_precise_codes() {
        let cases: &[(&[u8], u16)] = &[
            (b"GET\r\n\r\n", 400),
            (b"GET /x\r\n\r\n", 400),
            (b"GET /x HTTP/2.0\r\n\r\n", 505),
            (b"get /x HTTP/1.1\r\n\r\n", 400),
            (b"GET x HTTP/1.1\r\n\r\n", 400),
            (b"GET /x HTTP/1.1\r\nno-colon-line\r\n\r\n", 400),
            (b"GET /x HTTP/1.1\r\nBad Name: v\r\n\r\n", 400),
            (b"POST /x HTTP/1.1\r\nContent-Length: abc\r\n\r\n", 400),
            (b"POST /x HTTP/1.1\r\n\r\n", 411),
            (b"POST /x HTTP/1.1\r\nContent-Length: 9999\r\n\r\n", 413),
            (
                b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\n",
                400,
            ),
            (
                b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
                501,
            ),
        ];
        for (wire, want) in cases {
            let got = parse_request(wire, &L).unwrap_err();
            assert_eq!(got.status, *want, "{}", String::from_utf8_lossy(wire));
        }
    }

    #[test]
    fn oversized_head_is_431_even_without_terminator() {
        let junk = vec![b'A'; L.max_head + 1];
        assert_eq!(parse_request(&junk, &L).unwrap_err().status, 431);
    }

    #[test]
    fn huge_content_length_is_rejected_not_allocated() {
        let wire =
            b"POST /x HTTP/1.1\r\nContent-Length: 999999999999999999999\r\n\r\n";
        // overflows usize -> 400 (bad value), never an allocation
        assert_eq!(parse_request(wire, &L).unwrap_err().status, 400);
    }

    #[test]
    fn response_roundtrip() {
        let wire = response(200, "application/json", b"{\"k\":1}", true);
        let Step::Done(resp, used) = parse_response(&wire, &L).unwrap() else {
            panic!("incomplete");
        };
        assert_eq!(used, wire.len());
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{\"k\":1}");
        for cut in 0..wire.len() {
            assert_eq!(
                parse_response(&wire[..cut], &L).unwrap(),
                Step::Incomplete,
                "prefix {cut}"
            );
        }
    }

    #[test]
    fn reason_phrases_cover_emitted_codes() {
        for code in [200, 400, 404, 405, 408, 411, 413, 429, 431, 500, 501, 503, 505]
        {
            assert_ne!(reason(code), "Error", "code {code}");
        }
        assert_eq!(reason(418), "Error");
    }
}
