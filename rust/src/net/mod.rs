//! Socket serving front-end over [`crate::int8::serve::Int8Engine`]
//! (DESIGN.md §10) — the network layer that turns the in-process
//! serving stack of PRs 2–5 into a real server, with zero dependencies
//! beyond `std::net`.
//!
//! Two wire protocols share one listener port, distinguished by the
//! first byte of a connection (`0xFA` opens the binary protocol, any
//! other byte is parsed as HTTP):
//!
//! * **HTTP/1.1** ([`http`]): hand-rolled request parsing with
//!   keep-alive, `POST /v1/models/<name>/infer` carrying raw HWC u8
//!   pixels and answering JSON logits, `GET /stats` and `GET /healthz`.
//! * **Length-prefixed frames** ([`frame`]): a compact binary protocol
//!   for machine clients — magic, opcode, model name, `u32` body length,
//!   raw pixel bytes in, raw little-endian `f32` logits out.
//!
//! [`server::Server`] owns the listener: the accept loop and every
//! per-connection handler run on the worker pool's detached IO workers
//! ([`crate::util::threads::WorkerPool::spawn_io`]), requests are routed
//! by model name through a [`registry::ModelRegistry`] (which also
//! loads compiled `.fatm` artifacts and hot-reloads them by content
//! etag — `GET /models` lists each model's provenance), and admission
//! control rejects work beyond `max_inflight` with a `429`-style answer
//! instead of queueing unboundedly. Sockets carry read/write deadlines,
//! so slow-loris clients and half-dead peers are bounded, and
//! [`server::Server::drain`] performs a graceful shutdown: stop
//! accepting, finish in-flight work, then force-close stragglers.
//!
//! Bit-exactness survives the network hop: the frame protocol carries
//! logits as raw `f32` bits, and the HTTP path prints each logit with
//! Rust's shortest round-trip formatting and parses it back with the
//! correctly-rounded `str::parse::<f32>` — both reproduce
//! `run_quant_ref`'s bytes exactly (`rust/tests/serve_stress.rs`
//! asserts this over live sockets).

pub mod client;
pub mod frame;
pub mod http;
pub mod registry;
pub mod server;
pub mod signal;
pub mod watch;

pub use client::{FrameClient, HttpClient};
pub use registry::{ModelMeta, ModelRegistry, SyncReport};
pub use watch::DirWatcher;
pub use server::{Server, ServerOptions, ServerStats};

/// Parser size caps shared by both wire protocols. Every cap answers a
/// well-formed protocol error instead of growing a buffer without
/// bound, so a garbage-spewing client costs bounded memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Limits {
    /// Maximum HTTP head (request line + headers) bytes.
    pub max_head: usize,
    /// Maximum request body bytes (HTTP body or frame payload).
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        // 224*224*3 mobilenet input is ~150 KiB; 4 MiB leaves headroom
        // without letting one connection balloon the process.
        Limits { max_head: 8 * 1024, max_body: 4 << 20 }
    }
}

/// Outcome of feeding a byte buffer to an incremental parser: either
/// the message is not complete yet (read more bytes and retry — the
/// parser is pure, so re-parsing a grown buffer is always safe), or a
/// complete message plus the number of bytes it consumed (trailing
/// bytes belong to the next pipelined message).
#[derive(Debug, Clone, PartialEq)]
pub enum Step<T> {
    Incomplete,
    Done(T, usize),
}

/// A protocol violation with the HTTP status code it maps to (the frame
/// protocol folds these onto its one-byte status space via
/// [`frame::status_for`]). Parse errors are fatal to the connection:
/// after a malformed message the byte stream cannot be resynchronized.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub status: u16,
    pub msg: String,
}

impl WireError {
    pub fn new(status: u16, msg: impl Into<String>) -> Self {
        WireError { status, msg: msg.into() }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.status, self.msg)
    }
}

impl std::error::Error for WireError {}
