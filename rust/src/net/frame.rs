//! Length-prefixed binary frame protocol (DESIGN.md §10.2) — the
//! machine-client fallback sharing the listener port with HTTP.
//!
//! A connection opting into frames starts its first byte with `0xFA`
//! (no valid HTTP method does), so the server can sniff the protocol
//! from one byte. Wire layout, all integers little-endian:
//!
//! ```text
//! request:  FA 54 | op:u8 | name_len:u8 | name bytes | body_len:u32 | body
//! response: FA 54 | status:u8          |              body_len:u32 | body
//! ```
//!
//! `OP_INFER` carries raw HWC u8 pixels and answers raw `f32` logit
//! bits — bit-exactness needs no text round-trip at all. `OP_STATS`
//! answers the same JSON document as HTTP `GET /stats`. Response
//! statuses fold the HTTP codes onto one byte via [`status_for`].
//!
//! Like [`super::http`], parsers are pure and incremental: feed a
//! growing buffer, get [`Step::Incomplete`] until a whole frame is
//! present. Malformed magic or an oversized body is fatal to the
//! connection ([`WireError`]).

use super::{Limits, Step, WireError};

/// Frame magic: `0xFA` selects the protocol, `0x54` ("T") guards
/// against accidents.
pub const MAGIC: [u8; 2] = [0xFA, 0x54];

/// Request opcodes.
pub const OP_INFER: u8 = 1;
pub const OP_STATS: u8 = 2;

/// Response statuses.
pub const ST_OK: u8 = 0;
pub const ST_BAD_REQUEST: u8 = 1;
pub const ST_NOT_FOUND: u8 = 2;
pub const ST_OVERLOADED: u8 = 3;
pub const ST_DRAINING: u8 = 4;
pub const ST_INTERNAL: u8 = 5;

/// Fold an HTTP status onto the frame protocol's one-byte space.
pub fn status_for(http: u16) -> u8 {
    match http {
        200 => ST_OK,
        404 => ST_NOT_FOUND,
        429 => ST_OVERLOADED,
        503 => ST_DRAINING,
        500 => ST_INTERNAL,
        _ => ST_BAD_REQUEST,
    }
}

/// One parsed request frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub op: u8,
    pub model: String,
    pub body: Vec<u8>,
}

/// One parsed response frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameResponse {
    pub status: u8,
    pub body: Vec<u8>,
}

/// Check the magic prefix byte-by-byte so a wrong byte errors as soon
/// as it arrives instead of waiting for a full header that never comes.
fn check_magic(buf: &[u8]) -> Result<(), WireError> {
    for (i, want) in MAGIC.iter().enumerate() {
        match buf.get(i) {
            Some(got) if got == want => {}
            Some(_) => return Err(WireError::new(400, "bad frame magic")),
            None => return Ok(()), // not enough bytes yet
        }
    }
    Ok(())
}

fn body_len_at(buf: &[u8], at: usize, limits: &Limits) -> Result<Option<usize>, WireError> {
    if buf.len() < at + 4 {
        return Ok(None);
    }
    let n = u32::from_le_bytes([buf[at], buf[at + 1], buf[at + 2], buf[at + 3]]) as usize;
    if n > limits.max_body {
        return Err(WireError::new(413, "frame body too large"));
    }
    Ok(Some(n))
}

/// Incrementally parse one request frame from the front of `buf`.
pub fn parse_request(buf: &[u8], limits: &Limits) -> Result<Step<Frame>, WireError> {
    check_magic(buf)?;
    if buf.len() < 4 {
        return Ok(Step::Incomplete);
    }
    let op = buf[2];
    let name_len = buf[3] as usize;
    let body_at = 4 + name_len;
    let Some(body_len) = body_len_at(buf, body_at, limits)? else {
        return Ok(Step::Incomplete);
    };
    let total = body_at + 4 + body_len;
    if buf.len() < total {
        return Ok(Step::Incomplete);
    }
    let model = std::str::from_utf8(&buf[4..body_at])
        .map_err(|_| WireError::new(400, "non-utf8 model name"))?
        .to_string();
    Ok(Step::Done(
        Frame { op, model, body: buf[body_at + 4..total].to_vec() },
        total,
    ))
}

/// Incrementally parse one response frame from the front of `buf`.
pub fn parse_response(
    buf: &[u8],
    limits: &Limits,
) -> Result<Step<FrameResponse>, WireError> {
    check_magic(buf)?;
    if buf.len() < 3 {
        return Ok(Step::Incomplete);
    }
    let status = buf[2];
    let Some(body_len) = body_len_at(buf, 3, limits)? else {
        return Ok(Step::Incomplete);
    };
    let total = 3 + 4 + body_len;
    if buf.len() < total {
        return Ok(Step::Incomplete);
    }
    Ok(Step::Done(
        FrameResponse { status, body: buf[7..total].to_vec() },
        total,
    ))
}

/// Serialize a request frame.
pub fn encode_request(op: u8, model: &str, body: &[u8]) -> Vec<u8> {
    assert!(model.len() <= u8::MAX as usize, "model name too long for frame");
    assert!(body.len() <= u32::MAX as usize);
    let mut v = Vec::with_capacity(4 + model.len() + 4 + body.len());
    v.extend_from_slice(&MAGIC);
    v.push(op);
    v.push(model.len() as u8);
    v.extend_from_slice(model.as_bytes());
    v.extend_from_slice(&(body.len() as u32).to_le_bytes());
    v.extend_from_slice(body);
    v
}

/// Serialize a response frame.
pub fn encode_response(status: u8, body: &[u8]) -> Vec<u8> {
    let mut v = Vec::with_capacity(3 + 4 + body.len());
    v.extend_from_slice(&MAGIC);
    v.push(status);
    v.extend_from_slice(&(body.len() as u32).to_le_bytes());
    v.extend_from_slice(body);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: Limits = Limits { max_head: 1024, max_body: 4096 };

    #[test]
    fn request_roundtrip_and_prefixes() {
        let wire = encode_request(OP_INFER, "tiny_cnn", &[1, 2, 3, 0xFA]);
        match parse_request(&wire, &L).unwrap() {
            Step::Done(f, used) => {
                assert_eq!(used, wire.len());
                assert_eq!(f.op, OP_INFER);
                assert_eq!(f.model, "tiny_cnn");
                assert_eq!(f.body, [1, 2, 3, 0xFA]);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        for cut in 0..wire.len() {
            assert_eq!(
                parse_request(&wire[..cut], &L).unwrap(),
                Step::Incomplete,
                "prefix {cut}"
            );
        }
    }

    #[test]
    fn response_roundtrip_and_prefixes() {
        let wire = encode_response(ST_OK, &42f32.to_le_bytes());
        match parse_response(&wire, &L).unwrap() {
            Step::Done(r, used) => {
                assert_eq!(used, wire.len());
                assert_eq!(r.status, ST_OK);
                assert_eq!(r.body, 42f32.to_le_bytes());
            }
            other => panic!("expected Done, got {other:?}"),
        }
        for cut in 0..wire.len() {
            assert_eq!(
                parse_response(&wire[..cut], &L).unwrap(),
                Step::Incomplete,
                "prefix {cut}"
            );
        }
    }

    #[test]
    fn bad_magic_errors_as_early_as_possible() {
        assert_eq!(parse_request(&[0x47], &L).unwrap_err().status, 400);
        assert_eq!(parse_request(&[0xFA, 0x00], &L).unwrap_err().status, 400);
        assert_eq!(parse_request(&[], &L).unwrap(), Step::Incomplete);
        assert_eq!(parse_request(&[0xFA], &L).unwrap(), Step::Incomplete);
    }

    #[test]
    fn oversized_body_is_rejected_before_it_arrives() {
        let mut wire = encode_request(OP_INFER, "m", &[]);
        let len_at = wire.len() - 4;
        wire[len_at..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(parse_request(&wire, &L).unwrap_err().status, 413);
    }

    #[test]
    fn pipelined_frames_consume_exactly_one() {
        let mut wire = encode_request(OP_STATS, "", &[]);
        let first = wire.len();
        wire.extend_from_slice(&encode_request(OP_INFER, "m", &[9]));
        let Step::Done(f, used) = parse_request(&wire, &L).unwrap() else {
            panic!("incomplete");
        };
        assert_eq!(used, first);
        assert_eq!(f.op, OP_STATS);
    }

    #[test]
    fn status_mapping_covers_server_codes() {
        assert_eq!(status_for(200), ST_OK);
        assert_eq!(status_for(404), ST_NOT_FOUND);
        assert_eq!(status_for(429), ST_OVERLOADED);
        assert_eq!(status_for(503), ST_DRAINING);
        assert_eq!(status_for(500), ST_INTERNAL);
        assert_eq!(status_for(400), ST_BAD_REQUEST);
        assert_eq!(status_for(413), ST_BAD_REQUEST);
    }
}
