//! Filesystem change notification for `fat serve --reload-secs`
//! (DESIGN.md §10): on Linux, an inotify watch over the artifact
//! directories turns hot reload from "rescan every N seconds" into
//! "rescan within ~100 ms of a `.fatm` landing", while the timer
//! rescan stays on as a belt-and-braces heartbeat. Everywhere else —
//! and whenever inotify setup fails (exotic filesystems, fd
//! exhaustion) — [`DirWatcher`] degrades to a pure poll-fallback
//! object whose [`pending`] never fires, leaving the timer alone in
//! charge, which is exactly the pre-watcher behavior.
//!
//! Like [`crate::net::signal`] and [`crate::artifact::mmap`], the
//! syscalls are declared against the platform libc the Rust std
//! runtime already links — no new dependency.
//!
//! The watcher is an *edge trigger, not a truth source*: it only says
//! "something happened under these directories, a [`sync_dir`] pass is
//! worth running now". The registry's etag/stat checks remain the sole
//! arbiter of what actually reloads, so spurious wakeups (editor
//! temp files, partial writes) cost one cheap rescan, never a wrong
//! load.
//!
//! [`pending`]: DirWatcher::pending
//! [`sync_dir`]: crate::net::registry::ModelRegistry::sync_dir

use std::path::Path;

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_char, c_int, c_void};

    extern "C" {
        pub fn inotify_init1(flags: c_int) -> c_int;
        pub fn inotify_add_watch(
            fd: c_int,
            pathname: *const c_char,
            mask: u32,
        ) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
    }

    // <sys/inotify.h>: IN_NONBLOCK/IN_CLOEXEC alias O_NONBLOCK/O_CLOEXEC,
    // whose octal values are uniform across the Linux architectures this
    // crate supports (x86_64, aarch64).
    pub const IN_NONBLOCK: c_int = 0o4000;
    pub const IN_CLOEXEC: c_int = 0o2000000;
    pub const IN_ATTRIB: u32 = 0x004;
    pub const IN_CLOSE_WRITE: u32 = 0x008;
    pub const IN_MOVED_FROM: u32 = 0x040;
    pub const IN_MOVED_TO: u32 = 0x080;
    pub const IN_CREATE: u32 = 0x100;
    pub const IN_DELETE: u32 = 0x200;

    /// Events that can change what a directory scan would find: a file
    /// finished writing, appeared, vanished, or was renamed in/out.
    /// Deliberately *not* IN_MODIFY — mid-write torrents would wake the
    /// rescan loop once per `write(2)`.
    pub const MASK: u32 = IN_ATTRIB
        | IN_CLOSE_WRITE
        | IN_MOVED_FROM
        | IN_MOVED_TO
        | IN_CREATE
        | IN_DELETE;
}

/// Change detector over a fixed set of directories. Construction never
/// fails: directories that cannot be watched simply do not contribute
/// edges, and a watcher with no working inotify fd reports
/// [`Self::inotify_active`]` == false` so callers know the timer is
/// doing all the work.
pub struct DirWatcher {
    #[cfg(target_os = "linux")]
    fd: Option<i32>,
    watches: usize,
}

impl DirWatcher {
    pub fn new<P: AsRef<Path>>(dirs: &[P]) -> DirWatcher {
        #[cfg(target_os = "linux")]
        {
            let fd = unsafe {
                sys::inotify_init1(sys::IN_NONBLOCK | sys::IN_CLOEXEC)
            };
            if fd < 0 {
                return DirWatcher { fd: None, watches: 0 };
            }
            let mut watches = 0usize;
            for d in dirs {
                use std::os::unix::ffi::OsStrExt as _;
                let Ok(cpath) = std::ffi::CString::new(
                    d.as_ref().as_os_str().as_bytes(),
                ) else {
                    continue;
                };
                let wd = unsafe {
                    sys::inotify_add_watch(fd, cpath.as_ptr(), sys::MASK)
                };
                if wd >= 0 {
                    watches += 1;
                }
            }
            if watches == 0 {
                unsafe { sys::close(fd) };
                return DirWatcher { fd: None, watches: 0 };
            }
            DirWatcher { fd: Some(fd), watches }
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = dirs;
            DirWatcher { watches: 0 }
        }
    }

    /// True when kernel change notification is live; false in the
    /// poll-fallback mode where only the caller's timer drives rescans.
    pub fn inotify_active(&self) -> bool {
        #[cfg(target_os = "linux")]
        {
            self.fd.is_some()
        }
        #[cfg(not(target_os = "linux"))]
        {
            false
        }
    }

    /// Number of directories successfully under watch.
    pub fn watch_count(&self) -> usize {
        self.watches
    }

    /// One-line description for the serve banner.
    pub fn describe(&self) -> String {
        if self.inotify_active() {
            format!("inotify on {} dir(s)", self.watches)
        } else {
            "poll fallback (timer-driven rescan)".to_string()
        }
    }

    /// Drain all queued events; `true` means at least one change
    /// happened since the last call and a rescan is worth running now.
    /// In poll-fallback mode this is always `false` — the caller's
    /// timer owns the cadence. Non-blocking either way.
    pub fn pending(&mut self) -> bool {
        #[cfg(target_os = "linux")]
        {
            let Some(fd) = self.fd else { return false };
            // Each inotify_event is 16 bytes + a name up to NAME_MAX;
            // 4 KiB drains dozens of events per read.
            let mut buf = [0u8; 4096];
            let mut saw = false;
            loop {
                let n = unsafe {
                    sys::read(
                        fd,
                        buf.as_mut_ptr() as *mut std::os::raw::c_void,
                        buf.len(),
                    )
                };
                if n > 0 {
                    saw = true;
                    continue;
                }
                // 0 (never for inotify) or -1: with O_NONBLOCK the only
                // expected -1 is EAGAIN — queue drained either way.
                return saw;
            }
        }
        #[cfg(not(target_os = "linux"))]
        {
            false
        }
    }
}

impl Drop for DirWatcher {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        if let Some(fd) = self.fd.take() {
            unsafe { sys::close(fd) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(target_os = "linux")]
    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fat_watch_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn missing_dirs_degrade_to_poll_fallback() {
        let mut w = DirWatcher::new(&[Path::new(
            "/definitely/not/a/real/dir/for/fat/watch",
        )]);
        assert!(!w.inotify_active());
        assert_eq!(w.watch_count(), 0);
        assert!(!w.pending());
        assert!(w.describe().contains("poll fallback"));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn file_writes_raise_exactly_one_pending_edge() {
        let d = scratch_dir("edge");
        let mut w = DirWatcher::new(&[&d]);
        assert!(w.inotify_active(), "inotify unavailable on this Linux?");
        assert_eq!(w.watch_count(), 1);
        assert!(w.describe().contains("inotify"));
        // quiet directory: no edge
        assert!(!w.pending());
        // a completed write raises the edge once, then re-arms
        std::fs::write(d.join("m.fatm"), b"not-really-an-artifact").unwrap();
        assert!(w.pending(), "close-write event not observed");
        assert!(!w.pending(), "edge did not clear after drain");
        // deletes count too — a vanished .fatm must trigger a rescan
        // (sync_dir retires the entry)
        std::fs::remove_file(d.join("m.fatm")).unwrap();
        assert!(w.pending(), "delete event not observed");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn renames_into_the_dir_are_observed() {
        // the atomic-publish idiom: write to a temp name, rename over
        let d = scratch_dir("mv");
        let mut w = DirWatcher::new(&[&d]);
        assert!(w.inotify_active());
        assert!(!w.pending());
        let tmp = d.join(".m.fatm.tmp");
        std::fs::write(&tmp, b"bytes").unwrap();
        let _ = w.pending(); // drain the temp-file events
        std::fs::rename(&tmp, d.join("m.fatm")).unwrap();
        assert!(w.pending(), "moved-to event not observed");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn watching_two_dirs_sees_either() {
        let d1 = scratch_dir("two_a");
        let d2 = scratch_dir("two_b");
        let mut w = DirWatcher::new(&[&d1, &d2]);
        assert_eq!(w.watch_count(), 2);
        assert!(!w.pending());
        std::fs::write(d2.join("b.fatm"), b"x").unwrap();
        assert!(w.pending());
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }
}
