//! Scale / zero-point math (paper §2 eq. 1-9, eq. 20) and fixed-point
//! requantization multipliers (gemmlowp style, as in Jacob et al.).

/// Quantization parameters of one tensor: `real = scale * (q - zero_point)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
    pub qmin: i32,
    pub qmax: i32,
}

impl QParams {
    /// Symmetric signed int8 (paper eq. 1-4): q in [-127, 127], zp = 0.
    pub fn symmetric_signed(t: f32) -> Self {
        let t = t.max(1e-12);
        QParams { scale: t / 127.0, zero_point: 0, qmin: -127, qmax: 127 }
    }

    /// Symmetric unsigned (eq. 9): q in [0, 255], zp = 0 (for x >= 0).
    pub fn symmetric_unsigned(t: f32) -> Self {
        let t = t.max(1e-12);
        QParams { scale: t / 255.0, zero_point: 0, qmin: 0, qmax: 255 }
    }

    /// Affine over [left, left+width] mapped to [0, 255], zero-point
    /// nudged to an exact integer (Jacob et al. §3).
    pub fn asymmetric(left: f32, width: f32) -> Self {
        let width = width.max(1e-12);
        let scale = width / 255.0;
        let zp = (-left / scale).round_ties_even();
        let zero_point = zp.clamp(0.0, 255.0) as i32;
        QParams { scale, zero_point, qmin: 0, qmax: 255 }
    }

    /// Quantize one value (round to nearest even, clip — eq. 3-4).
    #[inline]
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale).round_ties_even() as i32 + self.zero_point;
        q.clamp(self.qmin, self.qmax)
    }

    #[inline]
    pub fn dequantize(&self, q: i32) -> f32 {
        self.scale * (q - self.zero_point) as f32
    }

    /// Fake-quantize (quantize → dequantize), the reference the Pallas
    /// kernels implement.
    #[inline]
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// The real-value range representable under these parameters.
    pub fn range(&self) -> (f32, f32) {
        (self.dequantize(self.qmin), self.dequantize(self.qmax))
    }

    /// Snap the scale to the nearest power of two in log2 space
    /// (TQT, arxiv 1903.08066): `scale ← 2^round(log2 scale)`. The
    /// zero-point is re-nudged so the represented range moves as little
    /// as possible. Powers of two are fixed points, so snapping is
    /// idempotent. With every scale in a requant ratio
    /// `s_in·s_w/s_out` a power of two, the ratio itself is one and
    /// requantization degenerates to a rounding shift.
    pub fn snap_pow2(self) -> QParams {
        let s2 = snap_pow2(self.scale);
        let zp = (self.zero_point as f64 * self.scale as f64 / s2 as f64)
            .round_ties_even() as i32;
        QParams {
            scale: s2,
            zero_point: zp.clamp(self.qmin, self.qmax),
            ..self
        }
    }
}

/// `2^round(log2 s)` for a positive scale (log2-domain rounding; exact
/// powers of two are fixed points).
pub fn snap_pow2(s: f32) -> f32 {
    let s = s.max(1e-12);
    ((s as f64).log2().round()).exp2() as f32
}

/// The exponent `e` when `m` is *exactly* `2^e`, else `None`. Exactness
/// is read off the f64 bit pattern (zero mantissa), so no float-compare
/// tolerance can misclassify a near-power.
pub fn pow2_exponent(m: f64) -> Option<i32> {
    if !(m.is_finite() && m > 0.0) {
        return None;
    }
    let bits = m.to_bits();
    if bits & ((1u64 << 52) - 1) != 0 {
        return None;
    }
    let biased = (bits >> 52) & 0x7ff;
    if biased == 0 {
        return None; // subnormal
    }
    Some(biased as i32 - 1023)
}

/// The per-channel rounding-shift table for a requant multiplier table
/// whose entries are all exact powers of two, else `None`. Entry `c`
/// satisfies `quantize_multiplier(2^-shift[c]) == (1 << 30,
/// shift[c] - 1)` — the invariant the `.fatm` loader re-checks before
/// trusting a serialized shift vector.
pub fn shift_table(multipliers: &[f64]) -> Option<Vec<i32>> {
    multipliers
        .iter()
        .map(|&m| pow2_exponent(m).map(|e| -e))
        .collect()
}

/// Bias quantization (paper eq. 20): int32 at scale `s_in * s_w`,
/// clipped to ±(2^31 - 1).
pub fn quantize_bias(b: f32, s_in: f32, s_w: f32) -> i32 {
    let q = (b as f64 / (s_in as f64 * s_w as f64)).round_ties_even();
    q.clamp(-(i32::MAX as f64), i32::MAX as f64) as i32
}

/// Decompose a positive real multiplier into (mantissa m0 in Q31, right
/// shift) such that `m ≈ m0 * 2^-31 * 2^-shift` (gemmlowp convention).
pub fn quantize_multiplier(m: f64) -> (i32, i32) {
    assert!(m > 0.0, "multiplier must be positive, got {m}");
    let mut shift = 0i32;
    let mut q = m;
    while q < 0.5 {
        q *= 2.0;
        shift += 1;
    }
    while q >= 1.0 {
        q /= 2.0;
        shift -= 1;
    }
    let mut m0 = (q * (1i64 << 31) as f64).round() as i64;
    if m0 == (1i64 << 31) {
        m0 /= 2;
        shift -= 1;
    }
    (m0 as i32, shift)
}

/// Saturating rounding doubling high multiply (gemmlowp
/// `SaturatingRoundingDoublingHighMul`).
#[inline]
pub fn sat_rounding_doubling_high_mul(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let ab = a as i64 * b as i64;
    let nudge = if ab >= 0 { 1i64 << 30 } else { 1 - (1i64 << 30) };
    ((ab + nudge) >> 31) as i32
}

/// Rounding arithmetic right shift (round half away from zero).
#[inline]
pub fn rounding_rshift(x: i32, shift: i32) -> i32 {
    if shift <= 0 {
        return x << (-shift);
    }
    let mask = (1i64 << shift) - 1;
    let remainder = (x as i64) & mask;
    let threshold = (mask >> 1) + if x < 0 { 1 } else { 0 };
    let mut r = x >> shift;
    if remainder > threshold {
        r += 1;
    }
    r
}

/// Apply a fixed-point multiplier: `x * m0 * 2^-31 * 2^-shift`.
#[inline]
pub fn apply_multiplier(x: i32, m0: i32, shift: i32) -> i32 {
    rounding_rshift(sat_rounding_doubling_high_mul(x, m0), shift)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_signed_roundtrip() {
        let q = QParams::symmetric_signed(2.0);
        assert_eq!(q.zero_point, 0);
        assert_eq!(q.quantize(2.0), 127);
        assert_eq!(q.quantize(-5.0), -127);
        assert!((q.fake_quant(1.0) - 1.0).abs() <= q.scale / 2.0);
    }

    #[test]
    fn asymmetric_zero_point_exact() {
        let q = QParams::asymmetric(-1.0, 4.0);
        // zero must be exactly representable after nudging
        let z = q.quantize(0.0);
        assert_eq!(q.dequantize(z), 0.0);
        assert_eq!(z, q.zero_point);
    }

    #[test]
    fn asymmetric_covers_range() {
        let q = QParams::asymmetric(-0.5, 2.0);
        let (lo, hi) = q.range();
        assert!(lo <= -0.45 && hi >= 1.45, "({lo},{hi})");
    }

    #[test]
    fn bias_eq20() {
        let b = quantize_bias(0.05, 0.01, 0.002);
        assert_eq!(b, 2500);
        assert_eq!(quantize_bias(-0.05, 0.01, 0.002), -2500);
    }

    #[test]
    fn multiplier_decomposition_accuracy() {
        for &m in &[0.7, 0.123, 0.00391, 0.9999, 1.7, 1e-6] {
            let (m0, shift) = quantize_multiplier(m);
            let recon = m0 as f64 / (1u64 << 31) as f64 / 2f64.powi(shift);
            assert!(
                (recon - m).abs() / m < 1e-6,
                "m={m} recon={recon} m0={m0} shift={shift}"
            );
        }
    }

    #[test]
    fn fixed_point_matches_float_requant() {
        // requantizing int32 accumulators by a real multiplier: fixed-point
        // path must agree with float within 1 ulp of the int8 grid.
        let m = 0.0007234;
        let (m0, shift) = quantize_multiplier(m);
        for acc in [-1_000_000, -12_345, -1, 0, 1, 9_999, 2_000_000] {
            let fx = apply_multiplier(acc, m0, shift);
            let fl = (acc as f64 * m).round() as i32;
            assert!((fx - fl).abs() <= 1, "acc={acc} fx={fx} fl={fl}");
        }
    }

    #[test]
    fn rounding_rshift_halfway() {
        assert_eq!(rounding_rshift(5, 1), 3); // 2.5 -> 3 (away from zero)
        assert_eq!(rounding_rshift(-5, 1), -3); // -2.5 -> -3 (gemmlowp)
        assert_eq!(rounding_rshift(4, 2), 1);
        assert_eq!(rounding_rshift(8, 0), 8);
    }

    #[test]
    fn snap_pow2_rounds_in_log2_domain() {
        assert_eq!(snap_pow2(0.25), 0.25); // fixed point
        assert_eq!(snap_pow2(0.26), 0.25);
        assert_eq!(snap_pow2(0.19), 0.25); // log2 0.19 ≈ -2.4 → -2
        assert_eq!(snap_pow2(0.17), 0.125); // log2 0.17 ≈ -2.56 → -3
        // idempotent for arbitrary inputs
        for s in [1e-6f32, 0.003, 0.7, 1.0, 9.0] {
            let once = snap_pow2(s);
            assert_eq!(snap_pow2(once), once, "s={s}");
        }
    }

    #[test]
    fn pow2_exponent_is_exact() {
        assert_eq!(pow2_exponent(1.0), Some(0));
        assert_eq!(pow2_exponent(0.5), Some(-1));
        assert_eq!(pow2_exponent(2f64.powi(-9)), Some(-9));
        assert_eq!(pow2_exponent(2f64.powi(17)), Some(17));
        assert_eq!(pow2_exponent(0.5000001), None);
        assert_eq!(pow2_exponent(0.4999999), None);
        assert_eq!(pow2_exponent(0.0), None);
        assert_eq!(pow2_exponent(-0.5), None);
        assert_eq!(pow2_exponent(f64::NAN), None);
    }

    #[test]
    fn pow2_multiplier_decomposes_to_half_mantissa() {
        // The invariant the .fatm loader checks: an exact 2^-e
        // multiplier always decomposes to (1<<30, e-1), so a serialized
        // shift vector can be cross-validated against the pair table.
        for e in -2..=30 {
            let (m0, shift) = quantize_multiplier(2f64.powi(-e));
            assert_eq!((m0, shift), (1 << 30, e - 1), "e={e}");
        }
    }

    #[test]
    fn shift_table_requires_all_pow2() {
        assert_eq!(
            shift_table(&[0.25, 0.5, 2f64.powi(-7)]),
            Some(vec![2, 1, 7])
        );
        assert_eq!(shift_table(&[0.25, 0.3]), None);
        assert_eq!(shift_table(&[]), Some(vec![]));
    }

    #[test]
    fn snap_pow2_qparams_renudges_zero_point() {
        let qp = QParams::asymmetric(-1.0, 4.0);
        let snapped = qp.snap_pow2();
        assert_eq!(pow2_exponent(snapped.scale as f64), Some(-6));
        // the represented left edge moves by less than one new step
        let left0 = qp.dequantize(qp.qmin);
        let left1 = snapped.dequantize(snapped.qmin);
        assert!((left0 - left1).abs() <= snapped.scale, "{left0} {left1}");
        // symmetric params keep zp = 0
        let s = QParams::symmetric_signed(1.7).snap_pow2();
        assert_eq!(s.zero_point, 0);
        assert!(pow2_exponent(s.scale as f64).is_some());
    }
}
