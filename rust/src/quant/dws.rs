//! §3.3 — mutual rescaling of DWS → [ReLU/ReLU6] → Conv weights.
//!
//! Runtime mirror of `python/compile/dws.py` (same constants, same six
//! steps; cross-checked by the `crosslang` integration test).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::{GraphDef, Op};
use crate::tensor::Tensor;

pub const LOCK_LIMIT: f32 = 5.9;
pub const RELU6_CAP: f32 = 6.0;
pub const SCALE_MIN: f32 = 1.0 / 64.0;
pub const SCALE_MAX: f32 = 64.0;

/// One rescalable chain in the folded graph.
#[derive(Debug, Clone)]
pub struct Pattern {
    pub dw: String,
    pub act: String,
    pub conv: String,
    pub relu6: bool,
}

/// Per-pattern rescale report (threshold spread is what §3.3 shrinks).
#[derive(Debug, Clone)]
pub struct PatternReport {
    pub dw: String,
    pub conv: String,
    pub locked: usize,
    pub channels: usize,
    pub spread_before: f32,
    pub spread_after: f32,
}

/// Find DWS→act→1x1-conv chains where the act feeds only that conv.
pub fn find_patterns(g: &GraphDef) -> Vec<Pattern> {
    let cons = g.consumers();
    let mut out = vec![];
    for n in &g.nodes {
        if n.op != Op::DwConv {
            continue;
        }
        let cs = &cons[n.id.as_str()];
        if cs.len() != 1 || !matches!(cs[0].op, Op::Relu | Op::Relu6) {
            continue;
        }
        let act = cs[0];
        let cs2 = &cons[act.id.as_str()];
        if cs2.len() != 1 || cs2[0].op != Op::Conv || cs2[0].k != 1 {
            continue;
        }
        out.push(Pattern {
            dw: n.id.clone(),
            act: act.id.clone(),
            conv: cs2[0].id.clone(),
            relu6: act.op == Op::Relu6,
        });
    }
    out
}

fn spread(w: &[f32], c: usize) -> f32 {
    let t = crate::quant::thresholds::per_channel_w_thresholds(w, c);
    let mx = t.iter().fold(0f32, |m, &v| m.max(v));
    let mn = t.iter().fold(f32::INFINITY, |m, &v| m.min(v));
    mx / mn.max(1e-12)
}

/// Compute per-channel scales for one pattern (paper steps 1-6).
pub fn pattern_scales(
    w_dw: &[f32],
    ch_max: &[f32],
    channels: usize,
    relu6: bool,
) -> (Vec<f32>, Vec<bool>) {
    let t_k: Vec<f32> =
        crate::quant::thresholds::per_channel_w_thresholds(w_dw, channels);

    let locked: Vec<bool> = if relu6 {
        ch_max.iter().map(|&m| m >= LOCK_LIMIT).collect()
    } else {
        vec![false; channels]
    };

    let n_locked = locked.iter().filter(|&&l| l).count();
    let t0 = if n_locked > 0 {
        t_k.iter()
            .zip(&locked)
            .filter(|(_, &l)| l)
            .map(|(&t, _)| t)
            .sum::<f32>()
            / n_locked as f32
    } else {
        t_k.iter().sum::<f32>() / channels as f32
    };

    let mut s = vec![1f32; channels];
    for k in 0..channels {
        if locked[k] {
            continue;
        }
        let mut sk = t0 / t_k[k];
        if relu6 {
            sk = sk.min(RELU6_CAP / ch_max[k].max(1e-12));
        }
        s[k] = sk.clamp(SCALE_MIN, SCALE_MAX);
    }
    (s, locked)
}

/// Inject per-filter range disparity into every DWS pattern —
/// function-preserving emulation of the disparity real ImageNet
/// MobileNet-v2 checkpoints exhibit (DESIGN.md §2: our briefly-trained
/// mini nets have per-filter spreads of only ~3-7x vs >100x in TF-slim
/// checkpoints, which is what makes the paper's scalar mode collapse).
///
/// Filter k is scaled by `s_k = 2^-(span·u)`, u ∈ [0,1) deterministic;
/// the following conv's input channel is scaled by `1/s_k`. Because
/// `s_k ≤ 1`, scaled pre-activations stay below the ReLU6 plateau
/// (paper eq. 26), so the FP function is exactly preserved.
pub fn inject_spread(
    g: &GraphDef,
    params: &mut BTreeMap<String, Tensor>,
    seed: u64,
    span_log2: f32,
) -> Result<usize> {
    let mut touched = 0;
    for (pi, pat) in find_patterns(g).iter().enumerate() {
        let channels = g.node(&pat.dw)?.ch;
        let s: Vec<f32> = (0..channels)
            .map(|k| {
                let u = crate::data::prng::uniform(
                    seed,
                    pi as u64,
                    200 + k as u64,
                    0,
                    0,
                    0,
                );
                (-(span_log2 * u)).exp2()
            })
            .collect();
        let wkey = format!("{}.w", pat.dw);
        let bkey = format!("{}.b", pat.dw);
        let ckey = format!("{}.w", pat.conv);
        {
            let w = params.get_mut(&wkey).unwrap().as_f32_mut()?;
            for (i, v) in w.iter_mut().enumerate() {
                *v *= s[i % channels];
            }
        }
        {
            let b = params.get_mut(&bkey).unwrap().as_f32_mut()?;
            for (k, v) in b.iter_mut().enumerate() {
                *v *= s[k];
            }
        }
        {
            let t = params.get_mut(&ckey).unwrap();
            let cout = *t.shape.last().unwrap();
            let w = t.as_f32_mut()?;
            for (i, v) in w.iter_mut().enumerate() {
                let cin = (i / cout) % channels;
                *v /= s[cin];
            }
        }
        touched += 1;
    }
    Ok(touched)
}

/// Apply §3.3 to all patterns in the folded graph. `ch_max[node]` holds
/// calibrated per-channel pre-activation maxima of each dwconv output.
/// Weights are modified in place; reports returned per pattern.
pub fn rescale_model(
    g: &GraphDef,
    params: &mut BTreeMap<String, Tensor>,
    ch_max: &BTreeMap<String, Vec<f32>>,
) -> Result<Vec<PatternReport>> {
    let mut reports = vec![];
    for pat in find_patterns(g) {
        let channels = g.node(&pat.dw)?.ch;
        let cm = ch_max
            .get(&pat.dw)
            .ok_or_else(|| anyhow::anyhow!("no channel stats for {}", pat.dw))?;
        let wkey = format!("{}.w", pat.dw);
        let bkey = format!("{}.b", pat.dw);
        let ckey = format!("{}.w", pat.conv);

        let spread_before;
        let spread_after;
        let (s, locked) = {
            let w_dw = params[&wkey].as_f32()?;
            spread_before = spread(w_dw, channels);
            pattern_scales(w_dw, cm, channels, pat.relu6)
        };
        // scale dw filters + bias
        {
            let w = params.get_mut(&wkey).unwrap().as_f32_mut()?;
            for (i, v) in w.iter_mut().enumerate() {
                *v *= s[i % channels];
            }
            spread_after = spread(w, channels);
        }
        {
            let b = params.get_mut(&bkey).unwrap().as_f32_mut()?;
            for (k, v) in b.iter_mut().enumerate() {
                *v *= s[k];
            }
        }
        // divide following conv's input channels: w_conv (1,1,C,Cout)
        {
            let t = params.get_mut(&ckey).unwrap();
            let cout = *t.shape.last().unwrap();
            let w = t.as_f32_mut()?;
            for (i, v) in w.iter_mut().enumerate() {
                let cin = (i / cout) % channels;
                *v /= s[cin];
            }
        }
        reports.push(PatternReport {
            dw: pat.dw,
            conv: pat.conv,
            locked: locked.iter().filter(|&&l| l).count(),
            channels,
            spread_before,
            spread_after,
        });
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locked_channels_scale_one() {
        let w: Vec<f32> = (0..9 * 4)
            .map(|i| ((i % 7) as f32 - 3.0) * 0.1 * ((i % 4) as f32 + 0.5))
            .collect();
        let ch_max = [1.0, 5.95, 2.0, 6.2];
        let (s, locked) = pattern_scales(&w, &ch_max, 4, true);
        assert_eq!(locked, vec![false, true, false, true]);
        assert_eq!(s[1], 1.0);
        assert_eq!(s[3], 1.0);
    }

    #[test]
    fn relu6_cap_respected() {
        let w: Vec<f32> = (0..9 * 4)
            .map(|i| [0.1f32, 1.0, 2.0, 0.5][i % 4] * (1.0 - (i / 4) as f32 * 0.01))
            .collect();
        let ch_max = [2.0, 3.0, 4.0, 5.0];
        let (s, _) = pattern_scales(&w, &ch_max, 4, true);
        for k in 0..4 {
            assert!(ch_max[k] * s[k] <= RELU6_CAP + 1e-4);
        }
    }

    #[test]
    fn relu_unbounded_equalises() {
        // with ReLU (no cap), scales equalise thresholds exactly (up to clip)
        let mut w = vec![0f32; 9 * 3];
        for (i, v) in w.iter_mut().enumerate() {
            *v = [0.5f32, 1.0, 2.0][i % 3];
        }
        let ch_max = [1.0, 1.0, 1.0];
        let (s, _) = pattern_scales(&w, &ch_max, 3, false);
        let t0 = (0.5 + 1.0 + 2.0) / 3.0;
        assert!((s[0] - t0 / 0.5).abs() < 1e-5);
        assert!((s[1] - t0 / 1.0).abs() < 1e-5);
        assert!((s[2] - t0 / 2.0).abs() < 1e-5);
    }

    #[test]
    fn scales_clamped() {
        let mut w = vec![0f32; 9 * 2];
        for (i, v) in w.iter_mut().enumerate() {
            *v = [1e-6f32, 100.0][i % 2];
        }
        let (s, _) = pattern_scales(&w, &[1.0, 1.0], 2, false);
        assert!(s[0] <= SCALE_MAX);
        assert!(s[1] >= SCALE_MIN);
    }
}
