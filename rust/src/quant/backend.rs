//! Execution backends for the float side of the pipeline (DESIGN.md §7).
//!
//! Every stage of the paper's flow that runs the *float* model —
//! calibration, FP32 evaluation, the fake-quant forward and threshold
//! fine-tuning — goes through the [`Executor`] trait. Two
//! implementations exist:
//!
//! * [`ArtifactExec`] — the original path: AOT-lowered HLO artifacts
//!   executed through the PJRT runtime (requires `make artifacts` and
//!   the `pjrt` cargo feature).
//! * [`NativeExec`] — the pure-Rust path (`crate::fp`): a planned,
//!   `FAT_THREADS`-parallel FP32 executor, native calibration, the
//!   eq. 4–9 fake-quant forward and the analytic STE threshold trainer.
//!
//! [`resolve`] picks the backend: `FAT_BACKEND=native|artifact` forces
//! one; the default (`auto`) uses artifacts when they exist *and* the
//! crate was built with `pjrt`, and the native backend otherwise — so a
//! bare `cargo run` on a fresh checkout executes the whole pipeline.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::evaluate::{accuracy_with, batch_size_of};
use crate::coordinator::finetune::{self, FinetuneOpts};
use crate::coordinator::marshal::{build_inputs, split_outputs, Group};
use crate::data::{Batcher, Split};
use crate::fp;
use crate::model::store::SitesJson;
use crate::model::{GraphDef, ModelStore};
use crate::runtime::{pjrt_available, Artifact, Registry};
use crate::tensor::Tensor;
use crate::util::threads::fat_threads;

use super::calibrate::CalibStats;
use super::export::{QuantKnobs, QuantMode};
use super::session::ThresholdSet;

/// Borrowed view of a session's model state — everything a backend
/// needs to run a float-side stage.
pub struct ModelView<'a> {
    pub graph: &'a GraphDef,
    pub sites: &'a SitesJson,
    pub weights: &'a BTreeMap<String, Tensor>,
}

/// A float-side execution backend. All methods are stage-level (one
/// call = one pipeline pass), so implementations own their batching.
pub trait Executor: Send + Sync {
    /// Short backend name for logs (`"native"` / `"artifact"`).
    fn name(&self) -> &'static str;

    /// Calibration pass: per-site + per-channel (min, max) over `images`
    /// training images.
    fn calibrate(&self, m: &ModelView, images: usize) -> Result<CalibStats>;

    /// Histogram pass over the calibrated ranges (percentile/KL
    /// calibrators).
    fn calibrate_hist(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        images: usize,
    ) -> Result<Vec<Vec<u32>>>;

    /// FP32 accuracy over the validation split.
    fn fp_accuracy(&self, m: &ModelView, val_images: usize) -> Result<f64>;

    /// Accuracy of the fake-quant forward under a trainable map.
    /// `knobs` selects the export-time numerics the student mirrors
    /// (pow2 scales, int4 weight grid); the artifact backend only
    /// supports the default knobs (its AOT graphs were lowered without
    /// them).
    fn quant_accuracy(
        &self,
        m: &ModelView,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64>;

    /// FAT threshold fine-tuning (RMSE distillation, unlabeled). Same
    /// `knobs` contract as [`Executor::quant_accuracy`].
    fn finetune(
        &self,
        m: &ModelView,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        progress: &mut dyn FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)>;

    /// Identity trainable map in this backend's key/shape convention.
    fn identity_trainables(
        &self,
        m: &ModelView,
        mode: QuantMode,
    ) -> Result<BTreeMap<String, Tensor>>;

    /// §4.2 point-wise fake-quant accuracy (mobilenet ladder).
    fn pointwise_accuracy(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        pw: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64>;

    /// §4.2 point-wise weight fine-tuning.
    fn finetune_pointwise(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        progress: &mut dyn FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)>;
}

/// Pick the backend for a session. `FAT_BACKEND` forces `native` or
/// `artifact`; `auto` (the default) prefers artifacts when both the
/// `pjrt` feature and the model's `fp_forward` manifest are present and
/// falls back to the native executor otherwise.
pub fn resolve(
    reg: &Arc<Registry>,
    store: Option<&ModelStore>,
) -> Result<Arc<dyn Executor>> {
    let choice =
        std::env::var("FAT_BACKEND").unwrap_or_else(|_| "auto".to_string());
    let manifests_present = store
        .map(|s| {
            s.artifact_path("fp_forward")
                .with_extension("manifest.json")
                .exists()
        })
        .unwrap_or(false);
    match choice.as_str() {
        "native" => Ok(Arc::new(NativeExec)),
        "artifact" => {
            anyhow::ensure!(
                pjrt_available(),
                "FAT_BACKEND=artifact, but this build has no `pjrt` \
                 feature — rebuild with `--features pjrt` or use the \
                 native backend"
            );
            let store = store.ok_or_else(|| {
                anyhow::anyhow!(
                    "FAT_BACKEND=artifact, but the model has no artifact \
                     directory (builtin models are native-only)"
                )
            })?;
            anyhow::ensure!(
                manifests_present,
                "FAT_BACKEND=artifact, but {:?} has no fp_forward \
                 manifest — run `make artifacts` first",
                store.dir
            );
            Ok(Arc::new(ArtifactExec::new(reg.clone(), store.clone())))
        }
        "auto" | "" => {
            if pjrt_available() && manifests_present {
                let store = store.expect("manifests imply a store");
                Ok(Arc::new(ArtifactExec::new(reg.clone(), store.clone())))
            } else {
                Ok(Arc::new(NativeExec))
            }
        }
        other => anyhow::bail!(
            "unknown FAT_BACKEND `{other}` (expected native, artifact or \
             auto)"
        ),
    }
}

/// The AOT artifacts were lowered from the plain fake-quant graph —
/// they cannot honor pow2/int4 export knobs. Error out loudly instead
/// of silently evaluating the wrong numerics.
fn require_default_knobs(knobs: QuantKnobs, stage: &str) -> Result<()> {
    anyhow::ensure!(
        knobs == QuantKnobs::default(),
        "the artifact backend's {stage} graphs were lowered without \
         pow2/int4 knobs ({knobs:?}) — use FAT_BACKEND=native for \
         `_pow2` / `_w4` modes"
    );
    Ok(())
}

// ---------------------------------------------------------------------
// ArtifactExec — the AOT PJRT path
// ---------------------------------------------------------------------

/// The AOT-artifact backend: every stage marshals tensors through the
/// lowered HLO executables in the model's artifact directory.
pub struct ArtifactExec {
    reg: Arc<Registry>,
    store: ModelStore,
}

impl ArtifactExec {
    pub fn new(reg: Arc<Registry>, store: ModelStore) -> Self {
        ArtifactExec { reg, store }
    }

    /// Compiled artifact handle by name.
    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        self.reg.get(self.store.artifact_path(name))
    }
}

impl Executor for ArtifactExec {
    fn name(&self) -> &'static str {
        "artifact"
    }

    fn calibrate(&self, m: &ModelView, images: usize) -> Result<CalibStats> {
        let art = self.artifact("calib_stats")?;
        let bs = batch_size_of(&art, "1")?;
        let mut stats = CalibStats::new(m.sites.sites.len());
        let indices: Vec<u64> = (0..images.max(bs) as u64).collect();
        let batcher = Batcher::new(Split::Train, indices, bs);
        for (x, _) in batcher.epoch_iter(0) {
            let inputs = build_inputs(
                &art.manifest,
                &[Group::Map(m.weights), Group::Single(&x)],
            )?;
            let outs = art.execute(&inputs)?;
            let o = split_outputs(&art.manifest, outs)?;
            let mm = o.singles[&0].as_f32()?;
            for (i, s) in stats.site_minmax.iter_mut().enumerate() {
                s.update(mm[i * 2], mm[i * 2 + 1]);
            }
            for (key, t) in &o.maps[&1] {
                let nid = key.trim_start_matches("ch:").to_string();
                let d = t.as_f32()?;
                let c = t.shape[1];
                let entry = stats
                    .channel_minmax
                    .entry(nid)
                    .or_insert_with(|| vec![Default::default(); c]);
                for (ci, e) in entry.iter_mut().enumerate() {
                    e.update(d[ci], d[c + ci]);
                }
            }
            stats.batches += 1;
        }
        Ok(stats)
    }

    fn calibrate_hist(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        images: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let art = self.artifact("calib_hist")?;
        let bs = batch_size_of(&art, "2")?;
        let act_t = stats.act_t_tensor();
        let nsites = m.sites.sites.len();
        let mut hists: Vec<Vec<u32>> = vec![];
        let indices: Vec<u64> = (0..images.max(bs) as u64).collect();
        let batcher = Batcher::new(Split::Train, indices, bs);
        for (x, _) in batcher.epoch_iter(0) {
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(m.weights),
                    Group::Single(&act_t),
                    Group::Single(&x),
                ],
            )?;
            let outs = art.execute(&inputs)?;
            let o = split_outputs(&art.manifest, outs)?;
            let h = o.singles[&0].as_i32()?;
            let bins = h.len() / nsites;
            if hists.is_empty() {
                hists = vec![vec![0u32; bins]; nsites];
            }
            for s in 0..nsites {
                for b in 0..bins {
                    hists[s][b] += h[s * bins + b] as u32;
                }
            }
        }
        Ok(hists)
    }

    fn fp_accuracy(&self, m: &ModelView, val_images: usize) -> Result<f64> {
        let art = self.artifact("fp_forward")?;
        let bs = batch_size_of(&art, "1")?;
        accuracy_with(bs, val_images, |x| {
            let inputs = build_inputs(
                &art.manifest,
                &[Group::Map(m.weights), Group::Single(x)],
            )?;
            Ok(art.execute(&inputs)?.remove(0))
        })
    }

    fn quant_accuracy(
        &self,
        m: &ModelView,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        require_default_knobs(knobs, "quant_fwd")?;
        let art = self.artifact(&format!("quant_fwd_{}", mode.name()))?;
        let bs = batch_size_of(&art, "3")?;
        let act_t = stats.act_t_tensor();
        accuracy_with(bs, val_images, |x| {
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(m.weights),
                    Group::Single(&act_t),
                    Group::Map(trained),
                    Group::Single(x),
                ],
            )?;
            Ok(art.execute(&inputs)?.remove(0))
        })
    }

    fn finetune(
        &self,
        m: &ModelView,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        progress: &mut dyn FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        require_default_knobs(knobs, "train_step")?;
        let art = self.artifact(&format!("train_step_{}", mode.name()))?;
        finetune::run(&art, m.weights, &stats.act_t_tensor(), opts, progress)
    }

    fn identity_trainables(
        &self,
        _m: &ModelView,
        mode: QuantMode,
    ) -> Result<BTreeMap<String, Tensor>> {
        let art = self.artifact(&format!("train_step_{}", mode.name()))?;
        Ok(finetune::init_trainables(&art))
    }

    fn pointwise_accuracy(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        pw: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        let art = self.artifact("quant_fwd_pw")?;
        let bs = batch_size_of(&art, "3")?;
        let act_t = stats.act_t_tensor();
        accuracy_with(bs, val_images, |x| {
            let inputs = build_inputs(
                &art.manifest,
                &[
                    Group::Map(m.weights),
                    Group::Single(&act_t),
                    Group::Map(pw),
                    Group::Single(x),
                ],
            )?;
            Ok(art.execute(&inputs)?.remove(0))
        })
    }

    fn finetune_pointwise(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        progress: &mut dyn FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        let art = self.artifact("train_step_pw")?;
        finetune::run(&art, m.weights, &stats.act_t_tensor(), opts, progress)
    }
}

// ---------------------------------------------------------------------
// NativeExec — the pure-Rust path
// ---------------------------------------------------------------------

/// Evaluation batch size of the native backend.
pub const NATIVE_EVAL_BATCH: usize = 50;

/// The native backend: planned FP32 executor + analytic trainer, no
/// artifacts, no PJRT (see `crate::fp`).
pub struct NativeExec;

impl NativeExec {
    fn plain_program(&self, m: &ModelView) -> Result<fp::FpProgram> {
        fp::FpProgram::compile(m.graph, m.weights, m.sites, None)
    }
}

impl Executor for NativeExec {
    fn name(&self) -> &'static str {
        "native"
    }

    fn calibrate(&self, m: &ModelView, images: usize) -> Result<CalibStats> {
        let prog = self.plain_program(m)?;
        fp::calibrate::calib_stats(&prog, images, fat_threads())
    }

    fn calibrate_hist(
        &self,
        m: &ModelView,
        stats: &CalibStats,
        images: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let prog = self.plain_program(m)?;
        fp::calibrate::calib_hist(&prog, stats, images, fat_threads())
    }

    fn fp_accuracy(&self, m: &ModelView, val_images: usize) -> Result<f64> {
        let prog = self.plain_program(m)?;
        let threads = fat_threads();
        accuracy_with(NATIVE_EVAL_BATCH, val_images, |x| {
            prog.run_batch(x, threads)
        })
    }

    fn quant_accuracy(
        &self,
        m: &ModelView,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        let tr = ThresholdSet::from_trainables(
            m.graph,
            mode,
            m.sites.sites.len(),
            trained,
        )?
        .into_trained();
        let prog = fp::fakequant::quantized_program_with(
            m.graph, m.weights, m.sites, stats, mode, &tr, knobs,
        )?;
        let threads = fat_threads();
        accuracy_with(NATIVE_EVAL_BATCH, val_images, |x| {
            prog.run_batch(x, threads)
        })
    }

    fn finetune(
        &self,
        m: &ModelView,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        progress: &mut dyn FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        let trainer = fp::Trainer::new_with(
            m.graph,
            m.weights,
            m.sites,
            stats,
            mode,
            knobs,
            fat_threads(),
        )?;
        finetune::run_loop(
            &fp::train::NativeStep { trainer },
            opts,
            progress,
        )
    }

    fn identity_trainables(
        &self,
        m: &ModelView,
        mode: QuantMode,
    ) -> Result<BTreeMap<String, Tensor>> {
        Ok(fp::train::identity_trainables_for_graph(
            m.graph,
            mode,
            m.sites.sites.len(),
        ))
    }

    fn pointwise_accuracy(
        &self,
        _m: &ModelView,
        _stats: &CalibStats,
        _pw: &BTreeMap<String, Tensor>,
        _val_images: usize,
    ) -> Result<f64> {
        anyhow::bail!(
            "the §4.2 point-wise path (quant_fwd_pw) has no native \
             implementation — it needs the AOT artifacts"
        )
    }

    fn finetune_pointwise(
        &self,
        _m: &ModelView,
        _stats: &CalibStats,
        _opts: &FinetuneOpts,
        _progress: &mut dyn FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        anyhow::bail!(
            "the §4.2 point-wise path (train_step_pw) has no native \
             implementation — it needs the AOT artifacts"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    fn view<'a>(
        g: &'a GraphDef,
        s: &'a SitesJson,
        w: &'a BTreeMap<String, Tensor>,
    ) -> ModelView<'a> {
        ModelView { graph: g, sites: s, weights: w }
    }

    #[test]
    fn native_identity_trainables_match_threshold_grammar() {
        let (g, s, w) = builtin::load("tiny_cnn").unwrap();
        let m = view(&g, &s, &w);
        for mode in QuantMode::all() {
            let tr = NativeExec.identity_trainables(&m, mode).unwrap();
            // the typed ThresholdSet parser accepts every key + shape
            let ts = ThresholdSet::from_trainables(
                &g,
                mode,
                s.sites.len(),
                &tr,
            )
            .unwrap();
            assert_eq!(ts.mode(), mode);
            if mode.asym() {
                assert!(tr.contains_key("act_at"));
                assert!(!tr.contains_key("act_a"));
            } else {
                assert!(tr.contains_key("act_a"));
            }
        }
    }

    #[test]
    fn artifact_knob_guard_rejects_non_default_knobs() {
        assert!(require_default_knobs(QuantKnobs::default(), "x").is_ok());
        for knobs in [
            QuantKnobs { pow2: true, w_bits: 8 },
            QuantKnobs { pow2: false, w_bits: 4 },
        ] {
            let err =
                require_default_knobs(knobs, "quant_fwd").unwrap_err();
            assert!(err.to_string().contains("FAT_BACKEND=native"), "{err}");
        }
    }

    #[test]
    fn native_pointwise_is_a_clear_error() {
        let (g, s, w) = builtin::load("tiny_cnn").unwrap();
        let m = view(&g, &s, &w);
        let stats = CalibStats::new(s.sites.len());
        let err = NativeExec
            .pointwise_accuracy(&m, &stats, &BTreeMap::new(), 10)
            .unwrap_err();
        assert!(err.to_string().contains("point-wise"), "{err}");
    }
}
