//! Quantization substrate: the paper's math on the Rust side.
//!
//! * [`scale`] — eq. 1-9 scale/zero-point math, eq. 20 bias quantization,
//!   gemmlowp-style fixed-point requantization multipliers.
//! * [`fold`] — BN folding (eq. 10-11), mirror of the Python fold.
//! * [`thresholds`] — threshold adjustment (eq. 12-13, 21-23).
//! * [`calibrate`] — calibration aggregation + baseline calibrators
//!   (max / percentile / KL) for the A1 ablation.
//! * [`dws`] — §3.3 DWS→Conv weight rescaling.
//! * [`export`] — quantized-model builder for the int8 engine.
//! * [`session`] — the staged public API: [`session::QuantSession`] →
//!   `Calibrated` → `Thresholded` → [`crate::int8::serve::Int8Engine`].
//! * [`backend`] — the float-side [`backend::Executor`] trait with its
//!   AOT-artifact and native (`crate::fp`) implementations.

pub mod backend;
pub mod calibrate;
pub mod dws;
pub mod export;
pub mod fold;
pub mod scale;
pub mod session;
pub mod thresholds;

pub use backend::Executor;
pub use export::{QuantMode, Rounding};
pub use scale::QParams;
pub use session::{CalibOpts, QuantSession, QuantSpec, ThresholdSet};
