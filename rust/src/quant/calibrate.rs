//! Calibration: aggregation of per-site statistics over calibration
//! batches, plus baseline threshold calibrators (max / percentile / KL)
//! used by the A1 ablation and the `calibration_study` example.

/// Running (min, max) aggregate per site.
#[derive(Debug, Clone)]
pub struct MinMax {
    pub min: f32,
    pub max: f32,
}

impl Default for MinMax {
    fn default() -> Self {
        MinMax { min: f32::INFINITY, max: f32::NEG_INFINITY }
    }
}

impl MinMax {
    pub fn update(&mut self, min: f32, max: f32) {
        self.min = self.min.min(min);
        self.max = self.max.max(max);
    }
}

/// Aggregated calibration statistics for a model.
#[derive(Debug, Clone, Default)]
pub struct CalibStats {
    /// Per activation site, in site order: (min, max).
    pub site_minmax: Vec<MinMax>,
    /// Per conv-like node: per-channel (min, max) of pre-activation output.
    pub channel_minmax: std::collections::BTreeMap<String, Vec<MinMax>>,
    /// Per-site histograms (counts over 128 bins spanning site min..max),
    /// filled by the optional second calibration pass.
    pub site_hist: Vec<Vec<u32>>,
    pub batches: usize,
}

impl CalibStats {
    pub fn new(num_sites: usize) -> Self {
        CalibStats {
            site_minmax: vec![MinMax::default(); num_sites],
            channel_minmax: Default::default(),
            site_hist: vec![],
            batches: 0,
        }
    }

    /// Stacked (S, 2) tensor of (min, max) in site order — the `act_t`
    /// input of the quantized artifacts.
    pub fn act_t_tensor(&self) -> crate::tensor::Tensor {
        let mut v = Vec::with_capacity(self.site_minmax.len() * 2);
        for mm in &self.site_minmax {
            v.push(mm.min);
            v.push(mm.max);
        }
        crate::tensor::Tensor::f32(vec![self.site_minmax.len(), 2], v)
    }
}

/// Baseline calibrator selection (A1 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Calibrator {
    /// Paper default: exact max (eq. 2/6).
    Max,
    /// Percentile of the distribution (e.g. 99.99).
    Percentile(u32), // in basis points: 9999 = 99.99%
    /// TensorRT-style KL-divergence minimisation over the histogram.
    Kl,
}

/// Reduce a histogram over [lo, hi] to a threshold per the calibrator.
pub fn threshold_from_hist(
    cal: Calibrator,
    hist: &[u32],
    lo: f32,
    hi: f32,
) -> f32 {
    match cal {
        Calibrator::Max => hi.abs().max(lo.abs()),
        Calibrator::Percentile(bp) => percentile_threshold(hist, lo, hi, bp),
        Calibrator::Kl => kl_threshold(hist, lo, hi),
    }
}

fn bin_upper(lo: f32, hi: f32, bins: usize, i: usize) -> f32 {
    lo + (hi - lo) * ((i + 1) as f32 / bins as f32)
}

/// Smallest upper edge covering `bp/10000` of the mass (by |value|; the
/// histogram is assumed to span [lo, hi] densely).
pub fn percentile_threshold(hist: &[u32], lo: f32, hi: f32, bp: u32) -> f32 {
    let total: u64 = hist.iter().map(|&c| c as u64).sum();
    if total == 0 {
        return hi.abs().max(lo.abs());
    }
    let bins = hist.len();
    // Accumulate bins by ascending |upper-edge| magnitude.
    let mut order: Vec<usize> = (0..bins).collect();
    let mag = |i: usize| -> f32 {
        let u = bin_upper(lo, hi, bins, i);
        let l = lo + (hi - lo) * (i as f32 / bins as f32);
        u.abs().max(l.abs())
    };
    order.sort_by(|&a, &b| mag(a).total_cmp(&mag(b)));
    let target = (total as f64 * bp as f64 / 10_000.0).ceil() as u64;
    let mut acc = 0u64;
    for &i in &order {
        acc += hist[i] as u64;
        if acc >= target {
            return mag(i).max(1e-8);
        }
    }
    hi.abs().max(lo.abs())
}

/// TensorRT-flavoured KL calibrator: choose the clip threshold whose
/// 255-level quantized distribution minimises KL(P||Q).
pub fn kl_threshold(hist: &[u32], lo: f32, hi: f32) -> f32 {
    let bins = hist.len();
    let tmax = hi.abs().max(lo.abs()).max(1e-8);
    // Work on the magnitude distribution re-binned over [0, tmax].
    let mut mags = vec![0f64; bins];
    for (i, &c) in hist.iter().enumerate() {
        let l = lo + (hi - lo) * (i as f32 / bins as f32);
        let u = bin_upper(lo, hi, bins, i);
        let m = u.abs().max(l.abs());
        let bi = ((m / tmax) * (bins as f32 - 1.0)) as usize;
        mags[bi.min(bins - 1)] += c as f64;
    }
    let mut best_t = tmax;
    let mut best_kl = f64::INFINITY;
    // candidate thresholds: from 25% of range upward
    for cut in (bins / 4)..=bins {
        let t = tmax * cut as f32 / bins as f32;
        let kl = kl_for_cut(&mags, cut);
        if kl < best_kl {
            best_kl = kl;
            best_t = t;
        }
    }
    best_t.max(1e-8)
}

fn kl_for_cut(mags: &[f64], cut: usize) -> f64 {
    let bins = mags.len();
    // P: clipped reference distribution
    let mut p: Vec<f64> = mags[..cut.min(bins)].to_vec();
    let clipped: f64 = mags[cut.min(bins)..].iter().sum();
    if let Some(last) = p.last_mut() {
        *last += clipped;
    }
    let psum: f64 = p.iter().sum();
    if psum <= 0.0 {
        return f64::INFINITY;
    }
    // Q: P re-quantized to 255 levels then expanded back
    let levels = 255usize.min(cut.max(1));
    let mut q = vec![0f64; p.len()];
    let chunk = p.len() as f64 / levels as f64;
    for lv in 0..levels {
        let a = (lv as f64 * chunk) as usize;
        let b = (((lv + 1) as f64 * chunk) as usize).min(p.len()).max(a + 1);
        let mass: f64 = p[a..b].iter().sum();
        let nz = p[a..b].iter().filter(|&&v| v > 0.0).count().max(1);
        for i in a..b {
            if p[i] > 0.0 {
                q[i] = mass / nz as f64;
            }
        }
    }
    let qsum: f64 = q.iter().sum();
    let mut kl = 0.0;
    for i in 0..p.len() {
        if p[i] > 0.0 && q[i] > 0.0 {
            kl += (p[i] / psum) * ((p[i] / psum) / (q[i] / qsum)).ln();
        } else if p[i] > 0.0 {
            kl += 1e3; // heavy penalty for zero-mass bins
        }
    }
    kl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_aggregates() {
        let mut mm = MinMax::default();
        mm.update(-1.0, 2.0);
        mm.update(-0.5, 3.0);
        assert_eq!(mm.min, -1.0);
        assert_eq!(mm.max, 3.0);
    }

    fn gaussian_hist(bins: usize, outlier: bool) -> (Vec<u32>, f32, f32) {
        // symmetric pseudo-gaussian histogram over [-4, 4]
        let mut h = vec![0u32; bins];
        for i in 0..bins {
            let x = -4.0 + 8.0 * (i as f32 + 0.5) / bins as f32;
            h[i] = (1e5 * (-x * x / 2.0).exp()) as u32;
        }
        if outlier {
            h[bins - 1] += 3; // a couple of far outliers
        }
        (h, -4.0, 4.0)
    }

    #[test]
    fn percentile_below_max_with_outliers() {
        let (h, lo, hi) = gaussian_hist(128, true);
        let p = percentile_threshold(&h, lo, hi, 9990);
        assert!(p < 4.0);
        assert!(p > 1.5);
    }

    #[test]
    fn percentile_10000_is_max() {
        let (h, lo, hi) = gaussian_hist(128, false);
        let p = percentile_threshold(&h, lo, hi, 10_000);
        assert!(p >= 3.9);
    }

    #[test]
    fn kl_clips_outliers() {
        let (h, lo, hi) = gaussian_hist(128, true);
        let t = kl_threshold(&h, lo, hi);
        assert!(t <= 4.0);
        assert!(t >= 1.0);
    }

    #[test]
    fn max_calibrator_is_identity() {
        let (h, lo, hi) = gaussian_hist(64, false);
        assert_eq!(threshold_from_hist(Calibrator::Max, &h, lo, hi), 4.0);
    }

    #[test]
    fn act_t_tensor_layout() {
        let mut cs = CalibStats::new(2);
        cs.site_minmax[0].update(-1.0, 2.0);
        cs.site_minmax[1].update(0.0, 5.0);
        let t = cs.act_t_tensor();
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), &[-1.0, 2.0, 0.0, 5.0]);
    }
}
