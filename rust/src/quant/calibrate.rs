//! Calibration: aggregation of per-site statistics over calibration
//! batches, plus baseline threshold calibrators (max / percentile / KL)
//! used by the A1 ablation and the `calibration_study` example.

/// Running (min, max) aggregate per site.
#[derive(Debug, Clone)]
pub struct MinMax {
    pub min: f32,
    pub max: f32,
}

impl Default for MinMax {
    fn default() -> Self {
        MinMax { min: f32::INFINITY, max: f32::NEG_INFINITY }
    }
}

impl MinMax {
    pub fn update(&mut self, min: f32, max: f32) {
        self.min = self.min.min(min);
        self.max = self.max.max(max);
    }
}

/// Aggregated calibration statistics for a model.
#[derive(Debug, Clone, Default)]
pub struct CalibStats {
    /// Per activation site, in site order: (min, max).
    pub site_minmax: Vec<MinMax>,
    /// Per conv-like node: per-channel (min, max) of pre-activation output.
    pub channel_minmax: std::collections::BTreeMap<String, Vec<MinMax>>,
    /// Per-site histograms (counts over 128 bins spanning site min..max),
    /// filled by the optional second calibration pass.
    pub site_hist: Vec<Vec<u32>>,
    pub batches: usize,
}

impl CalibStats {
    pub fn new(num_sites: usize) -> Self {
        CalibStats {
            site_minmax: vec![MinMax::default(); num_sites],
            channel_minmax: Default::default(),
            site_hist: vec![],
            batches: 0,
        }
    }

    /// Stacked (S, 2) tensor of (min, max) in site order — the `act_t`
    /// input of the quantized artifacts.
    pub fn act_t_tensor(&self) -> crate::tensor::Tensor {
        let mut v = Vec::with_capacity(self.site_minmax.len() * 2);
        for mm in &self.site_minmax {
            v.push(mm.min);
            v.push(mm.max);
        }
        crate::tensor::Tensor::f32(vec![self.site_minmax.len(), 2], v)
    }

    /// Shrink every site range to the calibrator's threshold derived
    /// from its histogram (`hists[i]` spans `site_minmax[i]`). A no-op
    /// for [`Calibrator::Max`]; this is how percentile/KL calibrators
    /// reach the fine-tune and int8-export paths (`quant::session`).
    /// A histogram-count mismatch is a hard error — silently leaving
    /// tail sites unclipped would corrupt results undetectably.
    pub fn apply_calibrator(
        &mut self,
        cal: Calibrator,
        hists: &[Vec<u32>],
    ) -> anyhow::Result<()> {
        if cal == Calibrator::Max {
            return Ok(());
        }
        anyhow::ensure!(
            hists.len() == self.site_minmax.len(),
            "apply_calibrator: {} histograms for {} sites",
            hists.len(),
            self.site_minmax.len()
        );
        for (i, mm) in self.site_minmax.iter_mut().enumerate() {
            let t = threshold_from_hist(cal, &hists[i], mm.min, mm.max);
            mm.min = mm.min.max(-t);
            mm.max = mm.max.min(t);
        }
        Ok(())
    }
}

/// Baseline calibrator selection (A1 ablation; reachable in the real
/// export path through `quant::session::QuantSpec`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Calibrator {
    /// Paper default: exact max (eq. 2/6).
    Max,
    /// Percentile of the distribution (e.g. 99.99).
    Percentile(u32), // in basis points: 9999 = 99.99%
    /// TensorRT-style KL-divergence minimisation over the histogram.
    Kl,
}

impl Calibrator {
    /// Parse a CLI-style name: `max`, `kl`, or `p<digits>` read as a
    /// percentage with implied decimals — `p99` = 99%, `p999` = 99.9%,
    /// `p9999` = 99.99%. Percentiles below 50% are rejected: they are
    /// never meaningful as clip thresholds, and the implied-decimal
    /// grammar would otherwise silently misread inputs like `p100` or
    /// `p1` (10% / 10%) that were probably meant as whole percentages.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        match s {
            "max" => return Ok(Calibrator::Max),
            "kl" => return Ok(Calibrator::Kl),
            _ => {}
        }
        if let Some(digits) = s.strip_prefix('p') {
            anyhow::ensure!(
                !digits.is_empty()
                    && digits.len() <= 4
                    && digits.chars().all(|c| c.is_ascii_digit()),
                "bad percentile calibrator `{s}` (use e.g. p99, p999, p9999)"
            );
            let n: u32 = digits.parse()?;
            let bp = n * 10u32.pow(4 - digits.len() as u32);
            anyhow::ensure!(
                (5_000..=10_000).contains(&bp),
                "percentile calibrator `{s}` reads as {}.{:02}%, outside \
                 the supported [50, 100]% (digits after `p` carry implied \
                 decimals: p99 = 99%, p999 = 99.9%, p9999 = 99.99%)",
                bp / 100,
                bp % 100
            );
            return Ok(Calibrator::Percentile(bp));
        }
        anyhow::bail!("unknown calibrator `{s}` (expected max, p<digits> or kl)")
    }

    /// Canonical CLI/report name (inverse of [`Calibrator::parse`] for
    /// the named variants).
    pub fn name(self) -> String {
        match self {
            Calibrator::Max => "max".to_string(),
            Calibrator::Kl => "kl".to_string(),
            Calibrator::Percentile(bp) => {
                // strip trailing zeros from the basis-point form
                let mut n = bp;
                let mut digits = 4;
                while digits > 2 && n % 10 == 0 {
                    n /= 10;
                    digits -= 1;
                }
                format!("p{n}")
            }
        }
    }
}

/// Reduce a histogram over [lo, hi] to a threshold per the calibrator.
pub fn threshold_from_hist(
    cal: Calibrator,
    hist: &[u32],
    lo: f32,
    hi: f32,
) -> f32 {
    match cal {
        Calibrator::Max => hi.abs().max(lo.abs()),
        Calibrator::Percentile(bp) => percentile_threshold(hist, lo, hi, bp),
        Calibrator::Kl => kl_threshold(hist, lo, hi),
    }
}

fn bin_upper(lo: f32, hi: f32, bins: usize, i: usize) -> f32 {
    lo + (hi - lo) * ((i + 1) as f32 / bins as f32)
}

/// Smallest upper edge covering `bp/10000` of the mass (by |value|; the
/// histogram is assumed to span [lo, hi] densely).
pub fn percentile_threshold(hist: &[u32], lo: f32, hi: f32, bp: u32) -> f32 {
    let total: u64 = hist.iter().map(|&c| c as u64).sum();
    if total == 0 {
        return hi.abs().max(lo.abs());
    }
    let bins = hist.len();
    // Accumulate bins by ascending |upper-edge| magnitude.
    let mut order: Vec<usize> = (0..bins).collect();
    let mag = |i: usize| -> f32 {
        let u = bin_upper(lo, hi, bins, i);
        let l = lo + (hi - lo) * (i as f32 / bins as f32);
        u.abs().max(l.abs())
    };
    order.sort_by(|&a, &b| mag(a).total_cmp(&mag(b)));
    let target = (total as f64 * bp as f64 / 10_000.0).ceil() as u64;
    let mut acc = 0u64;
    for &i in &order {
        acc += hist[i] as u64;
        if acc >= target {
            return mag(i).max(1e-8);
        }
    }
    hi.abs().max(lo.abs())
}

/// TensorRT-flavoured KL calibrator: choose the clip threshold whose
/// 255-level quantized distribution minimises KL(P||Q).
pub fn kl_threshold(hist: &[u32], lo: f32, hi: f32) -> f32 {
    let bins = hist.len();
    let tmax = hi.abs().max(lo.abs()).max(1e-8);
    // Work on the magnitude distribution re-binned over [0, tmax].
    let mut mags = vec![0f64; bins];
    for (i, &c) in hist.iter().enumerate() {
        let l = lo + (hi - lo) * (i as f32 / bins as f32);
        let u = bin_upper(lo, hi, bins, i);
        let m = u.abs().max(l.abs());
        let bi = ((m / tmax) * (bins as f32 - 1.0)) as usize;
        mags[bi.min(bins - 1)] += c as f64;
    }
    let mut best_t = tmax;
    let mut best_kl = f64::INFINITY;
    // candidate thresholds: from 25% of range upward
    for cut in (bins / 4)..=bins {
        let t = tmax * cut as f32 / bins as f32;
        let kl = kl_for_cut(&mags, cut);
        if kl < best_kl {
            best_kl = kl;
            best_t = t;
        }
    }
    best_t.max(1e-8)
}

fn kl_for_cut(mags: &[f64], cut: usize) -> f64 {
    let bins = mags.len();
    // P: clipped reference distribution
    let mut p: Vec<f64> = mags[..cut.min(bins)].to_vec();
    let clipped: f64 = mags[cut.min(bins)..].iter().sum();
    if let Some(last) = p.last_mut() {
        *last += clipped;
    }
    let psum: f64 = p.iter().sum();
    if psum <= 0.0 {
        return f64::INFINITY;
    }
    // Q: P re-quantized to 255 levels then expanded back
    let levels = 255usize.min(cut.max(1));
    let mut q = vec![0f64; p.len()];
    let chunk = p.len() as f64 / levels as f64;
    for lv in 0..levels {
        let a = (lv as f64 * chunk) as usize;
        let b = (((lv + 1) as f64 * chunk) as usize).min(p.len()).max(a + 1);
        let mass: f64 = p[a..b].iter().sum();
        let nz = p[a..b].iter().filter(|&&v| v > 0.0).count().max(1);
        for i in a..b {
            if p[i] > 0.0 {
                q[i] = mass / nz as f64;
            }
        }
    }
    let qsum: f64 = q.iter().sum();
    let mut kl = 0.0;
    for i in 0..p.len() {
        if p[i] > 0.0 && q[i] > 0.0 {
            kl += (p[i] / psum) * ((p[i] / psum) / (q[i] / qsum)).ln();
        } else if p[i] > 0.0 {
            kl += 1e3; // heavy penalty for zero-mass bins
        }
    }
    kl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_aggregates() {
        let mut mm = MinMax::default();
        mm.update(-1.0, 2.0);
        mm.update(-0.5, 3.0);
        assert_eq!(mm.min, -1.0);
        assert_eq!(mm.max, 3.0);
    }

    fn gaussian_hist(bins: usize, outlier: bool) -> (Vec<u32>, f32, f32) {
        // symmetric pseudo-gaussian histogram over [-4, 4]
        let mut h = vec![0u32; bins];
        for i in 0..bins {
            let x = -4.0 + 8.0 * (i as f32 + 0.5) / bins as f32;
            h[i] = (1e5 * (-x * x / 2.0).exp()) as u32;
        }
        if outlier {
            h[bins - 1] += 3; // a couple of far outliers
        }
        (h, -4.0, 4.0)
    }

    #[test]
    fn percentile_below_max_with_outliers() {
        let (h, lo, hi) = gaussian_hist(128, true);
        let p = percentile_threshold(&h, lo, hi, 9990);
        assert!(p < 4.0);
        assert!(p > 1.5);
    }

    #[test]
    fn percentile_10000_is_max() {
        let (h, lo, hi) = gaussian_hist(128, false);
        let p = percentile_threshold(&h, lo, hi, 10_000);
        assert!(p >= 3.9);
    }

    #[test]
    fn kl_clips_outliers() {
        let (h, lo, hi) = gaussian_hist(128, true);
        let t = kl_threshold(&h, lo, hi);
        assert!(t <= 4.0);
        assert!(t >= 1.0);
    }

    #[test]
    fn max_calibrator_is_identity() {
        let (h, lo, hi) = gaussian_hist(64, false);
        assert_eq!(threshold_from_hist(Calibrator::Max, &h, lo, hi), 4.0);
    }

    #[test]
    fn calibrator_parse_names() {
        assert_eq!(Calibrator::parse("max").unwrap(), Calibrator::Max);
        assert_eq!(Calibrator::parse("kl").unwrap(), Calibrator::Kl);
        assert_eq!(
            Calibrator::parse("p9999").unwrap(),
            Calibrator::Percentile(9999)
        );
        assert_eq!(
            Calibrator::parse("p999").unwrap(),
            Calibrator::Percentile(9990)
        );
        assert_eq!(
            Calibrator::parse("p99").unwrap(),
            Calibrator::Percentile(9900)
        );
        assert!(Calibrator::parse("p").is_err());
        assert!(Calibrator::parse("p99999").is_err());
        assert!(Calibrator::parse("median").is_err());
        // sub-50% readings are rejected, not silently misread:
        // p100 would otherwise parse as 10.0%, p1 as 10%
        assert!(Calibrator::parse("p100").is_err());
        assert!(Calibrator::parse("p1").is_err());
        assert_eq!(
            Calibrator::parse("p50").unwrap(),
            Calibrator::Percentile(5000)
        );
        // round-trip through the canonical name
        for c in [
            Calibrator::Max,
            Calibrator::Kl,
            Calibrator::Percentile(9999),
            Calibrator::Percentile(9990),
            Calibrator::Percentile(9900),
        ] {
            assert_eq!(Calibrator::parse(&c.name()).unwrap(), c);
        }
    }

    #[test]
    fn apply_calibrator_shrinks_ranges() {
        let (h, lo, hi) = gaussian_hist(128, true);
        let mut cs = CalibStats::new(1);
        cs.site_minmax[0].update(lo, hi);
        let untouched = cs.clone();
        cs.apply_calibrator(Calibrator::Max, &[h.clone()]).unwrap();
        assert_eq!(cs.site_minmax[0].max, untouched.site_minmax[0].max);
        cs.apply_calibrator(Calibrator::Percentile(9990), &[h.clone()])
            .unwrap();
        assert!(cs.site_minmax[0].max < hi);
        assert!(cs.site_minmax[0].min > lo);
        assert!(cs.site_minmax[0].min <= cs.site_minmax[0].max);
        // histogram-count mismatch is a hard error, not a silent skip
        let mut two = CalibStats::new(2);
        two.site_minmax[0].update(lo, hi);
        two.site_minmax[1].update(lo, hi);
        assert!(two.apply_calibrator(Calibrator::Kl, &[h]).is_err());
    }

    #[test]
    fn act_t_tensor_layout() {
        let mut cs = CalibStats::new(2);
        cs.site_minmax[0].update(-1.0, 2.0);
        cs.site_minmax[1].update(0.0, 5.0);
        let t = cs.act_t_tensor();
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), &[-1.0, 2.0, 0.0, 5.0]);
    }
}
