//! Build a fully-quantized [`QModel`] for the int8 engine from folded
//! weights + calibration stats + (optionally fine-tuned) FAT thresholds.
//!
//! This is the "convert for mobile" step of the paper's pipeline: weights
//! become int8 (per-tensor or per-filter, §3.1.5), biases int32 (eq. 20),
//! activations get per-site scale/zero-point from the adjusted thresholds,
//! and every conv→relu(6) pair is fused into a requant clamp.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::int8::engine::{AddParams, GapParams, QLayer, QModel, QNode};
use crate::int8::plan::ExecPlan;
use crate::int8::qtensor::to_i8_domain;
use crate::model::store::SitesJson;
use crate::model::{GraphDef, Op};
use crate::tensor::Tensor;

use super::calibrate::CalibStats;
use super::scale::{
    quantize_bias, quantize_multiplier, shift_table, snap_pow2, QParams,
};
use super::thresholds as th;

/// Quantization mode grid of Tables 1-2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    SymScalar,
    SymVector,
    AsymScalar,
    AsymVector,
}

impl QuantMode {
    pub fn asym(self) -> bool {
        matches!(self, QuantMode::AsymScalar | QuantMode::AsymVector)
    }

    pub fn vector(self) -> bool {
        matches!(self, QuantMode::SymVector | QuantMode::AsymVector)
    }

    /// Artifact suffix, e.g. `sym_scalar` in `train_step_sym_scalar`.
    pub fn name(self) -> &'static str {
        match self {
            QuantMode::SymScalar => "sym_scalar",
            QuantMode::SymVector => "sym_vector",
            QuantMode::AsymScalar => "asym_scalar",
            QuantMode::AsymVector => "asym_vector",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sym_scalar" => QuantMode::SymScalar,
            "sym_vector" => QuantMode::SymVector,
            "asym_scalar" => QuantMode::AsymScalar,
            "asym_vector" => QuantMode::AsymVector,
            other => anyhow::bail!("unknown mode {other}"),
        })
    }

    pub fn all() -> [QuantMode; 4] {
        [
            QuantMode::SymScalar,
            QuantMode::SymVector,
            QuantMode::AsymScalar,
            QuantMode::AsymVector,
        ]
    }
}

/// Export-time knobs orthogonal to the [`QuantMode`] grid (DESIGN.md
/// §13): power-of-two scales (shift-only requant) and the packed-weight
/// bit width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantKnobs {
    /// Snap every activation and weight **scale** to a power of two
    /// (TQT, arxiv 1903.08066). Every conv/dwconv/dense requant
    /// multiplier `s_in·s_w/s_out` is then an exact `2^-s` and those
    /// layers carry a shift table (`QLayer::requant_shift`), taking the
    /// shift-only epilogue. Scales are snapped — not thresholds: a
    /// pow2 *threshold* would still leave the `/127` in the scale and
    /// the ratio would not collapse. Gap and Add stay multiplier-based
    /// (their ratios fold non-pow2 factors like `1/(h·w)`).
    pub pow2: bool,
    /// Weight bit width: 8 (default), or 4 — weights clamp to `[-7, 7]`
    /// (scale `t/7`) and conv/dense panels pack two weights per byte
    /// (`int8::kernels`, int4 panels).
    pub w_bits: usize,
}

impl Default for QuantKnobs {
    fn default() -> Self {
        QuantKnobs { pow2: false, w_bits: 8 }
    }
}

impl QuantKnobs {
    pub fn validate(self) -> Result<()> {
        anyhow::ensure!(
            self.w_bits == 8 || self.w_bits == 4,
            "w_bits={} (want 8 or 4)",
            self.w_bits
        );
        Ok(())
    }

    /// The weight-side quantization ceiling: 127 for int8, 7 for int4.
    pub fn w_qmax(self) -> i32 {
        if self.w_bits == 4 {
            7
        } else {
            127
        }
    }
}

/// Rounding mode marker (the engine uses round-half-even at quantize time,
/// gemmlowp rounding in requant — kept for API clarity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Rounding {
    #[default]
    TiesEven,
}

/// Fine-tuned threshold scales, keyed like the artifact trainables:
/// `act_a` / (`act_at`, `act_ar`) per site and `w_a:<node>` per layer.
#[derive(Debug, Clone, Default)]
pub struct Trained {
    pub act_a: Vec<f32>,
    pub act_at: Vec<f32>,
    pub act_ar: Vec<f32>,
    pub w_a: BTreeMap<String, Vec<f32>>,
}

impl Trained {
    /// α = 1 defaults (pure calibration, "quantization without training").
    pub fn identity(g: &GraphDef, mode: QuantMode, num_sites: usize) -> Self {
        let mut w_a = BTreeMap::new();
        for n in g.conv_like() {
            let len = if mode.vector() && n.op != Op::Dense {
                n.out_channels()
            } else {
                1
            };
            w_a.insert(n.id.clone(), vec![1.0; len]);
        }
        Trained {
            act_a: vec![1.0; num_sites],
            act_at: vec![0.0; num_sites],
            act_ar: vec![1.0; num_sites],
            w_a,
        }
    }
}

/// Per-site activation QParams (i8 domain) from calibration + trained α.
pub fn site_qparams(
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
) -> BTreeMap<String, QParams> {
    site_qparams_with(sites, stats, mode, tr, QuantKnobs::default())
}

/// [`site_qparams`] with export knobs: in pow2 mode every site scale is
/// snapped to a power of two (zero-point re-nudged) before the i8
/// domain shift — the domain shift moves only the integer grid, so the
/// snapped scale survives it unchanged.
pub fn site_qparams_with(
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
    knobs: QuantKnobs,
) -> BTreeMap<String, QParams> {
    let mut out = BTreeMap::new();
    for (i, site) in sites.sites.iter().enumerate() {
        let mm = &stats.site_minmax[i];
        let qp = if mode.asym() {
            let (left, width) = th::adjust_asym(
                tr.act_at[i],
                tr.act_ar[i],
                mm.min,
                mm.max,
                site.unsigned,
            );
            QParams::asymmetric(left, width)
        } else {
            let t = th::adjust_sym(
                tr.act_a[i],
                th::sym_t_from_minmax(mm.min, mm.max),
            );
            if site.unsigned {
                QParams::symmetric_unsigned(t)
            } else {
                QParams::symmetric_signed(t)
            }
        };
        let qp = if knobs.pow2 { qp.snap_pow2() } else { qp };
        out.insert(site.id.clone(), to_i8_domain(qp));
    }
    out
}

/// Weight quantization: per-tensor or per-filter symmetric int8.
/// Returns (w_q, per-channel scales — len 1 in scalar mode).
pub fn quantize_weights(
    w: &Tensor,
    cout: usize,
    vector: bool,
    w_alpha: &[f32],
) -> Result<(Vec<i8>, Vec<f32>)> {
    quantize_weights_with(w, cout, vector, w_alpha, QuantKnobs::default())
}

/// [`quantize_weights`] with export knobs: `w_bits = 4` narrows the
/// grid to `[-7, 7]` (scale `t/7`, symmetric — the int4 panel's `-8` is
/// never produced, mirroring the int8 path's `-127`); `pow2` snaps each
/// scale to a power of two *after* the threshold adjustment, so the
/// trained α still steers which power is chosen.
pub fn quantize_weights_with(
    w: &Tensor,
    cout: usize,
    vector: bool,
    w_alpha: &[f32],
    knobs: QuantKnobs,
) -> Result<(Vec<i8>, Vec<f32>)> {
    knobs.validate()?;
    let data = w.as_f32()?;
    let qmax = knobs.w_qmax();
    let snap = |s: f32| if knobs.pow2 { snap_pow2(s) } else { s };
    if vector {
        let t = th::per_channel_w_thresholds(data, cout);
        let scales: Vec<f32> = t
            .iter()
            .enumerate()
            .map(|(c, &tc)| {
                snap(
                    th::adjust_sym(w_alpha[c.min(w_alpha.len() - 1)], tc)
                        / qmax as f32,
                )
            })
            .collect();
        let q = data
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let s = scales[i % cout];
                ((v / s).round_ties_even() as i32).clamp(-qmax, qmax) as i8
            })
            .collect();
        Ok((q, scales))
    } else {
        let t = th::adjust_sym(w_alpha[0], th::per_tensor_w_threshold(data));
        let s = snap(t / qmax as f32);
        let q = data
            .iter()
            .map(|&v| {
                ((v / s).round_ties_even() as i32).clamp(-qmax, qmax) as i8
            })
            .collect();
        Ok((q, vec![s]))
    }
}

/// For each node, its *effective* output site: the relu/relu6 consumer if
/// that is the sole consumer (the engine fuses the clamp), else itself.
fn effective_site(g: &GraphDef, id: &str) -> String {
    let cons = g.consumers();
    let cs = &cons[id];
    if cs.len() == 1 && matches!(cs[0].op, Op::Relu | Op::Relu6) {
        cs[0].id.clone()
    } else {
        id.to_string()
    }
}

/// Activation clamp for a producer writing into `site` (fusing relu/relu6).
fn clamp_for(g: &GraphDef, id: &str, qp: QParams) -> (i32, i32) {
    let cons = g.consumers();
    let cs = &cons[id];
    if cs.len() == 1 {
        match cs[0].op {
            Op::Relu => return (qp.zero_point.max(qp.qmin), qp.qmax),
            Op::Relu6 => {
                let hi = qp.zero_point
                    + (6.0 / qp.scale).round_ties_even() as i32;
                return (
                    qp.zero_point.max(qp.qmin),
                    hi.min(qp.qmax),
                );
            }
            _ => {}
        }
    }
    (qp.qmin, qp.qmax)
}

/// Input-site id feeding a node (resolving through fused relu nodes).
fn input_site(g: &GraphDef, node_input: &str) -> String {
    // the producer tensor's own effective site IS node_input unless the
    // producer was fused; but since fused relu nodes carry the producer's
    // tensor, the site id is simply the input node id when it is a site,
    // or the relu it was fused into. Because the engine stores tensors
    // under every node id (passthrough), the qparams of `node_input` are
    // those of its effective site.
    effective_site_of_tensor(g, node_input)
}

fn effective_site_of_tensor(g: &GraphDef, id: &str) -> String {
    // if `id` is a relu that was fused, its tensor carries its own site id;
    // if `id` is a producer whose sole consumer is a relu, its tensor was
    // produced directly into the relu's site.
    let n = g.node(id).unwrap();
    if matches!(n.op, Op::Relu | Op::Relu6) {
        return id.to_string();
    }
    effective_site(g, id)
}

/// Build the full quantized model (default knobs: multiplier requant,
/// int8 weights).
pub fn build_qmodel(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
) -> Result<QModel> {
    build_qmodel_with(g, weights, sites, stats, mode, tr, QuantKnobs::default())
}

/// [`build_qmodel`] with export knobs (pow2 shift-only requant, int4
/// weight packing).
pub fn build_qmodel_with(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    sites: &SitesJson,
    stats: &CalibStats,
    mode: QuantMode,
    tr: &Trained,
    knobs: QuantKnobs,
) -> Result<QModel> {
    knobs.validate()?;
    let site_qp = site_qparams_with(sites, stats, mode, tr, knobs);
    let qp_of = |sid: &str| -> Result<QParams> {
        site_qp
            .get(sid)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no site params for {sid}"))
    };

    let mut nodes = BTreeMap::new();
    let mut param_bytes = 0usize;
    for n in &g.nodes {
        match n.op {
            Op::Conv | Op::DwConv | Op::Dense => {
                let in_site = input_site(g, &n.inputs[0]);
                let out_site = effective_site(g, &n.id);
                let in_qp = qp_of(&in_site)?;
                let out_qp = qp_of(&out_site)?;
                let cout = n.out_channels();
                let w = &weights[&format!("{}.w", n.id)];
                let b = weights[&format!("{}.b", n.id)].as_f32()?;
                let ones = vec![1.0f32];
                let wa = tr.w_a.get(&n.id).unwrap_or(&ones);
                let vector = mode.vector() && n.op != Op::Dense;
                let (w_q, w_scales) =
                    quantize_weights_with(w, cout, vector, wa, knobs)?;
                let bias_q: Vec<i32> = b
                    .iter()
                    .enumerate()
                    .map(|(c, &bv)| {
                        quantize_bias(
                            bv,
                            in_qp.scale,
                            w_scales[c % w_scales.len()],
                        )
                    })
                    .collect();
                let multipliers: Vec<f64> = (0..cout)
                    .map(|c| {
                        in_qp.scale as f64
                            * w_scales[c % w_scales.len()] as f64
                            / out_qp.scale as f64
                    })
                    .collect();
                let requant: Vec<(i32, i32)> = multipliers
                    .iter()
                    .map(|&m| quantize_multiplier(m))
                    .collect();
                // pow2 mode: every scale in the ratio is an exact power
                // of two, so the f64 products/quotients are too — the
                // table collapses to per-channel rounding shifts. Only
                // the knob opts a model in; a coincidentally-pow2 table
                // under default knobs stays multiplier-based (the two
                // epilogues round differently).
                let requant_shift = if knobs.pow2 {
                    Some(shift_table(&multipliers).ok_or_else(|| {
                        anyhow::anyhow!(
                            "{}: pow2 mode produced a non-pow2 multiplier",
                            n.id
                        )
                    })?)
                } else {
                    None
                };
                // Conv/dense weights are prepacked once here, at plan
                // build time, into the strip/pair-interleaved layout the
                // SIMD microkernels consume (int8::kernels; depthwise
                // weights stay in (k,k,ch) layout — already tap-contiguous).
                // w_bits = 4 packs two weights per byte (|q| ≤ 7 by
                // construction of the narrowed grid).
                let (w_sums, packed) = if n.op == Op::DwConv {
                    (vec![], None)
                } else {
                    let k = w_q.len() / cout;
                    (
                        crate::int8::gemm::col_sums(&w_q, k, cout),
                        Some(crate::int8::kernels::PackedWeights::pack_bits(
                            &w_q,
                            k,
                            cout,
                            crate::int8::kernels::NR,
                            knobs.w_bits,
                        )),
                    )
                };
                param_bytes += w_q.len() + bias_q.len() * 4;
                nodes.insert(
                    n.id.clone(),
                    QNode::Layer(QLayer {
                        w_q: w_q.into(),
                        w_sums,
                        bias_q,
                        requant,
                        requant_shift,
                        out_qp,
                        clamp: clamp_for(g, &n.id, out_qp),
                        w_scales,
                        fused: packed.is_some(),
                        packed,
                        blocking: Default::default(),
                    }),
                );
            }
            Op::Add => {
                let sa = input_site(g, &n.inputs[0]);
                let sb = input_site(g, &n.inputs[1]);
                let so = effective_site(g, &n.id);
                let qa = qp_of(&sa)?;
                let qb = qp_of(&sb)?;
                let qo = qp_of(&so)?;
                nodes.insert(
                    n.id.clone(),
                    QNode::Add(AddParams {
                        ma: quantize_multiplier(
                            qa.scale as f64 / qo.scale as f64,
                        ),
                        mb: quantize_multiplier(
                            qb.scale as f64 / qo.scale as f64,
                        ),
                        out_qp: qo,
                        clamp: clamp_for(g, &n.id, qo),
                    }),
                );
            }
            Op::Gap => {
                let si = input_site(g, &n.inputs[0]);
                let so = effective_site(g, &n.id);
                let qi = qp_of(&si)?;
                let qo = qp_of(&so)?;
                // fold 1/(h*w) into the multiplier; spatial dims from the
                // input image shape walked through strides
                let hw = spatial_elems(g, &n.inputs[0])?;
                nodes.insert(
                    n.id.clone(),
                    QNode::Gap(GapParams {
                        m: quantize_multiplier(
                            qi.scale as f64
                                / qo.scale as f64
                                / hw as f64,
                        ),
                        out_qp: qo,
                    }),
                );
            }
            Op::Relu | Op::Relu6 => {
                nodes.insert(n.id.clone(), QNode::Passthrough);
            }
            Op::Input | Op::Bn => {}
        }
    }

    // Compile the execution plan once: topological schedule, dense
    // parameter indices, liveness-based buffer slots (int8::plan).
    let plan = ExecPlan::compile(g, nodes)?;

    let mut qm = QModel {
        graph: g.clone(),
        plan,
        input_qp: qp_of("input")?,
        param_bytes,
    };
    // Opt-in first-run tuning for models built in-process without an
    // artifact (`FAT_TUNE=capped|full`, capped by a wall-clock budget).
    // `fat export` tunes explicitly with the full sweep regardless of
    // the env, then persists the table in the `.fatm` PLAN section.
    if let Some(opts) = crate::int8::tune::TuneOptions::from_env() {
        crate::int8::tune::tune_model(&mut qm, &opts);
    }
    Ok(qm)
}

/// H*W of the tensor produced by `id` (input 32x32, halved per stride-2).
fn spatial_elems(g: &GraphDef, id: &str) -> Result<usize> {
    // walk back to input accumulating strides
    let mut cur = id.to_string();
    let mut factor = 1usize;
    loop {
        let n = g.node(&cur)?;
        match n.op {
            Op::Input => {
                let sh = n.input_shape.clone().unwrap_or(vec![32, 32, 3]);
                let h = sh[0].div_ceil(factor);
                let w = sh[1].div_ceil(factor);
                return Ok(h * w);
            }
            _ => {
                if n.stride > 1 {
                    factor *= n.stride;
                }
                cur = n.inputs[0].clone();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_grid() {
        assert!(QuantMode::AsymVector.asym());
        assert!(QuantMode::AsymVector.vector());
        assert!(!QuantMode::SymScalar.asym());
        assert_eq!(QuantMode::parse("sym_vector").unwrap(), QuantMode::SymVector);
        assert!(QuantMode::parse("nope").is_err());
        assert_eq!(QuantMode::all().len(), 4);
    }

    #[test]
    fn quantize_weights_scalar_vs_vector() {
        let w = Tensor::f32(vec![1, 1, 1, 2], vec![0.5, 4.0]);
        let (q_s, s_s) = quantize_weights(&w, 2, false, &[1.0]).unwrap();
        assert_eq!(s_s.len(), 1);
        // scalar: channel 0 poorly resolved (0.5 / (4/127) ≈ 16)
        assert_eq!(q_s[0], 16);
        assert_eq!(q_s[1], 127);
        let (q_v, s_v) = quantize_weights(&w, 2, true, &[1.0, 1.0]).unwrap();
        assert_eq!(s_v.len(), 2);
        // vector: both channels use their full range
        assert_eq!(q_v[0], 127);
        assert_eq!(q_v[1], 127);
    }

    #[test]
    fn trained_identity_shapes() {
        let g = GraphDef::from_json(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[8,8,3]},
             {"id":"c","op":"conv","inputs":["input"],"k":1,"stride":1,"cin":3,"cout":4,"bias":true},
             {"id":"g","op":"gap","inputs":["c"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":4,"cout":2,"bias":true}]}"#,
        )
        .unwrap();
        let t = Trained::identity(&g, QuantMode::SymVector, 4);
        assert_eq!(t.w_a["c"].len(), 4);
        assert_eq!(t.w_a["d"].len(), 1);
        let t2 = Trained::identity(&g, QuantMode::SymScalar, 4);
        assert_eq!(t2.w_a["c"].len(), 1);
    }
}
