//! Staged quantization sessions — the crate's public API for the paper's
//! pipeline (DESIGN.md §6).
//!
//! The paper's flow is a strict dataflow: **calibrate → (optional §3.3
//! rescale) → fine-tune thresholds → export an integer-only model**.
//! This module encodes that order in the type system so callers cannot
//! skip or reorder stages:
//!
//! ```text
//! QuantSession::open(reg, artifacts, model)        // stage 0: opened
//!     .calibrate(CalibOpts::images(100))?          // stage 1: Calibrated
//!     .dws_rescale()?                              //   optional §3.3 (re-calibrates)
//!     .finetune(&spec, &opts, progress)?           // stage 2: Thresholded
//!     // or .identity(&spec)?                      //   (α = 1, no fine-tune)
//!     .serve(EngineOptions::default())?            // stage 3: Int8Engine
//!     // or .serve_batched(16, 200)?               //   micro-batching scheduler (§9)
//! ```
//!
//! [`QuantSpec`] gathers every quantization knob (threshold symmetry,
//! per-filter weight scales, static calibrator, rounding) into one value,
//! and [`ThresholdSet`] is the single typed representation of adjusted
//! thresholds — replacing the old split between [`Trained`] and a
//! stringly-keyed trainable map (unknown keys are now a hard error, see
//! [`ThresholdSet::from_trainables`]).
//!
//! Every float-side stage runs through the session's resolved
//! [`Executor`] backend (DESIGN.md §7): AOT PJRT artifacts when they
//! exist and the build has the `pjrt` feature, the native `crate::fp`
//! executor otherwise — so the whole flow above works on a fresh
//! checkout with no artifacts at all.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::finetune::FinetuneOpts;
use crate::int8::batcher::BatchOptions;
use crate::int8::serve::{EngineOptions, Int8Engine};
use crate::int8::QModel;
use crate::model::store::SitesJson;
use crate::model::{builtin, GraphDef, ModelStore};
use crate::runtime::Registry;
use crate::tensor::Tensor;

use super::backend::{self, Executor, ModelView};
use super::calibrate::{CalibStats, Calibrator};
use super::dws::{self, PatternReport};
use super::export::{self, QuantKnobs, QuantMode, Rounding, Trained};
use super::fold;

// ---------------------------------------------------------------------
// QuantSpec
// ---------------------------------------------------------------------

/// One value holding every quantization knob of the paper's grid: the
/// threshold symmetry (Tables 1–2 rows), per-filter weight scales
/// (§3.1.5, Table 1 vs Table 2), the static threshold [`Calibrator`]
/// (A1 ablation; `Max` is the paper default) and the [`Rounding`] mode.
///
/// The legacy [`QuantMode`] is the (symmetry × per-filter) projection of
/// this spec; [`QuantSpec::mode`] / [`QuantSpec::from_mode`] convert.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    /// Asymmetric activation thresholds (eq. 21–23) instead of symmetric.
    pub asymmetric: bool,
    /// Per-filter (vector) weight thresholds instead of per-tensor.
    pub per_filter: bool,
    /// Static calibrator applied to the calibrated ranges before the
    /// threshold stage. `Max` (the paper default) is a no-op; percentile
    /// and KL calibrators shrink the ranges from activation histograms
    /// (requires the `calib_hist` artifact).
    pub calibrator: Calibrator,
    /// Rounding mode marker (the engine rounds ties-to-even at quantize
    /// time and uses gemmlowp rounding in requantization).
    pub rounding: Rounding,
    /// Snap every scale to a power of two so conv-like requant collapses
    /// to a shift-only epilogue (DESIGN.md §13; mode suffix `_pow2`).
    pub pow2: bool,
    /// Packed-weight bit width: 8, or 4 for nibble panels (mode suffix
    /// `_w4`). See [`QuantKnobs::w_bits`].
    pub w_bits: usize,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec {
            asymmetric: false,
            per_filter: false,
            calibrator: Calibrator::Max,
            rounding: Rounding::TiesEven,
            pow2: false,
            w_bits: 8,
        }
    }
}

impl QuantSpec {
    /// Spec equivalent to a legacy [`QuantMode`], with default calibrator
    /// and rounding.
    pub fn from_mode(mode: QuantMode) -> Self {
        QuantSpec {
            asymmetric: mode.asym(),
            per_filter: mode.vector(),
            ..Default::default()
        }
    }

    /// The (symmetry × per-filter) projection of this spec.
    pub fn mode(self) -> QuantMode {
        match (self.asymmetric, self.per_filter) {
            (false, false) => QuantMode::SymScalar,
            (false, true) => QuantMode::SymVector,
            (true, false) => QuantMode::AsymScalar,
            (true, true) => QuantMode::AsymVector,
        }
    }

    /// Replace the static calibrator.
    pub fn with_calibrator(mut self, cal: Calibrator) -> Self {
        self.calibrator = cal;
        self
    }

    /// Turn on power-of-two scales (shift-only requant).
    pub fn with_pow2(mut self, pow2: bool) -> Self {
        self.pow2 = pow2;
        self
    }

    /// Set the packed-weight bit width (8 or 4).
    pub fn with_w_bits(mut self, w_bits: usize) -> Self {
        self.w_bits = w_bits;
        self
    }

    /// The export-time knobs projection of this spec (everything the
    /// exporter needs beyond the [`QuantMode`]).
    pub fn knobs(self) -> export::QuantKnobs {
        export::QuantKnobs { pow2: self.pow2, w_bits: self.w_bits }
    }

    /// Parse a spec from CLI-style strings: a [`QuantMode`] name
    /// (`sym_scalar` | `sym_vector` | `asym_scalar` | `asym_vector`),
    /// optionally suffixed with knob tokens `_pow2` (power-of-two
    /// scales) and/or `_w4` (int4 packed weights) in either order —
    /// e.g. `sym_vector_pow2_w4` — and a [`Calibrator`] name
    /// (`max` | `p99`/`p999`/`p9999` | `kl`).
    pub fn parse(mode: &str, calibrator: &str) -> Result<Self> {
        let mut rest = mode;
        let (mut pow2, mut w_bits) = (false, 8);
        // Knob suffixes commute; strip until the bare mode remains.
        loop {
            if let Some(m) = rest.strip_suffix("_pow2") {
                anyhow::ensure!(!pow2, "mode `{mode}`: duplicate `_pow2`");
                pow2 = true;
                rest = m;
            } else if let Some(m) = rest.strip_suffix("_w4") {
                anyhow::ensure!(w_bits == 8, "mode `{mode}`: duplicate `_w4`");
                w_bits = 4;
                rest = m;
            } else {
                break;
            }
        }
        Ok(QuantSpec::from_mode(QuantMode::parse(rest)?)
            .with_calibrator(Calibrator::parse(calibrator)?)
            .with_pow2(pow2)
            .with_w_bits(w_bits))
    }
}

// ---------------------------------------------------------------------
// CalibOpts
// ---------------------------------------------------------------------

/// Options for the calibration stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CalibOpts {
    /// Calibration images from the train split (paper: 100). Values
    /// below one calibration batch are rounded up to a full batch by
    /// the pass itself.
    pub images: usize,
}

impl Default for CalibOpts {
    fn default() -> Self {
        CalibOpts { images: 100 }
    }
}

impl CalibOpts {
    /// Calibrate on `images` training images.
    pub fn images(images: usize) -> Self {
        CalibOpts { images }
    }
}

// ---------------------------------------------------------------------
// ThresholdSet
// ---------------------------------------------------------------------

/// The single typed representation of adjusted FAT thresholds: per-site
/// activation scales (α, or α_T/α_R in asymmetric mode) plus per-layer
/// weight scales, always tagged with the [`QuantMode`] they were built
/// for.
///
/// This replaces the old split between the exporter's [`Trained`] struct
/// and the stringly-keyed trainable map returned by the fine-tune
/// artifacts: [`ThresholdSet::from_trainables`] performs explicit key
/// parsing and rejects unknown keys and shape mismatches instead of
/// silently ignoring them.
#[derive(Debug, Clone)]
pub struct ThresholdSet {
    mode: QuantMode,
    trained: Trained,
}

impl ThresholdSet {
    /// Identity thresholds (α = 1): "quantization without fine-tuning".
    pub fn identity(g: &GraphDef, mode: QuantMode, num_sites: usize) -> Self {
        ThresholdSet { mode, trained: Trained::identity(g, mode, num_sites) }
    }

    /// Wrap an exporter-form [`Trained`] that is already known to match
    /// `mode` (legacy interop; prefer [`ThresholdSet::from_trainables`]).
    pub fn from_parts(mode: QuantMode, trained: Trained) -> Self {
        ThresholdSet { mode, trained }
    }

    /// Parse a trainable map (as produced by the `train_step_*`
    /// artifacts) into a typed threshold set.
    ///
    /// Accepted keys are exactly `act_a`, `act_at`, `act_ar` (length =
    /// number of quantization sites) and `w_a:<node>` where `<node>` is
    /// a conv-like node of `g`. Any other key — and any length mismatch —
    /// is an error, so a renamed or misrouted trainable can no longer be
    /// silently dropped.
    pub fn from_trainables(
        g: &GraphDef,
        mode: QuantMode,
        num_sites: usize,
        tr: &BTreeMap<String, Tensor>,
    ) -> Result<Self> {
        let mut out = Trained::identity(g, mode, num_sites);
        for (k, t) in tr {
            let v = t.as_f32()?.to_vec();
            let check_sites = |name: &str, len: usize| -> Result<()> {
                anyhow::ensure!(
                    len == num_sites,
                    "trainable {name}: expected {num_sites} per-site \
                     values, got {len}"
                );
                Ok(())
            };
            match k.as_str() {
                "act_a" => {
                    check_sites("act_a", v.len())?;
                    out.act_a = v;
                }
                "act_at" => {
                    check_sites("act_at", v.len())?;
                    out.act_at = v;
                }
                "act_ar" => {
                    check_sites("act_ar", v.len())?;
                    out.act_ar = v;
                }
                _ => {
                    let Some(node) = k.strip_prefix("w_a:") else {
                        anyhow::bail!(
                            "unknown trainable key `{k}` (expected act_a, \
                             act_at, act_ar or w_a:<node>)"
                        );
                    };
                    let expect = out.w_a.get(node).ok_or_else(|| {
                        anyhow::anyhow!(
                            "trainable `{k}` names `{node}`, which is not \
                             a conv-like node of graph `{}`",
                            g.name
                        )
                    })?;
                    anyhow::ensure!(
                        v.len() == expect.len(),
                        "trainable `{k}`: expected {} weight scales for \
                         {mode:?}, got {}",
                        expect.len(),
                        v.len()
                    );
                    out.w_a.insert(node.to_string(), v);
                }
            }
        }
        Ok(ThresholdSet { mode, trained: out })
    }

    /// The quantization mode these thresholds were built for.
    pub fn mode(&self) -> QuantMode {
        self.mode
    }

    /// Exporter-facing view of the thresholds.
    pub fn trained(&self) -> &Trained {
        &self.trained
    }

    /// Consume into the exporter's representation.
    pub fn into_trained(self) -> Trained {
        self.trained
    }
}

// ---------------------------------------------------------------------
// SessionCore
// ---------------------------------------------------------------------

/// Shared state + primitive operations behind every session stage: the
/// model's graph, quant-site metadata, (mutable) folded weights and the
/// resolved float-side execution backend.
///
/// Most callers should drive the staged [`QuantSession`] API instead;
/// the core is public so studies can reach the primitives.
#[derive(Clone)]
pub struct SessionCore {
    /// On-disk model directory handle (`None` for builtin models and
    /// sessions built from explicit parts — those are native-only).
    pub store: Option<ModelStore>,
    /// BN-folded graph IR.
    pub graph: GraphDef,
    /// Quantization-site metadata.
    pub sites: SitesJson,
    /// Rust-folded weights (mutated in place by §3.3 rescaling).
    pub weights: BTreeMap<String, Tensor>,
    /// Float-side execution backend (native or AOT artifacts), resolved
    /// once at open time (see `quant::backend::resolve`).
    pub exec: Arc<dyn Executor>,
}

impl SessionCore {
    /// Open a model and fold its weights (eq. 10–11). Prefers the
    /// on-disk artifact directory; when `artifacts/models/<model>` is
    /// absent and `model` names a builtin, the graph and deterministic
    /// weights come from [`crate::model::builtin`] and every float
    /// stage runs on the native backend.
    pub fn open<P: AsRef<Path>>(
        reg: Arc<Registry>,
        artifacts: P,
        model: &str,
    ) -> Result<Self> {
        let dir = artifacts.as_ref().join("models").join(model);
        if dir.exists() {
            let store = ModelStore::open(&artifacts, model)?;
            let raw_graph = store.graph()?;
            let graph = store.folded_graph()?;
            let sites = store.sites()?;
            let raw = store.raw_weights()?;
            // BN folding happens here, in Rust (eq. 10-11); the
            // Python-folded weights only serve as a golden cross-check.
            let weights = fold::fold_bn(&raw_graph, &raw)?;
            let exec = backend::resolve(&reg, Some(&store))?;
            Ok(SessionCore { store: Some(store), graph, sites, weights, exec })
        } else if builtin::is_builtin(model) {
            let (graph, sites, weights) = builtin::load(model)?;
            let exec = backend::resolve(&reg, None)?;
            Ok(SessionCore { store: None, graph, sites, weights, exec })
        } else {
            anyhow::bail!(
                "model `{model}`: no artifact directory at {dir:?} and no \
                 builtin of that name (builtins: {}; run `make artifacts` \
                 for pretrained models)",
                builtin::names().join(", ")
            )
        }
    }

    /// Build a native-only session from explicit parts (tests, custom
    /// graphs). No artifact directory is involved.
    pub fn from_parts(
        graph: GraphDef,
        sites: SitesJson,
        weights: BTreeMap<String, Tensor>,
    ) -> Self {
        SessionCore {
            store: None,
            graph,
            sites,
            weights,
            exec: Arc::new(backend::NativeExec),
        }
    }

    /// Short name of the resolved float-side backend (for logs).
    pub fn backend_name(&self) -> &'static str {
        self.exec.name()
    }

    /// Backend view of the model state.
    fn view(&self) -> ModelView<'_> {
        ModelView {
            graph: &self.graph,
            sites: &self.sites,
            weights: &self.weights,
        }
    }

    /// Run the calibration pass over `images` training images.
    pub fn calibrate(&self, images: usize) -> Result<CalibStats> {
        self.exec.calibrate(&self.view(), images)
    }

    /// Second pass: per-site histograms over the calibrated ranges (used
    /// by the percentile/KL calibrators and the A1 ablation).
    pub fn calibrate_hist(
        &self,
        stats: &CalibStats,
        images: usize,
    ) -> Result<Vec<Vec<u32>>> {
        self.exec.calibrate_hist(&self.view(), stats, images)
    }

    /// FP32 accuracy of the float forward.
    pub fn fp_accuracy(&self, val_images: usize) -> Result<f64> {
        self.exec.fp_accuracy(&self.view(), val_images)
    }

    /// Accuracy of the fake-quant forward under a trainable map
    /// (default export knobs).
    pub fn quant_accuracy(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        self.quant_accuracy_with(
            mode,
            QuantKnobs::default(),
            stats,
            trained,
            val_images,
        )
    }

    /// [`SessionCore::quant_accuracy`] under explicit export knobs
    /// (pow2 scales / int4 weights), so the fake-quant accuracy matches
    /// what the knob-carrying exporter will ship.
    pub fn quant_accuracy_with(
        &self,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        trained: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        self.exec.quant_accuracy(
            &self.view(),
            mode,
            knobs,
            stats,
            trained,
            val_images,
        )
    }

    /// §4.2 point-wise variant (mobilenet only; artifact backend).
    pub fn pointwise_accuracy(
        &self,
        stats: &CalibStats,
        pw: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        self.exec.pointwise_accuracy(&self.view(), stats, pw, val_images)
    }

    /// FAT threshold fine-tuning (RMSE distillation, unlabeled; default
    /// export knobs).
    pub fn finetune(
        &self,
        mode: QuantMode,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        self.finetune_with(mode, QuantKnobs::default(), stats, opts, progress)
    }

    /// [`SessionCore::finetune`] under explicit export knobs: the
    /// trainer's fake-quant student then snaps its scales / uses the
    /// int4 weight grid, so the thresholds adapt to the deployed
    /// numerics (log2-domain STE, DESIGN.md §13).
    pub fn finetune_with(
        &self,
        mode: QuantMode,
        knobs: QuantKnobs,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        mut progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        self.exec.finetune(&self.view(), mode, knobs, stats, opts, &mut progress)
    }

    /// §4.2 point-wise fine-tuning (artifact backend).
    pub fn finetune_pointwise(
        &self,
        stats: &CalibStats,
        opts: &FinetuneOpts,
        mut progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        self.exec.finetune_pointwise(&self.view(), stats, opts, &mut progress)
    }

    /// Inject per-filter range disparity (DESIGN.md §2 substitution for
    /// the disparity of real ImageNet checkpoints). Function-preserving.
    pub fn inject_spread(&mut self, seed: u64, span_log2: f32) -> Result<usize> {
        dws::inject_spread(&self.graph, &mut self.weights, seed, span_log2)
    }

    /// Apply §3.3 weight rescaling in place (before quantization).
    pub fn dws_rescale(
        &mut self,
        stats: &CalibStats,
    ) -> Result<Vec<PatternReport>> {
        let ch_max: BTreeMap<String, Vec<f32>> = stats
            .channel_minmax
            .iter()
            .map(|(k, v)| (k.clone(), v.iter().map(|mm| mm.max).collect()))
            .collect();
        dws::rescale_model(&self.graph, &mut self.weights, &ch_max)
    }

    /// Identity trainable map in the backend's key/shape convention.
    pub fn identity_trainables(
        &self,
        mode: QuantMode,
    ) -> Result<BTreeMap<String, Tensor>> {
        self.exec.identity_trainables(&self.view(), mode)
    }
}

// ---------------------------------------------------------------------
// Stage 0: QuantSession (opened)
// ---------------------------------------------------------------------

/// An opened quantization session (stage 0 of the dataflow): the model
/// is loaded and folded but not yet calibrated. The only way forward is
/// [`QuantSession::calibrate`].
pub struct QuantSession {
    core: Arc<SessionCore>,
}

impl QuantSession {
    /// Open `model` under `artifacts` (see [`SessionCore::open`]).
    pub fn open<P: AsRef<Path>>(
        reg: Arc<Registry>,
        artifacts: P,
        model: &str,
    ) -> Result<Self> {
        Ok(QuantSession { core: Arc::new(SessionCore::open(reg, artifacts, model)?) })
    }

    /// Open a native-only session from explicit parts (tests, custom
    /// graphs) — see [`SessionCore::from_parts`].
    pub fn from_parts(
        graph: GraphDef,
        sites: SitesJson,
        weights: BTreeMap<String, Tensor>,
    ) -> Self {
        QuantSession {
            core: Arc::new(SessionCore::from_parts(graph, sites, weights)),
        }
    }

    /// Shared state + primitives behind this session.
    pub fn core(&self) -> &SessionCore {
        &self.core
    }

    /// FP32 baseline accuracy (available at every stage).
    pub fn fp_accuracy(&self, val_images: usize) -> Result<f64> {
        self.core.fp_accuracy(val_images)
    }

    /// Inject per-filter range disparity before calibration
    /// (function-preserving; DESIGN.md §2). Returns the number of
    /// patterns touched.
    pub fn inject_spread(&mut self, seed: u64, span_log2: f32) -> Result<usize> {
        Arc::make_mut(&mut self.core).inject_spread(seed, span_log2)
    }

    /// Stage 1 transition: run the calibration pass. Non-consuming, so
    /// studies can calibrate one opened model several times (e.g. the
    /// calibration-set-size ablation).
    pub fn calibrate(&self, opts: CalibOpts) -> Result<Calibrated> {
        let stats = self.core.calibrate(opts.images)?;
        Ok(Calibrated {
            core: self.core.clone(),
            opts,
            stats,
            reports: vec![],
            refresh: true,
            hists: std::sync::OnceLock::new(),
        })
    }

    /// Stage 1 transition with externally supplied statistics (e.g.
    /// restored from a previous run's calibration). `opts` must describe
    /// how `stats` were produced: the percentile/KL histogram pass uses
    /// `opts.images`. Mutating stages ([`Calibrated::dws_rescale`]) skip
    /// the automatic re-calibration pass for such sessions, since the
    /// supplied stats cannot be regenerated faithfully here.
    pub fn assume_calibrated(
        &self,
        stats: CalibStats,
        opts: CalibOpts,
    ) -> Calibrated {
        Calibrated {
            core: self.core.clone(),
            opts,
            stats,
            reports: vec![],
            refresh: false,
            hists: std::sync::OnceLock::new(),
        }
    }
}

// ---------------------------------------------------------------------
// Stage 1: Calibrated
// ---------------------------------------------------------------------

/// A calibrated session (stage 1): per-site ranges are known. Optional
/// weight-mutating steps ([`Calibrated::dws_rescale`],
/// [`Calibrated::inject_spread`]) keep the stage; the threshold
/// transitions are [`Calibrated::finetune`] and [`Calibrated::identity`].
pub struct Calibrated {
    core: Arc<SessionCore>,
    opts: CalibOpts,
    stats: CalibStats,
    reports: Vec<PatternReport>,
    /// Whether the stats came from this session's own calibration pass.
    /// Externally supplied stats ([`QuantSession::assume_calibrated`])
    /// cannot be refreshed, so mutating stages skip re-calibration.
    refresh: bool,
    /// Per-site activation histograms, computed at most once per
    /// calibration (they depend only on `stats`/`opts`; the mutating
    /// stage transitions reset this cache along with `stats`).
    hists: std::sync::OnceLock<Vec<Vec<u32>>>,
}

impl Calibrated {
    /// Shared state + primitives behind this session.
    pub fn core(&self) -> &SessionCore {
        &self.core
    }

    /// Calibration statistics of this stage.
    pub fn stats(&self) -> &CalibStats {
        &self.stats
    }

    /// §3.3 rescale reports accumulated by [`Calibrated::dws_rescale`].
    pub fn rescale_reports(&self) -> &[PatternReport] {
        &self.reports
    }

    /// FP32 baseline accuracy.
    pub fn fp_accuracy(&self, val_images: usize) -> Result<f64> {
        self.core.fp_accuracy(val_images)
    }

    /// Apply §3.3 DWS→Conv mutual weight rescaling, then re-run the
    /// calibration pass (thresholds must be re-calibrated after weights
    /// move). Consumes the stage because it mutates the model.
    pub fn dws_rescale(mut self) -> Result<Calibrated> {
        let reports =
            Arc::make_mut(&mut self.core).dws_rescale(&self.stats)?;
        self.reports.extend(reports);
        if self.refresh {
            self.stats = self.core.calibrate(self.opts.images)?;
        }
        self.hists = std::sync::OnceLock::new(); // weights moved; recompute
        Ok(self)
    }

    /// Inject per-filter range disparity (DESIGN.md §2), then re-run the
    /// calibration pass. Prefer [`QuantSession::inject_spread`] (before
    /// the first calibration) when possible — it saves a pass.
    pub fn inject_spread(mut self, seed: u64, span_log2: f32) -> Result<Calibrated> {
        Arc::make_mut(&mut self.core).inject_spread(seed, span_log2)?;
        if self.refresh {
            self.stats = self.core.calibrate(self.opts.images)?;
        }
        self.hists = std::sync::OnceLock::new(); // weights moved; recompute
        Ok(self)
    }

    /// §4.2 point-wise weight fine-tuning (side path of the ladder; the
    /// main dataflow is [`Calibrated::finetune`]). Takes the spec so its
    /// static calibrator applies to these stats too, keeping the §4.2
    /// ladder rungs comparable under non-max calibrators.
    pub fn finetune_pointwise(
        &self,
        spec: &QuantSpec,
        opts: &FinetuneOpts,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<(BTreeMap<String, Tensor>, Vec<f32>)> {
        let stats = self.adjusted_stats(spec)?;
        self.core.finetune_pointwise(&stats, opts, progress)
    }

    /// Accuracy of the §4.2 point-wise fake-quant forward (same
    /// calibrator handling as [`Calibrated::finetune_pointwise`]).
    pub fn pointwise_accuracy(
        &self,
        spec: &QuantSpec,
        pw: &BTreeMap<String, Tensor>,
        val_images: usize,
    ) -> Result<f64> {
        let stats = self.adjusted_stats(spec)?;
        self.core.pointwise_accuracy(&stats, pw, val_images)
    }

    /// The activation histograms for this calibration, running the
    /// `calib_hist` artifact pass at most once per stage.
    fn hists(&self) -> Result<&[Vec<u32>]> {
        if self.hists.get().is_none() {
            let h = self
                .core
                .calibrate_hist(&self.stats, self.opts.images.max(1))?;
            let _ = self.hists.set(h); // racing setters computed equal data
        }
        Ok(self.hists.get().expect("histogram cache just filled").as_slice())
    }

    /// Calibration statistics with the spec's static calibrator applied
    /// (no-op for [`Calibrator::Max`]).
    fn adjusted_stats(&self, spec: &QuantSpec) -> Result<CalibStats> {
        let mut stats = self.stats.clone();
        if spec.calibrator != Calibrator::Max {
            stats.apply_calibrator(spec.calibrator, self.hists()?)?;
        }
        Ok(stats)
    }

    /// Stage 2 transition: FAT fine-tuning of the threshold scales
    /// (RMSE distillation on unlabeled data, Adam + cosine annealing
    /// with optimizer reset). Non-consuming so one calibration can feed
    /// several specs (e.g. the Tables 1–2 mode grid).
    pub fn finetune(
        &self,
        spec: &QuantSpec,
        opts: &FinetuneOpts,
        progress: impl FnMut(usize, f32, f32),
    ) -> Result<Thresholded> {
        let mode = spec.mode();
        let stats = self.adjusted_stats(spec)?;
        let (tr, losses) =
            self.core.finetune_with(mode, spec.knobs(), &stats, opts, progress)?;
        let thresholds = ThresholdSet::from_trainables(
            &self.core.graph,
            mode,
            self.core.sites.sites.len(),
            &tr,
        )?;
        Ok(Thresholded {
            core: self.core.clone(),
            spec: *spec,
            stats,
            thresholds,
            trainables: Some(tr),
            identity_tr: std::sync::OnceLock::new(),
            losses,
        })
    }

    /// Stage 2 transition without fine-tuning: identity thresholds
    /// (α = 1), i.e. pure calibration-based quantization.
    pub fn identity(&self, spec: &QuantSpec) -> Result<Thresholded> {
        let stats = self.adjusted_stats(spec)?;
        let thresholds = ThresholdSet::identity(
            &self.core.graph,
            spec.mode(),
            self.core.sites.sites.len(),
        );
        Ok(Thresholded {
            core: self.core.clone(),
            spec: *spec,
            stats,
            thresholds,
            trainables: None,
            identity_tr: std::sync::OnceLock::new(),
            losses: vec![],
        })
    }
}

// ---------------------------------------------------------------------
// Stage 2: Thresholded
// ---------------------------------------------------------------------

/// A session with final thresholds (stage 2): ready to evaluate the
/// fake-quant forward and to export the integer-only model.
pub struct Thresholded {
    core: Arc<SessionCore>,
    spec: QuantSpec,
    stats: CalibStats,
    thresholds: ThresholdSet,
    /// Trainable map as returned by the fine-tune artifact (absent for
    /// identity thresholds — synthesized from the manifest on first use
    /// and cached in `identity_tr`).
    trainables: Option<BTreeMap<String, Tensor>>,
    identity_tr: std::sync::OnceLock<BTreeMap<String, Tensor>>,
    losses: Vec<f32>,
}

impl Thresholded {
    /// Shared state + primitives behind this session.
    pub fn core(&self) -> &SessionCore {
        &self.core
    }

    /// The spec these thresholds were produced under.
    pub fn spec(&self) -> &QuantSpec {
        &self.spec
    }

    /// Calibrator-adjusted calibration statistics.
    pub fn stats(&self) -> &CalibStats {
        &self.stats
    }

    /// The typed threshold set.
    pub fn thresholds(&self) -> &ThresholdSet {
        &self.thresholds
    }

    /// Per-step fine-tune losses (empty for identity thresholds).
    pub fn losses(&self) -> &[f32] {
        &self.losses
    }

    /// The trainable map backing the fake-quant artifact: the fine-tuned
    /// map, or (for identity thresholds) a manifest-shaped identity map
    /// built once and cached.
    fn trainable_map(&self) -> Result<&BTreeMap<String, Tensor>> {
        if let Some(tr) = &self.trainables {
            return Ok(tr);
        }
        if self.identity_tr.get().is_none() {
            let tr = self.core.identity_trainables(self.spec.mode())?;
            let _ = self.identity_tr.set(tr); // racing setters built equal maps
        }
        Ok(self.identity_tr.get().expect("identity map just filled"))
    }

    /// Accuracy of the fake-quant forward under these thresholds (runs
    /// through the AOT `quant_fwd_*` artifact).
    pub fn quant_accuracy(&self, val_images: usize) -> Result<f64> {
        let tr = self.trainable_map()?;
        self.core.quant_accuracy_with(
            self.spec.mode(),
            self.spec.knobs(),
            &self.stats,
            tr,
            val_images,
        )
    }

    /// Stage 3 transition: build the integer-only deployment model.
    /// This compiles the engine's execution plan once (`int8::plan`).
    pub fn export(&self) -> Result<QModel> {
        export_with(
            &self.core.graph,
            &self.core.weights,
            &self.core.sites,
            &self.stats,
            &self.spec,
            &self.thresholds,
        )
    }

    /// Stage 3 transition straight to a serving handle: export the
    /// integer-only model and wrap it in an [`Int8Engine`].
    /// `opts.batch` turns on the dynamic micro-batching scheduler
    /// (DESIGN.md §9); the default options keep it off and preserve the
    /// pre-batching serving behavior.
    pub fn serve(&self, opts: EngineOptions) -> Result<Int8Engine> {
        Ok(Int8Engine::new(self.export()?, opts))
    }

    /// [`Thresholded::serve`] with micro-batching on: concurrent
    /// `infer` / `infer_batch` calls coalesce into micro-batches of up
    /// to `max_batch` rows, assembled for at most `max_wait_us`
    /// microseconds — bit-exact with the unbatched path.
    pub fn serve_batched(
        &self,
        max_batch: usize,
        max_wait_us: u64,
    ) -> Result<Int8Engine> {
        self.serve(
            EngineOptions::default()
                .with_batch(BatchOptions { max_batch, max_wait_us }),
        )
    }

    /// Stage 3 transition straight to a live socket server: export,
    /// wrap in an [`Int8Engine`] per `opts`, register it under the
    /// graph's name and bind `addr` (`crate::net`, DESIGN.md §10). The
    /// returned server is already accepting; route further models
    /// through [`crate::net::ModelRegistry::insert`] on its registry,
    /// and stop it with [`crate::net::Server::drain`].
    pub fn serve_http(
        &self,
        addr: &str,
        opts: EngineOptions,
        server: crate::net::server::ServerOptions,
    ) -> Result<crate::net::Server> {
        let registry = crate::net::ModelRegistry::new();
        registry.insert(&self.core.graph.name, self.serve(opts)?);
        crate::net::Server::bind(addr, registry, server)
    }
}

/// Build a quantized model from explicit parts — the one path into
/// [`export::build_qmodel_with`], carrying the spec's export knobs
/// (pow2 scales / int4 weights). The threshold set's mode must match
/// the spec (a [`ThresholdSet`] built for another mode is a hard error,
/// not a silent reinterpretation).
pub fn export_with(
    g: &GraphDef,
    weights: &BTreeMap<String, Tensor>,
    sites: &SitesJson,
    stats: &CalibStats,
    spec: &QuantSpec,
    thresholds: &ThresholdSet,
) -> Result<QModel> {
    anyhow::ensure!(
        thresholds.mode() == spec.mode(),
        "threshold set was built for {:?} but the spec requests {:?}",
        thresholds.mode(),
        spec.mode()
    );
    export::build_qmodel_with(
        g,
        weights,
        sites,
        stats,
        spec.mode(),
        thresholds.trained(),
        spec.knobs(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> GraphDef {
        GraphDef::from_json(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[8,8,3]},
             {"id":"c","op":"conv","inputs":["input"],"k":1,"stride":1,"cin":3,"cout":4,"bias":true},
             {"id":"g","op":"gap","inputs":["c"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":4,"cout":2,"bias":true}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn spec_mode_roundtrip() {
        for mode in QuantMode::all() {
            assert_eq!(QuantSpec::from_mode(mode).mode(), mode);
        }
        let d = QuantSpec::default();
        assert_eq!(d.mode(), QuantMode::SymScalar);
        assert_eq!(d.calibrator, Calibrator::Max);
    }

    #[test]
    fn spec_parse() {
        let s = QuantSpec::parse("asym_vector", "p9999").unwrap();
        assert_eq!(s.mode(), QuantMode::AsymVector);
        assert_eq!(s.calibrator, Calibrator::Percentile(9999));
        assert!(!s.pow2);
        assert_eq!(s.w_bits, 8);
        assert!(QuantSpec::parse("nope", "max").is_err());
        assert!(QuantSpec::parse("sym_scalar", "nope").is_err());
    }

    #[test]
    fn spec_parse_knob_suffixes() {
        let s = QuantSpec::parse("sym_vector_pow2", "max").unwrap();
        assert_eq!(s.mode(), QuantMode::SymVector);
        assert!(s.pow2);
        assert_eq!(s.w_bits, 8);

        let s = QuantSpec::parse("sym_scalar_w4", "max").unwrap();
        assert!(!s.pow2);
        assert_eq!(s.w_bits, 4);

        // the suffix tokens commute
        for m in ["asym_scalar_pow2_w4", "asym_scalar_w4_pow2"] {
            let s = QuantSpec::parse(m, "max").unwrap();
            assert_eq!(s.mode(), QuantMode::AsymScalar, "{m}");
            assert!(s.pow2, "{m}");
            assert_eq!(s.w_bits, 4, "{m}");
            assert_eq!(
                s.knobs(),
                export::QuantKnobs { pow2: true, w_bits: 4 },
                "{m}"
            );
        }

        // duplicates and a bare suffix are hard errors
        assert!(QuantSpec::parse("sym_scalar_pow2_pow2", "max").is_err());
        assert!(QuantSpec::parse("sym_scalar_w4_w4", "max").is_err());
        assert!(QuantSpec::parse("_pow2", "max").is_err());
    }

    #[test]
    fn threshold_set_accepts_known_keys() {
        let g = tiny_graph();
        let mut m = BTreeMap::new();
        m.insert("act_a".to_string(), Tensor::f32(vec![3], vec![0.9; 3]));
        m.insert("w_a:c".to_string(), Tensor::f32(vec![1], vec![1.1]));
        let ts = ThresholdSet::from_trainables(&g, QuantMode::SymScalar, 3, &m)
            .unwrap();
        assert_eq!(ts.trained().act_a, vec![0.9; 3]);
        assert_eq!(ts.trained().w_a["c"], vec![1.1]);
        // untouched entries keep identity defaults
        assert_eq!(ts.trained().w_a["d"], vec![1.0]);
    }

    #[test]
    fn threshold_set_rejects_unknown_keys() {
        let g = tiny_graph();
        let mut m = BTreeMap::new();
        m.insert("act_alpha".to_string(), Tensor::f32(vec![3], vec![1.0; 3]));
        let err = ThresholdSet::from_trainables(&g, QuantMode::SymScalar, 3, &m)
            .unwrap_err();
        assert!(err.to_string().contains("unknown trainable key"));

        let mut m = BTreeMap::new();
        m.insert("w_a:nope".to_string(), Tensor::f32(vec![1], vec![1.0]));
        assert!(
            ThresholdSet::from_trainables(&g, QuantMode::SymScalar, 3, &m)
                .is_err()
        );
    }

    #[test]
    fn threshold_set_rejects_shape_mismatch() {
        let g = tiny_graph();
        let mut m = BTreeMap::new();
        m.insert("act_a".to_string(), Tensor::f32(vec![2], vec![1.0; 2]));
        assert!(
            ThresholdSet::from_trainables(&g, QuantMode::SymScalar, 3, &m)
                .is_err()
        );
        // vector mode expects cout=4 scales for conv `c`
        let mut m = BTreeMap::new();
        m.insert("w_a:c".to_string(), Tensor::f32(vec![1], vec![1.0]));
        assert!(
            ThresholdSet::from_trainables(&g, QuantMode::SymVector, 3, &m)
                .is_err()
        );
    }

    #[test]
    fn export_with_rejects_mode_mismatch() {
        let g = tiny_graph();
        let ts = ThresholdSet::identity(&g, QuantMode::SymScalar, 3);
        let spec = QuantSpec::from_mode(QuantMode::SymVector);
        let sites = SitesJson {
            sites: vec![],
            channel_stats: vec![],
            weight_order: vec![],
            val_acc_fp_pretrain: -1.0,
        };
        let err = export_with(
            &g,
            &BTreeMap::new(),
            &sites,
            &CalibStats::new(0),
            &spec,
            &ts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("spec requests"));
    }
}
