//! Batch-norm folding (paper eq. 10-11), operating on the raw graph +
//! weights loaded from `raw.fatw`. Mirrors `python/compile/graph.fold_bn`
//! and is golden-tested against `folded.fatw`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::{GraphDef, Op};
use crate::tensor::Tensor;

/// BN epsilon — must match `python/compile/graph.EPS`.
pub const EPS: f32 = 1e-3;

/// Fold every conv/dwconv→bn pair: `W' = γW/√(σ²+ε)`, `b' = β − γμ/√(σ²+ε)`.
///
/// Returns the folded weight map keyed like the folded graph expects
/// (`<node>.w` / `<node>.b` for every conv-like node). The folded *graph*
/// itself ships as `folded.json`; this reproduces the weights.
pub fn fold_bn(
    g: &GraphDef,
    params: &BTreeMap<String, Tensor>,
) -> Result<BTreeMap<String, Tensor>> {
    // map conv node id -> bn node id
    let mut bn_after: BTreeMap<&str, &str> = BTreeMap::new();
    for n in &g.nodes {
        if n.op == Op::Bn {
            let src = g.node(&n.inputs[0])?;
            if !matches!(src.op, Op::Conv | Op::DwConv) {
                anyhow::bail!("bn after {:?} unsupported", src.op);
            }
            bn_after.insert(src.id.as_str(), n.id.as_str());
        }
    }

    let mut out = BTreeMap::new();
    for n in &g.nodes {
        if !n.op.is_conv_like() {
            continue;
        }
        let w = params
            .get(&format!("{}.w", n.id))
            .ok_or_else(|| anyhow::anyhow!("missing {}.w", n.id))?;
        let cout = n.out_channels();
        if let Some(bn) = bn_after.get(n.id.as_str()) {
            let gamma = params[&format!("{bn}.gamma")].as_f32()?;
            let beta = params[&format!("{bn}.beta")].as_f32()?;
            let mean = params[&format!("{bn}.mean")].as_f32()?;
            let var = params[&format!("{bn}.var")].as_f32()?;
            // scale over the last (output-channel) axis
            let wsrc = w.as_f32()?;
            let mut wf = vec![0f32; wsrc.len()];
            for (i, &v) in wsrc.iter().enumerate() {
                let c = i % cout;
                let scale = gamma[c] / (var[c] + EPS).sqrt();
                wf[i] = v * scale;
            }
            let mut bf = vec![0f32; cout];
            for c in 0..cout {
                bf[c] = beta[c] - gamma[c] * mean[c] / (var[c] + EPS).sqrt();
            }
            out.insert(format!("{}.w", n.id), Tensor::f32(w.shape.clone(), wf));
            out.insert(format!("{}.b", n.id), Tensor::f32(vec![cout], bf));
        } else {
            out.insert(format!("{}.w", n.id), w.clone());
            let bias = params
                .get(&format!("{}.b", n.id))
                .cloned()
                .unwrap_or_else(|| Tensor::zeros_f32(vec![cout]));
            out.insert(format!("{}.b", n.id), bias);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GraphDef;

    fn tiny_graph() -> GraphDef {
        GraphDef::from_json(
            r#"{"name":"t","num_classes":2,"nodes":[
             {"id":"input","op":"input","inputs":[],"shape":[4,4,1]},
             {"id":"c","op":"conv","inputs":["input"],"k":1,"stride":1,"cin":1,"cout":2},
             {"id":"c_bn","op":"bn","inputs":["c"],"ch":2},
             {"id":"r","op":"relu","inputs":["c_bn"]},
             {"id":"g","op":"gap","inputs":["r"]},
             {"id":"d","op":"dense","inputs":["g"],"cin":2,"cout":2}
            ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn fold_formula() {
        let g = tiny_graph();
        let mut p = BTreeMap::new();
        p.insert(
            "c.w".into(),
            Tensor::f32(vec![1, 1, 1, 2], vec![1.0, 2.0]),
        );
        p.insert("c_bn.gamma".into(), Tensor::f32(vec![2], vec![2.0, 0.5]));
        p.insert("c_bn.beta".into(), Tensor::f32(vec![2], vec![0.1, -0.1]));
        p.insert("c_bn.mean".into(), Tensor::f32(vec![2], vec![1.0, -1.0]));
        p.insert("c_bn.var".into(), Tensor::f32(vec![2], vec![4.0, 1.0]));
        p.insert("d.w".into(), Tensor::f32(vec![2, 2], vec![1.0; 4]));
        let f = fold_bn(&g, &p).unwrap();
        let w = f["c.w"].as_f32().unwrap();
        let s0 = 2.0 / (4.0f32 + EPS).sqrt();
        let s1 = 0.5 / (1.0f32 + EPS).sqrt();
        assert!((w[0] - 1.0 * s0).abs() < 1e-6);
        assert!((w[1] - 2.0 * s1).abs() < 1e-6);
        let b = f["c.b"].as_f32().unwrap();
        assert!((b[0] - (0.1 - 2.0 * 1.0 / (4.0f32 + EPS).sqrt())).abs() < 1e-6);
        assert!((b[1] - (-0.1 - 0.5 * -1.0 / (1.0f32 + EPS).sqrt())).abs() < 1e-6);
        // dense without bn gets a zero bias
        assert_eq!(f["d.b"].as_f32().unwrap(), &[0.0, 0.0]);
    }

    #[test]
    fn fold_covers_all_conv_like() {
        let g = tiny_graph();
        let mut p = BTreeMap::new();
        p.insert("c.w".into(), Tensor::f32(vec![1, 1, 1, 2], vec![1.0, 2.0]));
        p.insert("c_bn.gamma".into(), Tensor::ones_f32(vec![2]));
        p.insert("c_bn.beta".into(), Tensor::zeros_f32(vec![2]));
        p.insert("c_bn.mean".into(), Tensor::zeros_f32(vec![2]));
        p.insert("c_bn.var".into(), Tensor::ones_f32(vec![2]));
        p.insert("d.w".into(), Tensor::f32(vec![2, 2], vec![1.0; 4]));
        let f = fold_bn(&g, &p).unwrap();
        for key in ["c.w", "c.b", "d.w", "d.b"] {
            assert!(f.contains_key(key), "{key}");
        }
    }
}
