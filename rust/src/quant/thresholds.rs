//! Threshold adjustment (paper §3.1.3-3.1.4): the Rust mirror of
//! `python/compile/quantize.py`'s `adjust_sym` / `adjust_asym`, used when
//! exporting the fine-tuned thresholds into int8 engine parameters.

/// Empiric clip ranges (paper).
pub const ALPHA_MIN: f32 = 0.5;
pub const ALPHA_MAX: f32 = 1.0;
pub const AT_MIN_SIGNED: f32 = -0.2;
pub const AT_MIN_UNSIGNED: f32 = 0.0;
pub const AT_MAX: f32 = 0.4;
pub const AR_MIN: f32 = 0.5;
pub const AR_MAX: f32 = 1.0;

/// Symmetric: `T_adj = clip(α, 0.5, 1.0) · T_cal` (eq. 12-13).
#[inline]
pub fn adjust_sym(alpha: f32, t_cal: f32) -> f32 {
    alpha.clamp(ALPHA_MIN, ALPHA_MAX) * t_cal
}

/// Asymmetric (eq. 21-23): returns (left, width) of the adjusted range.
#[inline]
pub fn adjust_asym(
    alpha_t: f32,
    alpha_r: f32,
    t_l: f32,
    t_r: f32,
    unsigned: bool,
) -> (f32, f32) {
    let at_min = if unsigned { AT_MIN_UNSIGNED } else { AT_MIN_SIGNED };
    let r = t_r - t_l;
    let left = t_l + alpha_t.clamp(at_min, AT_MAX) * r;
    let width = alpha_r.clamp(AR_MIN, AR_MAX) * r;
    (left, width.max(1e-8))
}

/// Symmetric calibration threshold from a (min, max) pair.
#[inline]
pub fn sym_t_from_minmax(t_l: f32, t_r: f32) -> f32 {
    t_l.abs().max(t_r.abs()).max(1e-8)
}

/// Per-filter weight thresholds (max |w| over all but the last axis).
pub fn per_channel_w_thresholds(w: &[f32], cout: usize) -> Vec<f32> {
    let mut t = vec![0f32; cout];
    for (i, &v) in w.iter().enumerate() {
        let c = i % cout;
        t[c] = t[c].max(v.abs());
    }
    for v in &mut t {
        *v = v.max(1e-8);
    }
    t
}

/// Per-tensor weight threshold (eq. 2).
pub fn per_tensor_w_threshold(w: &[f32]) -> f32 {
    w.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sym_clip() {
        assert_eq!(adjust_sym(0.2, 10.0), 5.0);
        assert_eq!(adjust_sym(2.0, 10.0), 10.0);
        assert!((adjust_sym(0.75, 10.0) - 7.5).abs() < 1e-6);
    }

    #[test]
    fn asym_empirics() {
        let (l, w) = adjust_asym(-1.0, 1.0, -2.0, 6.0, false);
        assert!((l - (-2.0 + (-0.2) * 8.0)).abs() < 1e-5);
        assert_eq!(w, 8.0);
        let (l, w) = adjust_asym(-1.0, 0.1, -2.0, 6.0, true);
        assert_eq!(l, -2.0);
        assert_eq!(w, 4.0);
    }

    #[test]
    fn weight_thresholds() {
        let w = vec![0.5, -2.0, 1.0, 0.25]; // 2 channels interleaved
        assert_eq!(per_tensor_w_threshold(&w), 2.0);
        assert_eq!(per_channel_w_thresholds(&w, 2), vec![1.0, 2.0]);
    }

    #[test]
    fn sym_from_minmax() {
        assert_eq!(sym_t_from_minmax(-3.0, 1.0), 3.0);
        assert_eq!(sym_t_from_minmax(0.0, 2.5), 2.5);
    }
}
