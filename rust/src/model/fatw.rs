//! FATW named-tensor container (mirror of `python/compile/fatw.py`).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{Data, Tensor};

const MAGIC: &[u8; 8] = b"FATW0001";

/// Read all tensors from a `.fatw` file.
pub fn read_fatw<P: AsRef<Path>>(path: P) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    parse(&bytes)
}

fn parse(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut cur = std::io::Cursor::new(bytes);
    let mut magic = [0u8; 8];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad FATW magic");
    }
    let count = read_u32(&mut cur)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u32(&mut cur)? as usize;
        let mut name = vec![0u8; nlen];
        cur.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        cur.read_exact(&mut hdr)?;
        let (dt, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut cur)? as usize);
        }
        let n: usize = shape.iter().product();
        let data = match dt {
            0 => {
                let mut buf = vec![0u8; n * 4];
                cur.read_exact(&mut buf)?;
                Data::F32(
                    buf.chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
            1 => {
                let mut buf = vec![0u8; n];
                cur.read_exact(&mut buf)?;
                Data::I8(buf.into_iter().map(|b| b as i8).collect())
            }
            2 => {
                let mut buf = vec![0u8; n * 4];
                cur.read_exact(&mut buf)?;
                Data::I32(
                    buf.chunks_exact(4)
                        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
            3 => {
                let mut buf = vec![0u8; n];
                cur.read_exact(&mut buf)?;
                Data::U8(buf)
            }
            other => bail!("unknown dtype tag {other}"),
        };
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

/// Write tensors to a `.fatw` file (sorted by name for determinism).
pub fn write_fatw<P: AsRef<Path>>(
    path: P,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let dt = match t.data {
            Data::F32(_) => 0u8,
            Data::I8(_) => 1,
            Data::I32(_) => 2,
            Data::U8(_) => 3,
        };
        f.write_all(&[dt, t.shape.len() as u8])?;
        for d in &t.shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        f.write_all(t.raw_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert(
            "a.w".to_string(),
            Tensor::f32(vec![2, 2], vec![1.0, -2.5, 3.25, 0.0]),
        );
        m.insert("b".to_string(), Tensor::i32(vec![3], vec![1, -7, 42]));
        m.insert("c".to_string(), Tensor::i8(vec![2], vec![-128, 127]));
        let dir = std::env::temp_dir().join("fatw_test.fatw");
        write_fatw(&dir, &m).unwrap();
        let back = read_fatw(&dir).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("fatw_bad.fatw");
        std::fs::write(&p, b"NOTMAGIC....").unwrap();
        assert!(read_fatw(&p).is_err());
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("s".to_string(), Tensor::f32(vec![], vec![3.5]));
        let p = std::env::temp_dir().join("fatw_scalar.fatw");
        write_fatw(&p, &m).unwrap();
        let back = read_fatw(&p).unwrap();
        assert_eq!(back["s"].shape, Vec::<usize>::new());
        assert_eq!(back["s"].as_f32().unwrap(), &[3.5]);
    }
}
