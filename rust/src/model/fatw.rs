//! FATW named-tensor container (mirror of `python/compile/fatw.py`).
//!
//! The reader is hardened against truncated and corrupt files: it is
//! built on the length-checked cursor of `crate::artifact::layout`, so
//! every count, name length and shape product is validated against the
//! remaining input *before* any allocation, and hostile headers (huge
//! declared counts, overflowing shape products) fail with a contextual
//! error instead of a panic or an OOM.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::artifact::layout::Reader;
use crate::tensor::{Data, Tensor};

const MAGIC: &[u8; 8] = b"FATW0001";

/// Read all tensors from a `.fatw` file.
pub fn read_fatw<P: AsRef<Path>>(path: P) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    parse(&bytes).with_context(|| format!("parsing {:?}", path.as_ref()))
}

fn parse(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut r = Reader::new(bytes, "fatw");
    let magic = r.bytes(MAGIC.len()).context("magic")?;
    ensure!(magic == MAGIC, "bad FATW magic");
    let count = r.u32()?;
    let mut out = BTreeMap::new();
    for i in 0..count {
        let name = r
            .string()
            .with_context(|| format!("tensor {i}/{count}: name"))?;
        let dt = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| {
                anyhow::anyhow!("tensor {name}: shape product overflows")
            })?;
        let elem = match dt {
            0 | 2 => 4usize,
            1 | 3 => 1,
            other => bail!("tensor {name}: unknown dtype tag {other}"),
        };
        let nbytes = n.checked_mul(elem).ok_or_else(|| {
            anyhow::anyhow!("tensor {name}: byte length overflows")
        })?;
        // bytes() bounds-checks against the remaining input, so the
        // element collect below never allocates more than the file holds.
        let raw = r
            .bytes(nbytes)
            .with_context(|| format!("tensor {name}: payload"))?;
        let data = match dt {
            0 => Data::F32(
                raw.chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
            ),
            1 => Data::I8(raw.iter().map(|&b| b as i8).collect()),
            2 => Data::I32(
                raw.chunks_exact(4)
                    .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
            ),
            3 => Data::U8(raw.to_vec()),
            _ => unreachable!("dtype validated above"),
        };
        out.insert(name, Tensor { shape, data });
    }
    ensure!(
        r.exhausted(),
        "{} trailing bytes after {count} tensors",
        r.remaining()
    );
    Ok(out)
}

/// Write tensors to a `.fatw` file (sorted by name for determinism).
pub fn write_fatw<P: AsRef<Path>>(
    path: P,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let dt = match t.data {
            Data::F32(_) => 0u8,
            Data::I8(_) => 1,
            Data::I32(_) => 2,
            Data::U8(_) => 3,
        };
        f.write_all(&[dt, t.shape.len() as u8])?;
        for d in &t.shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        f.write_all(t.raw_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert(
            "a.w".to_string(),
            Tensor::f32(vec![2, 2], vec![1.0, -2.5, 3.25, 0.0]),
        );
        m.insert("b".to_string(), Tensor::i32(vec![3], vec![1, -7, 42]));
        m.insert("c".to_string(), Tensor::i8(vec![2], vec![-128, 127]));
        m
    }

    fn sample_bytes() -> Vec<u8> {
        let p = std::env::temp_dir().join("fatw_bytes.fatw");
        write_fatw(&p, &sample()).unwrap();
        std::fs::read(&p).unwrap()
    }

    #[test]
    fn roundtrip() {
        let m = sample();
        let dir = std::env::temp_dir().join("fatw_test.fatw");
        write_fatw(&dir, &m).unwrap();
        let back = read_fatw(&dir).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("fatw_bad.fatw");
        std::fs::write(&p, b"NOTMAGIC....").unwrap();
        assert!(read_fatw(&p).is_err());
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("s".to_string(), Tensor::f32(vec![], vec![3.5]));
        let p = std::env::temp_dir().join("fatw_scalar.fatw");
        write_fatw(&p, &m).unwrap();
        let back = read_fatw(&p).unwrap();
        assert_eq!(back["s"].shape, Vec::<usize>::new());
        assert_eq!(back["s"].as_f32().unwrap(), &[3.5]);
    }

    #[test]
    fn every_truncated_prefix_errors() {
        let bytes = sample_bytes();
        for cut in 0..bytes.len() {
            assert!(parse(&bytes[..cut]).is_err(), "prefix {cut} parsed");
        }
        assert!(parse(&bytes).is_ok());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = sample_bytes();
        bytes.push(0);
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn hostile_count_errors_cleanly() {
        // header claims u32::MAX tensors with an empty body
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn hostile_name_length_errors_before_allocating() {
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // name "length"
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn shape_product_overflow_errors() {
        // one tensor, empty name, f32, 4 dims of u32::MAX each: the
        // element count (and byte length) overflow usize
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes()); // name len 0
        bytes.push(0); // dtype f32
        bytes.push(4); // ndim
        for _ in 0..4 {
            bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let err = parse(&bytes).unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
    }

    #[test]
    fn declared_payload_beyond_file_errors() {
        // a (1000, 1000) f32 tensor with no payload must not allocate
        // 4 MB or panic — it must fail the length check
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'x');
        bytes.push(0); // f32
        bytes.push(2); // ndim
        bytes.extend_from_slice(&1000u32.to_le_bytes());
        bytes.extend_from_slice(&1000u32.to_le_bytes());
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn unknown_dtype_rejected() {
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'x');
        bytes.push(9); // bogus dtype
        bytes.push(0); // ndim
        assert!(parse(&bytes).is_err());
    }
}
