//! Artifact manifests: the marshalling contract between the AOT HLO
//! executables and the Rust runtime (`<artifact>.manifest.json`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::tensor::DType;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn dtype(&self) -> Result<DType> {
        DType::from_str(&self.dtype)
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

fn specs(j: &Json, key: &str) -> Result<Vec<IoSpec>> {
    j.req(key)?
        .as_arr()?
        .iter()
        .map(|s| {
            Ok(IoSpec {
                name: s.req("name")?.as_str()?.to_string(),
                shape: s
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: s.req("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl ArtifactManifest {
    pub fn from_json(s: &str) -> Result<Self> {
        let j = Json::parse(s)?;
        Ok(ArtifactManifest {
            name: j.req("name")?.as_str()?.to_string(),
            inputs: specs(&j, "inputs")?,
            outputs: specs(&j, "outputs")?,
        })
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let s = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json(&s)
    }

    /// Index of an input by its manifest name.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("{}: no input {name}", self.name))
    }

    /// Indices of inputs whose name starts with `prefix`, in manifest order.
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("{}: no output {name}", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_lookup() {
        let m = ArtifactManifest::from_json(
            r#"{"name":"t","inputs":[
                {"name":"0/a.w","shape":[2,3],"dtype":"f32"},
                {"name":"1","shape":[],"dtype":"f32"}],
               "outputs":[{"name":"0","shape":[4],"dtype":"i32"}]}"#,
        )
        .unwrap();
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.input_index("1").unwrap(), 1);
        assert_eq!(m.inputs_with_prefix("0/"), vec![0]);
        assert_eq!(m.inputs[0].elems(), 6);
        assert_eq!(m.outputs[0].dtype().unwrap(), DType::I32);
        assert!(m.input_index("nope").is_err());
    }
}
