//! Model substrate: weight containers, graph IR, artifact manifests,
//! the on-disk model directory produced by `make artifacts`, and the
//! builtin zoo used when no artifacts exist.

pub mod builtin;
pub mod fatw;
pub mod graphdef;
pub mod manifest;
pub mod store;

pub use fatw::{read_fatw, write_fatw};
pub use graphdef::{GraphDef, Node, Op};
pub use manifest::{ArtifactManifest, IoSpec};
pub use store::ModelStore;
