//! Graph IR mirror of `python/compile/graph.py` (`graph.json` /
//! `folded.json`). Interpreted by the quant substrate (BN fold, §3.3
//! rescale) and the int8 engine.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Input,
    Conv,
    DwConv,
    Dense,
    Bn,
    Relu,
    Relu6,
    Add,
    Gap,
}

impl Op {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "input" => Op::Input,
            "conv" => Op::Conv,
            "dwconv" => Op::DwConv,
            "dense" => Op::Dense,
            "bn" => Op::Bn,
            "relu" => Op::Relu,
            "relu6" => Op::Relu6,
            "add" => Op::Add,
            "gap" => Op::Gap,
            other => bail!("unknown op {other}"),
        })
    }

    pub fn is_conv_like(self) -> bool {
        matches!(self, Op::Conv | Op::DwConv | Op::Dense)
    }

    /// Canonical wire name — the inverse of [`Op::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv => "conv",
            Op::DwConv => "dwconv",
            Op::Dense => "dense",
            Op::Bn => "bn",
            Op::Relu => "relu",
            Op::Relu6 => "relu6",
            Op::Add => "add",
            Op::Gap => "gap",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
    pub ch: usize,
    pub bias: bool,
    pub input_shape: Option<Vec<usize>>,
}

impl Node {
    /// Output channel count of a conv-like node.
    pub fn out_channels(&self) -> usize {
        match self.op {
            Op::Conv | Op::Dense => self.cout,
            Op::DwConv => self.ch,
            _ => 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GraphDef {
    pub name: String,
    pub num_classes: usize,
    pub nodes: Vec<Node>,
    index: HashMap<String, usize>,
}

impl GraphDef {
    pub fn from_json(json: &str) -> Result<Self> {
        let j = Json::parse(json)?;
        let name = j.req("name")?.as_str()?.to_string();
        let num_classes = j.usize_or("num_classes", 10);
        let nodes: Vec<Node> = j
            .req("nodes")?
            .as_arr()?
            .iter()
            .map(|n| {
                let inputs = n
                    .req("inputs")?
                    .as_arr()?
                    .iter()
                    .map(|i| Ok(i.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?;
                let input_shape = match n.get("shape") {
                    Some(s) => Some(
                        s.as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                    ),
                    None => None,
                };
                Ok(Node {
                    op: Op::parse(n.req("op")?.as_str()?)?,
                    id: n.req("id")?.as_str()?.to_string(),
                    inputs,
                    k: n.usize_or("k", 0),
                    stride: n.usize_or("stride", 0),
                    cin: n.usize_or("cin", 0),
                    cout: n.usize_or("cout", 0),
                    ch: n.usize_or("ch", 0),
                    bias: n.bool_or("bias", false),
                    input_shape,
                })
            })
            .collect::<Result<_>>()?;
        let index = nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.id.clone(), i))
            .collect();
        Ok(GraphDef { name, num_classes, nodes, index })
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let s = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json(&s)
    }

    /// Serialize back to the `graph.json` wire form — the inverse of
    /// [`GraphDef::from_json`] (the `.fatm` artifact stores the graph
    /// this way; see `crate::artifact`). Every field `from_json` reads
    /// is emitted, so parse(serialize(g)) reproduces `g` exactly.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = String::with_capacity(64 + 96 * self.nodes.len());
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"num_classes\":{},\"nodes\":[",
            esc(&self.name),
            self.num_classes
        );
        for (i, n) in self.nodes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"id\":\"{}\",\"op\":\"{}\",\"inputs\":[",
                esc(&n.id),
                n.op.name()
            );
            for (j, inp) in n.inputs.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(out, "\"{}\"", esc(inp));
            }
            let _ = write!(
                out,
                "],\"k\":{},\"stride\":{},\"cin\":{},\"cout\":{},\
                 \"ch\":{},\"bias\":{}",
                n.k, n.stride, n.cin, n.cout, n.ch, n.bias
            );
            if let Some(sh) = &n.input_shape {
                out.push_str(",\"shape\":[");
                for (j, d) in sh.iter().enumerate() {
                    if j > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "{d}");
                }
                out.push(']');
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    pub fn node(&self, id: &str) -> Result<&Node> {
        self.index
            .get(id)
            .map(|&i| &self.nodes[i])
            .ok_or_else(|| anyhow::anyhow!("no node {id}"))
    }

    /// Consumers of each node output.
    pub fn consumers(&self) -> HashMap<&str, Vec<&Node>> {
        let mut out: HashMap<&str, Vec<&Node>> =
            self.nodes.iter().map(|n| (n.id.as_str(), vec![])).collect();
        for n in &self.nodes {
            for i in &n.inputs {
                out.get_mut(i.as_str()).unwrap().push(n);
            }
        }
        out
    }

    pub fn conv_like(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.op.is_conv_like())
    }

    /// Canonical folded-weight marshalling order (w then b per conv-like
    /// node, topo order) — mirror of `graph.folded_weight_order`.
    pub fn folded_weight_order(&self) -> Vec<String> {
        let mut out = vec![];
        for n in self.conv_like() {
            out.push(format!("{}.w", n.id));
            out.push(format!("{}.b", n.id));
        }
        out
    }

    /// Activation-quant sites of a folded graph (mirror of
    /// `interp.enumerate_sites`): (node id, unsigned).
    pub fn sites(&self) -> Vec<(String, bool)> {
        let cons = self.consumers();
        let mut sites = vec![];
        for n in &self.nodes {
            let cs = &cons[n.id.as_str()];
            if cs.len() == 1
                && matches!(cs[0].op, Op::Bn | Op::Relu | Op::Relu6)
            {
                continue;
            }
            if n.op == Op::Bn {
                continue;
            }
            let unsigned = match n.op {
                Op::Relu | Op::Relu6 | Op::Input => true,
                Op::Gap => {
                    let src = self.node(&n.inputs[0]).unwrap();
                    matches!(src.op, Op::Relu | Op::Relu6 | Op::Input)
                }
                _ => false,
            };
            sites.push((n.id.clone(), unsigned));
        }
        sites
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "tiny", "num_classes": 10,
      "nodes": [
        {"id": "input", "op": "input", "inputs": [], "shape": [32,32,3]},
        {"id": "c0", "op": "conv", "inputs": ["input"], "k":3, "stride":1, "cin":3, "cout":8, "bias": true},
        {"id": "r0", "op": "relu6", "inputs": ["c0"]},
        {"id": "g", "op": "gap", "inputs": ["r0"]},
        {"id": "d", "op": "dense", "inputs": ["g"], "cin":8, "cout":10, "bias": true}
      ]}"#;

    #[test]
    fn parse_sample() {
        let g = GraphDef::from_json(SAMPLE).unwrap();
        assert_eq!(g.name, "tiny");
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.node("c0").unwrap().cout, 8);
        assert_eq!(g.node("c0").unwrap().out_channels(), 8);
    }

    #[test]
    fn weight_order() {
        let g = GraphDef::from_json(SAMPLE).unwrap();
        assert_eq!(
            g.folded_weight_order(),
            vec!["c0.w", "c0.b", "d.w", "d.b"]
        );
    }

    #[test]
    fn sites_skip_pre_activation() {
        let g = GraphDef::from_json(SAMPLE).unwrap();
        let sites = g.sites();
        let ids: Vec<&str> = sites.iter().map(|(s, _)| s.as_str()).collect();
        assert_eq!(ids, vec!["input", "r0", "g", "d"]);
        let uns: Vec<bool> = sites.iter().map(|&(_, u)| u).collect();
        assert_eq!(uns, vec![true, true, true, false]);
    }

    #[test]
    fn to_json_round_trips() {
        let g = GraphDef::from_json(SAMPLE).unwrap();
        let g2 = GraphDef::from_json(&g.to_json()).unwrap();
        assert_eq!(g2.name, g.name);
        assert_eq!(g2.num_classes, g.num_classes);
        assert_eq!(g2.nodes.len(), g.nodes.len());
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(
                (a.k, a.stride, a.cin, a.cout, a.ch, a.bias),
                (b.k, b.stride, b.cin, b.cout, b.ch, b.bias)
            );
            assert_eq!(a.input_shape, b.input_shape);
        }
        // and the serialization is a fixed point
        assert_eq!(g.to_json(), g2.to_json());
    }

    #[test]
    fn op_name_inverts_parse() {
        for op in [
            Op::Input,
            Op::Conv,
            Op::DwConv,
            Op::Dense,
            Op::Bn,
            Op::Relu,
            Op::Relu6,
            Op::Add,
            Op::Gap,
        ] {
            assert_eq!(Op::parse(op.name()).unwrap(), op);
        }
    }

    #[test]
    fn unknown_op_rejected() {
        let bad = SAMPLE.replace("relu6", "gelu");
        assert!(GraphDef::from_json(&bad).is_err());
    }
}
