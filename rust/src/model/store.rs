//! On-disk model directory (`artifacts/models/<name>/`) produced by
//! `make artifacts`: weights, graph IR, quant-site metadata, HLO artifacts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensor::Tensor;
use crate::util::Json;

use super::{fatw, GraphDef};

#[derive(Debug, Clone)]
pub struct Site {
    pub id: String,
    pub unsigned: bool,
}

#[derive(Debug, Clone)]
pub struct ChannelStat {
    pub id: String,
    pub channels: usize,
}

#[derive(Debug, Clone)]
pub struct SitesJson {
    pub sites: Vec<Site>,
    pub channel_stats: Vec<ChannelStat>,
    pub weight_order: Vec<String>,
    pub val_acc_fp_pretrain: f64,
}

impl SitesJson {
    pub fn from_json(s: &str) -> Result<Self> {
        let j = Json::parse(s)?;
        let sites = j
            .req("sites")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(Site {
                    id: s.req("id")?.as_str()?.to_string(),
                    unsigned: s.bool_or("unsigned", false),
                })
            })
            .collect::<Result<_>>()?;
        let channel_stats = j
            .req("channel_stats")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(ChannelStat {
                    id: s.req("id")?.as_str()?.to_string(),
                    channels: s.usize_or("channels", 0),
                })
            })
            .collect::<Result<_>>()?;
        let weight_order = j
            .req("weight_order")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let val_acc_fp_pretrain = j
            .get("val_acc_fp_pretrain")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(-1.0);
        Ok(SitesJson { sites, channel_stats, weight_order, val_acc_fp_pretrain })
    }
}

/// Default directory for compiled `.fatm` artifacts under an artifacts
/// root: `<artifacts>/compiled` (written by `fat export`, scanned by
/// `fat serve --models <dir>` — see `crate::artifact`).
pub fn compiled_dir<P: AsRef<Path>>(artifacts: P) -> PathBuf {
    artifacts.as_ref().join("compiled")
}

/// Canonical `.fatm` path for a model name inside a compiled-artifact
/// directory.
pub fn fatm_path<P: AsRef<Path>>(dir: P, name: &str) -> PathBuf {
    dir.as_ref().join(format!("{name}.fatm"))
}

/// Handle on one model's artifact directory.
#[derive(Debug, Clone)]
pub struct ModelStore {
    pub name: String,
    pub dir: PathBuf,
}

impl ModelStore {
    pub fn open<P: AsRef<Path>>(artifacts: P, name: &str) -> Result<Self> {
        let dir = artifacts.as_ref().join("models").join(name);
        if !dir.exists() {
            anyhow::bail!(
                "model dir {:?} missing — run `make artifacts` first",
                dir
            );
        }
        Ok(ModelStore { name: name.to_string(), dir })
    }

    pub fn list<P: AsRef<Path>>(artifacts: P) -> Result<Vec<String>> {
        let mut names = vec![];
        let dir = artifacts.as_ref().join("models");
        for e in std::fs::read_dir(&dir)
            .with_context(|| format!("reading {dir:?}"))?
        {
            let e = e?;
            if e.file_type()?.is_dir() {
                names.push(e.file_name().to_string_lossy().into_owned());
            }
        }
        names.sort();
        Ok(names)
    }

    pub fn raw_weights(&self) -> Result<BTreeMap<String, Tensor>> {
        fatw::read_fatw(self.dir.join("raw.fatw"))
    }

    /// Python-folded weights (golden reference for the Rust fold).
    pub fn folded_weights_golden(&self) -> Result<BTreeMap<String, Tensor>> {
        fatw::read_fatw(self.dir.join("folded.fatw"))
    }

    pub fn graph(&self) -> Result<GraphDef> {
        GraphDef::load(self.dir.join("graph.json"))
    }

    pub fn folded_graph(&self) -> Result<GraphDef> {
        GraphDef::load(self.dir.join("folded.json"))
    }

    pub fn sites(&self) -> Result<SitesJson> {
        let s = std::fs::read_to_string(self.dir.join("sites.json"))?;
        SitesJson::from_json(&s)
    }

    /// Path prefix for an artifact (append `.hlo.txt` / `.manifest.json`).
    pub fn artifact_path(&self, artifact: &str) -> PathBuf {
        self.dir.join(artifact)
    }
}
