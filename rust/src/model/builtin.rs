//! Built-in model zoo — the artifact-free mirror of
//! `python/compile/models.py`.
//!
//! When `artifacts/models/<name>/` is absent, a session can still open
//! one of these models: the folded graph is built in code (BN is never
//! materialised, so no fold pass is needed), the weights are
//! deterministic He-uniform draws from the portable PRNG, and the
//! quant-site metadata comes from [`GraphDef::sites`]. Together with the
//! native FP32 backend (`crate::fp`) this makes the whole pipeline —
//! calibrate → fine-tune → export → int8 serving — runnable from a bare
//! `cargo run`, no Python and no AOT artifacts.
//!
//! The graphs mirror the Python zoo's topology and naming exactly
//! (`stem_conv`, `b0_exp_conv`, `head_dense`, …); only the weights
//! differ (the Python side pretrains, this side draws deterministic
//! initialisations — accuracy ladders are therefore only meaningful on
//! the artifact path, while the pipeline mechanics, the RMSE
//! distillation objective and the int8 export are exercised in full).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::prng;
use crate::tensor::Tensor;
use crate::util::prop;

use super::store::{ChannelStat, Site, SitesJson};
use super::{GraphDef, Op};

/// Deterministic weight seed (shared by every builtin model; the node
/// index is mixed in per layer).
pub const WEIGHT_SEED: u64 = 0xB111D_0001;

/// Names served by [`load`], in canonical order.
pub fn names() -> &'static [&'static str] {
    &[
        "mobilenet_v2_mini",
        "mnas_mini_10",
        "mnas_mini_13",
        "resnet_mini",
        "tiny_cnn",
    ]
}

/// Whether `name` is a builtin model.
pub fn is_builtin(name: &str) -> bool {
    names().contains(&name)
}

/// Build a builtin model: folded graph, quant-site metadata and
/// deterministic folded weights.
pub fn load(name: &str) -> Result<(GraphDef, SitesJson, BTreeMap<String, Tensor>)> {
    let g = match name {
        "mobilenet_v2_mini" => mobilenet_v2_mini()?,
        "mnas_mini_10" => mnas_mini(1.0, "mnas_mini_10")?,
        "mnas_mini_13" => mnas_mini(1.3, "mnas_mini_13")?,
        "resnet_mini" => resnet_mini()?,
        "tiny_cnn" => tiny_cnn()?,
        other => anyhow::bail!(
            "no builtin model `{other}` (available: {})",
            names().join(", ")
        ),
    };
    let sites = sites_of(&g);
    let weights = init_weights(&g, WEIGHT_SEED);
    Ok((g, sites, weights))
}

/// Quant-site metadata derived from the folded graph (mirror of the
/// `sites.json` the Python exporter writes).
pub fn sites_of(g: &GraphDef) -> SitesJson {
    SitesJson {
        sites: g
            .sites()
            .into_iter()
            .map(|(id, unsigned)| Site { id, unsigned })
            .collect(),
        channel_stats: g
            .conv_like()
            .filter(|n| n.op != Op::Dense)
            .map(|n| ChannelStat { id: n.id.clone(), channels: n.out_channels() })
            .collect(),
        weight_order: g.folded_weight_order(),
        val_acc_fp_pretrain: -1.0,
    }
}

/// Deterministic He-uniform weights (`±sqrt(6 / fan_in)`) + zero biases
/// for every conv-like node of a folded graph.
pub fn init_weights(g: &GraphDef, seed: u64) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for (i, n) in g.conv_like().enumerate() {
        let (shape, fan_in, cout) = match n.op {
            Op::Conv => {
                (vec![n.k, n.k, n.cin, n.cout], n.k * n.k * n.cin, n.cout)
            }
            Op::DwConv => (vec![n.k, n.k, n.ch], n.k * n.k, n.ch),
            Op::Dense => (vec![n.cin, n.cout], n.cin, n.cout),
            _ => unreachable!("conv_like returned {:?}", n.op),
        };
        let len: usize = shape.iter().product();
        let bound = (6.0f32 / fan_in.max(1) as f32).sqrt();
        let node_seed = prng::hash_u64(seed, i as u64, 101, 0, 0, 0);
        out.insert(
            format!("{}.w", n.id),
            Tensor::f32(shape, prop::f32s(node_seed, len, -bound, bound)),
        );
        out.insert(format!("{}.b", n.id), Tensor::zeros_f32(vec![cout]));
    }
    out
}

// ---------------------------------------------------------------------
// Folded-graph builder (mirror of python/compile/graph.Builder with
// bn=True folded away: conv-like nodes carry bias, bn nodes are never
// emitted).
// ---------------------------------------------------------------------

struct B {
    name: String,
    nodes: Vec<String>,
}

impl B {
    fn new(name: &str) -> B {
        B {
            name: name.to_string(),
            nodes: vec![
                r#"{"id":"input","op":"input","inputs":[],"shape":[32,32,3]}"#
                    .to_string(),
            ],
        }
    }

    fn act(&mut self, x: String, act: Option<&str>, hint: &str) -> String {
        match act {
            None => x,
            Some(a) => {
                let id = format!("{hint}_{a}");
                self.nodes.push(format!(
                    r#"{{"id":"{id}","op":"{a}","inputs":["{x}"]}}"#
                ));
                id
            }
        }
    }

    fn conv(
        &mut self,
        x: &str,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        act: Option<&str>,
        hint: &str,
    ) -> String {
        let id = format!("{hint}_conv");
        self.nodes.push(format!(
            r#"{{"id":"{id}","op":"conv","inputs":["{x}"],"k":{k},"stride":{stride},"cin":{cin},"cout":{cout},"bias":true}}"#
        ));
        self.act(id, act, hint)
    }

    fn dwconv(
        &mut self,
        x: &str,
        ch: usize,
        k: usize,
        stride: usize,
        act: Option<&str>,
        hint: &str,
    ) -> String {
        let id = format!("{hint}_dwconv");
        self.nodes.push(format!(
            r#"{{"id":"{id}","op":"dwconv","inputs":["{x}"],"k":{k},"stride":{stride},"ch":{ch},"bias":true}}"#
        ));
        self.act(id, act, hint)
    }

    fn add(&mut self, a: &str, b: &str, hint: &str) -> String {
        let id = format!("{hint}_add");
        self.nodes.push(format!(
            r#"{{"id":"{id}","op":"add","inputs":["{a}","{b}"]}}"#
        ));
        id
    }

    fn relu(&mut self, x: &str, hint: &str) -> String {
        let id = format!("{hint}_relu");
        self.nodes
            .push(format!(r#"{{"id":"{id}","op":"relu","inputs":["{x}"]}}"#));
        id
    }

    fn head(&mut self, x: &str, cin: usize) -> String {
        self.nodes.push(format!(
            r#"{{"id":"head_gap","op":"gap","inputs":["{x}"]}}"#
        ));
        let id = "head_dense".to_string();
        self.nodes.push(format!(
            r#"{{"id":"{id}","op":"dense","inputs":["head_gap"],"cin":{cin},"cout":10,"bias":true}}"#
        ));
        id
    }

    fn build(self) -> Result<GraphDef> {
        let json = format!(
            r#"{{"name":"{}","num_classes":10,"nodes":[{}]}}"#,
            self.name,
            self.nodes.join(",")
        );
        GraphDef::from_json(&json)
    }
}

#[allow(clippy::too_many_arguments)]
fn inverted_residual(
    b: &mut B,
    x: String,
    cin: usize,
    cout: usize,
    stride: usize,
    t: usize,
    act: &str,
    hint: &str,
) -> String {
    let mid = cin * t;
    let y = b.conv(&x, cin, mid, 1, 1, Some(act), &format!("{hint}_exp"));
    let y = b.dwconv(&y, mid, 3, stride, Some(act), &format!("{hint}_dw"));
    let y = b.conv(&y, mid, cout, 1, 1, None, &format!("{hint}_proj"));
    if stride == 1 && cin == cout {
        b.add(&x, &y, &format!("{hint}_res"))
    } else {
        y
    }
}

fn mobilenet_v2_mini() -> Result<GraphDef> {
    let mut b = B::new("mobilenet_v2_mini");
    let mut x = b.conv("input", 3, 16, 3, 1, Some("relu6"), "stem");
    let cfg: [(usize, usize, usize); 7] = [
        (1, 16, 1),
        (4, 24, 2),
        (4, 24, 1),
        (4, 32, 2),
        (4, 32, 1),
        (4, 64, 2),
        (4, 64, 1),
    ];
    let mut cin = 16;
    for (i, (t, cout, s)) in cfg.iter().enumerate() {
        x = inverted_residual(
            &mut b,
            x,
            cin,
            *cout,
            *s,
            *t,
            "relu6",
            &format!("b{i}"),
        );
        cin = *cout;
    }
    let x = b.conv(&x, cin, 128, 1, 1, Some("relu6"), "headconv");
    b.head(&x, 128);
    b.build()
}

fn mnas_mini(width: f32, name: &str) -> Result<GraphDef> {
    let c = |ch: usize| -> usize { ((ch as f32 * width + 0.5) as usize).max(8) };
    let mut b = B::new(name);
    let x = b.conv("input", 3, c(16), 3, 1, Some("relu"), "stem");
    let x = b.dwconv(&x, c(16), 3, 1, Some("relu"), "sep_dw");
    let mut x = b.conv(&x, c(16), c(16), 1, 1, None, "sep_pw");
    let cfg: [(usize, usize, usize, usize); 3] =
        [(3, 24, 2, 2), (3, 40, 2, 2), (6, 64, 2, 2)];
    let mut cin = c(16);
    for (bi, (t, cout, s, n)) in cfg.iter().enumerate() {
        for j in 0..*n {
            let stride = if j == 0 { *s } else { 1 };
            x = inverted_residual(
                &mut b,
                x,
                cin,
                c(*cout),
                stride,
                *t,
                "relu",
                &format!("m{bi}_{j}"),
            );
            cin = c(*cout);
        }
    }
    let x = b.conv(&x, cin, c(128), 1, 1, Some("relu"), "headconv");
    b.head(&x, c(128));
    b.build()
}

fn resnet_mini() -> Result<GraphDef> {
    let mut b = B::new("resnet_mini");
    let mut x = b.conv("input", 3, 16, 3, 1, Some("relu"), "stem");
    let mut cin = 16;
    for (si, (cout, s)) in [(16usize, 1usize), (32, 2), (64, 2)].iter().enumerate()
    {
        for j in 0..2usize {
            let stride = if j == 0 { *s } else { 1 };
            let y = b.conv(
                &x,
                cin,
                *cout,
                3,
                stride,
                Some("relu"),
                &format!("r{si}_{j}a"),
            );
            let y =
                b.conv(&y, *cout, *cout, 3, 1, None, &format!("r{si}_{j}b"));
            let y = if stride == 1 && cin == *cout {
                b.add(&x, &y, &format!("r{si}_{j}"))
            } else {
                let sc = b.conv(
                    &x,
                    cin,
                    *cout,
                    1,
                    stride,
                    None,
                    &format!("r{si}_{j}s"),
                );
                b.add(&sc, &y, &format!("r{si}_{j}"))
            };
            x = b.relu(&y, &format!("r{si}_{j}o"));
            cin = *cout;
        }
    }
    b.head(&x, 64);
    b.build()
}

/// Smallest builtin: one of every op kind (conv, dwconv, dense, add,
/// gap, relu, relu6) at test-friendly sizes — the CI / debug-build
/// workhorse for the native pipeline.
fn tiny_cnn() -> Result<GraphDef> {
    let mut b = B::new("tiny_cnn");
    let x = b.conv("input", 3, 8, 3, 2, Some("relu6"), "stem");
    let x = b.dwconv(&x, 8, 3, 1, Some("relu"), "dw");
    let y = b.conv(&x, 8, 8, 1, 1, None, "pw_a");
    let z = b.conv(&x, 8, 8, 1, 1, None, "pw_b");
    let x = b.add(&y, &z, "res");
    let x = b.conv(&x, 8, 16, 3, 2, Some("relu"), "down");
    b.head(&x, 16);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_loads_consistently() {
        for name in names() {
            let (g, sites, w) = load(name).unwrap();
            assert_eq!(&g.name, name);
            assert!(!sites.sites.is_empty(), "{name}");
            // weights cover exactly the folded weight order
            for key in g.folded_weight_order() {
                assert!(w.contains_key(&key), "{name}: missing {key}");
            }
            assert_eq!(w.len(), g.folded_weight_order().len(), "{name}");
            // folded graphs never carry bn nodes
            assert!(g.nodes.iter().all(|n| n.op != Op::Bn), "{name}");
            // input is a quant site (the paper quantizes the input too)
            assert_eq!(sites.sites[0].id, "input", "{name}");
        }
        assert!(load("nope").is_err());
        assert!(is_builtin("tiny_cnn"));
        assert!(!is_builtin("nope"));
    }

    #[test]
    fn weights_are_deterministic_and_bounded() {
        let (g, _, w1) = load("tiny_cnn").unwrap();
        let (_, _, w2) = load("tiny_cnn").unwrap();
        for (k, t) in &w1 {
            assert_eq!(t.as_f32().unwrap(), w2[k].as_f32().unwrap(), "{k}");
        }
        // He-uniform bound for the stem conv: sqrt(6 / (3*3*3))
        let stem = w1["stem_conv.w"].as_f32().unwrap();
        let bound = (6.0f32 / 27.0).sqrt();
        assert!(stem.iter().all(|v| v.abs() <= bound));
        assert!(stem.iter().any(|v| v.abs() > bound * 0.5));
        assert_eq!(w1["stem_conv.b"].as_f32().unwrap(), &[0.0f32; 8]);
        let _ = g;
    }

    #[test]
    fn mnas_names_mirror_python_builder() {
        let (g, _, _) = load("mnas_mini_10").unwrap();
        for id in ["stem_conv", "sep_dw_dwconv", "m0_0_exp_conv", "head_dense"]
        {
            assert!(g.node(id).is_ok(), "{id}");
        }
        // second block of each stage is a residual
        assert!(g.node("m0_1_res_add").is_ok());
    }
}
