//! # FAT: Fast Adjustable Threshold uniform NN quantization
//!
//! Rust + JAX + Pallas reproduction of Goncharenko et al., *FAT: Fast
//! Adjustable Threshold for Uniform Neural Network Quantization* (2018),
//! the winning solution of LPIRC-II.
//!
//! Three layers (see `DESIGN.md` at the repo root):
//!  * **L1** Pallas fake-quant / int8-GEMM kernels (`python/compile/kernels`)
//!  * **L2** JAX model graphs + FAT fine-tune step (`python/compile`),
//!    AOT-lowered to HLO-text artifacts at build time
//!  * **L3** this crate: the quantization pipeline coordinator,
//!    calibration, BN folding, §3.3 DWS rescaling, a **native FP32
//!    backend** ([`fp`]: planned float executor, fake-quant forward and
//!    analytic threshold trainer — DESIGN.md §7), an optional PJRT
//!    runtime for the AOT artifacts (behind the `pjrt` feature), and an
//!    integer-only int8 inference engine (the mobile-deployment
//!    simulator) driven by a precompiled execution plan with
//!    `FAT_THREADS`-way parallelism.
//!
//! The public API is staged (DESIGN.md §6): a
//! [`quant::session::QuantSession`] walks the paper's dataflow —
//! calibrate → optional §3.3 rescale → fine-tune or identity thresholds
//! → export — with each stage a distinct type, and serving traffic goes
//! through the [`int8::serve::Int8Engine`] handle (`Arc`-clone, pooled
//! per-worker execution state). The [`net`] module puts that handle
//! behind a real socket front-end — hand-rolled HTTP/1.1 plus a binary
//! frame protocol on one port, admission control, and graceful drain
//! (`fat serve`, DESIGN.md §10).
//!
//! Python never runs at runtime. With AOT artifacts present (and the
//! `pjrt` feature), float stages execute the lowered HLO; without them,
//! the native backend runs the identical pipeline on builtin models —
//! `cargo run --release -- --epochs 1` works on a bare checkout
//! (DESIGN.md §7).
//!
//! Exported models persist as **`.fatm` compiled artifacts**
//! ([`artifact`], DESIGN.md §11): a versioned, checksummed container for
//! everything `build_qmodel` produces — plan schedule, per-site qparams,
//! prepacked SIMD weight panels — written by `fat export` and loaded
//! zero-copy via `mmap` so serving cold-start skips re-quantization and
//! re-packing entirely (`fat serve --models <dir>`).
//!
//! Environment knobs: `FAT_ARTIFACTS` (artifact dir, default
//! `./artifacts`), `FAT_BACKEND` (`auto` | `native` | `artifact`),
//! `FAT_THREADS` (worker count for the int8 engine and the native FP32
//! backend, default = machine parallelism), `FAT_MMAP` (`off` pins the
//! `.fatm` loader to the read-into-heap path), `FAT_BENCH_ITERS` /
//! `FAT_BENCH_MAX_SECS` (bench harness).

pub mod artifact;
pub mod coordinator;
pub mod data;
pub mod fp;
pub mod int8;
pub mod model;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::{DType, Tensor};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$FAT_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FAT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
