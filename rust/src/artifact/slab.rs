//! Borrowed-vs-owned int8 weight storage (DESIGN.md §11.2).
//!
//! [`I8Slab`] is the storage type behind `QLayer::w_q` and
//! `PackedWeights` panel data: either an owned `Vec<i8>` (the
//! `build_qmodel` export path and every hand-built test layer) or a
//! window into a shared read-only [`Mapping`] (the zero-copy `.fatm`
//! load path). It derefs to `&[i8]`, so the kernels and the execution
//! plan are oblivious to where the weights live — a model can run
//! straight out of the page cache.

use std::ops::Deref;
use std::sync::Arc;

use super::mmap::Mapping;

/// Owned or mapping-backed `[i8]` storage with slice semantics.
#[derive(Clone)]
pub enum I8Slab {
    /// Heap-owned bytes (export path, hand-built layers).
    Owned(Vec<i8>),
    /// A `len`-byte window at `off` into a shared read-only mapping.
    /// Alignment is irrelevant for `i8` (align 1) and every bit pattern
    /// is a valid `i8`, so any in-bounds window is sound.
    Mapped { map: Arc<Mapping>, off: usize, len: usize },
}

impl I8Slab {
    /// View a window of a mapping as an i8 slab. Errors when the window
    /// exceeds the mapping — the loader calls this with attacker-visible
    /// offsets, so the check is not a debug assert.
    pub fn from_mapping(
        map: Arc<Mapping>,
        off: usize,
        len: usize,
    ) -> anyhow::Result<I8Slab> {
        anyhow::ensure!(
            off.checked_add(len).is_some_and(|end| end <= map.len()),
            "i8 slab [{off}, {off}+{len}) exceeds mapping of {} bytes",
            map.len()
        );
        Ok(I8Slab::Mapped { map, off, len })
    }

    /// Whether this slab borrows a mapping (vs owning its bytes).
    pub fn is_mapped(&self) -> bool {
        matches!(self, I8Slab::Mapped { .. })
    }

    pub fn len(&self) -> usize {
        match self {
            I8Slab::Owned(v) => v.len(),
            I8Slab::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Deref for I8Slab {
    type Target = [i8];

    fn deref(&self) -> &[i8] {
        match self {
            I8Slab::Owned(v) => v,
            I8Slab::Mapped { map, off, len } => {
                let bytes = &map.bytes()[*off..*off + *len];
                // SAFETY: i8 and u8 have identical size/alignment and
                // every bit pattern is valid for both; the range was
                // bounds-checked at construction and the mapping is
                // immutable and outlives `self` (Arc).
                unsafe {
                    std::slice::from_raw_parts(
                        bytes.as_ptr() as *const i8,
                        bytes.len(),
                    )
                }
            }
        }
    }
}

impl From<Vec<i8>> for I8Slab {
    fn from(v: Vec<i8>) -> I8Slab {
        I8Slab::Owned(v)
    }
}

impl PartialEq for I8Slab {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl std::fmt::Debug for I8Slab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "I8Slab::{}({} bytes)",
            if self.is_mapped() { "Mapped" } else { "Owned" },
            self.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_and_mapped_deref_equally() {
        let v: Vec<i8> = vec![-128, -1, 0, 1, 127];
        let owned: I8Slab = v.clone().into();
        let bytes: Vec<u8> = v.iter().map(|&b| b as u8).collect();
        let map = Arc::new(Mapping::from_vec(bytes));
        let mapped = I8Slab::from_mapping(map, 0, 5).unwrap();
        assert_eq!(&owned[..], &v[..]);
        assert_eq!(&mapped[..], &v[..]);
        assert_eq!(owned, mapped);
        assert!(!owned.is_mapped());
        assert!(mapped.is_mapped());
    }

    #[test]
    fn window_into_mapping() {
        let map = Arc::new(Mapping::from_vec(vec![0, 1, 2, 3, 4, 5]));
        let s = I8Slab::from_mapping(Arc::clone(&map), 2, 3).unwrap();
        assert_eq!(&s[..], &[2i8, 3, 4]);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn out_of_bounds_window_rejected() {
        let map = Arc::new(Mapping::from_vec(vec![0u8; 8]));
        assert!(I8Slab::from_mapping(Arc::clone(&map), 4, 5).is_err());
        assert!(I8Slab::from_mapping(Arc::clone(&map), usize::MAX, 2).is_err());
        assert!(I8Slab::from_mapping(map, 8, 0).is_ok()); // empty tail ok
    }
}
