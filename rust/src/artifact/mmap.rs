//! Read-only file mappings for zero-copy artifact loading
//! (DESIGN.md §11.2).
//!
//! [`Mapping`] backs the `.fatm` loader: on 64-bit unix it wraps the raw
//! `mmap(2)`/`munmap(2)` syscalls declared directly against the libc the
//! Rust runtime already links (zero-deps policy — no `libc` crate), so a
//! loaded model's weight panels are served straight out of the kernel
//! page cache and N server processes share one physical copy. Everywhere
//! else — and whenever `FAT_MMAP=off` asks for it — the file is read
//! into a heap buffer instead; both variants expose one `&[u8]` and the
//! loader above cannot tell them apart.
//!
//! ## Safety argument
//!
//! The mapped region is `PROT_READ` + `MAP_PRIVATE`: nothing in this
//! process can write through it, and writes by other processes to the
//! underlying file are not guaranteed to be observed (private mapping)
//! — but even if they were, every zero-copy consumer reads the bytes as
//! `i8`/`u8`, for which **every bit pattern is a valid value**, so a
//! concurrently-truncated or rewritten file can produce wrong logits
//! but never undefined behavior from the values themselves. (A
//! truncation that shrinks the file below the mapping can still fault
//! on touch, as with any mmap consumer; the deployment contract is that
//! artifacts are replaced atomically via rename, never truncated in
//! place.) Structured fields (lengths, offsets, i32/f32 tables) are
//! *copied out* through checked little-endian decoding at load time and
//! are never re-read from the mapping afterwards.

use std::path::Path;

use anyhow::{Context, Result};

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x2;

    extern "C" {
        // Declared against the platform libc the Rust std runtime
        // already links. 64-bit targets only (gated above): `off_t` is
        // 64-bit there, so the `i64` offset matches the ABI.
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

enum MapInner {
    /// A live `mmap(2)` region; unmapped on drop.
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mmap { ptr: *mut std::os::raw::c_void, len: usize },
    /// Heap fallback (non-unix targets, `FAT_MMAP=off`, or in-memory
    /// byte buffers from tests/fuzzing).
    Heap(Vec<u8>),
}

/// An immutable byte region backing a loaded artifact: either a real
/// file mapping or an owned heap buffer. Shared by every borrowed
/// weight slab of a loaded model via `Arc` (see
/// [`crate::artifact::I8Slab`]), so the region outlives all views into
/// it by construction.
pub struct Mapping {
    inner: MapInner,
}

// SAFETY: the region is immutable for the lifetime of the Mapping (heap
// buffer is never touched again; mmap is PROT_READ), so shared access
// from any thread is sound.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only. Uses `mmap` where available unless
    /// `FAT_MMAP=off|0` pins the heap path; falls back to reading the
    /// file into memory otherwise (including for empty files, which
    /// `mmap` rejects).
    pub fn map_file<P: AsRef<Path>>(path: P) -> Result<Mapping> {
        let force_heap = matches!(
            std::env::var("FAT_MMAP").ok().as_deref().map(str::trim),
            Some("off") | Some("0") | Some("false")
        );
        Self::map_file_with(path, force_heap)
    }

    /// [`Mapping::map_file`] with the heap fallback pinned explicitly.
    pub fn map_file_with<P: AsRef<Path>>(
        path: P,
        force_heap: bool,
    ) -> Result<Mapping> {
        let path = path.as_ref();
        if !force_heap {
            #[cfg(all(unix, target_pointer_width = "64"))]
            {
                return Self::mmap_unix(path);
            }
        }
        Self::read_heap(path)
    }

    /// Wrap an owned byte buffer (the in-memory load path).
    pub fn from_vec(bytes: Vec<u8>) -> Mapping {
        Mapping { inner: MapInner::Heap(bytes) }
    }

    fn read_heap(path: &Path) -> Result<Mapping> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {path:?}"))?;
        Ok(Mapping { inner: MapInner::Heap(bytes) })
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn mmap_unix(path: &Path) -> Result<Mapping> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {path:?}"))?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            // mmap(2) rejects zero-length maps; an empty artifact fails
            // header validation anyway, so hand back an empty buffer.
            return Ok(Mapping { inner: MapInner::Heap(Vec::new()) });
        }
        // SAFETY: valid fd for the duration of the call; the mapping
        // survives the fd close per POSIX. Failure is reported via
        // MAP_FAILED (-1), checked below.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            anyhow::bail!(
                "mmap {path:?} failed: {}",
                std::io::Error::last_os_error()
            );
        }
        Ok(Mapping { inner: MapInner::Mmap { ptr, len } })
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            // SAFETY: ptr/len came from a successful PROT_READ mmap
            // that lives until Drop; the region is immutable (module
            // safety argument) and u8 has no invalid bit patterns.
            MapInner::Mmap { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr as *const u8, *len)
            },
            MapInner::Heap(v) => v,
        }
    }

    /// Length in bytes.
    pub fn len(&self) -> usize {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            MapInner::Mmap { len, .. } => *len,
            MapInner::Heap(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this region is a real file mapping (vs the heap path) —
    /// surfaced in [`crate::artifact::LoadReport`].
    pub fn is_mmap(&self) -> bool {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            MapInner::Mmap { .. } => true,
            MapInner::Heap(_) => false,
        }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let MapInner::Mmap { ptr, len } = self.inner {
            // SAFETY: exactly the region returned by mmap in map_file;
            // dropped once (Drop runs once, Mapping is not Clone).
            unsafe {
                sys::munmap(ptr, len);
            }
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mapping")
            .field("len", &self.len())
            .field("mmap", &self.is_mmap())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_and_mmap_agree() {
        let p = std::env::temp_dir().join("fat_mapping_test.bin");
        let payload: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&p, &payload).unwrap();
        let heap = Mapping::map_file_with(&p, true).unwrap();
        assert!(!heap.is_mmap());
        assert_eq!(heap.bytes(), &payload[..]);
        let auto = Mapping::map_file_with(&p, false).unwrap();
        assert_eq!(auto.bytes(), &payload[..]);
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(auto.is_mmap());
    }

    #[test]
    fn empty_file_maps_as_empty() {
        let p = std::env::temp_dir().join("fat_mapping_empty.bin");
        std::fs::write(&p, b"").unwrap();
        let m = Mapping::map_file_with(&p, false).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), b"");
    }

    #[test]
    fn missing_file_errors() {
        assert!(Mapping::map_file("/nonexistent/fat/artifact.fatm").is_err());
    }

    #[test]
    fn from_vec_owns_bytes() {
        let m = Mapping::from_vec(vec![1, 2, 3]);
        assert_eq!(m.bytes(), &[1, 2, 3]);
        assert_eq!(m.len(), 3);
        assert!(!m.is_mmap());
    }
}
