//! Compiled-model artifact format (`.fatm`) with zero-copy mmap loading
//! (DESIGN.md §11).
//!
//! `quant::export::build_qmodel` is expensive relative to serving
//! cold-start: it re-quantizes weights, re-derives per-site qparams and
//! re-packs every conv/dense matrix into SIMD panels. A `.fatm` artifact
//! captures the *output* of that work — the compiled [`ExecPlan`]
//! schedule, buffer-slot table, per-site quantization parameters,
//! col sums and prepacked weight panels — in a versioned, checksummed,
//! alignment-aware container, so a server process goes from `open(2)` to
//! first inference without doing any of it again:
//!
//! ```text
//! fat export --models mobilenet_cifar     # build once  → .fatm
//! fat serve  --models artifacts/compiled  # load zero-copy, serve
//! ```
//!
//! Module map: [`layout`] (constants + checked LE reader/writer),
//! [`digest`] (FNV-1a 64 content digest = registry etag), [`mmap`]
//! (read-only file mappings via direct `mmap(2)`, heap fallback),
//! [`slab`] (owned-vs-mapped i8 weight storage behind the kernels),
//! [`save`] (deterministic writer, atomic rename), [`load`] (validating
//! zero-copy loader with ISA repack).
//!
//! The packing-ISA tag in the header records which microkernel level the
//! panels were packed for; the loader repacks from the unpacked weights
//! when the host differs ([`LoadReport::repacked`]). Loaded models serve
//! logits bit-identical to the in-memory export across every ISA ×
//! thread-count combination (`rust/tests/artifact_roundtrip.rs`).
//!
//! [`ExecPlan`]: crate::int8::plan::ExecPlan

pub mod digest;
pub mod layout;
pub mod load;
pub mod mmap;
pub mod save;
pub mod slab;

pub use digest::{etag, fnv1a64};
pub use load::{load, load_from_bytes, peek_etag, LoadOptions, LoadReport};
pub use mmap::Mapping;
pub use save::{save, to_bytes, to_bytes_versioned};
pub use slab::I8Slab;
