//! Content digest for `.fatm` artifacts: FNV-1a 64 (DESIGN.md §11.3).
//!
//! The digest serves two jobs: corruption detection at load (any
//! single-byte change to the digested region fails the open) and the
//! model **etag** the registry exposes over `/stats` and `/models` —
//! two artifacts with the same digest serve bit-identical logits, so
//! the etag doubles as the hot-reload change detector. FNV-1a is not
//! collision-resistant against adversaries; it guards against rot and
//! truncation, not tampering (matching the checksum discipline of the
//! `.fatw` container and TFLite-style flatbuffer artifacts).

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit over `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Render a digest as the registry etag string (`fnv64-<16 hex>`).
pub fn etag(digest: u64) -> String {
    format!("fnv64-{digest:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn single_byte_sensitivity() {
        let a = fnv1a64(b"fat artifact");
        let b = fnv1a64(b"fat artifacu");
        assert_ne!(a, b);
    }

    #[test]
    fn etag_format() {
        assert_eq!(etag(0xdead_beef), "fnv64-00000000deadbeef");
        assert_eq!(etag(u64::MAX), "fnv64-ffffffffffffffff");
    }
}
