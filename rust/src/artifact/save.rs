//! `.fatm` writer: serialize a compiled [`QModel`] — plan schedule,
//! per-site qparams, col sums, prepacked SIMD weight panels — into the
//! sectioned container of DESIGN.md §11.1.
//!
//! The writer is fully deterministic (no timestamps, no map iteration
//! order — everything follows the plan's dense schedule order), so the
//! same model always produces byte-identical files and the content
//! digest doubles as the registry's change-detecting etag.

use std::path::Path;

use anyhow::{Context, Result};

use crate::int8::engine::{QLayer, QModel, QNode};
use crate::int8::kernels::Isa;
use crate::quant::scale::QParams;

use super::digest::{etag, fnv1a64};
use super::layout::{
    align_up, i8_as_bytes, isa_tag, Writer, DIGEST_START, HEADER_LEN, MAGIC,
    PLAN_VERSION, SECTIONS, SEC_GRAPH, SEC_PANEL, SEC_PLAN, TOC_ENTRY_LEN,
};

/// Append a blob to the panel section at the next 64-byte boundary and
/// return its `(off, len)` reference (relative to the section start).
fn push_blob(panel: &mut Vec<u8>, bytes: &[i8]) -> (u64, u64) {
    let off = align_up(panel.len());
    panel.resize(off, 0);
    panel.extend_from_slice(i8_as_bytes(bytes));
    (off as u64, bytes.len() as u64)
}

fn put_qp(w: &mut Writer, qp: QParams) {
    w.f32(qp.scale);
    w.i32(qp.zero_point);
    w.i32(qp.qmin);
    w.i32(qp.qmax);
}

/// Serialize `qm` into `.fatm` bytes, tagging the weight panels with
/// `isa` (the packed layout itself is ISA-independent today; the tag
/// drives the loader's repack-on-mismatch rule so the format stays
/// correct if a future packing ever specializes per ISA). Writes PLAN
/// v3: each layer record carries its GEMM [`Blocking`] table entry,
/// optional shift-only requant table, and a bits tag on its packed
/// panel.
///
/// [`Blocking`]: crate::int8::kernels::Blocking
pub fn to_bytes(qm: &QModel, isa: Isa) -> Vec<u8> {
    to_bytes_versioned(qm, isa, PLAN_VERSION)
}

/// [`to_bytes`] at an explicit PLAN version — exists so back-compat
/// tests can produce genuine v1/v2 bytes. Older versions cannot
/// represent the newer features: v1 requires every layer to be at
/// [`Blocking::default`], and v1/v2 require no shift-only requant table
/// and 8-bit panels everywhere (debug-asserted in [`put_layer`]).
///
/// [`Blocking::default`]: crate::int8::kernels::Blocking::default
pub fn to_bytes_versioned(qm: &QModel, isa: Isa, version: u32) -> Vec<u8> {
    assert!(
        (super::layout::PLAN_VERSION_MIN..=PLAN_VERSION).contains(&version),
        "unwritable PLAN version {version}"
    );
    let graph = qm.graph.to_json().into_bytes();
    let plan = &qm.plan;

    // PLAN and PANEL are built together: the plan references weight
    // blobs by (off, len) into the panel section.
    let mut panel: Vec<u8> = Vec::new();
    let mut w = Writer::default();
    w.u32(version);
    w.u32(plan.num_slots as u32);
    w.u32(plan.input_slot as u32);
    w.u32(plan.output_slot as u32);
    put_qp(&mut w, qm.input_qp);
    w.u64(qm.param_bytes as u64);

    w.u32(plan.steps.len() as u32);
    for s in &plan.steps {
        w.string(&s.id);
        w.string(s.op.name());
        w.u32(s.param as u32);
        w.u32(s.a as u32);
        w.u32(s.b.map_or(0, |b| b as u32 + 1));
        w.u32(s.dst as u32);
        w.u32(s.k as u32);
        w.u32(s.stride as u32);
        w.u32(s.cout as u32);
        w.u32(s.frees.len() as u32);
        for &f in &s.frees {
            w.u32(f as u32);
        }
    }

    w.u32(plan.params.len() as u32);
    for p in &plan.params {
        match p {
            QNode::Layer(l) => {
                w.u32(0);
                put_layer(&mut w, &mut panel, l, version);
            }
            QNode::Add(a) => {
                w.u32(1);
                w.i32(a.ma.0);
                w.i32(a.ma.1);
                w.i32(a.mb.0);
                w.i32(a.mb.1);
                put_qp(&mut w, a.out_qp);
                w.i32(a.clamp.0);
                w.i32(a.clamp.1);
            }
            QNode::Gap(gp) => {
                w.u32(2);
                w.i32(gp.m.0);
                w.i32(gp.m.1);
                put_qp(&mut w, gp.out_qp);
            }
            QNode::Passthrough => w.u32(3),
        }
    }
    let plan_bytes = w.buf;

    // Assemble: header, TOC, then the three sections at 64-byte offsets.
    let toc_end = HEADER_LEN + SECTIONS.len() * TOC_ENTRY_LEN;
    let graph_off = align_up(toc_end);
    let plan_off = align_up(graph_off + graph.len());
    let panel_off = align_up(plan_off + plan_bytes.len());
    let file_size = panel_off + panel.len();

    let mut out = vec![0u8; file_size];
    out[0..8].copy_from_slice(MAGIC);
    out[8..16].copy_from_slice(&(file_size as u64).to_le_bytes());
    // digest written last
    out[24..28].copy_from_slice(&isa_tag(isa).to_le_bytes());
    out[28..32].copy_from_slice(&(SECTIONS.len() as u32).to_le_bytes());
    for (i, (kind, (off, len))) in SECTIONS
        .iter()
        .zip([
            (graph_off, graph.len()),
            (plan_off, plan_bytes.len()),
            (panel_off, panel.len()),
        ])
        .enumerate()
    {
        let e = HEADER_LEN + i * TOC_ENTRY_LEN;
        out[e..e + 4].copy_from_slice(&kind.to_le_bytes());
        out[e + 8..e + 16].copy_from_slice(&(off as u64).to_le_bytes());
        out[e + 16..e + 24].copy_from_slice(&(len as u64).to_le_bytes());
    }
    out[graph_off..graph_off + graph.len()].copy_from_slice(&graph);
    out[plan_off..plan_off + plan_bytes.len()].copy_from_slice(&plan_bytes);
    out[panel_off..panel_off + panel.len()].copy_from_slice(&panel);

    let d = fnv1a64(&out[DIGEST_START..]);
    out[16..24].copy_from_slice(&d.to_le_bytes());
    out
}

fn put_layer(w: &mut Writer, panel: &mut Vec<u8>, l: &QLayer, version: u32) {
    put_qp(w, l.out_qp);
    w.i32(l.clamp.0);
    w.i32(l.clamp.1);
    let (off, len) = push_blob(panel, &l.w_q);
    w.u64(off);
    w.u64(len);
    w.vec_i32(&l.w_sums);
    w.vec_i32(&l.bias_q);
    w.vec_i32_pair(&l.requant);
    w.vec_f32(&l.w_scales);
    if version >= 2 {
        // The tune-table entry sits *before* the packed-panel record so
        // the loader knows the strip width when it validates the panel
        // geometry.
        w.u32(l.blocking.kc as u32);
        w.u32(l.blocking.nr as u32);
        w.u32(l.blocking.mr as u32);
        w.u32(l.blocking.grain as u32);
    } else {
        debug_assert_eq!(
            l.blocking,
            Default::default(),
            "PLAN v1 cannot represent a tuned blocking table"
        );
    }
    if version >= 3 {
        // Shift-only requant table (pow2 exports) — present-flag, then
        // the per-channel shifts.
        match &l.requant_shift {
            Some(sh) => {
                w.u32(1);
                w.vec_i32(sh);
            }
            None => w.u32(0),
        }
    } else {
        debug_assert!(
            l.requant_shift.is_none(),
            "PLAN v{version} cannot represent a shift-only requant table"
        );
    }
    if version >= 4 {
        // Fused implicit-GEMM bit (DESIGN.md §14). Sits before the
        // packed record, mirroring the shift flag. v1–v3 writers drop
        // the bit silently: those readers default it from the packed
        // record, which is the export default anyway.
        w.u32(l.fused as u32);
    }
    match &l.packed {
        Some(pw) => {
            debug_assert_eq!(
                pw.nr(),
                l.blocking.nr,
                "panel strip width out of sync with the blocking table"
            );
            w.u32(1);
            w.u32(pw.k as u32);
            w.u32(pw.n as u32);
            if version >= 3 {
                w.u32(pw.bits() as u32);
            } else {
                debug_assert_eq!(
                    pw.bits(),
                    8,
                    "PLAN v{version} cannot represent an int4 panel"
                );
            }
            let (poff, plen) = push_blob(panel, pw.raw_data());
            w.u64(poff);
            w.u64(plen);
        }
        None => w.u32(0),
    }
}

/// Serialize `qm` and write it to `path` atomically (write to a
/// `.fatm.tmp` sibling, then rename — readers mapping the old file keep
/// their mapping; see the deployment contract in
/// [`super::mmap`]). Returns the artifact's etag.
pub fn save<P: AsRef<Path>>(qm: &QModel, path: P, isa: Isa) -> Result<String> {
    let path = path.as_ref();
    let bytes = to_bytes(qm, isa);
    let d = fnv1a64(&bytes[DIGEST_START..]);
    let tmp = path.with_extension("fatm.tmp");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {dir:?}"))?;
        }
    }
    std::fs::write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(etag(d))
}
