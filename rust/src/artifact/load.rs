//! `.fatm` loader: validate the container (magic, size, digest, TOC),
//! parse the plan with the checked [`Reader`], cross-check every step
//! and parameter against the embedded graph, and rebuild a [`QModel`]
//! whose weight slabs are zero-copy windows into the file mapping
//! (DESIGN.md §11).
//!
//! Validation layering:
//!  1. **Container**: magic / `file_size` / FNV digest — catches every
//!     truncation and every byte flip of a real artifact.
//!  2. **Structure**: the length-checked reader — no parse can read past
//!     a section or allocate beyond the input size, so even digest-valid
//!     hand-crafted files fail with errors, never panics or OOM.
//!  3. **Semantics**: plan indices ([`ExecPlan::from_parts`]), step ↔
//!     graph agreement, and per-layer geometry (weight blob length,
//!     packed panel shape, per-channel table lengths ≥ cout) — the
//!     invariants the executor's hot path assumes without checking.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::int8::engine::{AddParams, GapParams, QLayer, QModel, QNode};
use crate::int8::kernels::{Blocking, Isa, PackedWeights};
use crate::int8::plan::{ExecPlan, PlanStep};
use crate::model::{GraphDef, Node, Op};
use crate::quant::scale::QParams;

use super::digest::{etag, fnv1a64};
use super::layout::{
    isa_from_tag, Reader, ALIGN, DIGEST_START, HEADER_LEN, MAGIC,
    PLAN_VERSION, PLAN_VERSION_MIN, SECTIONS, TOC_ENTRY_LEN,
};
use super::mmap::Mapping;
use super::slab::I8Slab;

/// Executor slot tables are `Vec<Option<QTensor>>` sized from the file;
/// cap the count so a hostile header cannot trigger a huge allocation.
const MAX_SLOTS: usize = 1 << 16;

/// How to load a `.fatm` file.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadOptions {
    /// Read into a heap buffer instead of mmap (also forced by
    /// `FAT_MMAP=off`).
    pub force_heap: bool,
    /// ISA to validate the panel tag against; `None` = the process-wide
    /// [`Isa::detect`]. Panels packed under a different ISA tag are
    /// rebuilt from the unpacked weights ([`LoadReport::repacked`]).
    pub isa: Option<Isa>,
}

/// What a load did — surfaced by `fat serve` logs and the cold-start
/// bench.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Content etag (`fnv64-…`), the registry's change detector.
    pub etag: String,
    /// ISA tag recorded in the file.
    pub file_isa: Isa,
    /// ISA the model was loaded for.
    pub host_isa: Isa,
    /// Whether panels were repacked for the host ISA.
    pub repacked: bool,
    /// Whether the weights are served from a real file mapping.
    pub mapped: bool,
    /// Total artifact size in bytes.
    pub bytes: usize,
}

/// Load a `.fatm` artifact from disk (zero-copy via mmap unless
/// disabled).
pub fn load<P: AsRef<Path>>(
    path: P,
    opts: LoadOptions,
) -> Result<(QModel, LoadReport)> {
    let path = path.as_ref();
    let map = if opts.force_heap {
        Mapping::map_file_with(path, true)
    } else {
        Mapping::map_file(path)
    }
    .with_context(|| format!("loading artifact {path:?}"))?;
    load_mapping(Arc::new(map), opts)
        .with_context(|| format!("parsing artifact {path:?}"))
}

/// Load from an in-memory byte buffer (tests, fuzzing, network blobs).
/// Same code path as [`load`] — the buffer becomes a heap
/// [`Mapping`] and weight slabs are zero-copy windows into it.
pub fn load_from_bytes(
    bytes: Vec<u8>,
    opts: LoadOptions,
) -> Result<(QModel, LoadReport)> {
    load_mapping(Arc::new(Mapping::from_vec(bytes)), opts)
}

/// Read just the 64-byte header of `path` and return its etag — the
/// cheap change detector behind directory rescans
/// (`net::registry::ModelRegistry::sync_dir`). Trusts the stored digest;
/// full verification happens on the actual [`load`].
pub fn peek_etag<P: AsRef<Path>>(path: P) -> Result<String> {
    use std::io::Read as _;
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?;
    let mut hdr = [0u8; HEADER_LEN];
    f.read_exact(&mut hdr)
        .with_context(|| format!("reading header of {path:?}"))?;
    ensure!(&hdr[0..8] == MAGIC, "{path:?}: not a .fatm artifact");
    let d = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
    Ok(etag(d))
}

fn get_qp(r: &mut Reader) -> Result<QParams> {
    Ok(QParams {
        scale: r.f32()?,
        zero_point: r.i32()?,
        qmin: r.i32()?,
        qmax: r.i32()?,
    })
}

/// A section's absolute byte range in the file.
struct Section {
    off: usize,
    len: usize,
}

fn load_mapping(
    map: Arc<Mapping>,
    opts: LoadOptions,
) -> Result<(QModel, LoadReport)> {
    let b = map.bytes();
    let toc_end = HEADER_LEN + SECTIONS.len() * TOC_ENTRY_LEN;
    ensure!(
        b.len() >= toc_end,
        "file too small for a .fatm header ({} bytes)",
        b.len()
    );
    ensure!(&b[0..8] == MAGIC, "bad magic (not a .fatm artifact)");
    let file_size = u64::from_le_bytes(b[8..16].try_into().unwrap());
    ensure!(
        file_size == b.len() as u64,
        "file size mismatch: header says {file_size}, file has {}",
        b.len()
    );
    let stored = u64::from_le_bytes(b[16..24].try_into().unwrap());
    let computed = fnv1a64(&b[DIGEST_START..]);
    ensure!(
        stored == computed,
        "digest mismatch: stored {stored:#018x}, computed {computed:#018x} \
         (corrupt artifact)"
    );
    let file_isa =
        isa_from_tag(u32::from_le_bytes(b[24..28].try_into().unwrap()))?;
    let nsec = u32::from_le_bytes(b[28..32].try_into().unwrap());
    ensure!(
        nsec as usize == SECTIONS.len(),
        "expected {} sections, header says {nsec}",
        SECTIONS.len()
    );

    let mut sections = Vec::with_capacity(SECTIONS.len());
    let mut prev_end = toc_end as u64;
    for (i, &want_kind) in SECTIONS.iter().enumerate() {
        let e = HEADER_LEN + i * TOC_ENTRY_LEN;
        let kind = u32::from_le_bytes(b[e..e + 4].try_into().unwrap());
        ensure!(
            kind == want_kind,
            "section {i}: kind {kind}, want {want_kind}"
        );
        let off = u64::from_le_bytes(b[e + 8..e + 16].try_into().unwrap());
        let len = u64::from_le_bytes(b[e + 16..e + 24].try_into().unwrap());
        ensure!(off % ALIGN as u64 == 0, "section {i}: offset {off} unaligned");
        ensure!(off >= prev_end, "section {i}: overlaps previous section");
        let end = off
            .checked_add(len)
            .filter(|&end| end <= file_size)
            .ok_or_else(|| {
                anyhow::anyhow!("section {i}: [{off}, +{len}) out of file")
            })?;
        prev_end = end;
        sections.push(Section { off: off as usize, len: len as usize });
    }
    let [graph_sec, plan_sec, panel_sec] = match &sections[..] {
        [g, pl, pa] => [g, pl, pa],
        _ => unreachable!("section count checked above"),
    };

    let graph_raw = &b[graph_sec.off..graph_sec.off + graph_sec.len];
    let graph_json = std::str::from_utf8(graph_raw)
        .context("graph section is not UTF-8")?;
    let graph = GraphDef::from_json(graph_json)
        .context("parsing embedded graph.json")?;

    let plan_raw = &b[plan_sec.off..plan_sec.off + plan_sec.len];
    let mut r = Reader::new(plan_raw, "fatm plan");
    let version = r.u32()?;
    ensure!(
        (PLAN_VERSION_MIN..=PLAN_VERSION).contains(&version),
        "plan version {version}, this build reads \
         {PLAN_VERSION_MIN}..={PLAN_VERSION}"
    );
    let num_slots = r.usize_capped(MAX_SLOTS, "num_slots")?;
    let input_slot = r.u32()? as usize;
    let output_slot = r.u32()? as usize;
    let input_qp = get_qp(&mut r)?;
    let param_bytes = r.u64()? as usize;

    let n_steps = r.u32()?;
    let mut steps = Vec::new();
    for _ in 0..n_steps {
        let id = r.string()?;
        let op = Op::parse(&r.string()?)?;
        let param = r.u32()? as usize;
        let a = r.u32()? as usize;
        let b_plus1 = r.u32()?;
        let dst = r.u32()? as usize;
        let k = r.u32()? as usize;
        let stride = r.u32()? as usize;
        let cout = r.u32()? as usize;
        let n_frees = r.usize_capped(MAX_SLOTS, "n_frees")?;
        let mut frees = Vec::new();
        for _ in 0..n_frees {
            frees.push(r.u32()? as usize);
        }
        steps.push(PlanStep {
            id,
            op,
            param,
            a,
            b: (b_plus1 > 0).then(|| b_plus1 as usize - 1),
            dst,
            k,
            stride,
            cout,
            frees,
        });
    }

    let n_params = r.u32()?;
    let mut params: Vec<QNode> = Vec::new();
    for pi in 0..n_params {
        let tag = r.u32()?;
        params.push(match tag {
            0 => QNode::Layer(get_layer(&mut r, &map, panel_sec, version)?),
            1 => QNode::Add(AddParams {
                ma: (r.i32()?, r.i32()?),
                mb: (r.i32()?, r.i32()?),
                out_qp: get_qp(&mut r)?,
                clamp: (r.i32()?, r.i32()?),
            }),
            2 => QNode::Gap(GapParams {
                m: (r.i32()?, r.i32()?),
                out_qp: get_qp(&mut r)?,
            }),
            3 => QNode::Passthrough,
            other => bail!("param {pi}: unknown node tag {other}"),
        });
    }
    ensure!(
        r.exhausted(),
        "plan section has {} trailing bytes",
        r.remaining()
    );

    let mut plan =
        ExecPlan::from_parts(steps, params, num_slots, input_slot, output_slot)?;

    // Cross-check the plan against the embedded graph: the executor
    // trusts step geometry and per-layer table lengths on its hot path.
    for s in &plan.steps {
        let node = graph
            .node(&s.id)
            .with_context(|| format!("step {} not in graph", s.id))?;
        ensure!(
            node.op == s.op,
            "step {}: op {} but graph says {}",
            s.id,
            s.op.name(),
            node.op.name()
        );
        ensure!(
            s.k == node.k && s.stride == node.stride
                && s.cout == node.out_channels(),
            "step {}: geometry disagrees with graph",
            s.id
        );
        let p = &plan.params[s.param];
        match (s.op, p) {
            (Op::Conv | Op::DwConv | Op::Dense, QNode::Layer(l)) => {
                check_layer(node, l)?
            }
            (Op::Add, QNode::Add(_)) | (Op::Gap, QNode::Gap(_)) => {}
            (op, _) => bail!(
                "step {}: op {} paired with wrong param kind",
                s.id,
                op.name()
            ),
        }
    }

    // Repack panels when the file's packing ISA differs from the host's.
    // Today the packed layout is ISA-independent, so this reproduces the
    // identical bytes — the rule is what keeps the format correct if a
    // future packing specializes per ISA. The tuned blocking table was
    // also chosen on the packing host, so a foreign file falls back to
    // the default schedule (the mirror of the repack rule; re-tune by
    // re-exporting on this host).
    let host_isa = opts.isa.unwrap_or_else(Isa::detect);
    let mut repacked = false;
    if file_isa != host_isa {
        for p in &mut plan.params {
            if let QNode::Layer(l) = p {
                l.blocking = Blocking::default();
                if let Some(pw) = &l.packed {
                    let (k, n, bits) = (pw.k, pw.n, pw.bits());
                    // repack preserves the weight width: an int4 file
                    // stays int4 on the new host
                    l.packed = Some(PackedWeights::pack_bits(
                        &l.w_q,
                        k,
                        n,
                        crate::int8::kernels::NR,
                        bits,
                    ));
                    repacked = true;
                }
            }
        }
    }

    let report = LoadReport {
        etag: etag(stored),
        file_isa,
        host_isa,
        repacked,
        mapped: map.is_mmap(),
        bytes: map.len(),
    };
    let qm = QModel { graph, plan, input_qp, param_bytes };
    Ok((qm, report))
}

/// Expected `w_q` length of a conv-like node, from the graph's shape
/// fields (checked multiplication — these are file-controlled values).
fn expected_w_len(n: &Node) -> Result<usize> {
    let mul = |a: usize, bs: &[usize]| -> Result<usize> {
        bs.iter().try_fold(a, |acc, &x| {
            acc.checked_mul(x).ok_or_else(|| {
                anyhow::anyhow!("{}: weight shape overflows", n.id)
            })
        })
    };
    match n.op {
        Op::Conv => mul(n.k, &[n.k, n.cin, n.cout]),
        Op::DwConv => mul(n.k, &[n.k, n.ch]),
        Op::Dense => mul(n.cin, &[n.cout]),
        _ => bail!("{}: not a conv-like node", n.id),
    }
}

/// Layer geometry invariants the kernels assume: weight blob length
/// matches the graph shape, per-channel tables cover every output
/// channel, and a packed panel (if present) matches the unpacked shape
/// — `gemm_packed` reads `a` with unchecked indexing under `pw.k`, so
/// panel shape agreement is a safety requirement, not a nicety.
fn check_layer(n: &Node, l: &QLayer) -> Result<()> {
    let cout = n.out_channels();
    ensure!(cout > 0, "{}: zero output channels", n.id);
    let want_w = expected_w_len(n)?;
    ensure!(
        l.w_q.len() == want_w,
        "{}: weight blob {} bytes, graph shape wants {want_w}",
        n.id,
        l.w_q.len()
    );
    ensure!(
        l.bias_q.len() >= cout && l.requant.len() >= cout,
        "{}: bias/requant tables shorter than {cout} channels",
        n.id
    );
    ensure!(!l.w_scales.is_empty(), "{}: empty w_scales", n.id);
    if let Some(sh) = &l.requant_shift {
        // The shift table is a *redundant* encoding of the multiplier
        // pairs: each entry must satisfy
        // `quantize_multiplier(2^-shift[c]) == (1 << 30, shift[c] - 1)`
        // (the decomposition of a pow2 into a half-range mantissa).
        // A file whose shift table disagrees with its pairs would make
        // the shift-only epilogue diverge from `run_quant_ref` — reject.
        ensure!(
            sh.len() == l.requant.len(),
            "{}: shift table {} entries, requant has {}",
            n.id,
            sh.len(),
            l.requant.len()
        );
        for (c, &s) in sh.iter().enumerate() {
            let want = s.checked_sub(1).map(|e| (1 << 30, e));
            ensure!(
                Some(l.requant[c]) == want,
                "{}: shift table entry {c} (shift {s}) disagrees with \
                 requant pair {:?} — not a pow2 export",
                n.id,
                l.requant[c]
            );
        }
    }
    if let Some(pw) = &l.packed {
        ensure!(
            n.op != Op::DwConv,
            "{}: depthwise layer with a packed panel",
            n.id
        );
        let kk = want_w / cout;
        ensure!(
            pw.k == kk && pw.n == cout,
            "{}: packed panel shape ({}, {}) disagrees with ({kk}, {cout})",
            n.id,
            pw.k,
            pw.n
        );
        ensure!(
            l.w_sums.len() == cout,
            "{}: col-sum table {} entries, want {cout}",
            n.id,
            l.w_sums.len()
        );
        // An int4 panel must agree with its unpacked weights: the
        // foreign-ISA repack re-nibbles from `w_q`, and `pack_bits`
        // asserts (panics) on out-of-range lanes — reject here instead.
        ensure!(
            pw.bits() == 8 || crate::int8::kernels::fits_int4(&l.w_q),
            "{}: int4 panel but unpacked weights exceed [-8, 7]",
            n.id
        );
    } else if n.op != Op::DwConv {
        // unpacked GEMM path also consumes the col sums
        ensure!(
            l.w_sums.len() == cout,
            "{}: col-sum table {} entries, want {cout}",
            n.id,
            l.w_sums.len()
        );
    }
    Ok(())
}

fn get_layer(
    r: &mut Reader,
    map: &Arc<Mapping>,
    panel: &Section,
    version: u32,
) -> Result<QLayer> {
    let out_qp = get_qp(r)?;
    let clamp = (r.i32()?, r.i32()?);
    let w_q = get_blob(r, map, panel)?;
    let w_sums = r.vec_i32()?;
    let bias_q = r.vec_i32()?;
    let requant = r.vec_i32_pair()?;
    let w_scales = r.vec_f32()?;
    // v2: the tune-table entry precedes the packed-panel record, and is
    // validated *before* its strip width parameterizes the panel
    // geometry — a hostile blocking must never reach `gemm_packed`'s
    // unchecked inner loops.
    let blocking = if version >= 2 {
        let bk = Blocking {
            kc: r.u32()? as usize,
            nr: r.u32()? as usize,
            mr: r.u32()? as usize,
            grain: r.u32()? as usize,
        };
        bk.validate().context("hostile blocking table entry")?;
        bk
    } else {
        Blocking::default()
    };
    // v3: optional shift-only requant table (pow2 exports). Its
    // consistency with the multiplier pairs is enforced in
    // `check_layer` — a hostile shift table must never reach the
    // shift-only epilogue.
    let requant_shift = if version >= 3 {
        match r.u32()? {
            0 => None,
            1 => Some(r.vec_i32()?),
            other => bail!("bad has_shift flag {other}"),
        }
    } else {
        None
    };
    // v4: fused implicit-GEMM bit, between the shift table and the
    // packed record. None (pre-v4 file) defaults from the packed record
    // below — the export default — so tuned v2/v3 artifacts inherit the
    // fused win without a re-export.
    let fused = if version >= 4 {
        match r.u32()? {
            0 => Some(false),
            1 => Some(true),
            other => bail!("bad fused flag {other}"),
        }
    } else {
        None
    };
    let packed = match r.u32()? {
        0 => None,
        1 => {
            let k = r.u32()? as usize;
            let n = r.u32()? as usize;
            // v3: bits tag (8 or 4); `from_packed_bits` rejects other
            // values and validates the int4 panel byte length.
            let bits = if version >= 3 { r.u32()? as usize } else { 8 };
            let slab = get_blob(r, map, panel)?;
            Some(PackedWeights::from_packed_bits(slab, k, n, blocking.nr, bits)?)
        }
        other => bail!("bad has_packed flag {other}"),
    };
    // A fused bit without a panel to drive the micro-tiles is a
    // contradiction — the engine has no fused unpacked path. Reject
    // rather than silently clearing: the file is lying about itself.
    let fused = match fused {
        Some(true) if packed.is_none() => {
            bail!("fused flag set on a layer without a packed panel")
        }
        Some(f) => f,
        None => packed.is_some(),
    };
    Ok(QLayer {
        w_q,
        w_sums,
        bias_q,
        requant,
        requant_shift,
        out_qp,
        clamp,
        w_scales,
        packed,
        blocking,
        fused,
    })
}

/// Resolve a (off, len) panel-section reference into a zero-copy slab.
fn get_blob(
    r: &mut Reader,
    map: &Arc<Mapping>,
    panel: &Section,
) -> Result<I8Slab> {
    let off = r.u64()?;
    let len = r.u64()?;
    let end = off.checked_add(len).ok_or_else(|| {
        anyhow::anyhow!("panel blob [{off}, +{len}) overflows")
    })?;
    ensure!(
        end <= panel.len as u64,
        "panel blob [{off}, +{len}) exceeds panel section of {} bytes",
        panel.len
    );
    I8Slab::from_mapping(
        Arc::clone(map),
        panel.off + off as usize,
        len as usize,
    )
}
